"""Jacobi eigensolver (L2, plain-HLO lowerable) vs numpy's LAPACK eigh."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model


def _sym(n, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, n)).astype(np.float32) * scale
    return (a + a.T) / 2


class TestJacobiEigh:
    @settings(max_examples=20, deadline=None)
    @given(n=st.sampled_from([2, 3, 5, 8, 16, 32]), seed=st.integers(0, 2**31 - 1))
    def test_eigenvalues_match_lapack(self, n, seed):
        a = _sym(n, seed)
        w, _ = model.jacobi_eigh(jnp.asarray(a))
        w_ref = np.linalg.eigvalsh(a)[::-1]  # descending
        np.testing.assert_allclose(np.asarray(w), w_ref, rtol=1e-3, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([2, 4, 8, 16]), seed=st.integers(0, 2**31 - 1))
    def test_reconstruction(self, n, seed):
        a = _sym(n, seed)
        w, v = model.jacobi_eigh(jnp.asarray(a))
        w, v = np.asarray(w, dtype=np.float64), np.asarray(v, dtype=np.float64)
        np.testing.assert_allclose(v @ np.diag(w) @ v.T, a, rtol=1e-3, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([2, 4, 8, 16, 32]), seed=st.integers(0, 2**31 - 1))
    def test_eigenvectors_orthonormal(self, n, seed):
        a = _sym(n, seed)
        _, v = model.jacobi_eigh(jnp.asarray(a))
        v = np.asarray(v, dtype=np.float64)
        np.testing.assert_allclose(v.T @ v, np.eye(n), atol=1e-4)

    def test_descending_order(self):
        a = _sym(24, 123)
        w, _ = model.jacobi_eigh(jnp.asarray(a))
        w = np.asarray(w)
        assert np.all(w[:-1] >= w[1:] - 1e-6)

    def test_diagonal_matrix(self):
        d = np.diag(np.array([5.0, 1.0, 3.0], dtype=np.float32))
        w, v = model.jacobi_eigh(jnp.asarray(d))
        np.testing.assert_allclose(np.asarray(w), [5.0, 3.0, 1.0], atol=1e-6)
        np.testing.assert_allclose(np.abs(np.asarray(v)), np.eye(3)[:, [0, 2, 1]], atol=1e-6)

    def test_psd_gram_gives_nonnegative_eigs(self):
        rng = np.random.default_rng(5)
        x = rng.normal(size=(100, 12)).astype(np.float32)
        g = x.T @ x
        w, _ = model.jacobi_eigh(jnp.asarray(g))
        assert float(np.asarray(w).min()) >= -1e-2

    def test_clustered_eigenvalues(self):
        """Near-degenerate spectra are the classic Jacobi stress case."""
        q, _ = np.linalg.qr(np.random.default_rng(8).normal(size=(16, 16)))
        w_true = np.array([10.0] * 4 + [9.999] * 4 + [1.0] * 8)
        a = (q * w_true) @ q.T
        w, _ = model.jacobi_eigh(jnp.asarray(a.astype(np.float32)))
        np.testing.assert_allclose(np.sort(np.asarray(w)), np.sort(w_true), rtol=1e-3)
