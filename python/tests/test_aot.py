"""AOT artifact pipeline integrity: manifest schema, HLO parse-ability, and
round-trip execution of emitted HLO through jax's own HLO client is out of
scope (the rust integration tests cover execution); here we pin the contract
the rust ``runtime::artifact`` parser depends on."""

import os
import re
import subprocess
import sys

import pytest

from compile import aot

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
ARTIFACTS = os.path.join(REPO, "artifacts")

REQUIRED_KEYS = {"program", "name", "file", "dtype", "block", "n", "k", "ins", "outs"}


def _parse_manifest(path):
    entries = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            kv = dict(tok.split("=", 1) for tok in line.split())
            entries.append(kv)
    return entries


@pytest.fixture(scope="module")
def small_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts_small")
    aot.build(str(out), small=True)
    return str(out)


class TestManifestContract:
    def test_small_build_produces_manifest(self, small_artifacts):
        entries = _parse_manifest(os.path.join(small_artifacts, "manifest.txt"))
        # gram, project, fused, urecover, tmul, urecover_tmul, eigh
        assert len(entries) == 7

    def test_every_entry_has_required_keys(self, small_artifacts):
        for e in _parse_manifest(os.path.join(small_artifacts, "manifest.txt")):
            assert REQUIRED_KEYS <= set(e), e

    def test_files_exist_and_are_hlo_text(self, small_artifacts):
        for e in _parse_manifest(os.path.join(small_artifacts, "manifest.txt")):
            path = os.path.join(small_artifacts, e["file"])
            assert os.path.exists(path)
            text = open(path).read()
            assert "ENTRY" in text and "HloModule" in text

    def test_shapes_in_manifest_match_hlo_params(self, small_artifacts):
        """The module's parameter instruction shapes must equal the manifest's
        ``ins`` — that is what the rust side sizes its buffers from."""
        for e in _parse_manifest(os.path.join(small_artifacts, "manifest.txt")):
            text = open(os.path.join(small_artifacts, e["file"])).read()
            params = re.findall(r"= f32\[([0-9,]*)\](?:\{[0-9,]*\})? parameter\(", text)
            want = [s.replace("x", ",") for s in e["ins"].split(",") if s]
            for w in want:
                assert w in params, (e["name"], w, params)

    def test_no_custom_calls(self, small_artifacts):
        """interpret=True + jnp-only code must lower to plain HLO the CPU
        PJRT client can run — custom-call would break the rust side."""
        for e in _parse_manifest(os.path.join(small_artifacts, "manifest.txt")):
            text = open(os.path.join(small_artifacts, e["file"])).read()
            assert "custom-call" not in text, e["name"]


class TestCheckedInArtifacts:
    """Sanity over the real artifacts/ dir if it has been built."""

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ARTIFACTS, "manifest.txt")),
        reason="artifacts not built (run `make artifacts`)",
    )
    def test_full_manifest_parses(self):
        entries = _parse_manifest(os.path.join(ARTIFACTS, "manifest.txt"))
        assert len(entries) >= 5
        programs = {e["program"] for e in entries}
        assert {"gram", "project", "fused", "urecover", "tmul", "urecover_tmul", "eigh"} <= programs
