"""End-to-end L2 pipeline tests: the paper's randomized SVD vs dense SVD."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _low_rank(m, n, rank, seed, noise=0.0, decay=0.5):
    """Synthetic matrix with a decaying spectrum — the regime where a rank-k
    sketch is a faithful stand-in (Halko et al.)."""
    rng = np.random.default_rng(seed)
    u, _ = np.linalg.qr(rng.normal(size=(m, rank)))
    v, _ = np.linalg.qr(rng.normal(size=(n, rank)))
    s = np.array([10.0 * decay**i for i in range(rank)])
    a = (u * s) @ v.T
    if noise:
        a = a + noise * rng.normal(size=(m, n))
    return a.astype(np.float32)


class TestGramSvd:
    """Paper §2.0.1: exact route through A^T A for small n."""

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_singular_values_match_dense(self, seed):
        a = _low_rank(200, 16, 8, seed, noise=0.01)
        _, sig, _ = model.gram_svd(jnp.asarray(a))
        s_ref = np.linalg.svd(a, compute_uv=False)
        np.testing.assert_allclose(np.asarray(sig), s_ref, rtol=5e-2, atol=5e-2)

    def test_reconstruction(self):
        a = _low_rank(300, 20, 20, 0)
        u, sig, v = model.gram_svd(jnp.asarray(a))
        recon = np.asarray(u) * np.asarray(sig) @ np.asarray(v).T
        rel = np.linalg.norm(recon - a) / np.linalg.norm(a)
        assert rel < 1e-2

    def test_u_columns_orthonormal(self):
        # decay=0.85 keeps the condition number moderate; U = A V Sigma^{-1}
        # loses orthonormality in f32 once sigma_min approaches roundoff.
        a = _low_rank(150, 12, 12, 4, decay=0.85)
        u, _, _ = model.gram_svd(jnp.asarray(a))
        u = np.asarray(u, dtype=np.float64)
        np.testing.assert_allclose(u.T @ u, np.eye(12), atol=1e-2)


class TestRandomizedSvd:
    """Paper §2.0.3 + §2.1: the projected route for large n."""

    def _omega(self, n, k, seed):
        rng = np.random.default_rng(seed)
        return (rng.normal(size=(n, k)) / np.sqrt(k)).astype(np.float32)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_captures_top_singular_values(self, seed):
        rank, k = 6, 16
        a = _low_rank(400, 64, rank, seed)
        u, sig, v = model.randomized_svd(jnp.asarray(a), jnp.asarray(self._omega(64, k, seed + 1)))
        s_ref = np.linalg.svd(a, compute_uv=False)
        # Top singular values recovered within the sketch distortion.
        np.testing.assert_allclose(np.asarray(sig)[:rank], s_ref[:rank], rtol=0.15)

    def test_reconstruction_error_near_tail_energy(self):
        rank = 8
        a = _low_rank(500, 128, rank, 3, noise=0.0)
        u, sig, v = model.randomized_svd(jnp.asarray(a), jnp.asarray(self._omega(128, 24, 7)))
        recon = (np.asarray(u) * np.asarray(sig)) @ np.asarray(v).T
        rel = np.linalg.norm(recon - a) / np.linalg.norm(a)
        assert rel < 0.05, rel

    def test_more_dims_reduce_error(self):
        """JL claim: distortion shrinks as k grows."""
        a = _low_rank(400, 100, 12, 5, noise=0.05)
        errs = []
        for k in (4, 16, 48):
            u, sig, v = model.randomized_svd(jnp.asarray(a), jnp.asarray(self._omega(100, k, 11)))
            recon = (np.asarray(u) * np.asarray(sig)) @ np.asarray(v).T
            errs.append(np.linalg.norm(recon - a) / np.linalg.norm(a))
        assert errs[2] < errs[1] < errs[0] + 1e-6, errs

    def test_u_orthonormal_on_exact_low_rank(self):
        a = _low_rank(300, 60, 4, 9)
        u, _, _ = model.randomized_svd(jnp.asarray(a), jnp.asarray(self._omega(60, 12, 2)))
        u = np.asarray(u, dtype=np.float64)[:, :4]
        np.testing.assert_allclose(u.T @ u, np.eye(4), atol=5e-2)


class TestBlockCompositionEqualsDense:
    """The streaming decomposition the rust coordinator performs must equal
    the one-shot dense computation: sum of per-block Grams == full Gram, and
    stacked per-block projections == full projection."""

    def test_blocked_gram_sum(self):
        rng = np.random.default_rng(0)
        a = rng.normal(size=(1024, 32)).astype(np.float32)
        full = np.asarray(ref.gram_ref(jnp.asarray(a)))
        acc = np.zeros((32, 32), np.float32)
        for i in range(0, 1024, 256):
            acc += np.asarray(model.gram_program(jnp.asarray(a[i : i + 256]))[0])
        np.testing.assert_allclose(acc, full, rtol=1e-3, atol=1e-3)

    def test_blocked_fused_pipeline(self):
        rng = np.random.default_rng(1)
        a = rng.normal(size=(768, 48)).astype(np.float32)
        w = rng.normal(size=(48, 8)).astype(np.float32)
        y_full = a @ w
        g_full = y_full.T @ y_full
        ys, g_acc = [], np.zeros((8, 8), np.float32)
        for i in range(0, 768, 256):
            y, g = model.project_gram_program(jnp.asarray(a[i : i + 256]), jnp.asarray(w))
            ys.append(np.asarray(y))
            g_acc += np.asarray(g)
        np.testing.assert_allclose(np.vstack(ys), y_full, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(g_acc, g_full, rtol=1e-2, atol=1e-2)

    def test_ragged_tail_via_zero_padding(self):
        """700 rows in 256-blocks: the last block is zero-padded; result must
        equal the unpadded dense computation."""
        rng = np.random.default_rng(2)
        a = rng.normal(size=(700, 24)).astype(np.float32)
        full = a.T @ a
        acc = np.zeros((24, 24), np.float32)
        for i in range(0, 700, 256):
            blk = np.zeros((256, 24), np.float32)
            chunk = a[i : i + 256]
            blk[: len(chunk)] = chunk
            acc += np.asarray(model.gram_program(jnp.asarray(blk))[0])
        np.testing.assert_allclose(acc, full, rtol=1e-3, atol=1e-3)
