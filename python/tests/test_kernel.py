"""Kernel-vs-reference correctness: the CORE signal for L1.

Hypothesis sweeps shapes and dtypes; every Pallas kernel must match its
pure-jnp oracle to fp tolerance, plus the zero-row-padding invariant the rust
coordinator relies on (padded rows contribute nothing to Gram/projection).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import fused, gram, project, ref, tmul, urecover

TILE = 128


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return rng.normal(size=shape).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-3) if dtype == np.float32 else dict(rtol=1e-9, atol=1e-10)


blocks = st.sampled_from([128, 256, 384, 512])
ns = st.sampled_from([1, 3, 8, 64, 100, 256])
ks = st.sampled_from([1, 2, 7, 16, 32])
dtypes = st.sampled_from([np.float32])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


class TestGram:
    @settings(max_examples=25, deadline=None)
    @given(b=blocks, n=ns, dtype=dtypes, seed=seeds)
    def test_matches_ref(self, b, n, dtype, seed):
        x = _rand((b, n), dtype, seed)
        got = np.asarray(gram.gram_block(jnp.asarray(x)))
        want = np.asarray(ref.gram_ref(jnp.asarray(x)))
        np.testing.assert_allclose(got, want, **_tol(dtype))

    @settings(max_examples=10, deadline=None)
    @given(b=blocks, n=ns, seed=seeds)
    def test_matches_paper_outer_product_form(self, b, n, seed):
        """§2.0.2: sum of per-row outer products == X^T X."""
        x = _rand((b, n), np.float32, seed)
        got = np.asarray(gram.gram_block(jnp.asarray(x)))
        want = np.asarray(ref.gram_outer_ref(jnp.asarray(x)))
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-3)

    def test_symmetry(self):
        x = _rand((256, 64), np.float32, 7)
        g = np.asarray(gram.gram_block(jnp.asarray(x)))
        np.testing.assert_allclose(g, g.T, rtol=0, atol=0)

    def test_zero_row_padding_invariant(self):
        """Padding a block with zero rows must not change the Gram sum."""
        x = _rand((128, 32), np.float32, 11)
        padded = np.zeros((256, 32), np.float32)
        padded[:128] = x
        g1 = np.asarray(gram.gram_block(jnp.asarray(x)))
        g2 = np.asarray(gram.gram_block(jnp.asarray(padded)))
        np.testing.assert_allclose(g1, g2, rtol=1e-6, atol=1e-6)

    def test_psd(self):
        x = _rand((256, 16), np.float32, 3)
        g = np.asarray(gram.gram_block(jnp.asarray(x)), dtype=np.float64)
        w = np.linalg.eigvalsh((g + g.T) / 2)
        assert w.min() >= -1e-3

    def test_rejects_ragged_block(self):
        with pytest.raises(ValueError):
            gram.gram_block(jnp.zeros((100, 8), jnp.float32))


class TestProject:
    @settings(max_examples=25, deadline=None)
    @given(b=blocks, n=ns, k=ks, dtype=dtypes, seed=seeds)
    def test_matches_ref(self, b, n, k, dtype, seed):
        x = _rand((b, n), dtype, seed)
        w = _rand((n, k), dtype, seed + 1)
        got = np.asarray(project.project_block(jnp.asarray(x), jnp.asarray(w)))
        want = np.asarray(ref.project_ref(jnp.asarray(x), jnp.asarray(w)))
        np.testing.assert_allclose(got, want, **_tol(dtype))

    def test_zero_rows_project_to_zero(self):
        w = _rand((64, 16), np.float32, 0)
        y = np.asarray(project.project_block(jnp.zeros((128, 64), jnp.float32), jnp.asarray(w)))
        assert np.all(y == 0)

    def test_inner_dim_mismatch(self):
        with pytest.raises(ValueError):
            project.project_block(jnp.zeros((128, 8), jnp.float32), jnp.zeros((9, 4), jnp.float32))


class TestFused:
    @settings(max_examples=25, deadline=None)
    @given(b=blocks, n=ns, k=ks, dtype=dtypes, seed=seeds)
    def test_matches_ref(self, b, n, k, dtype, seed):
        x = _rand((b, n), dtype, seed)
        w = _rand((n, k), dtype, seed + 1)
        y, g = fused.project_gram_block(jnp.asarray(x), jnp.asarray(w))
        yr, gr = ref.project_gram_ref(jnp.asarray(x), jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), **_tol(dtype))
        np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=5e-2, atol=5e-2)

    def test_consistent_with_unfused(self):
        x = _rand((256, 64), np.float32, 5)
        w = _rand((64, 16), np.float32, 6)
        y_f, g_f = fused.project_gram_block(jnp.asarray(x), jnp.asarray(w))
        y_s = project.project_block(jnp.asarray(x), jnp.asarray(w))
        g_s = gram.gram_block(jnp.asarray(np.asarray(y_s)))
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_s), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_s), rtol=1e-3, atol=1e-3)

    def test_gram_accumulates_across_tiles(self):
        """G must cover ALL row tiles, not just the last grid step."""
        x = _rand((512, 32), np.float32, 9)
        w = _rand((32, 8), np.float32, 10)
        _, g = fused.project_gram_block(jnp.asarray(x), jnp.asarray(w))
        yr = x @ w
        np.testing.assert_allclose(np.asarray(g), yr.T @ yr, rtol=1e-3, atol=1e-3)


class TestURecover:
    @settings(max_examples=20, deadline=None)
    @given(b=blocks, k=ks, dtype=dtypes, seed=seeds)
    def test_matches_ref(self, b, k, dtype, seed):
        y = _rand((b, k), dtype, seed)
        m = _rand((k, k), dtype, seed + 1)
        got = np.asarray(urecover.u_recover_block(jnp.asarray(y), jnp.asarray(m)))
        want = np.asarray(ref.u_recover_ref(jnp.asarray(y), jnp.asarray(m)))
        np.testing.assert_allclose(got, want, **_tol(dtype))

    def test_identity_passthrough(self):
        y = _rand((128, 16), np.float32, 1)
        got = np.asarray(urecover.u_recover_block(jnp.asarray(y), jnp.eye(16, dtype=jnp.float32)))
        np.testing.assert_allclose(got, y, rtol=1e-6, atol=1e-6)


class TestTmul:
    @settings(max_examples=20, deadline=None)
    @given(b=blocks, n=ns, k=ks, dtype=dtypes, seed=seeds)
    def test_matches_ref(self, b, n, k, dtype, seed):
        x = _rand((b, n), dtype, seed)
        z = _rand((b, k), dtype, seed + 1)
        got = np.asarray(tmul.tmul_block(jnp.asarray(x), jnp.asarray(z)))
        want = np.asarray(ref.tmul_ref(jnp.asarray(x), jnp.asarray(z)))
        np.testing.assert_allclose(got, want, **_tol(dtype))

    def test_matches_outer_product_form(self):
        x = _rand((256, 32), np.float32, 21)
        z = _rand((256, 8), np.float32, 22)
        got = np.asarray(tmul.tmul_block(jnp.asarray(x), jnp.asarray(z)))
        want = np.asarray(ref.tmul_outer_ref(jnp.asarray(x), jnp.asarray(z)))
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-3)

    def test_accumulates_across_tiles(self):
        x = _rand((512, 16), np.float32, 23)
        z = _rand((512, 4), np.float32, 24)
        got = np.asarray(tmul.tmul_block(jnp.asarray(x), jnp.asarray(z)))
        np.testing.assert_allclose(got, x.T @ z, rtol=1e-3, atol=1e-3)

    def test_zero_row_padding_invariant(self):
        x = _rand((128, 16), np.float32, 25)
        z = _rand((128, 4), np.float32, 26)
        xp = np.zeros((256, 16), np.float32)
        zp = np.zeros((256, 4), np.float32)
        xp[:128], zp[:128] = x, z
        g1 = np.asarray(tmul.tmul_block(jnp.asarray(x), jnp.asarray(z)))
        g2 = np.asarray(tmul.tmul_block(jnp.asarray(xp), jnp.asarray(zp)))
        np.testing.assert_allclose(g1, g2, rtol=1e-6, atol=1e-6)

    def test_row_block_mismatch(self):
        with pytest.raises(ValueError):
            tmul.tmul_block(jnp.zeros((128, 8), jnp.float32), jnp.zeros((256, 4), jnp.float32))


class TestVmemEstimates:
    """Structural perf contracts (DESIGN.md §Perf): VMEM-resident working sets
    must stay far under a ~16 MiB VMEM budget for every shipped variant."""

    VMEM_BUDGET = 16 * 1024 * 1024

    def test_all_default_variants_fit(self):
        from compile import aot

        for b, n in aot.GRAM_VARIANTS:
            assert gram.vmem_bytes(b, n) < self.VMEM_BUDGET
        for b, n, k in aot.PROJECT_VARIANTS:
            assert project.vmem_bytes(b, n, k) < self.VMEM_BUDGET
        for b, n, k in aot.FUSED_VARIANTS:
            assert fused.vmem_bytes(b, n, k) < self.VMEM_BUDGET
        for b, k in aot.URECOVER_VARIANTS:
            assert urecover.vmem_bytes(b, k) < self.VMEM_BUDGET
