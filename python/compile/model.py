"""L2: the paper's compute graph in JAX, composed from the L1 Pallas kernels.

Three things live here:

1. Block-level programs (``gram_program``, ``project_program``,
   ``project_gram_program``, ``u_recover_program``) — thin jit-able wrappers
   around the Pallas kernels with static shapes, lowered by ``aot.py`` into
   one HLO artifact per shape variant. These are what the rust coordinator
   executes per row block on its hot path.

2. ``jacobi_eigh`` — a cyclic-Jacobi symmetric eigensolver written in pure
   jnp control flow (``fori_loop`` + dynamic slices). ``jnp.linalg.eigh``
   lowers to a LAPACK custom-call on CPU which the PJRT client used by the
   rust side cannot be assumed to resolve; Jacobi lowers to plain HLO. The
   paper reduces the big SVD to exactly this small dense eigenproblem
   ("fast computation around k x k matrices computed on a single machine").

3. ``randomized_svd`` — the whole paper pipeline in jnp, used as the python
   reference for the rust driver and by the pytest suite.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels.fused import project_gram_block
from .kernels.gram import gram_block
from .kernels.project import project_block
from .kernels.tmul import tmul_block
from .kernels.urecover import u_recover_block


# ---------------------------------------------------------------------------
# Block-level programs (AOT artifact entry points)
# ---------------------------------------------------------------------------

def gram_program(x):
    """(block_m, n) -> (n, n). Lowered as ``gram_b{B}_n{N}``."""
    return (gram_block(x),)


def project_program(x, w):
    """(block_m, n), (n, k) -> (block_m, k). Lowered as ``project_b{B}_n{N}_k{K}``."""
    return (project_block(x, w),)


def project_gram_program(x, w):
    """(block_m, n), (n, k) -> ((block_m, k), (k, k)). The fused pass-1 program."""
    y, g = project_gram_block(x, w)
    return (y, g)


def u_recover_program(y, m):
    """(block_m, k), (k, k) -> (block_m, k). The pass-3 program."""
    return (u_recover_block(y, m),)


def tmul_program(x, z):
    """(block_m, n), (block_m, k) -> (n, k). The pass-2 W-accumulation program."""
    return (tmul_block(x, z),)


def urecover_tmul_program(x, y, m):
    """Fused pass-2: (block_m, n) A rows, (block_m, k) Y rows, (k, k) M ->
    ((block_m, k) U0 rows, (n, k) W partial). One pass computes the basis
    rows AND the A^T U0 partial."""
    u0 = u_recover_block(y, m)
    w = tmul_block(x, u0)
    return (u0, w)


# ---------------------------------------------------------------------------
# Cyclic Jacobi eigensolver (plain-HLO lowerable)
# ---------------------------------------------------------------------------

def _jacobi_pairs(n):
    """Static (p, q) index arrays for one cyclic sweep over the strict upper
    triangle (kept for the python-side reference/tests)."""
    ps, qs = [], []
    for p in range(n - 1):
        for q in range(p + 1, n):
            ps.append(p)
            qs.append(q)
    return jnp.array(ps, dtype=jnp.int32), jnp.array(qs, dtype=jnp.int32)


def jacobi_eigh(a, sweeps: int = 12):
    """Eigendecomposition of a symmetric matrix by parallel-ordered Jacobi
    rotations (circle-method ordering: ``n/2`` disjoint rotations per round,
    ``n - 1`` rounds per sweep, every pair annihilated once per sweep).

    Returns ``(eigvals, eigvecs)`` sorted in *descending* eigenvalue order
    (the SVD convention: ``sigma_i = sqrt(max(eigval_i, 0))``). ``sweeps``
    full sweeps are unconditionally applied; 12 sweeps converge to fp32
    roundoff for the k <= 128 matrices this system produces (Jacobi is
    ultimately quadratically convergent).

    AOT-COMPAT NOTE — why this looks nothing like textbook Jacobi: the
    HLO-text artifacts execute on xla_extension 0.5.1 (the runtime behind
    the rust ``xla`` crate), and bisection against it showed two miscompile
    classes inside ``while`` bodies:

      1. dynamic-index scatter (``a.at[p, :].set``) and dynamic gather from
         a constant index table silently corrupt indices;
      2. ``dot`` with a *literal-constant* operand evaluates to zeros, even
         when the constant is threaded through the loop state (constants
         get re-folded into the body).

    ``iota``-derived values are immune (they are computed, not literal), so
    everything here is built from ``jnp.arange``: the identity, the
    round-robin partner schedule (circle method, in closed form
    ``partner(j) = (r - j) mod (n-1)`` with the fixed player ``n-1``), the
    per-index one-hot partner matrix, and the combined Givens matrix
    ``G = diag(c) + (+/- s at (j, partner(j)))``. Angles for all ``n/2``
    pairs of a round are computed vectorized; ``sign(0) := 1`` keeps the
    equal-diagonal pair rotating (``jnp.sign`` would stall it). Verified
    bit-compatible between jax execution and the rust PJRT path for
    k in {8, 16, 32, 64}.
    """
    n = a.shape[0]
    dtype = a.dtype
    if n % 2 == 1:
        # Pad odd sizes with a decoupled zero row/col; the pad eigenpair is
        # exactly (0, e_n), so drop the column whose last entry is ~1.
        a_pad = jnp.pad(a, ((0, 1), (0, 1)))
        w, v = jacobi_eigh(a_pad, sweeps)
        mask = jnp.abs(v[n, :]) < 0.5
        order = jnp.argsort(~mask)  # real columns first, order preserved
        return w[order][:n], v[:n, order][:, :n]

    nr = n - 1  # rounds per sweep (circle method 1-factorization of K_n)
    half = n // 2
    iota = jnp.arange(n, dtype=jnp.int32)
    eye = (iota[:, None] == iota[None, :]).astype(dtype)  # iota, not literal
    ones = jnp.ones((n,), dtype=dtype)

    def body(t, state):
        a, v = state
        # Re-symmetrize: G A G^T drifts from symmetry at roundoff level, and
        # a pair's two orientations would then derive *different* angles from
        # a[p,q] vs a[q,p] once those are tiny — making G non-orthogonal and
        # stalling convergence on clustered spectra. 0.5 (a + a^T) reads
        # identically from both orientations (IEEE + is commutative).
        a = 0.5 * (a + a.T)
        r = jnp.mod(t, nr)
        # Closed-form partner schedule for round r.
        m0 = jnp.mod(r - iota, nr)
        partner = jnp.where(m0 == iota, n - 1, m0)
        jstar = jnp.mod(r * half, nr)  # who meets the fixed player n-1
        partner = jnp.where(iota == n - 1, jstar, partner)
        pm = (iota[None, :] == partner[:, None]).astype(dtype)

        # Pair scalars for every index j, vectorized (dots with computed
        # matrices only): a_jj, a[j, partner], a[partner, partner].
        diag_a = (a * eye) @ ones
        a_jm = (a * pm) @ ones
        diag_p = pm @ diag_a
        is_p = iota < partner  # j is the p (upper-left) end of its pair
        lo = jnp.where(is_p, diag_a, diag_p)   # a_pp
        hi = jnp.where(is_p, diag_p, diag_a)   # a_qq
        apq_safe = jnp.where(a_jm == 0, jnp.asarray(1.0, dtype), a_jm)
        tau = (hi - lo) / (2.0 * apq_safe)
        sgn = jnp.where(tau >= 0, jnp.asarray(1.0, dtype), jnp.asarray(-1.0, dtype))
        tn = sgn / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
        tn = jnp.where(a_jm == 0, jnp.asarray(0.0, dtype), tn)
        c = 1.0 / jnp.sqrt(1.0 + tn * tn)
        s = tn * c

        # Combined Givens matrix of the n/2 disjoint rotations:
        # G[j,j] = c_j, G[p,q] = -s, G[q,p] = +s.
        gs = jnp.where(is_p, -s, s)
        g = c[:, None] * eye + gs[:, None] * pm
        return g @ a @ g.T, v @ g.T

    a_out, v_out = jax.lax.fori_loop(0, sweeps * nr, body, (a, eye))
    w = (a_out * eye) @ ones
    order = jnp.argsort(-w)
    return w[order], v_out[:, order]


def eigh_program(g):
    """(k, k) -> ((k,), (k, k)). Lowered as ``eigh_k{K}`` — descending order."""
    w, v = jacobi_eigh(g)
    return (w, v)


# ---------------------------------------------------------------------------
# Whole-pipeline jnp reference (paper §2, end to end)
# ---------------------------------------------------------------------------

def randomized_svd(a, omega, sweeps: int = 12):
    """Rank-k SVD of tall ``a`` via the paper's route.

    ``omega`` is the (n, k) Gaussian projection matrix (materialized here; the
    rust side regenerates it virtually). Pipeline:

        Y = A Omega                (pass 1, streamed)
        G = Y^T Y = V' S^2 V'^T    (k x k, leader)
        sigma = sqrt(eig(G)),  V_y = eigvecs
        U = Y V_y sigma^{-1}       (pass 2, streamed)
        V = A^T U sigma^{-1}       (right vectors of A, lifted back to n dims)

    Returns ``(U, sigma, V)`` with U ``(m, k)``, sigma ``(k,)``, V ``(n, k)``.
    """
    y = a @ omega
    g = y.T @ y
    w, vy = jacobi_eigh(g, sweeps=sweeps)
    sig_y = jnp.sqrt(jnp.maximum(w, 0.0))
    cutoff = 1e-5 * jnp.maximum(sig_y[0], 1e-30)
    inv_y = jnp.where(sig_y > cutoff, 1.0 / jnp.maximum(sig_y, 1e-30), 0.0)
    # Orthonormal basis of range(Y) — approximates A's top-k left subspace.
    u0 = y @ (vy * inv_y[None, :])
    # sigma(Y) carries the sketch's distortion. Recover accurate factors from
    # A itself: with U0 an orthonormal basis of range(A)'s sketch,
    #   A ≈ U0 U0^T A = U0 W^T,  W = A^T U0  (n x k; the rust pass-2
    # accumulates it as sum_i a_i (outer) u_i). The SVD of W is again only a
    # k x k eigenproblem: W^T W = P S^2 P^T, giving
    #   sigma = S,  V = W P S^{-1},  U = U0 P.
    # Exact when rank(A) <= k; otherwise error = tail energy + sketch error.
    wmat = a.T @ u0
    gw = wmat.T @ wmat
    w2, p = jacobi_eigh(gw, sweeps=sweeps)
    sigma = jnp.sqrt(jnp.maximum(w2, 0.0))
    cut2 = 1e-7 * jnp.maximum(sigma[0], 1e-30)
    inv_s = jnp.where(sigma > cut2, 1.0 / jnp.maximum(sigma, 1e-30), 0.0)
    v = wmat @ (p * inv_s[None, :])
    u = u0 @ p
    return u, sigma, v


def gram_svd(a, sweeps: int = 12):
    """The paper's small-n route (§2.0.1): eigendecompose A^T A directly."""
    g = a.T @ a
    w, v = jacobi_eigh(g, sweeps=sweeps)
    sigma = jnp.sqrt(jnp.maximum(w, 0.0))
    inv = jnp.where(sigma > 0, 1.0 / jnp.maximum(sigma, 1e-30), 0.0)
    u = a @ (v * inv[None, :])
    return u, sigma, v


# jit-able entry points with sweeps fixed (static control flow for lowering)
gram_program_jit = jax.jit(gram_program)
project_program_jit = jax.jit(project_program)
project_gram_program_jit = jax.jit(project_gram_program)
u_recover_program_jit = jax.jit(u_recover_program)
eigh_program_jit = jax.jit(eigh_program)
randomized_svd_jit = jax.jit(partial(randomized_svd, sweeps=12))
