"""L1 Pallas kernel: blocked Gram accumulation  C = X^T X.

The paper's core primitive (§2.0.2): ``A^T A = sum_i A_i (outer) A_i``. A
whole row-block of A is streamed HBM->VMEM one tile at a time and the small
``n x n`` accumulator stays resident in VMEM across grid steps — exactly the
"small result accumulated in memory" the paper builds its parallel scheme on.

TPU mapping (DESIGN.md §Hardware-Adaptation): the per-tile update is a
``tile_m x n`` by ``n x tile_m`` matmul on the MXU; the grid walks row tiles
sequentially so the ``o_ref += ...`` accumulation is well-defined. Lowered with
``interpret=True`` for CPU-PJRT execution (Mosaic custom-calls cannot run on
the CPU plugin).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_M = 128


def _gram_kernel(x_ref, o_ref):
    """One grid step: o += x_tile^T @ x_tile (zero-init on the first step)."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    o_ref[...] += jnp.dot(x.T, x, preferred_element_type=o_ref.dtype)


def gram_block(x, *, tile_m: int = DEFAULT_TILE_M, interpret: bool = True):
    """Gram matrix of one row block: ``x`` is ``(block_m, n)`` -> ``(n, n)``.

    ``block_m`` must be a multiple of ``tile_m`` (the rust coordinator pads the
    ragged tail with zero rows; zero rows contribute nothing to the Gram sum,
    an invariant the test suites check on both sides of the FFI).
    """
    block_m, n = x.shape
    if block_m % tile_m != 0:
        raise ValueError(f"block_m={block_m} not a multiple of tile_m={tile_m}")
    grid = (block_m // tile_m,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_m, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), x.dtype),
        interpret=interpret,
    )(x)


def gram_block_jit(block_m: int, n: int, dtype=jnp.float32, tile_m: int = DEFAULT_TILE_M):
    """A jit-able closure with static shapes, for AOT lowering."""
    del block_m, n, dtype  # shapes carried by the example args at lower time
    return partial(gram_block, tile_m=tile_m)


def vmem_bytes(block_m: int, n: int, tile_m: int = DEFAULT_TILE_M, itemsize: int = 4) -> int:
    """Structural VMEM footprint estimate (see DESIGN.md §Perf): one input tile
    plus the resident accumulator."""
    return (tile_m * n + n * n) * itemsize
