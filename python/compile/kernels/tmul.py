"""L1 Pallas kernel: transpose-matmul partial  W = X^T Z.

The pass-2 hot path of the randomized SVD driver: each worker accumulates
``W = A^T U0`` over its rows as ``W += X_blk^T Z_blk`` where ``X_blk`` is a
row block of A and ``Z_blk = Y_blk M`` the matching block of the orthonormal
basis. Per-element this is again the paper's row-outer-product pattern
(§2.0.2): ``W = sum_i a_i (outer) z_i`` — commutative, so worker partials
reduce in any order.

Grid walks row tiles; the (n x k) accumulator is VMEM-resident. For very
large n the accumulator dominates VMEM (n*k*4 bytes) — the shipped variants
keep n*k <= 2048*32.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_M = 128


def _tmul_kernel(x_ref, z_ref, w_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        w_ref[...] = jnp.zeros_like(w_ref)

    w_ref[...] += jnp.dot(x_ref[...].T, z_ref[...], preferred_element_type=w_ref.dtype)


def tmul_block(x, z, *, tile_m: int = DEFAULT_TILE_M, interpret: bool = True):
    """``(block_m, n)^T @ (block_m, k) -> (n, k)``."""
    block_m, n = x.shape
    bm2, k = z.shape
    if block_m != bm2:
        raise ValueError(f"row blocks differ: {block_m} vs {bm2}")
    if block_m % tile_m != 0:
        raise ValueError(f"block_m={block_m} not a multiple of tile_m={tile_m}")
    grid = (block_m // tile_m,)
    return pl.pallas_call(
        _tmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, n), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), x.dtype),
        interpret=interpret,
    )(x, z)


def tmul_block_jit(tile_m: int = DEFAULT_TILE_M):
    return partial(tmul_block, tile_m=tile_m)


def vmem_bytes(block_m: int, n: int, k: int, tile_m: int = DEFAULT_TILE_M, itemsize: int = 4) -> int:
    """One X tile + one Z tile + the resident (n, k) accumulator."""
    return (tile_m * n + tile_m * k + n * k) * itemsize
