"""L1 Pallas kernel: blocked random projection  Y = X @ W.

Paper §2.0.3: multiply each row block of the tall matrix A with the (small)
``n x k`` projection matrix. W is VMEM-resident across the whole grid (it is
the paper's "matrix B ... brought into memory completely"); row tiles of X
stream through. The virtual-B trick (§2.1) lives on the rust side: W's block
is regenerated from a counter-based PRNG rather than stored, then handed to
this kernel — the kernel itself only sees a dense operand.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_M = 128


def _project_kernel(x_ref, w_ref, y_ref):
    y_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=y_ref.dtype)


def project_block(x, w, *, tile_m: int = DEFAULT_TILE_M, interpret: bool = True):
    """Project one row block: ``(block_m, n) @ (n, k) -> (block_m, k)``."""
    block_m, n = x.shape
    n2, k = w.shape
    if n != n2:
        raise ValueError(f"inner dims differ: {n} vs {n2}")
    if block_m % tile_m != 0:
        raise ValueError(f"block_m={block_m} not a multiple of tile_m={tile_m}")
    grid = (block_m // tile_m,)
    return pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, n), lambda i: (i, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((block_m, k), x.dtype),
        interpret=interpret,
    )(x, w)


def project_block_jit(tile_m: int = DEFAULT_TILE_M):
    return partial(project_block, tile_m=tile_m)


def vmem_bytes(block_m: int, n: int, k: int, tile_m: int = DEFAULT_TILE_M, itemsize: int = 4) -> int:
    """One X tile + resident W + one Y tile."""
    return (tile_m * n + n * k + tile_m * k) * itemsize
