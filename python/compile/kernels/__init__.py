"""L1 Pallas kernels for the tall-and-fat randomized SVD.

All kernels lower with ``interpret=True`` so the emitted HLO contains only
plain ops runnable on the CPU PJRT client (see /opt/xla-example/README.md).
"""

from .gram import gram_block  # noqa: F401
from .project import project_block  # noqa: F401
from .fused import project_gram_block  # noqa: F401
from .tmul import tmul_block  # noqa: F401
from .urecover import u_recover_block  # noqa: F401
