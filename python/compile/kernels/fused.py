"""L1 Pallas kernel: fused projection + Gram  (Y, G) = (X @ W, Y^T Y).

The paper's pipeline composition (§2.0.3): project A down to Y = A @ Omega,
then compute Y^T Y to reduce the SVD to a k x k eigenproblem. Doing both in
one kernel halves the passes over A's row blocks — Y tiles never round-trip
to HBM before the Gram update. This is the pass-1 hot path of the randomized
SVD driver (rust `svd/pipeline.rs`).

Grid walks row tiles sequentially; the k x k accumulator G stays VMEM-resident
(k is small by construction — that is the whole point of the paper).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_M = 128


def _fused_kernel(x_ref, w_ref, y_ref, g_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    y = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=y_ref.dtype)
    y_ref[...] = y
    g_ref[...] += jnp.dot(y.T, y, preferred_element_type=g_ref.dtype)


def project_gram_block(x, w, *, tile_m: int = DEFAULT_TILE_M, interpret: bool = True):
    """``(block_m, n), (n, k) -> ((block_m, k), (k, k))``: Y block + Y^T Y partial."""
    block_m, n = x.shape
    n2, k = w.shape
    if n != n2:
        raise ValueError(f"inner dims differ: {n} vs {n2}")
    if block_m % tile_m != 0:
        raise ValueError(f"block_m={block_m} not a multiple of tile_m={tile_m}")
    grid = (block_m // tile_m,)
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, n), lambda i: (i, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((block_m, k), x.dtype),
            jax.ShapeDtypeStruct((k, k), x.dtype),
        ],
        interpret=interpret,
    )(x, w)


def project_gram_block_jit(tile_m: int = DEFAULT_TILE_M):
    return partial(project_gram_block, tile_m=tile_m)


def vmem_bytes(block_m: int, n: int, k: int, tile_m: int = DEFAULT_TILE_M, itemsize: int = 4) -> int:
    """One X tile + resident W + one Y tile + resident G accumulator."""
    return (tile_m * n + n * k + tile_m * k + k * k) * itemsize
