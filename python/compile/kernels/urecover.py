"""L1 Pallas kernel: U recovery  U = Y @ M  with M = V diag(1/sigma).

Paper §2.0.1: ``U = A V Sigma^{-1}``. After the k x k eigensolve the rust
leader forms M = V diag(1/sigma) once (k x k, tiny) and streams Y's row blocks
through this kernel on pass 2. Structurally identical to `project.py` but kept
as its own program so artifact shapes/grids can be tuned independently and the
benches can attribute time per phase.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_M = 128


def _urecover_kernel(y_ref, m_ref, u_ref):
    u_ref[...] = jnp.dot(y_ref[...], m_ref[...], preferred_element_type=u_ref.dtype)


def u_recover_block(y, m, *, tile_m: int = DEFAULT_TILE_M, interpret: bool = True):
    """``(block_m, k) @ (k, k) -> (block_m, k)``."""
    block_m, k = y.shape
    k2, k3 = m.shape
    if k != k2 or k2 != k3:
        raise ValueError(f"M must be ({k},{k}), got ({k2},{k3})")
    if block_m % tile_m != 0:
        raise ValueError(f"block_m={block_m} not a multiple of tile_m={tile_m}")
    grid = (block_m // tile_m,)
    return pl.pallas_call(
        _urecover_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((block_m, k), y.dtype),
        interpret=interpret,
    )(y, m)


def u_recover_block_jit(tile_m: int = DEFAULT_TILE_M):
    return partial(u_recover_block, tile_m=tile_m)


def vmem_bytes(block_m: int, k: int, tile_m: int = DEFAULT_TILE_M, itemsize: int = 4) -> int:
    return (tile_m * k + k * k + tile_m * k) * itemsize
