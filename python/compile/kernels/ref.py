"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference here written the *dumb* way
(including the paper's own row-outer-product formulation of the Gram matrix)
so pytest/hypothesis can sweep shapes and dtypes and assert allclose.
"""

import jax.numpy as jnp


def gram_ref(x):
    """C = X^T X."""
    return x.T @ x


def gram_outer_ref(x):
    """The paper's §2.0.2 formulation: sum of per-row outer products.

    Mathematically identical to ``gram_ref``; kept separate so the tests pin
    the equivalence the whole system rests on.
    """
    return jnp.einsum("mi,mj->ij", x, x)


def project_ref(x, w):
    """Y = X W."""
    return x @ w


def project_gram_ref(x, w):
    """(Y, Y^T Y)."""
    y = x @ w
    return y, y.T @ y


def u_recover_ref(y, m):
    """U = Y M."""
    return y @ m


def tmul_ref(x, z):
    """W = X^T Z."""
    return x.T @ z


def tmul_outer_ref(x, z):
    """Row-outer-product formulation of ``tmul_ref`` (paper §2.0.2 pattern)."""
    return jnp.einsum("mi,mj->ij", x, z)


def rank_k_svd_ref(a, k):
    """Direct dense rank-k SVD via jnp.linalg.svd — the gold oracle for the
    end-to-end pipeline tests."""
    u, s, vt = jnp.linalg.svd(a, full_matrices=False)
    return u[:, :k], s[:k], vt[:k, :].T
