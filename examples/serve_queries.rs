//! End-to-end tour of the model lifecycle: factorize a clustered document
//! matrix, persist it as a versioned model directory, boot the HTTP query
//! server, drive it like a client — project, top-k similarity,
//! reconstruction — then append a batch of new documents with the
//! incremental updater and hot-swap the server to the new generation with
//! zero downtime, cross-checking one query against an in-process oracle.
//!
//! ```sh
//! cargo run --release --example serve_queries -- --rows 3000 --cols 256 --k 12
//! ```

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::io::dataset::gen_clustered;
use tallfat::io::InputSpec;
use tallfat::linalg::matmul;
use tallfat::serve::{EngineHandle, Json, ModelServer, ServeOptions};
use tallfat::svd::Svd;
use tallfat::update::Update;
use tallfat::util::Args;

fn post_query(addr: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    // `Connection: close` keeps read_to_string finite under keep-alive.
    let req = format!(
        "POST /query HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    resp.split("\r\n\r\n").nth(1).unwrap_or("").to_string()
}

fn main() -> tallfat::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let m = args.usize_or("rows", 3000)?;
    let n = args.usize_or("cols", 256)?;
    let k = args.usize_or("k", 12)?;
    let clusters = args.usize_or("clusters", 10)?;

    let dir = std::env::temp_dir().join("tallfat_serve_example");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    // ---- 1. factorize a clustered "document" matrix ----------------------
    println!("== {m} documents x {n} terms, {clusters} topics, rank-{k} model ==");
    let (a, labels) = gen_clustered(m, n, clusters, 3.0, 2013);
    let input = InputSpec::csv(dir.join("docs.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input)?;
    // ---- 2. factorize and persist as a servable model (one builder run) --
    let model_dir = dir.join("model");
    let t0 = std::time::Instant::now();
    let result = Svd::over(&input)?
        .rank(k)
        .oversample(8)
        .workers(4)
        .seed(5)
        .work_dir(dir.join("work").to_string_lossy().into_owned())
        .save_model(model_dir.to_string_lossy().into_owned())
        .run()?;
    println!("   factorized in {:.2?} ({} U shards)", t0.elapsed(), result.shards);
    let gen0_dir = tallfat::serve::resolve_current(&model_dir)?;
    let model_bytes: u64 = std::fs::read_dir(&gen0_dir)?
        .filter_map(|e| e.ok()?.metadata().ok())
        .map(|md| md.len())
        .sum();
    println!(
        "   generation 0 saved to {} ({})",
        gen0_dir.display(),
        tallfat::util::humanize::fmt_bytes(model_bytes)
    );

    // ---- 3. boot the HTTP server on an ephemeral port --------------------
    let engines =
        Arc::new(EngineHandle::open(&model_dir, 4, Arc::new(NativeBackend::new()))?);
    let oracle_engine = engines.current();
    let server = ModelServer::bind(
        engines,
        &ServeOptions {
            addr: "127.0.0.1:0".into(),
            max_requests: Some(5),
            ..ServeOptions::default()
        },
    )?;
    let addr = server.local_addr()?.to_string();
    println!("== serving on http://{addr}/query ==");
    let srv = std::thread::spawn(move || server.run());

    // ---- 4. query it like a client ---------------------------------------
    let qdoc = 17usize;
    let row_json = Json::from_f64s(a.row(qdoc)).render();
    let body = format!(
        "{{\"op\":\"project\",\"row\":{row_json}}}\n\
         {{\"op\":\"similar\",\"row\":{row_json},\"k\":8}}\n\
         {{\"op\":\"reconstruct\",\"row_id\":{qdoc}}}\n"
    );
    let ndjson = post_query(&addr, &body);
    let lines: Vec<Json> = ndjson.lines().map(|l| Json::parse(l).unwrap()).collect();

    let latent = lines[0].get("latent").and_then(Json::as_f64_array).unwrap();
    println!(
        "\nproject doc #{qdoc} -> latent[{}] = [{}]",
        latent.len(),
        latent.iter().take(4).map(|v| format!("{v:.3}")).collect::<Vec<_>>().join(", ")
    );

    println!("\ntop-8 similar documents (doc #{qdoc} is topic {}):", labels[qdoc]);
    println!("{:>8} {:>10} {:>7}", "doc", "cosine", "topic");
    for h in lines[1].get("hits").and_then(Json::as_array).unwrap() {
        let row = h.get("row").and_then(Json::as_usize).unwrap();
        let score = h.get("score").and_then(Json::as_f64).unwrap();
        println!("{row:>8} {score:>10.4} {:>7}", labels[row]);
    }

    let recon = lines[2].get("values").and_then(Json::as_f64_array).unwrap();
    let err: f64 =
        recon.iter().zip(a.row(qdoc)).map(|(g, w)| (g - w) * (g - w)).sum::<f64>().sqrt();
    let scale: f64 = a.row(qdoc).iter().map(|v| v * v).sum::<f64>().sqrt();
    println!("\nreconstruct doc #{qdoc}: rank-{k} relative error {:.4}", err / scale.max(1e-12));

    // ---- 5. append new documents, hot-swap without restarting ------------
    let (extra, _) = gen_clustered(m / 10, n, clusters, 3.0, 4096);
    let batch = InputSpec::csv(dir.join("new_docs.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&extra, &batch)?;
    let t0 = std::time::Instant::now();
    let next = Update::of(&model_dir)?
        .rows(&batch)
        .workers(4)
        .seed(6)
        .work_dir(dir.join("work_update").to_string_lossy().into_owned())
        .backend(Arc::new(NativeBackend::new()))
        .run()?;
    println!(
        "\n== appended {} new docs in {:.2?} -> generation {} ==",
        next.rows_added,
        t0.elapsed(),
        next.generation
    );
    // The reload response itself carries the new generation; a *fresh*
    // body then observes it everywhere (inline ops of the reload's own
    // body would still answer from that body's pre-swap snapshot).
    let swap = post_query(&addr, "{\"op\":\"reload\"}\n");
    let swap_line = Json::parse(swap.trim()).unwrap();
    let info = post_query(&addr, "{\"op\":\"info\"}\n");
    let info_line = Json::parse(info.trim()).unwrap();
    println!(
        "hot-swap: swapped={} now serving generation {} with m={}",
        swap_line.get("swapped").and_then(Json::as_bool).unwrap(),
        info_line.get("generation").and_then(Json::as_usize).unwrap(),
        info_line.get("m").and_then(Json::as_usize).unwrap(),
    );

    // ---- 6. metrics + oracle cross-check ---------------------------------
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
    let mut metrics = String::new();
    s.read_to_string(&mut metrics).unwrap();
    // fifth served request hits max_requests and stops the server
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"GET /model HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
    let mut _drain = String::new();
    let _ = s.read_to_string(&mut _drain);
    let _ = srv.join();
    println!("\nserve metrics:");
    for line in metrics.lines().filter(|l| l.starts_with("tallfat_serve_")) {
        println!("  {line}");
    }

    let oracle = matmul(
        &tallfat::linalg::Matrix::from_rows(&[a.row(qdoc).to_vec()])?,
        oracle_engine.projection_matrix(),
    )?;
    let max_diff = latent
        .iter()
        .zip(oracle.row(0).iter())
        .fold(0.0f64, |acc, (g, w)| acc.max((g - w).abs()));
    println!("\nHTTP projection vs in-process linalg oracle: max |Δ| = {max_diff:.2e}");
    assert!(max_diff < 1e-6);
    println!("OK — served results match the oracle.");
    Ok(())
}
