//! Paper §3.1 done literally: streaming `A^T A` with per-worker shards.
//!
//! Reproduces the paper's `ATAJob` flow end to end, including the
//! `/tmp/C-%d.csv` partial spills its `post()` writes, then the leader
//! reduce + eigendecomposition of the Gram (paper §2.0.1) — i.e. the exact
//! SVD-without-projection route for a "tall-and-skinny" matrix. Compares
//! the paper-literal row mode (one outer product per row) against the
//! block mode this library uses on the hot path.
//!
//! ```sh
//! cargo run --release --example streaming_ata -- --rows 100000 --cols 48
//! ```

use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::config::InputFormat;
use tallfat::io::dataset::{gen_streamed, Spectrum};
use tallfat::io::writer::ShardSet;
use tallfat::io::InputSpec;
use tallfat::jobs::{AtaBlockJob, AtaRowJob};
use tallfat::splitproc::{self, Blocked};
use tallfat::util::Args;

fn main() -> tallfat::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let m = args.usize_or("rows", 100_000)?;
    let n = args.usize_or("cols", 48)?;
    let workers = args.usize_or("workers", 4)?;

    let dir = std::env::temp_dir().join("tallfat_streaming_ata");
    std::fs::create_dir_all(&dir)?;
    let input = InputSpec::csv(dir.join("A.csv").to_string_lossy().into_owned());
    if !std::path::Path::new(&input.path).exists() {
        println!("== generating {m} x {n} ==");
        gen_streamed(&input, m, n, 12, Spectrum::Geometric { scale: 5.0, decay: 0.8 }, 0.01, 7)?;
    }

    // ---- paper-literal: row outer products + C-%d shard spills ------------
    println!("== row mode ({workers} workers, outer products, C-%d spills) ==");
    let shards = ShardSet::new(&dir, "C", InputFormat::Csv)?;
    let t0 = std::time::Instant::now();
    let results = splitproc::run(&input, workers, |chunk| {
        Ok(AtaRowJob::new(n).with_spill(shards.clone(), chunk.index))
    })?;
    let n_shards = results.len();
    let rows: u64 = results.iter().map(|r| r.rows).sum();
    let gram_row =
        splitproc::reduce_partials(results.into_iter().map(|r| r.job.into_partial()).collect())?;
    let t_row = t0.elapsed();
    println!(
        "   {rows} rows in {:.2?} ({:.0} rows/s); partials at {}",
        t_row,
        rows as f64 / t_row.as_secs_f64(),
        shards.shard_path(0)
    );

    // ---- block mode: the library's hot path -------------------------------
    println!("== block mode (256-row blocks through the backend) ==");
    let backend = Arc::new(NativeBackend::new());
    let t0 = std::time::Instant::now();
    let results = splitproc::run(&input, workers, |_| {
        Ok(Blocked::new(AtaBlockJob::new(backend.clone(), n), 256, n))
    })?;
    let gram_blk = splitproc::reduce_partials(
        results.into_iter().map(|r| r.job.into_inner().into_partial()).collect(),
    )?;
    let t_blk = t0.elapsed();
    println!(
        "   {rows} rows in {:.2?} ({:.0} rows/s) — {:.1}x the row mode",
        t_blk,
        rows as f64 / t_blk.as_secs_f64(),
        t_row.as_secs_f64() / t_blk.as_secs_f64()
    );
    println!("   max |Δ| between modes = {:.2e}", gram_row.max_abs_diff(&gram_blk));

    // ---- leader: A^T A = V Σ² V^T (paper §2.0.1) ---------------------------
    let (evals, _v) = tallfat::linalg::eigen::eigh(&gram_blk)?;
    println!("\n== leader eigensolve of the {n}x{n} Gram ==");
    println!(
        "singular values (top 8): [{}]",
        evals
            .iter()
            .take(8)
            .map(|&l| format!("{:.3}", l.max(0.0).sqrt()))
            .collect::<Vec<_>>()
            .join(", ")
    );

    // Clean up the paper's /tmp/C-%d.csv analogues.
    shards.cleanup(n_shards);
    Ok(())
}
