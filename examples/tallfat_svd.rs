//! End-to-end driver: the paper's headline workload at laptop scale.
//!
//! Generates a genuinely tall-and-fat matrix on disk (default 20,000 x 1024,
//! ~160 MB of CSV — override with `--rows/--cols/--k`), then runs the full
//! three-layer system:
//!
//!   * L3 split-process workers stream byte-chunks of the file,
//!   * per-block compute goes through the AOT JAX/Pallas artifacts via PJRT
//!     when shapes match (`--backend auto`, the default here), pure-rust
//!     otherwise,
//!   * the leader eigensolves only (k+p) x (k+p) matrices,
//!
//! and reports the phase breakdown, throughput, and accuracy vs the
//! synthetic ground truth. This is the run recorded in EXPERIMENTS.md §E6.
//!
//! ```sh
//! cargo run --release --example tallfat_svd -- --rows 20000 --cols 1024 --k 24
//! ```

use std::sync::Arc;
use tallfat::backend::{self, native::NativeBackend, xla::XlaBackend};
use tallfat::config::BackendKind;
use tallfat::io::dataset::{gen_streamed, Spectrum};
use tallfat::io::InputSpec;
use tallfat::svd::{validate, Svd};
use tallfat::util::Args;

fn main() -> tallfat::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let m = args.usize_or("rows", 20_000)?;
    let n = args.usize_or("cols", 1024)?;
    let k = args.usize_or("k", 24)?;
    let oversample = args.usize_or("oversample", 8)?;
    let workers = args.usize_or("workers", 4)?;
    let backend_kind = BackendKind::parse(&args.str_or("backend", "auto"))?;

    let dir = std::env::temp_dir().join("tallfat_e2e");
    std::fs::create_dir_all(&dir)?;
    let input_path = dir.join(format!("A_{m}x{n}.csv")).to_string_lossy().into_owned();
    let input = InputSpec::csv(&input_path);

    // ---- dataset (cached across runs) -----------------------------------
    if !std::path::Path::new(&input_path).exists() {
        println!("== generating {m} x {n} (streamed, never materialized) ==");
        let t0 = std::time::Instant::now();
        gen_streamed(
            &input,
            m,
            n,
            48,
            Spectrum::Geometric { scale: 10.0, decay: 0.85 },
            0.005,
            2013,
        )?;
        let mb = std::fs::metadata(&input_path)?.len() as f64 / 1e6;
        println!("   {mb:.0} MB in {:.1?}", t0.elapsed());
    } else {
        println!("== reusing {input_path} ==");
    }

    // ---- backend ---------------------------------------------------------
    let artifacts_dir = args.str_or("artifacts-dir", "artifacts");
    let (backend, xla_handle): (backend::BackendRef, Option<Arc<XlaBackend>>) =
        match backend_kind {
            BackendKind::Native => (Arc::new(NativeBackend::new()), None),
            kind => match XlaBackend::start(&artifacts_dir, kind == BackendKind::Auto) {
                Ok(x) => {
                    let x = Arc::new(x);
                    (x.clone(), Some(x))
                }
                Err(e) => {
                    println!("xla backend unavailable ({e}); falling back to native");
                    (Arc::new(NativeBackend::new()), None)
                }
            },
        };
    println!("== backend: {} ==", backend.name());

    // ---- the pipeline ------------------------------------------------------
    let t0 = std::time::Instant::now();
    let result = Svd::over(&input)?
        .rank(k)
        .oversample(oversample)
        .workers(workers)
        .block(256)
        .seed(1)
        .work_dir(dir.join("work").to_string_lossy().into_owned())
        .backend(backend.clone())
        .run()?;
    let elapsed = t0.elapsed();

    println!("\n{}", result.report.render());
    let bytes = std::fs::metadata(&input_path)?.len();
    // The pipeline reads A twice (+1 per power iteration).
    println!(
        "end-to-end: {:.2?}  ({:.0} rows/s/pass, {:.1} MB/s of CSV)",
        elapsed,
        2.0 * m as f64 / elapsed.as_secs_f64(),
        2.0 * bytes as f64 / 1e6 / elapsed.as_secs_f64()
    );
    println!(
        "sigma[0..8] = [{}]",
        result.sigma.iter().take(8).map(|s| format!("{s:.3}")).collect::<Vec<_>>().join(", ")
    );

    // ---- validation ---------------------------------------------------------
    let err = validate::reconstruction_error_streaming(&input, &result)?;
    println!("relative reconstruction error = {err:.6}");
    let ortho = validate::u_orthonormality_residual(&result.u_shards, result.shards, result.k)?;
    println!("U orthonormality residual ||U^T U - I||_max = {ortho:.2e}");

    // If the XLA backend ran, report how many block calls hit the artifacts.
    if let Some(x) = &xla_handle {
        let (hits, misses) = x.call_counts();
        println!("xla artifact calls: {hits} hit, {misses} fell back to native shapes");
    }
    Ok(())
}
