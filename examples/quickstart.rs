//! Quickstart: generate a small tall matrix, run the paper's randomized
//! rank-k SVD through the public API, and check the factorization.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tallfat::io::dataset::{gen_exact, Spectrum};
use tallfat::io::InputSpec;
use tallfat::svd::{validate, Svd};

fn main() -> tallfat::Result<()> {
    let dir = std::env::temp_dir().join("tallfat_quickstart");
    std::fs::create_dir_all(&dir)?;
    let input_path = dir.join("A.csv").to_string_lossy().into_owned();

    // 1. A synthetic 2000 x 64 matrix with a known geometric spectrum.
    println!("== generating 2000 x 64 input with known spectrum ==");
    let (a, true_sigma) = gen_exact(
        2000,
        64,
        16,
        Spectrum::Geometric { scale: 10.0, decay: 0.7 },
        0.0,
        42,
    )?;
    let input = InputSpec::csv(&input_path);
    tallfat::io::write_matrix(&a, &input)?;

    // 2. Randomized rank-8 SVD: two streaming passes over the file,
    //    leader-side math only on (k+p) x (k+p) matrices.
    println!("== randomized rank-8 SVD (4 split-process workers) ==");
    let result = Svd::over(&input)?
        .rank(8)
        .oversample(8)
        .workers(4)
        .seed(7)
        .work_dir(dir.join("work").to_string_lossy().into_owned())
        .run()?;

    println!("{}", result.report.render());
    println!("singular values (computed vs true):");
    for i in 0..result.k {
        println!(
            "  sigma[{i}]  {:>10.5}  vs  {:>10.5}   (rel err {:.2e})",
            result.sigma[i],
            true_sigma[i],
            (result.sigma[i] - true_sigma[i]).abs() / true_sigma[i]
        );
    }

    // 3. Validate: streaming reconstruction error against the input file.
    let err = validate::reconstruction_error_streaming(&input, &result)?;
    println!("\nrelative reconstruction error ||A - U S V^T||_F / ||A||_F = {err:.6}");
    let tail: f64 = true_sigma[result.k..].iter().map(|s| s * s).sum::<f64>().sqrt();
    let total: f64 = true_sigma.iter().map(|s| s * s).sum::<f64>().sqrt();
    println!("best possible (rank-{} tail energy)              = {:.6}", result.k, tail / total);
    Ok(())
}
