//! LSA-style similarity search — the paper's motivating application.
//!
//! The conclusion of the paper notes that the random projection "can also
//! be used in place of SVD [7] as preserving distances between projected
//! rows is useful for any similarity calculation". This example measures
//! exactly that trade on clustered "document vectors":
//!
//! 1. generate m x n clustered vectors (documents around topic centers),
//! 2. rank-k LSA via the randomized SVD pipeline → similarity in U·Σ space,
//! 3. plain JL projection (virtual Ω, no SVD at all) → similarity in Y space,
//! 4. compare nearest-neighbor retrieval quality (same-cluster precision)
//!    and pairwise-distance distortion of both against the raw space.
//!
//! ```sh
//! cargo run --release --example lsa_similarity -- --rows 4000 --cols 512
//! ```

use tallfat::io::dataset::gen_clustered;
use tallfat::io::InputSpec;
use tallfat::linalg::Matrix;
use tallfat::rng::VirtualMatrix;
use tallfat::svd::{validate::distance_distortion, Svd};
use tallfat::util::Args;

/// Precision@10 of same-cluster retrieval under Euclidean NN in `space`.
fn retrieval_precision(space: &Matrix, labels: &[usize], queries: usize) -> f64 {
    let m = space.rows();
    let mut hit = 0usize;
    let mut total = 0usize;
    for q in (0..m).step_by((m / queries).max(1)).take(queries) {
        // brute-force 10-NN
        let mut d: Vec<(f64, usize)> = (0..m)
            .filter(|&i| i != q)
            .map(|i| {
                let dist: f64 = space
                    .row(q)
                    .iter()
                    .zip(space.row(i))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                (dist, i)
            })
            .collect();
        d.sort_by(|a, b| a.0.total_cmp(&b.0));
        for &(_, i) in d.iter().take(10) {
            hit += (labels[i] == labels[q]) as usize;
            total += 1;
        }
    }
    hit as f64 / total as f64
}

fn main() -> tallfat::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let m = args.usize_or("rows", 4000)?;
    let n = args.usize_or("cols", 512)?;
    let k = args.usize_or("k", 16)?;
    let clusters = args.usize_or("clusters", 12)?;

    println!("== {m} documents x {n} terms, {clusters} topics ==");
    let (a, labels) = gen_clustered(m, n, clusters, args.f64_or("spread", 3.5)?, 99);

    let dir = std::env::temp_dir().join("tallfat_lsa");
    std::fs::create_dir_all(&dir)?;
    let input = InputSpec::csv(dir.join("docs.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input)?;

    // ---- route 1: rank-k LSA via the randomized SVD pipeline -------------
    let t0 = std::time::Instant::now();
    let svd = Svd::over(&input)?
        .rank(k)
        .oversample(8)
        .workers(4)
        .seed(3)
        .work_dir(dir.join("work").to_string_lossy().into_owned())
        .run()?;
    let t_svd = t0.elapsed();
    let u = svd.u_matrix()?;
    let lsa = u.scale_cols(&svd.sigma)?; // document coordinates U·Σ

    // ---- route 2: plain JL projection, no SVD ----------------------------
    // (the library's hybrid default: Ω defined virtually by the seed,
    // materialized once per worker, applied as a blocked matmul — E3)
    let t0 = std::time::Instant::now();
    let omega = VirtualMatrix::projection(17, n, k).materialize();
    let y = tallfat::linalg::matmul(&a, &omega)?;
    let t_proj = t0.elapsed();

    // ---- comparison -------------------------------------------------------
    let p_raw = retrieval_precision(&a, &labels, 64);
    let p_lsa = retrieval_precision(&lsa, &labels, 64);
    let p_jl = retrieval_precision(&y, &labels, 64);
    let (d_lsa_mean, d_lsa_max) = distance_distortion(&a, &lsa, 2000, 5);
    let (d_jl_mean, d_jl_max) = distance_distortion(&a, &y, 2000, 5);

    println!("\n{:<26} {:>12} {:>14} {:>14} {:>10}", "space", "dim", "dist mean|max", "", "time");
    println!(
        "{:<26} {:>12} {:>7}|{:>6} {:>14} {:>10}",
        "raw", n, "0.000", "0.000", "", "-"
    );
    println!(
        "{:<26} {:>12} {:>7.3}|{:>6.3} {:>14} {:>9.2?}",
        format!("LSA (U·Σ, rank {k})"), k, d_lsa_mean, d_lsa_max, "", t_svd
    );
    println!(
        "{:<26} {:>12} {:>7.3}|{:>6.3} {:>14} {:>9.2?}",
        format!("JL projection (k={k})"), k, d_jl_mean, d_jl_max, "", t_proj
    );
    println!("\nsame-topic precision@10 (64 queries):");
    println!("  raw {n}-dim        : {p_raw:.3}");
    println!("  LSA rank-{k:<3}     : {p_lsa:.3}");
    println!("  JL  k={k:<3} (no SVD): {p_jl:.3}");
    println!(
        "\npaper's claim: the projection alone preserves similarity structure\n\
         at a fraction of the cost — JL ran {:.0}x faster than the SVD route.",
        t_svd.as_secs_f64() / t_proj.as_secs_f64().max(1e-9)
    );
    Ok(())
}
