//! Coordinator CLI integration: every subcommand end to end through
//! `run_cli`, exactly as the binary drives it.

use tallfat::coordinator::run_cli;
use tallfat::util::Args;

fn dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("tallfat_cli_it").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn run(tokens: &[&str]) -> tallfat::Result<()> {
    run_cli(&Args::parse(tokens.iter().map(|s| s.to_string())).unwrap())
}

fn gen(path: &str, rows: usize, cols: usize) {
    run(&[
        "gen-data", "--out", path, "--rows", &rows.to_string(), "--cols", &cols.to_string(),
        "--rank", "4", "--noise", "0.01",
    ])
    .unwrap();
}

#[test]
fn full_cli_workflow() {
    let d = dir("workflow");
    let input = d.join("a.csv").to_string_lossy().into_owned();
    gen(&input, 300, 24);
    assert!(std::path::Path::new(&input).exists());
    // exact spectrum sidecar written for in-memory datasets
    assert!(std::path::Path::new(&format!("{input}.sigma")).exists());

    let work = d.join("work").to_string_lossy().into_owned();
    let prefix = d.join("out").to_string_lossy().into_owned();
    run(&[
        "svd", "--input", &input, "--k", "4", "--workers", "2", "--work-dir", &work,
        "--validate", "--out-prefix", &prefix,
    ])
    .unwrap();
    assert!(std::path::Path::new(&format!("{prefix}.sigma.csv")).exists());
    assert!(std::path::Path::new(&format!("{prefix}.V.csv")).exists());
}

#[test]
fn ata_and_mr_ata() {
    let d = dir("ata");
    let input = d.join("a.csv").to_string_lossy().into_owned();
    gen(&input, 100, 8);
    let out = d.join("gram.csv").to_string_lossy().into_owned();
    run(&["ata", "--input", &input, "--workers", "3", "--out", &out]).unwrap();
    let g = tallfat::io::read_matrix(&tallfat::io::InputSpec::auto(out)).unwrap();
    assert_eq!(g.shape(), (8, 8));

    let work = d.join("mrwork").to_string_lossy().into_owned();
    run(&[
        "mr-ata", "--input", &input, "--mappers", "2", "--reducers", "2", "--upper",
        "--work-dir", &work,
    ])
    .unwrap();
}

#[test]
fn project_and_mult() {
    let d = dir("proj");
    let input = d.join("a.csv").to_string_lossy().into_owned();
    gen(&input, 120, 16);
    let yprefix = d.join("Y").to_string_lossy().into_owned();
    run(&[
        "project", "--input", &input, "--k", "4", "--oversample", "0", "--workers", "2",
        "--out-prefix", &yprefix,
    ])
    .unwrap();
    assert!(std::path::Path::new(&format!("{yprefix}-0.csv")).exists());

    // B for mult: 16 x 3
    let b = d.join("b.csv").to_string_lossy().into_owned();
    let bm = tallfat::linalg::Matrix::from_fn(16, 3, |i, j| (i + j) as f64 * 0.1);
    tallfat::io::write_matrix(&bm, &tallfat::io::InputSpec::csv(b.clone())).unwrap();
    let cprefix = d.join("C").to_string_lossy().into_owned();
    run(&[
        "mult", "--input", &input, "--b", &b, "--workers", "2", "--out-prefix", &cprefix,
    ])
    .unwrap();
    assert!(std::path::Path::new(&format!("{cprefix}-0.csv")).exists());
}

#[test]
fn exact_svd_and_simulate() {
    let d = dir("exact");
    let input = d.join("a.csv").to_string_lossy().into_owned();
    gen(&input, 150, 10);
    let work = d.join("work").to_string_lossy().into_owned();
    run(&["exact-svd", "--input", &input, "--k", "4", "--work-dir", &work]).unwrap();
    run(&[
        "simulate", "--input", &input, "--workers-list", "1,2,4", "--rows-per-sec", "50000",
    ])
    .unwrap();
}

#[test]
fn config_file_precedence() {
    let d = dir("config");
    let input = d.join("a.csv").to_string_lossy().into_owned();
    gen(&input, 80, 8);
    let cfg_path = d.join("run.toml").to_string_lossy().into_owned();
    std::fs::write(
        &cfg_path,
        format!(
            "[svd]\nk = 3\nworkers = 2\nwork_dir = \"{}\"\n",
            d.join("w").to_string_lossy()
        ),
    )
    .unwrap();
    // CLI --k overrides the file's k = 3.
    run(&["svd", "--input", &input, "--config", &cfg_path, "--k", "2"]).unwrap();
}

#[test]
fn errors_are_reported_not_panicked() {
    assert!(run(&["svd"]).is_err()); // missing --input
    assert!(run(&["frobnicate"]).is_err()); // unknown command
    assert!(run(&["ata", "--input", "/no/such/file.csv"]).is_err());
    assert!(run(&["gen-data", "--rows", "10"]).is_err()); // missing --out
}

#[test]
fn streamed_gen_data_bin() {
    let d = dir("streamed");
    let input = d.join("big.bin").to_string_lossy().into_owned();
    run(&[
        "gen-data", "--out", &input, "--rows", "5000", "--cols", "32", "--streamed",
    ])
    .unwrap();
    let (m, n) = tallfat::io::InputSpec::auto(input).dims().unwrap();
    assert_eq!((m, n), (5000, 32));
}
