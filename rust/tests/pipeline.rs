//! End-to-end pipeline integration tests: the full randomized SVD over
//! files, against known ground truth, plus failure injection.

use tallfat::io::dataset::{gen_clustered, gen_exact, gen_streamed, Spectrum};
use tallfat::io::InputSpec;
use tallfat::jobs::AtaRowJob;
use tallfat::linalg::{exact_svd, matmul, Matrix};
use tallfat::mapreduce::{ata_mapreduce, AtaMrMode};
use tallfat::splitproc;
use tallfat::svd::{validate, Svd, SvdResult};

fn dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("tallfat_it").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Builder with the fixture defaults every test below shares.
fn builder<'a>(input: &InputSpec, work: &std::path::Path, k: usize, workers: usize) -> Svd<'a> {
    Svd::over(input)
        .unwrap()
        .rank(k)
        .oversample(8)
        .workers(workers)
        .block(64)
        .seed(42)
        .work_dir(work.to_string_lossy().into_owned())
}

/// Fallible end-to-end run (for the failure-injection tests, where the
/// error may surface in `Svd::over` or mid-pass).
fn try_run(
    input: &InputSpec,
    work: &std::path::Path,
    k: usize,
    workers: usize,
) -> tallfat::Result<SvdResult> {
    Svd::over(input)?
        .rank(k)
        .oversample(8)
        .workers(workers)
        .block(64)
        .seed(42)
        .work_dir(work.to_string_lossy().into_owned())
        .run()
}

/// Exact low-rank input: the randomized SVD must recover the spectrum to
/// near machine precision (rank <= sketch width).
#[test]
fn recovers_exact_low_rank_spectrum() {
    let d = dir("exact_lowrank");
    let (a, sigma) = gen_exact(
        500,
        48,
        8,
        Spectrum::Geometric { scale: 10.0, decay: 0.6 },
        0.0,
        1,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();

    let res = builder(&input, &d, 8, 3).run().unwrap();
    for i in 0..8 {
        let rel = (res.sigma[i] - sigma[i]).abs() / sigma[i];
        assert!(rel < 1e-8, "sigma[{i}]: {} vs {}", res.sigma[i], sigma[i]);
    }
    let err = validate::reconstruction_error_streaming(&input, &res).unwrap();
    assert!(err < 1e-7, "reconstruction error {err}");
    // U orthonormal
    let ortho = validate::u_orthonormality_residual(&res.u_shards, res.shards, res.k).unwrap();
    assert!(ortho < 1e-8, "orthonormality {ortho}");
}

/// Noisy full-rank input: error must approach the optimal rank-k error
/// (exact SVD tail), within the sketching constant.
#[test]
fn near_optimal_on_noisy_spectrum() {
    let d = dir("noisy");
    let (a, _) = gen_exact(
        300,
        40,
        40,
        Spectrum::Geometric { scale: 10.0, decay: 0.8 },
        0.0,
        2,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();

    let k = 10;
    let res = builder(&input, &d, k, 2).run().unwrap();
    let err = validate::reconstruction_error_streaming(&input, &res).unwrap();

    let svd = exact_svd(&a).unwrap();
    let opt = tallfat::linalg::truncation_error(&a, &svd, k);
    assert!(
        err < 1.5 * opt + 1e-12,
        "rand err {err} vs optimal {opt} (should be within 1.5x)"
    );
}

/// V agreement: right singular vectors from the pipeline vs exact SVD
/// (up to sign), on a well-separated spectrum.
#[test]
fn right_singular_vectors_match_exact() {
    let d = dir("vvecs");
    let (a, _) = gen_exact(
        400,
        24,
        6,
        Spectrum::Geometric { scale: 8.0, decay: 0.5 },
        0.0,
        3,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();
    let res = builder(&input, &d, 6, 2).run().unwrap();
    let v = res.v.as_ref().unwrap();
    let svd = exact_svd(&a).unwrap();
    for j in 0..6 {
        let dot: f64 = (0..24).map(|i| v.get(i, j) * svd.v.get(i, j)).sum();
        assert!(dot.abs() > 0.9999, "V col {j}: |dot| = {}", dot.abs());
    }
}

/// The exact-Gram route (paper §2.0.1, small n) equals the exact SVD.
#[test]
fn gram_route_equals_exact_svd() {
    let d = dir("gram_route");
    let (a, _) = gen_exact(
        250,
        16,
        16,
        Spectrum::Power { scale: 5.0 },
        0.0,
        4,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();
    let res = builder(&input, &d, 16, 3).exact_gram(true).run().unwrap();
    let svd = exact_svd(&a).unwrap();
    for i in 0..16 {
        let rel = (res.sigma[i] - svd.sigma[i]).abs() / svd.sigma[i].max(1e-12);
        assert!(rel < 1e-6, "sigma[{i}] {} vs {}", res.sigma[i], svd.sigma[i]);
    }
}

/// Power iterations improve the hard (slow-decay) case.
#[test]
fn power_iterations_help_slow_decay() {
    let d = dir("power");
    let (a, _) = gen_exact(300, 64, 64, Spectrum::Power { scale: 10.0 }, 0.0, 5).unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();
    let mut e = vec![];
    for q in [0usize, 2] {
        let res = builder(&input, &d.join(format!("w{q}")), 8, 2)
            .power_iters(q)
            .run()
            .unwrap();
        e.push(validate::reconstruction_error_streaming(&input, &res).unwrap());
    }
    assert!(
        e[1] <= e[0] + 1e-9,
        "q=2 ({}) should not be worse than q=0 ({})",
        e[1],
        e[0]
    );
}

/// Worker count must not change results (bitwise determinism is not
/// required across worker counts, but fp-tolerance equality is).
#[test]
fn worker_count_invariance() {
    let d = dir("workers");
    let (a, _) = gen_exact(
        333,
        32,
        8,
        Spectrum::Geometric { scale: 5.0, decay: 0.7 },
        0.01,
        6,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();
    let mut sigmas = vec![];
    for w in [1usize, 2, 5] {
        let res = builder(&input, &d.join(format!("w{w}")), 6, w).run().unwrap();
        sigmas.push(res.sigma);
    }
    for s in &sigmas[1..] {
        for i in 0..6 {
            let rel = (s[i] - sigmas[0][i]).abs() / sigmas[0][i];
            assert!(rel < 1e-9, "worker-count drift at sigma[{i}]");
        }
    }
}

/// Binary and CSV inputs produce identical factorizations.
#[test]
fn csv_and_bin_inputs_agree() {
    let d = dir("formats");
    let (a, _) = gen_exact(
        200,
        24,
        6,
        Spectrum::Geometric { scale: 4.0, decay: 0.6 },
        0.0,
        7,
    )
    .unwrap();
    let csv = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    let bin = InputSpec::bin(d.join("a.bin").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &csv).unwrap();
    tallfat::io::write_matrix(&a, &bin).unwrap();
    let r1 = builder(&csv, &d.join("c"), 6, 2).run().unwrap();
    let r2 = builder(&bin, &d.join("b"), 6, 2).run().unwrap();
    for i in 0..6 {
        // CSV stores ~12 significant digits; allow that roundtrip error.
        let rel = (r1.sigma[i] - r2.sigma[i]).abs() / r1.sigma[i];
        assert!(rel < 1e-9, "format drift at sigma[{i}]: {rel}");
    }
}

/// Streamed generator + clustered generator smoke: pipeline runs over them.
#[test]
fn generators_feed_the_pipeline() {
    let d = dir("gens");
    let streamed = InputSpec::bin(d.join("s.bin").to_string_lossy().into_owned());
    gen_streamed(&streamed, 2000, 32, 8, Spectrum::Geometric { scale: 3.0, decay: 0.7 }, 0.01, 8)
        .unwrap();
    let res = builder(&streamed, &d, 8, 3).run().unwrap();
    assert_eq!(res.m, 2000);
    assert!(res.sigma[0] > 0.0);

    let (c, _) = gen_clustered(150, 20, 5, 0.3, 9);
    let cin = InputSpec::csv(d.join("c.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&c, &cin).unwrap();
    let res = builder(&cin, &d.join("c"), 4, 2).run().unwrap();
    assert_eq!(res.n, 20);
}

/// Map-Reduce baseline and Split-Process agree on the Gram matrix.
#[test]
fn mapreduce_equals_splitproc() {
    let d = dir("mr_eq");
    let (a, _) = gen_exact(
        120,
        10,
        10,
        Spectrum::Geometric { scale: 2.0, decay: 0.9 },
        0.1,
        10,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();
    let results = splitproc::run(&input, 3, |_| Ok(AtaRowJob::new(10))).unwrap();
    let sp = splitproc::reduce_partials(results.into_iter().map(|r| r.job.into_partial()).collect())
        .unwrap();
    for mode in [AtaMrMode::Full, AtaMrMode::Upper] {
        let (mr, stats) = ata_mapreduce(&input, d.join("work"), 3, 2, mode).unwrap();
        assert!(mr.max_abs_diff(&sp) < 1e-9);
        assert!(stats.shuffle_bytes > 0);
    }
}

// ---------------------------------------------------------------------------
// failure injection
// ---------------------------------------------------------------------------

#[test]
fn malformed_csv_row_is_an_error_not_a_hang() {
    let d = dir("bad_csv");
    let path = d.join("bad.csv").to_string_lossy().into_owned();
    std::fs::write(&path, "1.0;2.0;3.0\n1.0;banana;3.0\n4.0;5.0;6.0\n").unwrap();
    let input = InputSpec::csv(path);
    let r = try_run(&input, &d, 2, 2);
    assert!(r.is_err());
}

#[test]
fn ragged_csv_rows_error() {
    let d = dir("ragged");
    let path = d.join("ragged.csv").to_string_lossy().into_owned();
    std::fs::write(&path, "1.0;2.0;3.0\n1.0;2.0\n").unwrap();
    let r = try_run(&InputSpec::csv(path), &d, 2, 1);
    assert!(r.is_err());
}

#[test]
fn missing_file_errors() {
    let d = dir("missing");
    let r = try_run(&InputSpec::csv("/nonexistent/never/a.csv"), &d, 2, 1);
    assert!(r.is_err());
}

#[test]
fn empty_file_errors() {
    let d = dir("empty");
    let path = d.join("empty.csv").to_string_lossy().into_owned();
    std::fs::write(&path, "").unwrap();
    let r = try_run(&InputSpec::csv(path), &d, 2, 2);
    assert!(r.is_err());
}

#[test]
fn more_workers_than_rows_still_correct() {
    let d = dir("overworkers");
    let (a, sigma) = gen_exact(
        6,
        12,
        3,
        Spectrum::Geometric { scale: 4.0, decay: 0.5 },
        0.0,
        11,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();
    let res = builder(&input, &d, 3, 16).run().unwrap();
    for i in 0..3 {
        let rel = (res.sigma[i] - sigma[i]).abs() / sigma[i];
        assert!(rel < 1e-8, "sigma[{i}]");
    }
}

/// U^T U stays orthonormal even when sigma has a zero tail (rank-deficient
/// guarded inverse path).
#[test]
fn rank_deficient_input_is_guarded() {
    let d = dir("rankdef");
    // rank 3 matrix but ask for k = 6
    let (a, _) = gen_exact(
        120,
        16,
        3,
        Spectrum::LowRank { scale: 5.0, r: 3 },
        0.0,
        12,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();
    let res = builder(&input, &d, 6, 2).run().unwrap();
    // Reconstruction must still be near perfect (tail sigma ~ 0).
    let err = validate::reconstruction_error_streaming(&input, &res).unwrap();
    assert!(err < 1e-6, "rank-deficient reconstruction {err}");
    // And nothing is NaN.
    assert!(res.sigma.iter().all(|s| s.is_finite()));
    let u = res.u_matrix().unwrap();
    assert!(u.data().iter().all(|v| v.is_finite()));
}

/// Reconstruction helper on SvdResult composes U, sigma, V correctly.
#[test]
fn reconstruct_matches_input() {
    let d = dir("reconstruct");
    let (a, _) = gen_exact(
        80,
        12,
        4,
        Spectrum::Geometric { scale: 3.0, decay: 0.5 },
        0.0,
        13,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();
    let res = builder(&input, &d, 4, 2).run().unwrap();
    let ak = res.reconstruct().unwrap();
    // a is exactly rank 4, so A_4 == A.
    assert!(ak.max_abs_diff(&a) < 1e-8);
    // Cross-check with dense error helper.
    let u = res.u_matrix().unwrap();
    let e =
        validate::dense_reconstruction_error(&a, &u, &res.sigma, res.v.as_ref().unwrap()).unwrap();
    let _ = matmul(&u.t(), &u).unwrap();
    assert!(e < 1e-8);
}

/// PCA mode: centered factorization matches the exact SVD of `A - 1 muT`.
#[test]
fn pca_centering_matches_dense_centered_svd() {
    let d = dir("pca");
    // Shift columns by large offsets so centering is load-bearing.
    let (mut a, _) = gen_exact(
        400,
        20,
        5,
        Spectrum::Geometric { scale: 4.0, decay: 0.6 },
        0.0,
        30,
    )
    .unwrap();
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            let v = a.get(i, j) + 10.0 * (j as f64 + 1.0);
            a.set(i, j, v);
        }
    }
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();

    let res = builder(&input, &d, 5, 3).center(true).run().unwrap();

    // Dense oracle: exact SVD of the centered matrix.
    let means: Vec<f64> = (0..a.cols())
        .map(|j| (0..a.rows()).map(|i| a.get(i, j)).sum::<f64>() / a.rows() as f64)
        .collect();
    let centered = Matrix::from_fn(a.rows(), a.cols(), |i, j| a.get(i, j) - means[j]);
    let svd = exact_svd(&centered).unwrap();
    for i in 0..5 {
        let rel = (res.sigma[i] - svd.sigma[i]).abs() / svd.sigma[i].max(1e-12);
        assert!(rel < 1e-8, "pca sigma[{i}]: {} vs {}", res.sigma[i], svd.sigma[i]);
    }
    // Recorded means round-trip.
    let got_means = res.means.as_ref().unwrap();
    for j in 0..a.cols() {
        assert!((got_means[j] - means[j]).abs() < 1e-9);
    }
    // Streaming validation knows to compare against the centered matrix.
    let err = validate::reconstruction_error_streaming(&input, &res).unwrap();
    assert!(err < 1e-7, "centered reconstruction {err}");

    // Without centering the same k misses badly (offsets dominate).
    let res_raw = builder(&input, &d.join("raw"), 5, 3).run().unwrap();
    assert!(
        (res_raw.sigma[0] - res.sigma[0]).abs() / res.sigma[0] > 1.0,
        "column offsets should dominate the uncentered spectrum"
    );
}
