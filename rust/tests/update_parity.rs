//! Incremental-update parity — the acceptance gate of the `update/`
//! subsystem: for a random tall-and-fat A split into A₀ ‖ A₁, updating the
//! A₀ model with the A₁ rows must match a from-scratch factorization of
//! the concatenated input — Σ to relative tolerance, U/V up to per-column
//! sign on the well-separated leading spectrum, and the full rank-k
//! reconstruction (rotation-proof) against the actual data — under both
//! the in-process [`LocalExecutor`] and remote TCP workers via
//! [`ClusterExecutor`], centered and uncentered. Plus the degenerate
//! batches: rank-deficient rows, fewer rows than k, an empty batch (a
//! no-op generation), and running-mean correctness for PCA models.

use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::cluster::ClusterExecutor;
use tallfat::config::InputFormat;
use tallfat::coordinator::run_cli;
use tallfat::io::dataset::{gen_exact, Spectrum};
use tallfat::io::{InputSpec, ShardSet};
use tallfat::linalg::{matmul, Matrix};
use tallfat::serve::ModelStore;
use tallfat::svd::{Svd, SvdResult};
use tallfat::update::Update;
use tallfat::util::Args;

mod harness;
use harness::{free_addr, spawn_workers};

const M0: usize = 200;
const M1: usize = 90;
const N: usize = 20;
const RANK: usize = 5;
const K: usize = 8;

fn dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("tallfat_update_it").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn write_spec(a: &Matrix, path: std::path::PathBuf) -> InputSpec {
    let spec = InputSpec::csv(path.to_string_lossy().into_owned());
    tallfat::io::write_matrix(a, &spec).unwrap();
    spec
}

/// Exact-rank data split into base + batch (+ the full file for the
/// from-scratch reference run).
fn fixture(d: &std::path::Path, m0: usize, m1: usize) -> (Matrix, InputSpec, InputSpec, InputSpec) {
    let (a, _) = gen_exact(
        m0 + m1,
        N,
        RANK,
        Spectrum::Geometric { scale: 10.0, decay: 0.55 },
        0.0,
        2024,
    )
    .unwrap();
    let base = write_spec(&a.slice_rows(0, m0), d.join("A0.csv"));
    let batch = write_spec(&a.slice_rows(m0, m0 + m1), d.join("A1.csv"));
    let full = write_spec(&a, d.join("A.csv"));
    (a, base, batch, full)
}

/// Factorize the base split and persist it as a model root.
fn build_model(d: &std::path::Path, base: &InputSpec, center: bool) -> std::path::PathBuf {
    let model = d.join("model");
    Svd::over(base)
        .unwrap()
        .rank(K)
        .oversample(6)
        .workers(3)
        .block(32)
        .seed(77)
        .center(center)
        .work_dir(d.join("work_base").to_string_lossy().into_owned())
        .backend(Arc::new(NativeBackend::new()))
        .save_model(model.to_string_lossy().into_owned())
        .run()
        .unwrap();
    model
}

/// The from-scratch reference over the concatenated input.
fn scratch(d: &std::path::Path, full: &InputSpec, center: bool) -> SvdResult {
    Svd::over(full)
        .unwrap()
        .rank(K)
        .oversample(6)
        .workers(3)
        .block(32)
        .seed(78)
        .center(center)
        .work_dir(d.join("work_scratch").to_string_lossy().into_owned())
        .backend(Arc::new(NativeBackend::new()))
        .run()
        .unwrap()
}

fn assert_cols_match_up_to_sign(a: &Matrix, b: &Matrix, cols: usize, tol: f64, what: &str) {
    assert_eq!(a.rows(), b.rows(), "{what}: row mismatch");
    for j in 0..cols {
        let dot: f64 = (0..a.rows()).map(|i| a.get(i, j) * b.get(i, j)).sum();
        let sign = if dot >= 0.0 { 1.0 } else { -1.0 };
        for i in 0..a.rows() {
            let diff = (a.get(i, j) - sign * b.get(i, j)).abs();
            assert!(
                diff < tol,
                "{what}[{i},{j}]: {} vs {} (sign {sign})",
                a.get(i, j),
                b.get(i, j)
            );
        }
    }
}

/// Open the updated model and compare it against the from-scratch result
/// and the raw concatenated data.
///
/// * Σ: every value, relative where live, near-zero where the reference is.
/// * Reconstruction: `U Σ Vᵀ (+ 1μᵀ)` must reproduce `a_full` — this pins
///   the U/V subspaces without assuming any spectral gap.
/// * U/V columns up to sign for the first `strict_cols` (callers pass the
///   provably gap-separated prefix — sign comparison is ill-posed at
///   near-degenerate σ).
fn assert_model_matches(
    model: &std::path::Path,
    reference: &SvdResult,
    a_full: &Matrix,
    strict_cols: usize,
) {
    let store = ModelStore::open(model, 4).unwrap();
    assert_eq!(store.m(), a_full.rows(), "updated model row count");
    assert_eq!(store.m(), reference.m);
    assert_eq!(store.k(), reference.k);
    let s0 = reference.sigma[0];

    for i in 0..store.k() {
        let got = store.sigma()[i];
        let want = reference.sigma[i];
        if want > 1e-6 * s0 {
            let rel = (got - want).abs() / want;
            assert!(rel < 1e-5, "sigma[{i}]: {got} vs {want} (rel {rel})");
        } else {
            assert!(got.abs() < 1e-5 * s0, "tail sigma[{i}] = {got} not ~0");
        }
    }

    // Rotation-proof subspace check: the updated factors reconstruct the
    // actual concatenated input.
    let u_updated = ShardSet::new(store.dir(), "U", InputFormat::Bin)
        .unwrap()
        .merge_to_matrix(store.shards())
        .unwrap();
    let mut recon = matmul(
        &u_updated.scale_cols(store.sigma()).unwrap(),
        &store.v().t(),
    )
    .unwrap();
    if let Some(mu) = store.means() {
        for i in 0..recon.rows() {
            for (v, m) in recon.row_mut(i).iter_mut().zip(mu.iter()) {
                *v += m;
            }
        }
    }
    let err = recon.max_abs_diff(a_full);
    assert!(err < 1e-5 * s0, "reconstruction err {err} vs sigma0 {s0}");

    // Strict per-column comparison on the separated prefix.
    assert_cols_match_up_to_sign(
        store.v(),
        reference.v.as_ref().unwrap(),
        strict_cols,
        1e-4,
        "V",
    );
    let u_reference = reference.u_matrix().unwrap();
    assert_cols_match_up_to_sign(&u_updated, &u_reference, strict_cols, 1e-4, "U");

    // The norms sidecar must describe the *rotated* embeddings.
    for row in [0usize, store.m() / 2, store.m() - 1] {
        let emb: f64 = u_updated
            .row(row)
            .iter()
            .zip(store.sigma().iter())
            .map(|(u, s)| (u * s) * (u * s))
            .sum::<f64>()
            .sqrt();
        let norms = store.norms().unwrap();
        assert!(
            (emb - norms[row]).abs() < 1e-8 * s0.max(1.0),
            "norm sidecar row {row}: {} vs {emb}",
            norms[row]
        );
    }
}

fn run_local(center: bool, name: &str) {
    let d = dir(name);
    let (a, base, batch, full) = fixture(&d, M0, M1);
    let model = build_model(&d, &base, center);
    let result = Update::of(&model)
        .unwrap()
        .rows(&batch)
        .oversample(6)
        .workers(3)
        .block(32)
        .seed(5)
        .work_dir(d.join("work_update").to_string_lossy().into_owned())
        .backend(Arc::new(NativeBackend::new()))
        .run()
        .unwrap();
    assert_eq!(result.generation, 1);
    assert_eq!(result.m, M0 + M1);
    assert_eq!(result.rows_added, M1);
    let reference = scratch(&d, &full, center);
    // Centering perturbs the spectrum by the mean direction, so only the
    // top of the spectrum is guaranteed gap-separated there.
    let strict = if center { 2 } else { RANK };
    assert_model_matches(&model, &reference, &a, strict);
}

#[test]
fn update_matches_scratch_local() {
    run_local(false, "local_plain");
}

#[test]
fn update_matches_scratch_local_centered() {
    run_local(true, "local_centered");
}

fn run_cluster(center: bool, name: &str, workers: usize) {
    let d = dir(name);
    let (a, base, batch, full) = fixture(&d, M0, M1);
    let model = build_model(&d, &base, center);

    let addr = free_addr();
    let handles = spawn_workers(&addr, workers);
    let mut cluster = ClusterExecutor::accept(&addr, workers).unwrap();
    let result = Update::of(&model)
        .unwrap()
        .rows(&batch)
        .oversample(6)
        .block(32)
        .seed(5)
        .work_dir(d.join("work_update").to_string_lossy().into_owned())
        .backend(Arc::new(NativeBackend::new()))
        .executor(&mut cluster)
        .run()
        .unwrap();
    cluster.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(result.generation, 1);
    // The batch was fanned out chunk-grained: one new U shard per
    // scheduler chunk, appended after the parent's shards.
    let new_chunks = tallfat::splitproc::plan_chunks_policy(
        &batch,
        workers,
        &tallfat::splitproc::SchedPolicy::default(),
    )
    .unwrap()
    .len();
    assert!(new_chunks > workers, "fine-grained plan expected");
    let parent = ModelStore::open(model.join("gen-000000"), 1).unwrap();
    let store = ModelStore::open(&model, 1).unwrap();
    assert_eq!(store.shards(), parent.shards() + new_chunks);
    drop((store, parent));
    let reference = scratch(&d, &full, center);
    let strict = if center { 2 } else { RANK };
    assert_model_matches(&model, &reference, &a, strict);
}

#[test]
fn update_matches_scratch_cluster() {
    run_cluster(false, "cluster_plain", 3);
}

#[test]
fn update_matches_scratch_cluster_centered() {
    run_cluster(true, "cluster_centered", 2);
}

/// Local and cluster updates of the same model+batch+seed agree with each
/// other to near-fp precision (same math, same reduction shape).
#[test]
fn local_and_cluster_updates_agree() {
    let d = dir("local_vs_cluster");
    let (_, base, batch, _) = fixture(&d, M0, M1);
    let model_l = build_model(&dir("local_vs_cluster_l"), &base, false);
    let model_c = build_model(&dir("local_vs_cluster_c"), &base, false);

    let local = Update::of(&model_l)
        .unwrap()
        .rows(&batch)
        .workers(2)
        .block(32)
        .seed(9)
        .work_dir(d.join("wl").to_string_lossy().into_owned())
        .run()
        .unwrap();

    let addr = free_addr();
    let handles = spawn_workers(&addr, 2);
    let mut cluster = ClusterExecutor::accept(&addr, 2).unwrap();
    let dist = Update::of(&model_c)
        .unwrap()
        .rows(&batch)
        .block(32)
        .seed(9)
        .work_dir(d.join("wc").to_string_lossy().into_owned())
        .executor(&mut cluster)
        .run()
        .unwrap();
    cluster.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }

    for (a, b) in local.sigma.iter().zip(dist.sigma.iter()) {
        assert!((a - b).abs() < 1e-9 * local.sigma[0], "{a} vs {b}");
    }
    let sl = ModelStore::open(&model_l, 1).unwrap();
    let sc = ModelStore::open(&model_c, 1).unwrap();
    assert_cols_match_up_to_sign(sl.v(), sc.v(), RANK, 1e-8, "V local-vs-cluster");
}

// ---- degenerate batches ---------------------------------------------------

/// A batch entirely inside the model's row space (duplicated base rows):
/// the residual is rank-deficient end to end and must not break anything.
#[test]
fn rank_deficient_batch_is_handled() {
    let d = dir("rankdef");
    let (a, base, _, _) = fixture(&d, M0, M1);
    // Batch = copies of base rows => residual exactly zero.
    let dup = a.slice_rows(10, 40);
    let batch = write_spec(&dup, d.join("dup.csv"));
    let concat = a.slice_rows(0, M0).vstack(&dup).unwrap();
    let full = write_spec(&concat, d.join("full.csv"));
    let model = build_model(&d, &base, false);
    let result = Update::of(&model)
        .unwrap()
        .rows(&batch)
        .workers(2)
        .block(32)
        .seed(3)
        .work_dir(d.join("work_update").to_string_lossy().into_owned())
        .run()
        .unwrap();
    assert_eq!(result.m, M0 + 30);
    assert!(result.sigma.iter().all(|s| s.is_finite()));
    let reference = scratch(&d, &full, false);
    assert_model_matches(&model, &reference, &concat, RANK);
}

/// A batch with fewer rows than k: the residual sketch shrinks to the
/// batch size and parity still holds.
#[test]
fn batch_smaller_than_k() {
    let d = dir("tiny_batch");
    let m1 = 3; // < K = 8
    let (a, base, batch, full) = fixture(&d, M0, m1);
    let model = build_model(&d, &base, false);
    let result = Update::of(&model)
        .unwrap()
        .rows(&batch)
        .workers(2)
        .block(32)
        .seed(4)
        .work_dir(d.join("work_update").to_string_lossy().into_owned())
        .run()
        .unwrap();
    assert_eq!(result.rows_added, m1);
    let reference = scratch(&d, &full, false);
    assert_model_matches(&model, &reference, &a, RANK);
}

/// An empty batch commits a no-op generation: same factors, next number.
#[test]
fn update_of_generation_dir_is_rejected() {
    // Pointing an update at /model/gen-NNNNNN instead of the model root
    // would nest a generation inside an immutable gen dir and never move
    // the real CURRENT; it must fail loudly instead.
    let d = dir("gen_dir_guard");
    let (_, base, _, _) = fixture(&d, M0, 4);
    let model = build_model(&d, &base, false);
    let err = Update::of(model.join("gen-000000")).unwrap_err().to_string();
    assert!(err.contains("generation directory"), "{err}");
    assert!(!model.join("gen-000000").join("CURRENT").exists());
}

#[test]
fn empty_batch_is_noop_generation() {
    let d = dir("empty_batch");
    let (_, base, _, _) = fixture(&d, M0, 4);
    let model = build_model(&d, &base, false);
    let before = ModelStore::open(&model, 1).unwrap();
    let empty = d.join("empty.csv");
    std::fs::write(&empty, "").unwrap();
    let result = Update::of(&model)
        .unwrap()
        .rows(&InputSpec::csv(empty.to_string_lossy().into_owned()))
        .run()
        .unwrap();
    assert_eq!(result.generation, 1);
    assert_eq!(result.rows_added, 0);
    let after = ModelStore::open(&model, 1).unwrap();
    assert_eq!(after.generation(), 1);
    assert_eq!(after.m(), before.m());
    assert_eq!(after.sigma(), before.sigma());
    assert_eq!(after.v(), before.v());
    assert_eq!(after.u_row(0).unwrap(), before.u_row(0).unwrap());
}

/// Centered models: the updated generation's means must equal the column
/// means of the full concatenated input (the running-mean merge).
#[test]
fn centered_update_tracks_running_mean() {
    let d = dir("running_mean");
    let (a, base, batch, _) = fixture(&d, M0, M1);
    let model = build_model(&d, &base, true);
    Update::of(&model)
        .unwrap()
        .rows(&batch)
        .workers(3)
        .block(32)
        .seed(5)
        .work_dir(d.join("work_update").to_string_lossy().into_owned())
        .run()
        .unwrap();
    let store = ModelStore::open(&model, 1).unwrap();
    let means = store.means().expect("updated model stays centered");
    for j in 0..N {
        let want: f64 = (0..M0 + M1).map(|i| a.get(i, j)).sum::<f64>() / (M0 + M1) as f64;
        assert!(
            (means[j] - want).abs() < 1e-9,
            "mean[{j}]: {} vs {want}",
            means[j]
        );
    }
}

/// Consecutive updates stack: gen 0 -> 1 -> 2, each building on the last,
/// with old generations garbage-collected down to the keep budget — and
/// the final factors still match scratch over everything.
#[test]
fn chained_updates_advance_generations_and_gc() {
    let d = dir("chained");
    let (a, base, _, _) = fixture(&d, M0, M1);
    let model = build_model(&d, &base, false);
    let split = M0 + M1 / 2;
    let b1 = write_spec(&a.slice_rows(M0, split), d.join("b1.csv"));
    let b2 = write_spec(&a.slice_rows(split, M0 + M1), d.join("b2.csv"));
    for (i, b) in [b1, b2].iter().enumerate() {
        Update::of(&model)
            .unwrap()
            .rows(b)
            .workers(2)
            .block(32)
            .seed(6 + i as u64)
            .keep_generations(2)
            .work_dir(d.join(format!("w{i}")).to_string_lossy().into_owned())
            .run()
            .unwrap();
    }
    let store = ModelStore::open(&model, 1).unwrap();
    assert_eq!(store.generation(), 2);
    assert_eq!(store.m(), M0 + M1);
    drop(store);
    // keep_generations(2): gen 0 must be gone, 1 and 2 remain.
    let gens: Vec<u64> = tallfat::serve::list_generations(&model)
        .unwrap()
        .iter()
        .map(|(g, _)| *g)
        .collect();
    assert_eq!(gens, vec![1, 2]);
    let full = write_spec(&a, d.join("full.csv"));
    let reference = scratch(&d, &full, false);
    assert_model_matches(&model, &reference, &a, RANK);
}

/// rank 0 is rejected up front, exactly like the factorization builder.
#[test]
fn rank_zero_is_rejected() {
    let d = dir("rank_zero");
    let (_, base, batch, _) = fixture(&d, M0, 10);
    let model = build_model(&d, &base, false);
    let err = Update::of(&model).unwrap().rows(&batch).rank(0).run();
    assert!(err.is_err());
    // Nothing was published: still generation 0.
    assert_eq!(ModelStore::open(&model, 1).unwrap().generation(), 0);
}

/// Generations are immutable even across a CURRENT rollback: an update of
/// a rolled-back model gets a fresh number instead of rewriting the
/// abandoned newer generation in place.
#[test]
fn rolled_back_current_never_overwrites_existing_generations() {
    let d = dir("rollback");
    let (a, base, batch, _) = fixture(&d, M0, M1);
    let model = build_model(&d, &base, false);
    Update::of(&model)
        .unwrap()
        .rows(&batch)
        .workers(2)
        .block(32)
        .seed(7)
        .work_dir(d.join("w1").to_string_lossy().into_owned())
        .run()
        .unwrap();
    // Roll back to generation 0 (the pointer is the truth) and update with
    // a different batch.
    tallfat::serve::publish_generation(&model, 0).unwrap();
    let gen1_manifest =
        std::fs::read_to_string(model.join("gen-000001").join("model.manifest")).unwrap();
    let other = write_spec(&a.slice_rows(M0, M0 + 10), d.join("other.csv"));
    let result = Update::of(&model)
        .unwrap()
        .rows(&other)
        .workers(2)
        .block(32)
        .seed(8)
        .keep_generations(3)
        .work_dir(d.join("w2").to_string_lossy().into_owned())
        .run()
        .unwrap();
    // Fresh number past everything on disk; gen 1 untouched.
    assert_eq!(result.generation, 2);
    assert_eq!(
        std::fs::read_to_string(model.join("gen-000001").join("model.manifest")).unwrap(),
        gen1_manifest
    );
    let store = ModelStore::open(&model, 1).unwrap();
    assert_eq!(store.generation(), 2);
    assert_eq!(store.m(), M0 + 10);
}

/// The `tallfat update` CLI drives the same path.
#[test]
fn update_cli_roundtrip() {
    let d = dir("cli");
    let (_, base, batch, _) = fixture(&d, M0, 20);
    let model = build_model(&d, &base, false);
    let model_str = model.to_string_lossy().into_owned();
    let work = d.join("work_cli").to_string_lossy().into_owned();
    let args: Vec<String> = [
        "update",
        &model_str,
        "--rows",
        &batch.path,
        "--workers",
        "2",
        "--block",
        "32",
        "--work-dir",
        &work,
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    run_cli(&Args::parse(args).unwrap()).unwrap();
    let store = ModelStore::open(&model, 1).unwrap();
    assert_eq!(store.generation(), 1);
    assert_eq!(store.m(), M0 + 20);
}
