//! `stream/` end-to-end — the acceptance gate of the one-pass subsystem:
//! a model factored from a genuinely non-seekable source (a process pipe)
//! in exactly one forward pass must agree with the batch pipeline; the
//! adaptive range finder must stop near the true rank and meet its `tol`
//! residual estimate; an interrupted checkpointed stream must resume to
//! the same factors; and a daemon stream job fed through a FIFO must
//! publish a new generation that serves without a restart.
//!
//! Stream runs report through the process-global [`MetricsRegistry`], so
//! every test here serializes on one mutex.

use std::io::Read;
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use tallfat::backend::native::NativeBackend;
use tallfat::backend::BackendRef;
use tallfat::config::InputFormat;
use tallfat::coordinator::server::MetricsRegistry;
use tallfat::daemon::{Daemon, DaemonClient, DaemonOptions, JobKind, JobSpec};
use tallfat::io::dataset::{gen_exact, Spectrum};
use tallfat::io::InputSpec;
use tallfat::linalg::Matrix;
use tallfat::serve::json::Json;
use tallfat::stream::StreamSvd;
use tallfat::svd::Svd;

const M: usize = 120;
const N: usize = 16;
const RANK: usize = 4;
const K: usize = 6;

fn serial() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("tallfat_stream_it").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn path_str(p: &Path) -> String {
    p.to_string_lossy().into_owned()
}

fn write_spec(a: &Matrix, spec: InputSpec) -> InputSpec {
    tallfat::io::write_matrix(a, &spec).unwrap();
    spec
}

fn fixture(m: usize, n: usize, rank: usize, seed: u64) -> Matrix {
    let spectrum = Spectrum::Geometric { scale: 6.0, decay: 0.5 };
    gen_exact(m, n, rank, spectrum, 0.0, seed).unwrap().0
}

fn batch_svd(spec: &InputSpec, d: &Path, center: bool) -> tallfat::svd::SvdResult {
    Svd::over(spec)
        .unwrap()
        .rank(K)
        .oversample(6)
        .seed(5)
        .center(center)
        .work_dir(path_str(&d.join("work_batch")))
        .backend(Arc::new(NativeBackend::new()))
        .run()
        .unwrap()
}

fn assert_sigma_close(got: &[f64], want: &[f64], count: usize, tol: f64, what: &str) {
    for i in 0..count {
        let rel = (got[i] - want[i]).abs() / want[i].abs().max(1e-300);
        assert!(rel < tol, "{what}: sigma[{i}] {} vs {} (rel {rel:.3e})", got[i], want[i]);
    }
}

/// Wraps the pipe's read end so the test can prove every byte was pulled
/// through it exactly once (a pipe cannot be rewound, so bytes seen ==
/// bytes produced means one forward pass).
struct CountingReader<R: Read> {
    inner: R,
    count: Arc<AtomicU64>,
}

impl<R: Read> Read for CountingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.count.fetch_add(n as u64, Ordering::SeqCst);
        Ok(n)
    }
}

/// The headline acceptance test: factor rows arriving from another
/// process's stdout — no file, no seeking — and match the batch pipeline.
#[test]
fn pipe_is_read_in_exactly_one_forward_pass() {
    let _g = serial();
    let d = dir("pipe");
    let a = fixture(M, N, RANK, 11);
    let spec = write_spec(&a, InputSpec::csv(path_str(&d.join("A.csv"))));
    let bytes = std::fs::read(&spec.path).unwrap();
    let total = bytes.len() as u64;

    let mut child = Command::new("cat")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn cat");
    let mut stdin = child.stdin.take().unwrap();
    let feeder = std::thread::spawn(move || {
        use std::io::Write;
        stdin.write_all(&bytes).unwrap();
    });
    let count = Arc::new(AtomicU64::new(0));
    let reader = CountingReader { inner: child.stdout.take().unwrap(), count: Arc::clone(&count) };

    let streamed = StreamSvd::from(reader)
        .format(InputFormat::Csv)
        .rank(K)
        .oversample(6)
        .seed(5)
        .batch_rows(32)
        .work_dir(path_str(&d.join("work_stream")))
        .run()
        .unwrap();
    feeder.join().unwrap();
    child.wait().unwrap();

    assert_eq!(
        count.load(Ordering::SeqCst),
        total,
        "stream must consume the pipe to EOF in one pass"
    );
    assert_eq!((streamed.m, streamed.n), (M, N));

    let batch = batch_svd(&spec, &d, false);
    assert_sigma_close(&streamed.sigma, &batch.sigma, RANK, 1e-7, "pipe vs batch");
    let rec = streamed.reconstruct().unwrap();
    let rel = rec.max_abs_diff(&a) / a.max_abs();
    assert!(rel < 1e-7, "one-pass reconstruction off by {rel:.3e}");
}

/// Dense parity, centered and uncentered: the single-pass factors agree
/// with `Svd::over` on exactly low-rank data.
#[test]
fn stream_matches_batch_svd_dense() {
    let _g = serial();
    for center in [false, true] {
        let d = dir(if center { "dense_centered" } else { "dense" });
        // Shift columns so centering has real work to do.
        let base = fixture(M, N, RANK, 21);
        let a = if center {
            Matrix::from_fn(M, N, |i, j| base.get(i, j) + 3.0 * (j as f64 + 1.0))
        } else {
            base
        };
        let spec = write_spec(&a, InputSpec::csv(path_str(&d.join("A.csv"))));

        let streamed = StreamSvd::open(&spec.path)
            .rank(K)
            .oversample(6)
            .seed(5)
            .center(center)
            .batch_rows(24)
            .work_dir(path_str(&d.join("work_stream")))
            .run()
            .unwrap();
        let batch = batch_svd(&spec, &d, center);

        assert_sigma_close(&streamed.sigma, &batch.sigma, RANK, 1e-7, "dense stream vs batch");
        let target = if center {
            let mu = streamed.means.as_ref().expect("centered run returns means");
            for (j, m) in mu.iter().enumerate() {
                let want: f64 = (0..M).map(|i| a.get(i, j)).sum::<f64>() / M as f64;
                assert!((m - want).abs() < 1e-10, "mean[{j}] {m} vs {want}");
            }
            Matrix::from_fn(M, N, |i, j| a.get(i, j) - mu[j])
        } else {
            a.clone()
        };
        let rec = streamed.reconstruct().unwrap();
        let rel = rec.max_abs_diff(&target) / target.max_abs();
        assert!(rel < 1e-7, "center={center}: reconstruction off by {rel:.3e}");
    }
}

/// Sparse parity: a libsvm stream (pinned column count) matches the batch
/// sparse pipeline over the same file.
#[test]
fn stream_matches_batch_svd_sparse() {
    let _g = serial();
    let d = dir("sparse");
    let a = fixture(M, N, RANK, 31);
    let spec = write_spec(&a, InputSpec::libsvm(path_str(&d.join("A.libsvm"))));

    let streamed = StreamSvd::open(&spec.path)
        .format(InputFormat::Libsvm)
        .cols(N)
        .rank(K)
        .oversample(6)
        .seed(5)
        .batch_rows(32)
        .work_dir(path_str(&d.join("work_stream")))
        .run()
        .unwrap();
    let batch = batch_svd(&spec, &d, false);

    assert_eq!((streamed.m, streamed.n), (M, N));
    assert_sigma_close(&streamed.sigma, &batch.sigma, RANK, 1e-7, "sparse stream vs batch");
    let rec = streamed.reconstruct().unwrap();
    let rel = rec.max_abs_diff(&a) / a.max_abs();
    assert!(rel < 1e-7, "sparse reconstruction off by {rel:.3e}");
}

/// The adaptive range finder: started far below the true rank it must
/// widen, stop within `rank + oversample`, and its final residual
/// estimate must meet `--tol`.
#[test]
fn adaptive_width_stops_near_true_rank_and_meets_tol() {
    let _g = serial();
    let d = dir("adaptive");
    let rank = 10;
    let oversample = 6;
    let tol = 1e-3;
    let spectrum = Spectrum::Geometric { scale: 8.0, decay: 0.35 };
    let (a, _) = gen_exact(240, 32, rank, spectrum, 0.0, 41).unwrap();
    let spec = write_spec(&a, InputSpec::csv(path_str(&d.join("A.csv"))));

    let metrics = MetricsRegistry::global();
    metrics.set("stream_widenings", 0.0);
    let streamed = StreamSvd::open(&spec.path)
        .tol(tol)
        .start_width(4)
        .oversample(oversample)
        .seed(5)
        .batch_rows(48)
        .work_dir(path_str(&d.join("work_stream")))
        .run()
        .unwrap();

    // Width grew from 4 (k <= width, so k > 4 proves at least one widening)
    // but stopped at true rank plus the oversampling cushion.
    assert!(
        streamed.k > 4 && streamed.k <= rank + oversample,
        "adaptive k = {} not in (4, {}]",
        streamed.k,
        rank + oversample
    );
    assert!(metrics.get("stream_widenings").unwrap_or(0.0) >= 1.0, "no widening recorded");
    let residual = metrics.get("stream_residual").expect("finish records its residual");
    assert!(residual <= tol, "final residual estimate {residual:.3e} misses tol {tol:.1e}");
    // Early batches were sketched below the true rank, so the one-pass
    // factors are approximate — but the dominant spectrum must be right
    // and the reconstruction within a small multiple of tol.
    let batch = batch_svd(&spec, &d, false);
    assert_sigma_close(&streamed.sigma, &batch.sigma, 3, 2e-2, "adaptive leading sigma");
    let rec = streamed.reconstruct().unwrap();
    let rel = rec.max_abs_diff(&a) / a.max_abs();
    assert!(rel < 5e-2, "adaptive reconstruction off by {rel:.3e}");
}

/// Always fails — stands in for a producer dying mid-stream.
struct FailingReader;

impl Read for FailingReader {
    fn read(&mut self, _buf: &mut [u8]) -> std::io::Result<usize> {
        Err(std::io::Error::other("injected stream failure"))
    }
}

/// A checkpointed stream killed mid-flight resumes from its sketch state
/// (the source re-serves from the top; absorbed rows are skipped, never
/// re-factored) and lands on the same factors as an uninterrupted run.
#[test]
fn interrupted_stream_resumes_from_checkpoint() {
    let _g = serial();
    let d = dir("resume");
    let a = fixture(100, 12, RANK, 51);
    let spec = write_spec(&a, InputSpec::csv(path_str(&d.join("A.csv"))));
    let text = std::fs::read_to_string(&spec.path).unwrap();
    let head: String = text.lines().take(60).map(|l| format!("{l}\n")).collect();
    let work = path_str(&d.join("work"));

    // First attempt: 60 rows arrive, then the producer dies. Batches of 16
    // checkpoint as they land (zero cadence = every batch), so 48 rows of
    // sketch state survive.
    let err = StreamSvd::from(std::io::Cursor::new(head.into_bytes()).chain(FailingReader))
        .format(InputFormat::Csv)
        .rank(RANK)
        .oversample(4)
        .seed(9)
        .batch_rows(16)
        .work_dir(&work)
        .checkpoint(true)
        .checkpoint_interval(Duration::from_secs(0))
        .run();
    assert!(err.is_err(), "injected failure must abort the stream");

    let resumed = StreamSvd::open(&spec.path)
        .rank(RANK)
        .oversample(4)
        .seed(9)
        .batch_rows(16)
        .work_dir(&work)
        .checkpoint(true)
        .checkpoint_interval(Duration::from_secs(0))
        .resume(true)
        .run()
        .unwrap();
    assert_eq!(resumed.m, 100, "resume must account for every row exactly once");

    let single = StreamSvd::open(&spec.path)
        .rank(RANK)
        .oversample(4)
        .seed(9)
        .batch_rows(16)
        .work_dir(path_str(&d.join("work_single")))
        .run()
        .unwrap();
    assert_sigma_close(&resumed.sigma, &single.sigma, RANK, 1e-9, "resumed vs single-shot");
    let diff = resumed
        .reconstruct()
        .unwrap()
        .max_abs_diff(&single.reconstruct().unwrap())
        / a.max_abs();
    assert!(diff < 1e-9, "resumed factors drift from single-shot by {diff:.3e}");
}

/// The daemon acceptance test: a stream job whose `--rows` is a FIFO — a
/// source that cannot be reopened or seeked — factors the piped rows,
/// merges them into the model, and the new generation serves queries with
/// no restart.
#[test]
fn daemon_stream_job_over_fifo_hot_swaps() {
    let _g = serial();
    let d = dir("fifo_job");
    let n = 10;
    let a = fixture(120, n, 3, 29);

    let fifo = d.join("rows.csv");
    match Command::new("mkfifo").arg(&fifo).status() {
        Ok(s) if s.success() => {}
        _ => {
            eprintln!("skipping: mkfifo unavailable");
            return;
        }
    }

    let base_spec = write_spec(&a.slice_rows(0, 80), InputSpec::csv(path_str(&d.join("A0.csv"))));
    let model = d.join("model");
    Svd::over(&base_spec)
        .unwrap()
        .rank(3)
        .seed(5)
        .work_dir(path_str(&d.join("work_base")))
        .backend(Arc::new(NativeBackend::new()))
        .save_model(path_str(&model))
        .run()
        .unwrap();

    let backend: BackendRef = Arc::new(NativeBackend::new());
    let opts = DaemonOptions {
        addr: "127.0.0.1:0".to_string(),
        health_poll: Some(Duration::from_millis(150)),
        ..DaemonOptions::default()
    };
    let daemon = Daemon::bind(d.join("state"), backend, &opts).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || daemon.run());
    let client = DaemonClient::new(addr);
    client.register("m", &model.to_string_lossy()).unwrap();

    // The producer: blocks on the FIFO's write end until the stream job
    // opens it for reading, then pushes 40 fresh rows and hangs up.
    let tail = a.slice_rows(80, 120);
    let fifo_spec = InputSpec::csv(path_str(&fifo));
    let producer = std::thread::spawn(move || {
        tallfat::io::write_matrix(&tail, &fifo_spec).unwrap();
    });

    let mut spec = JobSpec::new("m", path_str(&fifo));
    spec.kind = JobKind::Stream;
    spec.rank = 3;
    spec.batch_rows = 8;
    let id = client.submit_job(&spec).unwrap();
    let end = client.wait_job(id, Duration::from_secs(180)).unwrap();
    let job = end.get("job").unwrap();
    assert_eq!(job.get("state").and_then(Json::as_str), Some("done"), "{}", end.render());
    assert_eq!(job.get("generation").and_then(Json::as_usize), Some(1));
    producer.join().unwrap();

    // The publish hot-swaps into serving: generation 1 and the grown row
    // count become visible to queries with no daemon restart.
    let health = Json::obj(vec![("op", Json::str("health")), ("model", Json::str("m"))]);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let reply = client.call(&health).unwrap();
        if reply.get("generation").and_then(Json::as_usize) == Some(1) {
            break;
        }
        assert!(Instant::now() < deadline, "generation 1 never became visible to queries");
        std::thread::sleep(Duration::from_millis(50));
    }
    let info = client
        .call(&Json::obj(vec![("op", Json::str("info")), ("model", Json::str("m"))]))
        .unwrap();
    assert_eq!(info.get("m").and_then(Json::as_usize), Some(120));

    client.drain().unwrap();
    server.join().unwrap().unwrap();
}
