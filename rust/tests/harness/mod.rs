//! Shared scaffolding for leader/worker integration tests: ephemeral
//! ports, in-process worker threads speaking the real TCP protocol, and a
//! fault-injection worker that dies mid-pass.

use std::net::TcpStream;
use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::backend::BackendRef;
use tallfat::cluster::proto::{ToLeader, ToWorker, VERSION};
use tallfat::cluster::worker::{self, execute_assignment, PhaseConfig};

/// Pick an ephemeral port by probing.
pub fn free_addr() -> String {
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap().to_string();
    drop(probe);
    addr
}

#[allow(dead_code)]
fn connect_retrying(addr: &str) -> TcpStream {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return s,
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    }
}

/// Spawn `n` worker threads that connect to `addr` (retrying until the
/// leader is listening) and serve until shutdown. Returns join handles.
/// (Not every test binary that includes this module spawns workers.)
#[allow(dead_code)]
pub fn spawn_workers(addr: &str, n: usize) -> Vec<std::thread::JoinHandle<()>> {
    (0..n)
        .map(|_| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let stream = connect_retrying(&addr);
                worker::serve(stream, Arc::new(NativeBackend::new())).unwrap();
            })
        })
        .collect()
}

/// Spawn one worker that connects, correctly completes `complete_chunks`
/// chunk assignments, then *dies* (drops its connection) the moment the
/// next chunk is assigned — i.e. mid-pass, with a chunk in flight that the
/// leader must requeue onto the survivors.
#[allow(dead_code)]
pub fn spawn_flaky_worker(addr: &str, complete_chunks: usize) -> std::thread::JoinHandle<()> {
    let addr = addr.to_string();
    std::thread::spawn(move || {
        let mut stream = connect_retrying(&addr);
        stream.set_nodelay(true).ok();
        {
            let mut w: &TcpStream = &stream;
            ToLeader::Hello { version: VERSION }.write(&mut w).unwrap();
        }
        let backend: BackendRef = Arc::new(NativeBackend::new());
        let mut phase: Option<PhaseConfig> = None;
        let mut done = 0usize;
        loop {
            let msg = match ToWorker::read(&mut stream) {
                Ok(m) => m,
                Err(_) => return,
            };
            match &msg {
                ToWorker::Shutdown => return,
                ToWorker::Phase { .. } => {
                    phase = Some(PhaseConfig::from_msg(&msg).unwrap());
                }
                ToWorker::Assign { phase: pid, chunk, trace: _ } => {
                    if done >= complete_chunks {
                        // Die with this chunk in flight: the connection
                        // drop is the leader's death signal.
                        return;
                    }
                    let cfg = phase.as_ref().expect("assign before phase setup");
                    assert_eq!(cfg.id, *pid, "assign for a phase we never saw");
                    let (rows, partial) =
                        execute_assignment(&backend, cfg, *chunk as usize).unwrap();
                    let reply = ToLeader::ChunkDone {
                        phase: *pid,
                        chunk: *chunk,
                        rows,
                        decode_us: 0,
                        compute_us: 0,
                        encode_us: 0,
                        partial,
                    };
                    let mut w: &TcpStream = &stream;
                    if reply.write(&mut w).is_err() {
                        return;
                    }
                    done += 1;
                }
            }
        }
    })
}
