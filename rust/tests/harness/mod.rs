//! Shared scaffolding for leader/worker integration tests: ephemeral
//! ports, in-process worker threads speaking the real TCP protocol, and
//! fault-injection workers that die mid-pass or mid-reduce.

use std::net::TcpStream;
use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::backend::BackendRef;
use tallfat::cluster::proto::{ToLeader, ToWorker, CAP_CODEC, CAP_HOLD, VERSION};
use tallfat::cluster::worker::{self, execute_assignment, PhaseConfig};
use tallfat::linalg::Matrix;

/// Pick an ephemeral port by probing.
pub fn free_addr() -> String {
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap().to_string();
    drop(probe);
    addr
}

#[allow(dead_code)]
fn connect_retrying(addr: &str) -> TcpStream {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return s,
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    }
}

/// Spawn `n` worker threads that connect to `addr` (retrying until the
/// leader is listening) and serve until shutdown. Returns join handles.
/// (Not every test binary that includes this module spawns workers.)
#[allow(dead_code)]
pub fn spawn_workers(addr: &str, n: usize) -> Vec<std::thread::JoinHandle<()>> {
    (0..n)
        .map(|_| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let stream = connect_retrying(&addr);
                worker::serve(stream, Arc::new(NativeBackend::new())).unwrap();
            })
        })
        .collect()
}

/// Spawn one worker that connects, correctly completes `complete_chunks`
/// chunk assignments, then *dies* (drops its connection) the moment the
/// next chunk is assigned — i.e. mid-pass, with a chunk in flight that the
/// leader must requeue onto the survivors.
///
/// It greets with `caps: 0` — the old-binary shape: the leader must treat
/// it as a ship-partials worker even in tree-reduce mode (mixed fleet).
#[allow(dead_code)]
pub fn spawn_flaky_worker(addr: &str, complete_chunks: usize) -> std::thread::JoinHandle<()> {
    let addr = addr.to_string();
    std::thread::spawn(move || {
        let mut stream = connect_retrying(&addr);
        stream.set_nodelay(true).ok();
        {
            let mut w: &TcpStream = &stream;
            ToLeader::Hello { version: VERSION, caps: 0 }.write(&mut w).unwrap();
        }
        let backend: BackendRef = Arc::new(NativeBackend::new());
        let mut phase: Option<PhaseConfig> = None;
        let mut done = 0usize;
        loop {
            let msg = match ToWorker::read(&mut stream) {
                Ok(m) => m,
                Err(_) => return,
            };
            match &msg {
                ToWorker::Shutdown => return,
                ToWorker::Phase { .. } => {
                    phase = Some(PhaseConfig::from_msg(&msg).unwrap());
                }
                ToWorker::Assign { phase: pid, chunk, trace: _ } => {
                    if done >= complete_chunks {
                        // Die with this chunk in flight: the connection
                        // drop is the leader's death signal.
                        return;
                    }
                    let cfg = phase.as_ref().expect("assign before phase setup");
                    assert_eq!(cfg.id, *pid, "assign for a phase we never saw");
                    let (rows, partial) =
                        execute_assignment(&backend, cfg, *chunk as usize).unwrap();
                    let reply = ToLeader::ChunkDone {
                        phase: *pid,
                        chunk: *chunk,
                        rows,
                        decode_us: 0,
                        compute_us: 0,
                        encode_us: 0,
                        partial,
                    };
                    let mut w: &TcpStream = &stream;
                    if reply.write(&mut w).is_err() {
                        return;
                    }
                    done += 1;
                }
                // A caps-0 worker must never be asked to reduce; dying on
                // the protocol violation is the loudest possible answer.
                ToWorker::RMerge { .. } | ToWorker::RFetch { .. } | ToWorker::RWriteV { .. } => {
                    panic!("leader sent a reduce frame to a caps-0 worker")
                }
            }
        }
    })
}

/// Spawn one worker that advertises the hold capability, completes every
/// chunk assignment correctly (holding partials as tree-reduce leaves the
/// way a real worker does — i.e. shipping an empty `ChunkDone`), then
/// *dies* the moment the first reduce frame (`RMerge` / `RFetch` /
/// `RWriteV`) arrives — mid-reduce-round, with its held leaves lost. The
/// leader must restart the phase attempt on the survivors.
#[allow(dead_code)]
pub fn spawn_reduce_flaky_worker(addr: &str) -> std::thread::JoinHandle<()> {
    let addr = addr.to_string();
    std::thread::spawn(move || {
        let mut stream = connect_retrying(&addr);
        stream.set_nodelay(true).ok();
        {
            let mut w: &TcpStream = &stream;
            ToLeader::Hello { version: VERSION, caps: CAP_HOLD | CAP_CODEC }.write(&mut w).unwrap();
        }
        let backend: BackendRef = Arc::new(NativeBackend::new());
        let mut phase: Option<PhaseConfig> = None;
        loop {
            let msg = match ToWorker::read(&mut stream) {
                Ok(m) => m,
                Err(_) => return,
            };
            match &msg {
                ToWorker::Shutdown => return,
                ToWorker::Phase { .. } => {
                    phase = Some(PhaseConfig::from_msg(&msg).unwrap());
                }
                ToWorker::Assign { phase: pid, chunk, trace: _ } => {
                    let cfg = phase.as_ref().expect("assign before phase setup");
                    assert_eq!(cfg.id, *pid, "assign for a phase we never saw");
                    let (rows, partial) =
                        execute_assignment(&backend, cfg, *chunk as usize).unwrap();
                    // Hold mode: the leaves stay worker-side (here: are
                    // dropped — we die before anyone can fetch them).
                    let wire = if cfg.hold && partial.rows() > 0 {
                        Matrix::zeros(0, 0)
                    } else {
                        partial
                    };
                    let reply = ToLeader::ChunkDone {
                        phase: *pid,
                        chunk: *chunk,
                        rows,
                        decode_us: 0,
                        compute_us: 0,
                        encode_us: 0,
                        partial: wire,
                    };
                    let mut w: &TcpStream = &stream;
                    if reply.write(&mut w).is_err() {
                        return;
                    }
                }
                // The injected fault: die with held leaves in play the
                // moment the leader starts a reduce round through us.
                ToWorker::RMerge { .. } | ToWorker::RFetch { .. } | ToWorker::RWriteV { .. } => {
                    return;
                }
            }
        }
    })
}
