//! Shared scaffolding for leader/worker integration tests: ephemeral
//! ports and in-process worker threads speaking the real TCP protocol.

use std::net::TcpStream;
use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::cluster::worker;

/// Pick an ephemeral port by probing.
pub fn free_addr() -> String {
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap().to_string();
    drop(probe);
    addr
}

/// Spawn `n` worker threads that connect to `addr` (retrying until the
/// leader is listening) and serve until shutdown. Returns join handles.
/// (Not every test binary that includes this module spawns workers.)
#[allow(dead_code)]
pub fn spawn_workers(addr: &str, n: usize) -> Vec<std::thread::JoinHandle<()>> {
    (0..n)
        .map(|_| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let stream = loop {
                    match TcpStream::connect(&addr) {
                        Ok(s) => break s,
                        Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                    }
                };
                worker::serve(stream, Arc::new(NativeBackend::new())).unwrap();
            })
        })
        .collect()
}
