//! Executor parity — the acceptance gate of the "one pipeline, many
//! executors" redesign: the same input + seed must produce matching Σ/V
//! (and U up to column sign) whether the passes run on the in-process
//! [`LocalExecutor`] or on remote TCP workers via [`ClusterExecutor`].
//! Plus: the gram and randomized routes agree on a small dense matrix.

use tallfat::cluster::ClusterExecutor;
use tallfat::io::dataset::{gen_exact, Spectrum};
use tallfat::io::InputSpec;
use tallfat::linalg::Matrix;
use tallfat::svd::{LocalExecutor, ReduceMode, Svd, SvdResult};

mod harness;
use harness::{free_addr, spawn_flaky_worker, spawn_reduce_flaky_worker, spawn_workers};

fn dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("tallfat_parity_it").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Column-wise comparison up to sign: singular vectors are only defined up
/// to a per-column sign flip.
fn assert_cols_match_up_to_sign(a: &Matrix, b: &Matrix, tol: f64, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape mismatch");
    for j in 0..a.cols() {
        let dot: f64 = (0..a.rows()).map(|i| a.get(i, j) * b.get(i, j)).sum();
        let sign = if dot >= 0.0 { 1.0 } else { -1.0 };
        for i in 0..a.rows() {
            let diff = (a.get(i, j) - sign * b.get(i, j)).abs();
            assert!(
                diff < tol,
                "{what}[{i},{j}]: {} vs {} (sign {sign})",
                a.get(i, j),
                b.get(i, j)
            );
        }
    }
}

fn fixture(
    d: &std::path::Path,
    m: usize,
    n: usize,
    rank: usize,
    noise: f64,
    seed: u64,
) -> InputSpec {
    let (a, _) = gen_exact(
        m,
        n,
        rank,
        Spectrum::Geometric { scale: 10.0, decay: 0.65 },
        noise,
        seed,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();
    input
}

/// Generic-lifetime builder so local and cluster call sites each infer
/// their own executor borrow.
fn build<'a>(input: &InputSpec, work: String, k: usize, center: bool) -> Svd<'a> {
    Svd::over(input)
        .unwrap()
        .rank(k)
        .oversample(6)
        .workers(3)
        .block(32)
        .seed(77)
        .center(center)
        .work_dir(work)
}

fn assert_parity(local: &SvdResult, dist: &SvdResult, k: usize) {
    assert_eq!(local.k, k);
    assert_eq!(dist.k, k);
    // Σ: identical math, identical reduction order => near-bitwise equal.
    for i in 0..k {
        let rel = (local.sigma[i] - dist.sigma[i]).abs() / local.sigma[i].max(1e-300);
        assert!(rel < 1e-12, "sigma[{i}]: {} vs {}", local.sigma[i], dist.sigma[i]);
    }
    // V up to column sign.
    assert_cols_match_up_to_sign(
        local.v.as_ref().unwrap(),
        dist.v.as_ref().unwrap(),
        1e-9,
        "V",
    );
    // U (merged from shards) up to column sign.
    let ul = local.u_matrix().unwrap();
    let ud = dist.u_matrix().unwrap();
    assert_cols_match_up_to_sign(&ul, &ud, 1e-9, "U");
}

#[test]
fn local_and_cluster_executors_agree() {
    let d = dir("plain");
    let input = fixture(&d, 450, 24, 6, 0.005, 31);

    let addr = free_addr();
    let handles = spawn_workers(&addr, 3);
    let mut cluster = ClusterExecutor::accept(&addr, 3).unwrap();
    let dist = build(&input, d.join("dist").to_string_lossy().into_owned(), 6, false)
        .executor(&mut cluster)
        .run()
        .unwrap();
    cluster.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }

    // Explicit LocalExecutor through the same seam (not just the default).
    let mut local_exec = LocalExecutor::new(3);
    let local = build(&input, d.join("local").to_string_lossy().into_owned(), 6, false)
        .executor(&mut local_exec)
        .run()
        .unwrap();

    assert_parity(&local, &dist, 6);
}

/// PCA mode across the cluster: the centering pass (new PhaseKind) must
/// produce the same means and factors as the local executor.
#[test]
fn centered_parity_across_executors() {
    let d = dir("centered");
    let input = fixture(&d, 300, 18, 5, 0.0, 32);

    let addr = free_addr();
    let handles = spawn_workers(&addr, 2);
    let mut cluster = ClusterExecutor::accept(&addr, 2).unwrap();
    let dist = build(&input, d.join("dist").to_string_lossy().into_owned(), 5, true)
        .workers(2)
        .executor(&mut cluster)
        .run()
        .unwrap();
    cluster.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }
    let local = build(&input, d.join("local").to_string_lossy().into_owned(), 5, true)
        .workers(2)
        .run()
        .unwrap();

    let ml = local.means.as_ref().unwrap();
    let md = dist.means.as_ref().unwrap();
    assert_eq!(ml.len(), md.len());
    for (a, b) in ml.iter().zip(md.iter()) {
        assert!((a - b).abs() < 1e-12, "means drift: {a} vs {b}");
    }
    assert_parity(&local, &dist, 5);
}

/// The input's parse format travels on the wire: a binary file whose
/// extension would mis-guess as CSV must still run identically through
/// both executors (workers must not re-derive the format from the path).
#[test]
fn format_explicit_input_parity() {
    let d = dir("binfmt");
    let (a, _) = gen_exact(
        200,
        10,
        4,
        Spectrum::Geometric { scale: 5.0, decay: 0.6 },
        0.0,
        34,
    )
    .unwrap();
    // `.data` extension: InputFormat::from_path would wrongly say Csv.
    let input = InputSpec::bin(d.join("a.data").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();

    let addr = free_addr();
    let handles = spawn_workers(&addr, 2);
    let mut cluster = ClusterExecutor::accept(&addr, 2).unwrap();
    let dist = build(&input, d.join("dist").to_string_lossy().into_owned(), 4, false)
        .workers(2)
        .executor(&mut cluster)
        .run()
        .unwrap();
    cluster.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }
    let local = build(&input, d.join("local").to_string_lossy().into_owned(), 4, false)
        .workers(2)
        .run()
        .unwrap();
    assert_parity(&local, &dist, 4);
}

/// Fault injection: one of three workers completes a single chunk, then
/// dies with its next chunk in flight. The scheduler must requeue the
/// orphaned chunk onto the survivors and the run must still produce Σ/V/U
/// parity with the local executor — the acceptance gate of the dynamic
/// chunk scheduler.
#[test]
fn worker_killed_mid_pass_still_reaches_parity() {
    let d = dir("killed");
    let input = fixture(&d, 450, 24, 6, 0.005, 35);

    let addr = free_addr();
    let survivors = spawn_workers(&addr, 2);
    let flaky = spawn_flaky_worker(&addr, 1);
    let mut cluster = ClusterExecutor::accept(&addr, 3).unwrap();
    let dist = build(&input, d.join("dist").to_string_lossy().into_owned(), 6, false)
        .executor(&mut cluster)
        .run()
        .unwrap();
    assert!(cluster.workers() < 3, "the flaky worker should have been fenced");
    cluster.shutdown().unwrap();
    for h in survivors {
        h.join().unwrap();
    }
    flaky.join().unwrap();

    let mut local_exec = LocalExecutor::new(3);
    let local = build(&input, d.join("local").to_string_lossy().into_owned(), 6, false)
        .executor(&mut local_exec)
        .run()
        .unwrap();
    assert_parity(&local, &dist, 6);
}

/// A worker joining mid-run is handed the current phase setup and pulls
/// queued chunks; whatever it ends up doing, the factors must not change.
#[test]
fn late_joining_worker_preserves_parity() {
    let d = dir("latejoin");
    let input = fixture(&d, 12_000, 16, 5, 0.002, 36);

    let addr = free_addr();
    let handles = spawn_workers(&addr, 2);
    let mut cluster = ClusterExecutor::accept(&addr, 2).unwrap();
    // Joins a beat after the run starts — typically mid-pass. (If the run
    // finishes first the joiner just idles; parity must hold either way,
    // so the test is timing-robust.)
    let late_addr = addr.clone();
    let _late = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(30));
        let stream = std::net::TcpStream::connect(&late_addr)?;
        tallfat::cluster::worker::serve(stream, std::sync::Arc::new(
            tallfat::backend::native::NativeBackend::new(),
        ))
    });
    // `workers(2)` on both sides: the chunk plan is anchored to the
    // *initial* worker count, so local and cluster share one plan (and one
    // reduction order) no matter when the third worker joins.
    let dist = build(&input, d.join("dist").to_string_lossy().into_owned(), 5, false)
        .workers(2)
        .power_iters(1)
        .executor(&mut cluster)
        .run()
        .unwrap();
    cluster.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }
    // `_late` is deliberately not joined: if it registered it got the
    // shutdown; if the run beat it to the finish it parks on a dead socket.

    let local = build(&input, d.join("local").to_string_lossy().into_owned(), 5, false)
        .workers(2)
        .power_iters(1)
        .run()
        .unwrap();
    assert_parity(&local, &dist, 5);
}

/// The escape hatch still works end to end: with `--reduce star` both
/// executors fall back to the ship-everything fold and must still agree
/// with each other to the same tolerances as the default tree mode.
#[test]
fn star_mode_parity_across_executors() {
    let d = dir("star");
    let input = fixture(&d, 450, 24, 6, 0.005, 38);

    let addr = free_addr();
    let handles = spawn_workers(&addr, 3);
    let mut cluster = ClusterExecutor::accept(&addr, 3).unwrap();
    let dist = build(&input, d.join("dist").to_string_lossy().into_owned(), 6, false)
        .reduce(ReduceMode::Star)
        .executor(&mut cluster)
        .run()
        .unwrap();
    cluster.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }

    let mut local_exec = LocalExecutor::new(3);
    let local = build(&input, d.join("local").to_string_lossy().into_owned(), 6, false)
        .reduce(ReduceMode::Star)
        .executor(&mut local_exec)
        .run()
        .unwrap();
    assert_parity(&local, &dist, 6);
}

/// Fault injection in the *reduce* rounds: one of three workers completes
/// its chunks (holding its partials as tree leaves), then dies the moment
/// the first merge/fetch frame reaches it — its held leaves are gone. The
/// leader must restart the phase attempt on the survivors and the run must
/// still reach Σ/V/U parity with the local executor.
#[test]
fn worker_killed_mid_reduce_round_still_reaches_parity() {
    let d = dir("killed_reduce");
    let input = fixture(&d, 450, 24, 6, 0.005, 37);

    let addr = free_addr();
    let survivors = spawn_workers(&addr, 2);
    let flaky = spawn_reduce_flaky_worker(&addr);
    let mut cluster = ClusterExecutor::accept(&addr, 3).unwrap();
    let dist = build(&input, d.join("dist").to_string_lossy().into_owned(), 6, false)
        .executor(&mut cluster)
        .run()
        .unwrap();
    assert!(cluster.workers() < 3, "the reduce-flaky worker should have been fenced");
    cluster.shutdown().unwrap();
    for h in survivors {
        h.join().unwrap();
    }
    flaky.join().unwrap();

    let mut local_exec = LocalExecutor::new(3);
    let local = build(&input, d.join("local").to_string_lossy().into_owned(), 6, false)
        .executor(&mut local_exec)
        .run()
        .unwrap();
    assert_parity(&local, &dist, 6);
}

/// The tentpole acceptance gate: a factorization whose star-mode leader
/// state cannot fit under a hard memory cap must *fail* in star mode and
/// *succeed* in tree mode under the same cap — with the leader's tracked
/// reduce-state peak staying under the cap and `V` delivered as staged row
/// shards, never materialized leader-side. The factors must still match a
/// local oracle run.
#[test]
fn tree_reduce_completes_under_memory_cap_where_star_cannot() {
    let d = dir("memcap");
    let input = fixture(&d, 4000, 96, 8, 0.001, 39);
    const CAP: u64 = 64 * 1024;
    // power_iters stays 0: the power rounds' extra passes would ship
    // operands star-style regardless of the reduce plan.
    // Star partials for the W pass alone are chunks x (96 x 14 x 8B)
    // ~ 129 KiB with 12 chunks — over the cap by construction. Adaptive
    // re-planning is pinned off: the cap math (and the bitwise oracle
    // comparison) needs the static 12-chunk plan on every run.

    // Star route under the cap: must fail, naming the cap.
    {
        let addr = free_addr();
        let handles = spawn_workers(&addr, 3);
        let mut cluster = ClusterExecutor::accept(&addr, 3).unwrap();
        cluster.leader_mut().set_mem_cap(CAP);
        let r = build(&input, d.join("star").to_string_lossy().into_owned(), 8, false)
            .reduce(ReduceMode::Star)
            .adaptive_chunks(false)
            .executor(&mut cluster)
            .run();
        let err = match r {
            Ok(_) => panic!("star reduce must exceed a 64 KiB leader cap"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("memory cap exceeded"), "unexpected error: {err}");
        cluster.shutdown().unwrap();
        for h in handles {
            h.join().unwrap();
        }
    }

    // Tree route under the same cap: must complete, with the leader peak
    // actually measured under the cap.
    let addr = free_addr();
    let handles = spawn_workers(&addr, 3);
    let mut cluster = ClusterExecutor::accept(&addr, 3).unwrap();
    cluster.leader_mut().set_mem_cap(CAP);
    let dist = build(&input, d.join("tree").to_string_lossy().into_owned(), 8, false)
        .reduce(ReduceMode::Tree)
        .band_rows(32)
        .materialize_v(false)
        .adaptive_chunks(false)
        .executor(&mut cluster)
        .run()
        .unwrap();
    let peak = cluster.mem_peak();
    assert!(peak > 0, "gauge never saw reduce state");
    assert!(peak <= CAP, "tree leader peak {peak} bytes exceeds the {CAP} byte cap");
    cluster.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }

    // V was never materialized leader-side: it arrives as staged shards.
    assert!(dist.v.is_none(), "materialize_v(false) still produced a dense V");
    assert!(dist.v_shards.is_some() && dist.v_bands > 0, "V shards missing");

    // Oracle: local run, same seed, same band geometry.
    let local = build(&input, d.join("local").to_string_lossy().into_owned(), 8, false)
        .band_rows(32)
        .adaptive_chunks(false)
        .run()
        .unwrap();
    for i in 0..8 {
        let rel = (local.sigma[i] - dist.sigma[i]).abs() / local.sigma[i].max(1e-300);
        assert!(rel < 1e-12, "sigma[{i}]: {} vs {}", local.sigma[i], dist.sigma[i]);
    }
    let vl = local.v_matrix().unwrap();
    let vd = dist.v_matrix().unwrap();
    assert_cols_match_up_to_sign(&vl, &vd, 1e-9, "memcap V");
    let ul = local.u_matrix().unwrap();
    let ud = dist.u_matrix().unwrap();
    assert_cols_match_up_to_sign(&ul, &ud, 1e-9, "memcap U");
}

/// The two mathematical routes agree: on a small dense matrix whose rank
/// fits inside the sketch, the randomized pipeline recovers the exact-Gram
/// factors (Σ to high precision, V and U up to sign).
#[test]
fn gram_and_randomized_routes_agree() {
    let d = dir("routes");
    let input = fixture(&d, 220, 16, 5, 0.0, 33);

    let rand = build(&input, d.join("rand").to_string_lossy().into_owned(), 5, false)
        .run()
        .unwrap();
    let gram = build(&input, d.join("gram").to_string_lossy().into_owned(), 5, false)
        .exact_gram(true)
        .run()
        .unwrap();

    assert_eq!(rand.k, 5);
    assert_eq!(gram.k, 5);
    for i in 0..5 {
        let rel = (rand.sigma[i] - gram.sigma[i]).abs() / gram.sigma[i].max(1e-300);
        assert!(rel < 1e-7, "route sigma[{i}]: {} vs {}", rand.sigma[i], gram.sigma[i]);
    }
    assert_cols_match_up_to_sign(
        rand.v.as_ref().unwrap(),
        gram.v.as_ref().unwrap(),
        1e-6,
        "route V",
    );
    let ur = rand.u_matrix().unwrap();
    let ug = gram.u_matrix().unwrap();
    assert_cols_match_up_to_sign(&ur, &ug, 1e-6, "route U");
}

// ---------------------------------------------------------------------------
// sparse (CSR) input parity
// ---------------------------------------------------------------------------

/// Deterministic ~`density`-sparse fixture. Rows listed in `zero_rows` are
/// forced all-zero; column `n-1` and column `0` are pinned nonzero so the
/// text formats' scanned width equals `n`. Returns the dense oracle matrix
/// plus csv / libsvm / csr copies of it on disk.
fn sparse_fixture(
    d: &std::path::Path,
    m: usize,
    n: usize,
    density: f64,
    seed: u64,
    zero_rows: &[usize],
) -> (Matrix, InputSpec, InputSpec, InputSpec) {
    use tallfat::rng::splitmix::{mix3, to_unit_open};
    let g = tallfat::rng::Gaussian::new(seed);
    let mut a = Matrix::zeros(m, n);
    for i in 0..m {
        if zero_rows.contains(&i) {
            continue;
        }
        for j in 0..n {
            let u = to_unit_open(mix3(seed ^ 0xBEEF, i as u64, j as u64));
            let pinned = (i == 0 && (j == 0 || j == n - 1)) || j == i % n;
            if u < density || pinned {
                a.set(i, j, g.sample(i as u64, j as u64));
            }
        }
    }
    let dense = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &dense).unwrap();
    let libsvm = InputSpec::libsvm(d.join("a.libsvm").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &libsvm).unwrap();
    let csr = InputSpec::csr(d.join("a.csr").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &csr).unwrap();
    (a, dense, libsvm, csr)
}

/// Looser parity for densify-vs-CSR: the kernels differ in summation order
/// (blocked dense vs per-nonzero), so the factors agree to roundoff-scaled
/// tolerances, not bitwise.
fn assert_parity_loose(a: &SvdResult, b: &SvdResult, k: usize, what: &str) {
    assert_eq!(a.k, k, "{what}");
    assert_eq!(b.k, k, "{what}");
    for i in 0..k {
        let rel = (a.sigma[i] - b.sigma[i]).abs() / a.sigma[0].max(1e-300);
        assert!(rel < 1e-8, "{what} sigma[{i}]: {} vs {}", a.sigma[i], b.sigma[i]);
    }
    assert_cols_match_up_to_sign(
        a.v.as_ref().unwrap(),
        b.v.as_ref().unwrap(),
        1e-5,
        &format!("{what} V"),
    );
    let ua = a.u_matrix().unwrap();
    let ub = b.u_matrix().unwrap();
    assert_cols_match_up_to_sign(&ua, &ub, 1e-5, &format!("{what} U"));
}

/// Densify-vs-CSR factor parity on the LocalExecutor, centered and
/// uncentered, across the text (libsvm) and binary (csr) sparse formats.
#[test]
fn sparse_and_densified_inputs_agree_locally() {
    for center in [false, true] {
        let name = if center { "sparse_local_c" } else { "sparse_local" };
        let d = dir(name);
        let (_, dense, libsvm, csr) = sparse_fixture(&d, 260, 16, 0.12, 41, &[]);
        let run = |input: &InputSpec, sub: &str| {
            build(input, d.join(sub).to_string_lossy().into_owned(), 5, center)
                .run()
                .unwrap()
        };
        let from_dense = run(&dense, "dense");
        let from_libsvm = run(&libsvm, "libsvm");
        let from_csr = run(&csr, "csr");
        assert_parity_loose(&from_dense, &from_libsvm, 5, "libsvm");
        assert_parity_loose(&from_dense, &from_csr, 5, "csr");
        // Identical sparse math path in both sparse formats: near-bitwise.
        for i in 0..5 {
            let rel = (from_libsvm.sigma[i] - from_csr.sigma[i]).abs()
                / from_libsvm.sigma[i].max(1e-300);
            assert!(rel < 1e-12, "libsvm vs csr sigma[{i}]");
        }
    }
}

/// The same CSR input through remote workers: the cluster executor must
/// reproduce the local executor's sparse factors (Σ near-bitwise — same
/// kernels, same chunk-order reduction), centered and uncentered.
#[test]
fn sparse_parity_across_executors() {
    for center in [false, true] {
        let name = if center { "sparse_cluster_c" } else { "sparse_cluster" };
        let d = dir(name);
        let (_, _, _, csr) = sparse_fixture(&d, 300, 14, 0.15, 42, &[]);

        let addr = free_addr();
        let handles = spawn_workers(&addr, 2);
        let mut cluster = ClusterExecutor::accept(&addr, 2).unwrap();
        let dist = build(&csr, d.join("dist").to_string_lossy().into_owned(), 4, center)
            .workers(2)
            .executor(&mut cluster)
            .run()
            .unwrap();
        cluster.shutdown().unwrap();
        for h in handles {
            h.join().unwrap();
        }
        let local = build(&csr, d.join("local").to_string_lossy().into_owned(), 4, center)
            .workers(2)
            .run()
            .unwrap();
        assert_parity(&local, &dist, 4);
    }
}

/// Degenerate sparse inputs: all-zero rows (representable in libsvm and
/// csr) and a whole stripe of zero rows wide enough that some chunks'
/// shards hold nothing but zeros. The factorization must run, keep row
/// alignment (zero input rows → zero U rows), and still match the
/// densified oracle.
#[test]
fn sparse_degenerate_zero_rows_and_empty_chunks() {
    let d = dir("sparse_zeros");
    // Rows 40..60 all zero: with several chunks planned over 90 rows, at
    // least one chunk is entirely zero rows — its Y/U shards are all-zero
    // ("empty" content-wise) and must still publish and align.
    let zero_rows: Vec<usize> = (40..60).collect();
    let (a, dense, libsvm, csr) = sparse_fixture(&d, 90, 12, 0.2, 43, &zero_rows);
    for (input, sub) in [(&libsvm, "libsvm"), (&csr, "csr")] {
        let r = build(input, d.join(sub).to_string_lossy().into_owned(), 4, false)
            .workers(3)
            .run()
            .unwrap();
        assert_eq!(r.m, 90, "{sub}");
        let u = r.u_matrix().unwrap();
        assert_eq!(u.rows(), 90, "{sub}");
        for i in 40..60 {
            for j in 0..r.k {
                assert!(
                    u.get(i, j).abs() < 1e-9,
                    "{sub}: zero input row {i} produced U[{i},{j}] = {}",
                    u.get(i, j)
                );
            }
        }
        let dense_work = d.join(format!("{sub}_dense")).to_string_lossy().into_owned();
        let from_dense = build(&dense, dense_work, 4, false).workers(3).run().unwrap();
        assert_parity_loose(&from_dense, &r, 4, sub);
        let _ = &a;
    }
}
