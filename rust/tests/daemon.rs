//! `tallfatd` end-to-end: a fleet of named models behind one front door,
//! supervised update jobs over the control protocol, and the declarative
//! chaos scenarios the daemon exists to survive — a worker killed
//! mid-update, GC racing a reload, a drain with a job still queued, and a
//! restart with a job still queued. Every scenario must end with a
//! consistent published generation and zero failed queries.
//!
//! Run serially (`--test-threads=1`): each test binds its own ephemeral
//! port but shares the process-global metrics registry and thread budget.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};
use tallfat::backend::native::NativeBackend;
use tallfat::backend::BackendRef;
use tallfat::daemon::{Daemon, DaemonClient, DaemonOptions, JobSpec, Scenario};
use tallfat::io::dataset::{gen_exact, Spectrum};
use tallfat::io::InputSpec;
use tallfat::serve::json::Json;
use tallfat::svd::Svd;

fn dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("tallfat_daemon_it").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Factorize a small synthetic matrix into a servable model root.
fn build_model(d: &Path, tag: &str, m: usize, n: usize, seed: u64) -> PathBuf {
    let (a, _) = gen_exact(
        m,
        n,
        3,
        Spectrum::Geometric { scale: 5.0, decay: 0.6 },
        0.0,
        seed,
    )
    .unwrap();
    let spec = InputSpec::csv(d.join(format!("{tag}.csv")).to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &spec).unwrap();
    let model = d.join(format!("{tag}_model"));
    Svd::over(&spec)
        .unwrap()
        .rank(3)
        .workers(2)
        .block(32)
        .work_dir(d.join(format!("{tag}_work")).to_string_lossy().into_owned())
        .save_model(model.to_string_lossy().into_owned())
        .run()
        .unwrap();
    model
}

/// A row batch (same width as the model) for update jobs.
fn rows_batch(d: &Path, tag: &str, rows: usize, n: usize, seed: u64) -> String {
    let (b, _) = gen_exact(
        rows,
        n,
        3,
        Spectrum::Geometric { scale: 4.0, decay: 0.5 },
        0.0,
        seed,
    )
    .unwrap();
    let spec = InputSpec::csv(d.join(format!("{tag}.csv")).to_string_lossy().into_owned());
    tallfat::io::write_matrix(&b, &spec).unwrap();
    spec.path
}

fn query(op: &str, model: &str) -> Json {
    Json::obj(vec![("op", Json::str(op)), ("model", Json::str(model))])
}

/// The acceptance core: one daemon serves two named models concurrently,
/// completes an update job submitted over the control protocol, and the
/// new generation is visible to queries with no restart.
#[test]
fn daemon_serves_two_models_and_applies_update_live() {
    let d = dir("two_models");
    let alpha = build_model(&d, "alpha", 80, 10, 41);
    let beta = build_model(&d, "beta", 60, 8, 43);
    let rows = rows_batch(&d, "alpha_rows", 30, 10, 45);
    let backend: BackendRef = Arc::new(NativeBackend::new());
    let opts = DaemonOptions {
        addr: "127.0.0.1:0".to_string(),
        health_poll: Some(Duration::from_millis(150)),
        ..DaemonOptions::default()
    };
    let daemon = Daemon::bind(d.join("state"), backend, &opts).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || daemon.run());
    let client = DaemonClient::new(addr);

    client.register("alpha", &alpha.to_string_lossy()).unwrap();
    client.register("beta", &beta.to_string_lossy()).unwrap();
    let list = client.list().unwrap();
    assert_eq!(list.get("models").and_then(Json::as_array).unwrap().len(), 2);

    // One ND-JSON body interleaving both models — replies in input order,
    // each model batched on its own engine.
    let lines = vec![
        query("info", "alpha"),
        Json::obj(vec![
            ("op", Json::str("project")),
            ("model", Json::str("beta")),
            ("indices", Json::arr(vec![Json::num(0.0)])),
            ("values", Json::arr(vec![Json::num(1.0)])),
        ]),
        query("health", "alpha"),
        Json::obj(vec![
            ("op", Json::str("project")),
            ("model", Json::str("alpha")),
            ("row", Json::from_f64s(&[0.5; 10])),
        ]),
    ];
    let replies = client.call_many(&lines).unwrap();
    for r in &replies {
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "reply: {}", r.render());
    }
    assert!(replies[1].get("latent").is_some(), "sparse project should return a latent");
    assert_eq!(replies[0].get("m").and_then(Json::as_usize), Some(80));

    // Update alpha over the control protocol.
    let id = client.submit_job(&JobSpec::new("alpha", rows)).unwrap();
    let end = client.wait_job(id, Duration::from_secs(120)).unwrap();
    let job = end.get("job").unwrap();
    assert_eq!(job.get("state").and_then(Json::as_str), Some("done"), "{}", end.render());
    assert_eq!(job.get("generation").and_then(Json::as_usize), Some(1));

    // The publish hot-swaps into serving: generation 1 (and the grown row
    // count) become visible to queries with no daemon restart.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let health = client.call(&query("health", "alpha")).unwrap();
        if health.get("generation").and_then(Json::as_usize) == Some(1) {
            break;
        }
        assert!(Instant::now() < deadline, "generation 1 never became visible to queries");
        std::thread::sleep(Duration::from_millis(50));
    }
    let info = client.call(&query("info", "alpha")).unwrap();
    assert_eq!(info.get("m").and_then(Json::as_usize), Some(110));
    let beta_health = client.call(&query("health", "beta")).unwrap();
    assert_eq!(beta_health.get("generation").and_then(Json::as_usize), Some(0));

    client.drain().unwrap();
    server.join().unwrap().unwrap();
}

/// Chaos: the first update attempt dies mid-pass. The supervisor must
/// requeue it, the retry must publish, and queries must never notice.
#[test]
fn scenario_worker_killed_mid_update() {
    let d = dir("worker_kill");
    let model = build_model(&d, "movies", 80, 10, 51);
    let rows = rows_batch(&d, "rows", 30, 10, 53);
    let mut job = JobSpec::new("movies", rows);
    job.chaos_fail_passes = 1;
    let report = Scenario::new("worker_killed_mid_update")
        .state_dir(d.join("state"))
        .model("movies", &model)
        .workload(2)
        .submit_update(job)
        .await_jobs(120)
        .expect_all_jobs_done()
        .expect_zero_failed_queries()
        .expect_generation_at_least("movies", 1)
        .run()
        .unwrap();
    assert_eq!(report.queries_failed, 0);
    assert!(report.queries_ok > 0, "workload never got a query through");
    assert_eq!(report.jobs_done, 1);
}

/// Chaos: chained updates with `keep_generations=1`, so GC deletes the
/// old generation while the health poller is reloading under live
/// queries. The reload retry must always land on a live generation.
#[test]
fn scenario_gc_races_reload() {
    let d = dir("gc_reload");
    let model = build_model(&d, "movies", 80, 10, 61);
    let rows = rows_batch(&d, "rows", 25, 10, 63);
    let mut first = JobSpec::new("movies", rows.clone());
    first.keep_generations = 1;
    let mut second = JobSpec::new("movies", rows);
    second.keep_generations = 1;
    second.seed = 19;
    let report = Scenario::new("gc_races_reload")
        .state_dir(d.join("state"))
        .model("movies", &model)
        .workload(3)
        .health_poll_ms(100)
        .submit_update(first)
        .await_jobs(120)
        .sleep_ms(300) // let the poller observe (and swap past) the GC
        .submit_update(second)
        .await_jobs(120)
        .sleep_ms(300)
        .expect_all_jobs_done()
        .expect_zero_failed_queries()
        .expect_generation_at_least("movies", 2)
        .run()
        .unwrap();
    assert_eq!(report.queries_failed, 0);
    assert_eq!(report.jobs_done, 2);
    assert_eq!(report.generations["movies"], 2);
}

/// Chaos: drain arrives while a job is still queued (held by its delay).
/// Drain must finish the queued job before the daemon exits — the new
/// generation is on disk even though serving has stopped.
#[test]
fn scenario_drain_with_queued_job() {
    let d = dir("drain_queued");
    let model = build_model(&d, "movies", 80, 10, 71);
    let rows = rows_batch(&d, "rows", 20, 10, 73);
    let mut job = JobSpec::new("movies", rows);
    job.delay_ms = 700; // still queued when the drain lands
    let report = Scenario::new("drain_with_queued_job")
        .state_dir(d.join("state"))
        .model("movies", &model)
        .workload(2)
        .submit_update(job)
        .drain()
        .expect_zero_failed_queries()
        .expect_generation_at_least("movies", 1)
        .run()
        .unwrap();
    assert_eq!(report.queries_failed, 0);
    assert_eq!(report.generations["movies"], 1);
}

/// Chaos: the daemon is halted with a job still queued. The restarted
/// daemon must restore the fleet and the queue from its manifests and
/// complete the job — at-least-once across process death.
#[test]
fn scenario_restart_with_queued_job() {
    let d = dir("restart_queued");
    let model = build_model(&d, "movies", 80, 10, 81);
    let rows = rows_batch(&d, "rows", 20, 10, 83);
    let mut job = JobSpec::new("movies", rows);
    job.delay_ms = 60_000; // parked far past the halt; restart clears it
    let report = Scenario::new("restart_with_queued_job")
        .state_dir(d.join("state"))
        .model("movies", &model)
        .workload(2)
        .submit_update(job)
        .halt()
        .restart()
        .await_jobs(120)
        .expect_all_jobs_done()
        .expect_zero_failed_queries()
        .expect_generation_at_least("movies", 1)
        .run()
        .unwrap();
    assert_eq!(report.queries_failed, 0);
    assert_eq!(report.jobs_done, 1);
}
