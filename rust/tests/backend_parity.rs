//! Native-vs-XLA backend parity: every block op the pipeline uses must
//! agree across the two `Backend` implementations to f32 tolerance,
//! including on padded (short) blocks. Skipped cleanly if `artifacts/` has
//! not been built (CI without `make artifacts`).

use tallfat::backend::{native::NativeBackend, xla::XlaBackend, Backend};
use tallfat::linalg::{gram, Matrix};
use tallfat::rng::Gaussian;

fn xla() -> Option<XlaBackend> {
    match XlaBackend::start("artifacts", false) {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("skipping backend parity: {e}");
            None
        }
    }
}

fn rand(rows: usize, cols: usize, seed: u64) -> Matrix {
    let g = Gaussian::new(seed);
    Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
}

/// f32 tolerance scaled by the reduction length and magnitude.
fn tol(len: usize) -> f64 {
    3e-5 * (len as f64).sqrt().max(1.0)
}

#[test]
fn gram_parity_full_and_padded_blocks() {
    let Some(x) = xla() else { return };
    let native = NativeBackend::new();
    for n in [64usize, 256] {
        for rows in [256usize, 100, 1] {
            let a = rand(rows, n, 1);
            let g_n = native.gram_block(&a).unwrap();
            let g_x = x.gram_block(&a).unwrap();
            assert!(
                g_x.max_abs_diff(&g_n) < tol(rows) * 50.0,
                "gram n={n} rows={rows}: {}",
                g_x.max_abs_diff(&g_n)
            );
        }
    }
}

#[test]
fn project_parity() {
    let Some(x) = xla() else { return };
    let native = NativeBackend::new();
    for (n, k) in [(256usize, 32usize), (1024, 32)] {
        for rows in [256usize, 17] {
            let a = rand(rows, n, 2);
            let w = rand(n, k, 3);
            let y_n = native.project_block(&a, &w).unwrap();
            let y_x = x.project_block(&a, &w).unwrap();
            assert_eq!(y_x.shape(), (rows, k));
            assert!(y_x.max_abs_diff(&y_n) < tol(n), "project n={n} rows={rows}");
        }
    }
}

#[test]
fn fused_parity_and_consistency() {
    let Some(x) = xla() else { return };
    let native = NativeBackend::new();
    for n in [256usize, 1024, 2048] {
        let a = rand(256, n, 4);
        let w = rand(n, 32, 5);
        let (y_n, g_n) = native.project_gram_block(&a, &w).unwrap();
        let (y_x, g_x) = x.project_gram_block(&a, &w).unwrap();
        assert!(y_x.max_abs_diff(&y_n) < tol(n), "fused Y n={n}");
        // Gram entries are sums over 256 products of O(n)-magnitude values.
        assert!(g_x.max_abs_diff(&g_n) < tol(n) * 300.0, "fused G n={n}");
        // Internal consistency: G == gram(Y) on the xla outputs themselves.
        assert!(g_x.max_abs_diff(&gram(&y_x)) < tol(n) * 300.0, "fused self n={n}");
    }
}

#[test]
fn tmul_parity() {
    let Some(x) = xla() else { return };
    let native = NativeBackend::new();
    for n in [256usize, 1024, 2048] {
        let a = rand(256, n, 6);
        let z = rand(256, 32, 7);
        let w_n = native.tmul_block(&a, &z).unwrap();
        let w_x = x.tmul_block(&a, &z).unwrap();
        assert!(w_x.max_abs_diff(&w_n) < tol(256) * 20.0, "tmul n={n}");
    }
}

#[test]
fn urecover_parity() {
    let Some(x) = xla() else { return };
    let native = NativeBackend::new();
    for k in [16usize, 32] {
        let y = rand(256, k, 8);
        let m = rand(k, k, 9);
        let u_n = native.u_recover_block(&y, &m).unwrap();
        let u_x = x.u_recover_block(&y, &m).unwrap();
        assert!(u_x.max_abs_diff(&u_n) < tol(k) * 10.0, "urecover k={k}");
    }
}

#[test]
fn eigh_parity_eigenvalues_and_vectors() {
    let Some(x) = xla() else { return };
    let native = NativeBackend::new();
    for k in [16usize, 32, 64] {
        let base = rand(4 * k, k, 10);
        let psd = gram(&base);
        let (w_n, v_n) = native.eigh(&psd).unwrap();
        let (w_x, v_x) = x.eigh(&psd).unwrap();
        for i in 0..k {
            let rel = (w_n[i] - w_x[i]).abs() / w_n[0].max(1e-9);
            assert!(rel < 1e-4, "eigh k={k} eigval {i}: {} vs {}", w_n[i], w_x[i]);
        }
        // eigenvectors agree up to sign
        for j in 0..k {
            let dot: f64 = (0..k).map(|i| v_n.get(i, j) * v_x.get(i, j)).sum();
            assert!(dot.abs() > 0.98, "eigh k={k} eigvec {j}: |dot| = {}", dot.abs());
        }
    }
}

#[test]
fn auto_backend_falls_back_on_unknown_shapes() {
    let Ok(auto) = XlaBackend::start("artifacts", true) else { return };
    // n = 100 has no artifact: must succeed via native fallback.
    let a = rand(64, 100, 11);
    let g = auto.gram_block(&a).unwrap();
    let native = NativeBackend::new().gram_block(&a).unwrap();
    assert!(g.max_abs_diff(&native) < 1e-9);
    let (xla_calls, native_calls) = auto.call_counts();
    assert_eq!(xla_calls, 0);
    assert!(native_calls > 0);
}

#[test]
fn strict_backend_errors_on_unknown_shapes() {
    let Some(x) = xla() else { return };
    let a = rand(64, 100, 12);
    assert!(x.gram_block(&a).is_err());
}
