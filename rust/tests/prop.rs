//! Property-based tests on the system's core invariants.
//!
//! `proptest` is unavailable offline, so `support::Cases` provides the same
//! discipline by hand: a seeded, deterministic case generator sweeping a
//! randomized parameter space, with the failing seed printed on panic.

mod support;

use support::Cases;
use tallfat::backend::{native::NativeBackend, Backend};
use tallfat::linalg::{eigen::eigh, gram, gram_outer, matmul, qr::thin_qr, Matrix};
use tallfat::rng::{Gaussian, VirtualMatrix};
use tallfat::splitproc::{BlockJob, Blocked};

fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let g = Gaussian::new(seed);
    Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
}

/// `A^T A` from row outer products == blocked syrk == full matmul.
#[test]
fn prop_gram_paths_agree() {
    Cases::new(40, 0xA11CE).run(|case| {
        let m = case.usize_in(1, 200);
        let n = case.usize_in(1, 24);
        let a = rand_matrix(m, n, case.seed());
        let g_outer = gram_outer(&a);
        let g_syrk = gram(&a);
        let g_mm = matmul(&a.t(), &a).unwrap();
        let tol = 1e-9 * (m as f64).max(1.0);
        assert!(g_outer.max_abs_diff(&g_mm) < tol, "outer vs matmul: {case}");
        assert!(g_syrk.max_abs_diff(&g_mm) < tol, "syrk vs matmul: {case}");
    });
}

/// Zero-row padding leaves Gram/projection/tmul unchanged (the invariant
/// the fixed-shape XLA artifacts rely on).
#[test]
fn prop_zero_row_padding_is_identity() {
    Cases::new(40, 0xBEEF).run(|case| {
        let m = case.usize_in(1, 64);
        let n = case.usize_in(1, 16);
        let k = case.usize_in(1, 8);
        let pad = case.usize_in(1, 32);
        let a = rand_matrix(m, n, case.seed());
        let w = rand_matrix(n, k, case.seed() ^ 1);
        let mut padded = Matrix::zeros(m + pad, n);
        for i in 0..m {
            padded.row_mut(i).copy_from_slice(a.row(i));
        }
        assert!(gram(&padded).max_abs_diff(&gram(&a)) < 1e-12, "{case}");
        let y = matmul(&a, &w).unwrap();
        let y_pad = matmul(&padded, &w).unwrap();
        assert!(y_pad.slice_rows(0, m).max_abs_diff(&y) < 1e-12, "{case}");
        for i in m..m + pad {
            assert!(y_pad.row(i).iter().all(|&v| v == 0.0), "{case}");
        }
    });
}

/// The virtual Ω is deterministic and order/block independent.
#[test]
fn prop_virtual_matrix_deterministic() {
    Cases::new(30, 0xC0FFEE).run(|case| {
        let n = case.usize_in(1, 64);
        let k = case.usize_in(1, 16);
        let seed = case.seed();
        let vm = VirtualMatrix::projection(seed, n, k);
        let full = vm.materialize();
        // Block materialization at any split point agrees elementwise.
        let split = case.usize_in(0, n);
        let top = vm.materialize_rows(0, split);
        let bot = vm.materialize_rows(split, n - split);
        for i in 0..n {
            for j in 0..k {
                let want = full.get(i, j);
                let got = if i < split { top.get(i, j) } else { bot.get(i - split, j) };
                assert_eq!(want, got, "block vs full at ({i},{j}): {case}");
                assert_eq!(want, vm.element(i, j), "element vs full: {case}");
            }
        }
    });
}

/// Jacobi eigendecomposition: V orthonormal, A V = V diag(w), trace
/// preserved, descending order.
#[test]
fn prop_eigh_invariants() {
    Cases::new(30, 0xE16E).run(|case| {
        let n = case.usize_in(1, 24);
        let x = rand_matrix(n + case.usize_in(1, 20), n, case.seed());
        let a = gram(&x); // symmetric PSD
        let (w, v) = eigh(&a).unwrap();
        // descending
        for i in 1..n {
            assert!(w[i - 1] >= w[i] - 1e-9, "order: {case}");
        }
        // trace preserved
        let tr: f64 = (0..n).map(|i| a.get(i, i)).sum();
        let sw: f64 = w.iter().sum();
        assert!((tr - sw).abs() <= 1e-8 * tr.abs().max(1.0), "trace: {case}");
        // orthonormal V
        let vtv = matmul(&v.t(), &v).unwrap();
        assert!(vtv.max_abs_diff(&Matrix::eye(n)) < 1e-8, "orthonormality: {case}");
        // A V = V diag(w)
        let av = matmul(&a, &v).unwrap();
        let vw = v.scale_cols(&w).unwrap();
        let scale = w.first().copied().unwrap_or(1.0).abs().max(1.0);
        assert!(av.max_abs_diff(&vw) < 1e-7 * scale, "residual: {case}");
    });
}

/// Thin QR: Q orthonormal, QR = A.
#[test]
fn prop_qr_invariants() {
    Cases::new(30, 0x9A).run(|case| {
        let n = case.usize_in(1, 16);
        let m = n + case.usize_in(0, 48);
        let a = rand_matrix(m, n, case.seed());
        let (q, r) = thin_qr(&a).unwrap();
        let qtq = matmul(&q.t(), &q).unwrap();
        assert!(qtq.max_abs_diff(&Matrix::eye(n)) < 1e-9, "Q orth: {case}");
        let qr = matmul(&q, &r).unwrap();
        assert!(qr.max_abs_diff(&a) < 1e-9, "QR = A: {case}");
        // R upper triangular
        for i in 0..n {
            for j in 0..i {
                assert!(r.get(i, j).abs() < 1e-12, "R triangular: {case}");
            }
        }
    });
}

/// Blocked row-buffering delivers exactly the same blocks-sum as unblocked.
#[test]
fn prop_blocked_adapter_is_lossless() {
    struct Collect {
        rows_seen: usize,
        sum: f64,
    }
    impl BlockJob for Collect {
        fn exec_block(&mut self, block: &Matrix) -> tallfat::Result<()> {
            self.rows_seen += block.rows();
            self.sum += block.data().iter().sum::<f64>();
            Ok(())
        }
    }
    Cases::new(40, 0xB10C).run(|case| {
        let m = case.usize_in(1, 300);
        let n = case.usize_in(1, 8);
        let block = case.usize_in(1, 64);
        let a = rand_matrix(m, n, case.seed());
        let mut job = Blocked::new(Collect { rows_seen: 0, sum: 0.0 }, block, n);
        use tallfat::splitproc::RowJob;
        for i in 0..m {
            job.exec_row(a.row(i)).unwrap();
        }
        job.post().unwrap();
        let inner = job.into_inner();
        assert_eq!(inner.rows_seen, m, "{case}");
        let want: f64 = a.data().iter().sum();
        assert!((inner.sum - want).abs() < 1e-9 * (m as f64), "{case}");
    });
}

/// Native backend fused op == separate project + gram of the projection.
#[test]
fn prop_fused_equals_composed() {
    let backend = NativeBackend::new();
    Cases::new(30, 0xF5ED).run(|case| {
        let b = case.usize_in(1, 128);
        let n = case.usize_in(1, 32);
        let k = case.usize_in(1, 8);
        let x = rand_matrix(b, n, case.seed());
        let w = rand_matrix(n, k, case.seed() ^ 7);
        let (y_fused, g_fused) = backend.project_gram_block(&x, &w).unwrap();
        let y = backend.project_block(&x, &w).unwrap();
        let g = gram(&y);
        assert!(y_fused.max_abs_diff(&y) < 1e-10, "{case}");
        assert!(g_fused.max_abs_diff(&g) < 1e-9, "{case}");
    });
}

/// Random projection approximately preserves pairwise distances (JL):
/// statistical property, wide tolerance, but must hold for every seed.
#[test]
fn prop_jl_distance_preservation() {
    Cases::new(10, 0x11).run(|case| {
        let m = 40;
        let n = 64;
        let k = 48; // generous k for a tight-ish bound
        let a = rand_matrix(m, n, case.seed());
        let vm = VirtualMatrix::projection(case.seed() ^ 0xABCD, n, k);
        let omega = vm.materialize();
        let y = matmul(&a, &omega).unwrap();
        let (mean, max) = tallfat::svd::validate::distance_distortion(&a, &y, 200, 3);
        assert!(mean < 0.25, "mean distortion {mean}: {case}");
        assert!(max < 0.8, "max distortion {max}: {case}");
    });
}
