//! Connection-runtime integration: keep-alive across all three HTTP
//! planes, idle reaping (slowloris defense), malformed-head fuzz through
//! the one shared parser, admission-control shedding, and the daemon
//! client's pooled-connection reuse — all over real TCP sockets.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;
use tallfat::backend::native::NativeBackend;
use tallfat::backend::BackendRef;
use tallfat::daemon::{Daemon, DaemonClient, DaemonOptions};
use tallfat::io::dataset::{gen_exact, Spectrum};
use tallfat::io::InputSpec;
use tallfat::net::http::{HttpRequest, HttpResponse};
use tallfat::net::{NetHandler, NetOptions, NetServer};
use tallfat::serve::{
    EngineHandle, Json, ModelServer, ModelStore, QueryEngine, ServeOptions,
};
use tallfat::svd::Svd;
use tallfat::util::Args;

fn dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("tallfat_net_it").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Read exactly one Content-Length-framed response off a (possibly
/// keep-alive) socket. Returns (status, head, body).
fn read_response(s: &mut TcpStream) -> (u16, String, String) {
    let mut buf: Vec<u8> = Vec::new();
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p;
        }
        let mut chunk = [0u8; 4096];
        let n = s.read(&mut chunk).expect("read head");
        assert!(n > 0, "closed before a full head: {:?}", String::from_utf8_lossy(&buf));
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8(buf[..head_end].to_vec()).unwrap();
    let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
    let len: usize = head
        .lines()
        .find_map(|l| {
            let (name, v) = l.split_once(':')?;
            if name.eq_ignore_ascii_case("content-length") {
                v.trim().parse().ok()
            } else {
                None
            }
        })
        .expect("reply without Content-Length");
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < len {
        let mut chunk = [0u8; 4096];
        let n = s.read(&mut chunk).expect("read body");
        assert!(n > 0, "closed mid-body");
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(len);
    (status, head, String::from_utf8(body).unwrap())
}

/// The socket's next read reports a clean close (EOF) within 2s.
fn assert_closed(s: &mut TcpStream) {
    s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
    let mut chunk = [0u8; 64];
    match s.read(&mut chunk) {
        Ok(0) => {}
        Ok(n) => panic!("expected close, got {n} more bytes"),
        // A reset counts as closed: the peer tore down with bytes of ours
        // still unread (possible when it errors mid-head).
        Err(e) if e.kind() == std::io::ErrorKind::ConnectionReset => {}
        Err(e) => panic!("expected clean close, got {e}"),
    }
}

fn connect_retrying(addr: &str) -> TcpStream {
    for _ in 0..200 {
        if let Ok(s) = TcpStream::connect(addr) {
            return s;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("listener at {addr} never came up");
}

struct Echo;

impl NetHandler for Echo {
    fn handle(&self, req: HttpRequest) -> HttpResponse {
        HttpResponse::ok("text/plain", req.body)
    }
}

/// Pins a pool worker long enough for admission control to bite.
struct SlowEcho(Duration);

impl NetHandler for SlowEcho {
    fn handle(&self, req: HttpRequest) -> HttpResponse {
        std::thread::sleep(self.0);
        HttpResponse::ok("text/plain", req.body)
    }
}

fn post(path: &str, body: &str, close: bool) -> String {
    let conn = if close { "Connection: close\r\n" } else { "" };
    format!(
        "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n{conn}\r\n{body}",
        body.len()
    )
}

// ---------------------------------------------------------------------
// Keep-alive across the three planes
// ---------------------------------------------------------------------

/// Metrics plane: three sequential requests down ONE connection; the
/// first two stay open, the last closes because `--max-requests` is hit.
#[test]
fn metrics_plane_keep_alive_sequential() {
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap().to_string();
    drop(probe);
    let addr2 = addr.clone();
    let server = std::thread::spawn(move || {
        let args = Args::parse(
            ["serve-metrics", "--addr", &addr2, "--max-requests", "3"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        tallfat::coordinator::server::serve_metrics(&args).unwrap();
    });
    let mut s = connect_retrying(&addr);
    s.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let (status, head, body) = read_response(&mut s);
    assert_eq!(status, 200);
    assert_eq!(body, "ok\n");
    assert!(!head.contains("Connection: close"), "{head}");
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let (status, _, body) = read_response(&mut s);
    assert_eq!(status, 200);
    assert!(body.starts_with('#'), "{body}");
    s.write_all(b"GET /nope HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let (status, head, _) = read_response(&mut s);
    assert_eq!(status, 404);
    assert!(head.contains("Connection: close"), "final response must close: {head}");
    assert_closed(&mut s);
    server.join().unwrap();
}

/// Serve plane: one connection carries a GET, a POST query (whose
/// `health` op reports admission state), and another GET — and the
/// server counts exactly one accepted connection.
#[test]
fn serve_plane_keep_alive_one_connection() {
    let d = dir("serve_ka");
    let (a, _) = gen_exact(40, 8, 3, Spectrum::Geometric { scale: 5.0, decay: 0.6 }, 0.0, 7)
        .unwrap();
    let spec = InputSpec::csv(d.join("A.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &spec).unwrap();
    let result = Svd::over(&spec)
        .unwrap()
        .rank(3)
        .oversample(4)
        .workers(2)
        .block(16)
        .work_dir(d.join("work").to_string_lossy().into_owned())
        .backend(Arc::new(NativeBackend::new()))
        .run()
        .unwrap();
    let model_dir = d.join("model");
    result.save_model(&model_dir, Some(0)).unwrap();
    let store = Arc::new(ModelStore::open(&model_dir, 2).unwrap());
    let engine = Arc::new(QueryEngine::new(store, Arc::new(NativeBackend::new())).unwrap());
    let server = ModelServer::bind(
        Arc::new(EngineHandle::fixed(engine)),
        &ServeOptions {
            addr: "127.0.0.1:0".into(),
            max_requests: Some(3),
            ..ServeOptions::default()
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = server.handle();
    let srv = std::thread::spawn(move || server.run().unwrap());

    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"GET /model HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let (status, _, body) = read_response(&mut s);
    assert_eq!(status, 200);
    let info = Json::parse(body.trim()).unwrap();
    assert_eq!(info.get("m").and_then(Json::as_usize), Some(40));

    let q = "{\"op\":\"health\"}\n";
    s.write_all(post("/query", q, false).as_bytes()).unwrap();
    let (status, _, body) = read_response(&mut s);
    assert_eq!(status, 200);
    let health = Json::parse(body.trim()).unwrap();
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true), "{body}");
    let admission = health.get("admission").expect("health reply must report admission state");
    assert!(admission.get("in_flight").and_then(Json::as_f64).is_some(), "{body}");
    assert!(admission.get("queue_depth").and_then(Json::as_f64).is_some(), "{body}");
    assert!(admission.get("shed_total").and_then(Json::as_f64).is_some(), "{body}");

    s.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let (status, _, body) = read_response(&mut s);
    assert_eq!(status, 200);
    assert_eq!(body, "ok\n");
    assert_closed(&mut s);
    srv.join().unwrap();
    assert_eq!(handle.stats().accepted(), 1, "three requests must share one connection");
    assert_eq!(handle.stats().served(), 3);
}

/// Daemon plane: the client pools one keep-alive connection across many
/// calls (the daemon's accept counter barely moves), `/healthz` reports
/// admission state, and a server-side close is survived transparently.
#[test]
fn daemon_client_reuses_one_connection() {
    let d = dir("daemon_ka");
    let backend: BackendRef = Arc::new(NativeBackend::new());
    let opts = DaemonOptions { addr: "127.0.0.1:0".to_string(), ..DaemonOptions::default() };
    let daemon = Daemon::bind(d.join("state"), backend, &opts).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || daemon.run());

    let healthz = |addr: &str| -> Json {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
        let (status, _, body) = read_response(&mut s);
        assert_eq!(status, 200);
        Json::parse(body.trim()).unwrap()
    };

    let client = DaemonClient::new(addr.clone());
    client.status().unwrap();
    let h1 = healthz(&addr);
    let admission = h1.get("admission").expect("daemon /healthz must report admission state");
    assert!(admission.get("in_flight").and_then(Json::as_f64).is_some(), "{}", h1.render());
    assert!(admission.get("shed_total").and_then(Json::as_f64).is_some(), "{}", h1.render());
    let accepted1 = h1.get("accepted").and_then(Json::as_f64).unwrap();

    for _ in 0..10 {
        client.status().unwrap();
    }
    let h2 = healthz(&addr);
    let accepted2 = h2.get("accepted").and_then(Json::as_f64).unwrap();
    // Ten more client calls rode the pooled connection; only this probe's
    // own connection (and slack for scheduling) is new.
    assert!(
        accepted2 - accepted1 <= 2.0,
        "client opened new connections per call: accepted {accepted1} -> {accepted2}"
    );

    client.halt().unwrap();
    server.join().unwrap().unwrap();
}

// ---------------------------------------------------------------------
// Reaping, fuzz, and admission control on the bare runtime
// ---------------------------------------------------------------------

/// Slowloris defense: a connection stalled mid-head is reaped at the idle
/// deadline while a healthy connection on the same server keeps serving.
#[test]
fn stalled_connection_reaped_while_healthy_completes() {
    let nopts = NetOptions {
        idle_timeout: Duration::from_millis(250),
        plane: "test-reap",
        ..NetOptions::default()
    };
    let server = NetServer::bind("127.0.0.1:0", nopts).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = server.handle();
    let srv = std::thread::spawn(move || server.run(Arc::new(Echo)));

    let mut stalled = TcpStream::connect(&addr).unwrap();
    stalled.write_all(b"POST /e HT").unwrap(); // never finishes the head

    let mut healthy = TcpStream::connect(&addr).unwrap();
    for i in 0..4 {
        let body = format!("ping{i}");
        healthy.write_all(post("/e", &body, false).as_bytes()).unwrap();
        let (status, _, echoed) = read_response(&mut healthy);
        assert_eq!(status, 200);
        assert_eq!(echoed, body, "healthy connection must keep serving");
        std::thread::sleep(Duration::from_millis(120));
    }

    // ~480ms elapsed, idle deadline is 250ms: the stalled conn is gone.
    assert_closed(&mut stalled);
    assert!(handle.stats().reaped() >= 1, "reaped = {}", handle.stats().reaped());

    handle.shutdown();
    srv.join().unwrap().unwrap();
}

/// Malformed and truncated heads through the one shared parser: every
/// case gets its explicit status and a closed connection, none hang or
/// kill the server.
#[test]
fn malformed_heads_get_explicit_errors_and_server_survives() {
    let server = NetServer::bind(
        "127.0.0.1:0",
        NetOptions { plane: "test-fuzz", ..NetOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = server.handle();
    let srv = std::thread::spawn(move || server.run(Arc::new(Echo)));

    let huge_head = format!("GET /x HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(20_000));
    let cases: Vec<(String, u16)> = vec![
        ("GARBAGE\r\n\r\n".into(), 400),
        ("get /x HTTP/1.1\r\n\r\n".into(), 400),
        ("GET /x HTTP/2.0\r\n\r\n".into(), 400),
        ("POST /x HTTP/1.1\r\nno colon here\r\n\r\n".into(), 400),
        ("POST /x HTTP/1.1\r\nContent-Length: zork\r\n\r\n".into(), 400),
        ("POST /x HTTP/1.1\r\nContent-Length: 109951162777600\r\n\r\n".into(), 413),
        ("POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".into(), 501),
        (huge_head, 431),
    ];
    for (wire, want) in &cases {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(wire.as_bytes()).unwrap();
        let (status, head, _) = read_response(&mut s);
        assert_eq!(status, *want, "{}", wire.escape_debug());
        assert!(head.contains("Connection: close"), "protocol errors must close: {head}");
        assert_closed(&mut s);
    }

    // A head truncated by a client disconnect is dropped quietly.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"POST /x HT").unwrap();
    drop(s);

    // The server is unharmed: a healthy roundtrip still works.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(post("/e", "still alive", true).as_bytes()).unwrap();
    let (status, _, body) = read_response(&mut s);
    assert_eq!(status, 200);
    assert_eq!(body, "still alive");

    handle.shutdown();
    srv.join().unwrap().unwrap();
}

/// Admission control: with one warm handler and a one-deep queue, a burst
/// sheds — and every shed is an explicit, well-formed 503 with
/// `Retry-After` and a JSON body naming the reason. No resets.
#[test]
fn overload_sheds_are_explicit_503_json() {
    let server = NetServer::bind(
        "127.0.0.1:0",
        NetOptions {
            max_inflight: 1,
            max_queue: 1,
            plane: "test-shed",
            ..NetOptions::default()
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = server.handle();
    let srv =
        std::thread::spawn(move || server.run(Arc::new(SlowEcho(Duration::from_millis(300)))));

    let results: Vec<(u16, String, String)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..6)
            .map(|i| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut s = TcpStream::connect(&addr).unwrap();
                    s.write_all(post("/e", &format!("burst{i}"), true).as_bytes()).unwrap();
                    read_response(&mut s)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let mut sheds = 0u64;
    for (status, head, body) in &results {
        match status {
            200 => {}
            503 => {
                sheds += 1;
                assert!(head.contains("Retry-After:"), "shed without Retry-After: {head}");
                let line = Json::parse(body.trim()).expect("shed body must be valid JSON");
                assert_eq!(line.get("ok").and_then(Json::as_bool), Some(false), "{body}");
                assert_eq!(
                    line.get("error").and_then(Json::as_str),
                    Some("overloaded"),
                    "{body}"
                );
                let reason = line.get("reason").and_then(Json::as_str).unwrap_or("");
                assert!(
                    reason == "queue_full" || reason == "draining",
                    "unexpected shed reason {reason:?}"
                );
                assert!(line.get("retry_after_s").and_then(Json::as_f64).is_some(), "{body}");
            }
            other => panic!("unexpected status {other}"),
        }
    }
    assert!(sheds >= 1, "a 6-deep burst into inflight=1/queue=1 must shed");
    assert!(handle.stats().shed_total() >= sheds, "stats lost sheds");

    handle.shutdown();
    srv.join().unwrap().unwrap();
}
