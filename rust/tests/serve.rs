//! Serve-layer integration: model save→load→query over real HTTP, checked
//! against oracles computed with `linalg` directly from the model files.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::config::InputFormat;
use tallfat::coordinator::run_cli;
use tallfat::io::dataset::{gen_exact, Spectrum};
use tallfat::io::{InputSpec, ShardSet};
use tallfat::linalg::{matmul, Matrix};
use tallfat::serve::{EngineHandle, Json, ModelServer, ModelStore, QueryEngine, ServeOptions};
use tallfat::svd::Svd;
use tallfat::update::Update;
use tallfat::util::Args;

mod harness;
use harness::free_addr;

fn dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("tallfat_serve_it").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn http_request(addr: &str, request: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    resp
}

fn http_post_query(addr: &str, body: &str) -> String {
    // `Connection: close` keeps read_to_string finite under keep-alive.
    let req = format!(
        "POST /query HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    http_request(addr, &req)
}

fn body_of(resp: &str) -> &str {
    resp.split("\r\n\r\n").nth(1).unwrap_or("")
}

/// Oracle built with `linalg` straight from the model directory files —
/// shares no code path with the serving engine's backend dispatch.
struct Oracle {
    u: Matrix,
    sigma: Vec<f64>,
    w: Matrix, // V Σ⁻¹ (n x k)
    means: Option<Vec<f64>>,
}

impl Oracle {
    fn from_model_dir(model_dir: &std::path::Path) -> Oracle {
        let store = ModelStore::open(model_dir, 64).unwrap();
        // U shards live in the resolved generation directory.
        let u = ShardSet::new(store.dir(), "U", InputFormat::Bin)
            .unwrap()
            .merge_to_matrix(store.shards())
            .unwrap();
        let sigma = store.sigma().to_vec();
        let smax = sigma[0].max(1e-300);
        let inv: Vec<f64> =
            sigma.iter().map(|&s| if s > 1e-12 * smax { 1.0 / s } else { 0.0 }).collect();
        let w = store.v().scale_cols(&inv).unwrap();
        let means = store.means().map(|m| m.to_vec());
        Oracle { u, sigma, w, means }
    }

    fn project(&self, row: &[f64]) -> Vec<f64> {
        let centered: Vec<f64> = match &self.means {
            Some(mu) => row.iter().zip(mu.iter()).map(|(x, m)| x - m).collect(),
            None => row.to_vec(),
        };
        let x = Matrix::from_rows(&[centered]).unwrap();
        matmul(&x, &self.w).unwrap().row(0).to_vec()
    }

    /// Brute-force cosine top-k over the `u_i ∘ σ` embeddings.
    fn topk(&self, latent: &[f64], k: usize) -> Vec<(usize, f64)> {
        let qnorm: f64 = latent.iter().map(|v| v * v).sum::<f64>().sqrt();
        let mut scored: Vec<(usize, f64)> = (0..self.u.rows())
            .map(|i| {
                let e: Vec<f64> =
                    self.u.row(i).iter().zip(self.sigma.iter()).map(|(u, s)| u * s).collect();
                let dot: f64 = e.iter().zip(latent.iter()).map(|(a, b)| a * b).sum();
                let enorm: f64 = e.iter().map(|v| v * v).sum::<f64>().sqrt();
                let denom = enorm * qnorm;
                (i, if denom > 0.0 { dot / denom } else { 0.0 })
            })
            .collect();
        scored.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        scored.truncate(k);
        scored
    }
}

fn parse_hits(line: &Json) -> Vec<(usize, f64)> {
    line.get("hits")
        .and_then(Json::as_array)
        .unwrap()
        .iter()
        .map(|h| {
            (
                h.get("row").and_then(Json::as_usize).unwrap(),
                h.get("score").and_then(Json::as_f64).unwrap(),
            )
        })
        .collect()
}

#[test]
fn model_server_answers_queries_matching_linalg_oracle() {
    let d = dir("server");
    // Tiny synthetic model from io::dataset.
    let (a, _) = gen_exact(
        150,
        20,
        5,
        Spectrum::Geometric { scale: 10.0, decay: 0.6 },
        0.01,
        7,
    )
    .unwrap();
    let spec = InputSpec::csv(d.join("A.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &spec).unwrap();
    let result = Svd::over(&spec)
        .unwrap()
        .rank(6)
        .oversample(6)
        .workers(3)
        .block(32)
        .work_dir(d.join("work").to_string_lossy().into_owned())
        .backend(Arc::new(NativeBackend::new()))
        .run()
        .unwrap();
    let model_dir = d.join("model");
    result.save_model(&model_dir, Some(0)).unwrap();

    let store = Arc::new(ModelStore::open(&model_dir, 2).unwrap());
    let engine = Arc::new(QueryEngine::new(store, Arc::new(NativeBackend::new())).unwrap());
    let server = ModelServer::bind(
        Arc::new(EngineHandle::fixed(engine)),
        &ServeOptions {
            addr: "127.0.0.1:0".into(),
            max_requests: Some(4),
            ..ServeOptions::default()
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run().unwrap());

    // 1. model info.
    let resp = http_request(&addr, "GET /model HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
    assert!(resp.contains("200 OK"), "{resp}");
    let info = Json::parse(body_of(&resp).trim()).unwrap();
    assert_eq!(info.get("m").and_then(Json::as_usize), Some(150));
    assert_eq!(info.get("k").and_then(Json::as_usize), Some(6));
    assert_eq!(info.get("generation").and_then(Json::as_usize), Some(0));

    // 2. a batch of ND-JSON queries in one POST.
    let qrow = a.row(33);
    let row_json = Json::from_f64s(qrow).render();
    let body = format!(
        "{{\"op\":\"project\",\"row\":{row_json}}}\n\
         {{\"op\":\"similar\",\"row\":{row_json},\"k\":7}}\n\
         {{\"op\":\"reconstruct\",\"row_id\":33}}\n\
         {{\"op\":\"info\"}}\n\
         {{\"op\":\"nope\"}}\n\
         not even json\n"
    );
    let resp = http_post_query(&addr, &body);
    assert!(resp.contains("200 OK"), "{resp}");
    let lines: Vec<Json> =
        body_of(&resp).lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(lines.len(), 6);

    let oracle = Oracle::from_model_dir(&model_dir);

    // project matches the linalg oracle within 1e-6.
    assert_eq!(lines[0].get("ok"), Some(&Json::Bool(true)));
    let latent = lines[0].get("latent").and_then(Json::as_f64_array).unwrap();
    let want_latent = oracle.project(qrow);
    assert_eq!(latent.len(), want_latent.len());
    for (g, w) in latent.iter().zip(want_latent.iter()) {
        assert!((g - w).abs() < 1e-6, "{g} vs {w}");
    }

    // cosine top-k identical to the oracle's ranking.
    assert_eq!(lines[1].get("ok"), Some(&Json::Bool(true)));
    let hits = parse_hits(&lines[1]);
    let want = oracle.topk(&want_latent, 7);
    assert_eq!(
        hits.iter().map(|h| h.0).collect::<Vec<_>>(),
        want.iter().map(|h| h.0).collect::<Vec<_>>()
    );
    for (g, w) in hits.iter().zip(want.iter()) {
        assert!((g.1 - w.1).abs() < 1e-9);
    }
    assert_eq!(hits[0].0, 33, "a model row must be its own nearest neighbor");

    // reconstruct approximates the input row (noise-limited).
    let values = lines[2].get("values").and_then(Json::as_f64_array).unwrap();
    let err: f64 =
        values.iter().zip(qrow.iter()).map(|(g, w)| (g - w) * (g - w)).sum::<f64>().sqrt();
    let scale: f64 = qrow.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(err < 0.05 * scale.max(1.0), "reconstruct err {err} vs scale {scale}");

    // info + error lines.
    assert_eq!(lines[3].get("m").and_then(Json::as_usize), Some(150));
    assert_eq!(lines[4].get("ok"), Some(&Json::Bool(false)));
    assert_eq!(lines[5].get("ok"), Some(&Json::Bool(false)));

    // 3. metrics flowed into the shared registry.
    let resp =
        http_request(&addr, "GET /metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
    assert!(resp.contains("tallfat_serve_requests_total"), "{resp}");
    assert!(resp.contains("tallfat_serve_qps"), "{resp}");
    assert!(resp.contains("tallfat_serve_request_ms_bucket{le="), "{resp}");
    assert!(resp.contains("tallfat_serve_request_ms_count"), "{resp}");

    // 4. a hostile Content-Length is rejected, not allocated.
    let resp = http_request(
        &addr,
        "POST /query HTTP/1.1\r\nHost: x\r\nContent-Length: 109951162777600\r\n\r\n",
    );
    assert!(resp.contains("413"), "{resp}");
    srv.join().unwrap();
}

#[test]
fn cli_svd_save_model_then_serve_roundtrip() {
    let d = dir("cli");
    let run = |tokens: &[&str]| {
        run_cli(&Args::parse(tokens.iter().map(|s| s.to_string())).unwrap())
    };
    let input = d.join("a.csv").to_string_lossy().into_owned();
    run(&[
        "gen-data", "--out", &input, "--rows", "200", "--cols", "16", "--rank", "4", "--noise",
        "0.01",
    ])
    .unwrap();
    let work = d.join("work").to_string_lossy().into_owned();
    let model = d.join("model").to_string_lossy().into_owned();
    run(&[
        "svd", "--input", &input, "--k", "5", "--workers", "2", "--work-dir", &work,
        "--save-model", &model,
    ])
    .unwrap();
    assert!(d.join("model").join("CURRENT").exists());
    assert!(d.join("model").join("gen-000000").join("model.manifest").exists());

    let addr = free_addr();
    let addr2 = addr.clone();
    let model2 = model.clone();
    let srv = std::thread::spawn(move || {
        run(&[
            "serve", &model2, "--addr", &addr2, "--max-requests", "1", "--batch-window-ms", "0",
        ])
        .unwrap();
    });

    let a = tallfat::io::read_matrix(&InputSpec::auto(input)).unwrap();
    let qrow = a.row(12);
    let row_json = Json::from_f64s(qrow).render();
    let body = format!(
        "{{\"op\":\"project\",\"row\":{row_json}}}\n{{\"op\":\"similar\",\"row\":{row_json},\"k\":5}}\n"
    );
    let request = format!(
        "POST /query HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    // Retry until the listener is up.
    let mut resp = String::new();
    for _ in 0..200 {
        if let Ok(mut s) = TcpStream::connect(&addr) {
            s.write_all(request.as_bytes()).unwrap();
            s.read_to_string(&mut resp).unwrap();
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    srv.join().unwrap();
    assert!(resp.contains("200 OK"), "{resp}");
    let lines: Vec<Json> = body_of(&resp).lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(lines.len(), 2);

    let oracle = Oracle::from_model_dir(std::path::Path::new(&model));
    let latent = lines[0].get("latent").and_then(Json::as_f64_array).unwrap();
    let want_latent = oracle.project(qrow);
    for (g, w) in latent.iter().zip(want_latent.iter()) {
        assert!((g - w).abs() < 1e-6, "projection {g} vs oracle {w}");
    }
    let hits = parse_hits(&lines[1]);
    let want = oracle.topk(&want_latent, 5);
    assert_eq!(
        hits.iter().map(|h| h.0).collect::<Vec<_>>(),
        want.iter().map(|h| h.0).collect::<Vec<_>>(),
        "cosine top-k must match the linalg oracle exactly"
    );
    assert_eq!(hits[0].0, 12);
}

#[test]
fn concurrent_http_clients_are_batched_and_correct() {
    let d = dir("concurrent");
    let (a, _) = gen_exact(
        100,
        12,
        4,
        Spectrum::Geometric { scale: 6.0, decay: 0.5 },
        0.0,
        3,
    )
    .unwrap();
    let spec = InputSpec::csv(d.join("A.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &spec).unwrap();
    let result = Svd::over(&spec)
        .unwrap()
        .rank(4)
        .oversample(4)
        .workers(2)
        .block(32)
        .work_dir(d.join("work").to_string_lossy().into_owned())
        .backend(Arc::new(NativeBackend::new()))
        .run()
        .unwrap();
    let model_dir = d.join("model");
    result.save_model(&model_dir, None).unwrap();
    let store = Arc::new(ModelStore::open(&model_dir, 2).unwrap());
    let engine = Arc::new(QueryEngine::new(store, Arc::new(NativeBackend::new())).unwrap());

    const CLIENTS: usize = 6;
    let server = ModelServer::bind(
        Arc::new(EngineHandle::fixed(engine)),
        &ServeOptions {
            addr: "127.0.0.1:0".into(),
            max_requests: Some(CLIENTS as u64),
            ..ServeOptions::default()
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run().unwrap());

    let oracle = Oracle::from_model_dir(&model_dir);
    let responses: Vec<(usize, Json)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|i| {
                let addr = addr.clone();
                let row_json = Json::from_f64s(a.row(i * 15)).render();
                scope.spawn(move || {
                    let body = format!("{{\"op\":\"similar\",\"row\":{row_json},\"k\":3}}\n");
                    let resp = http_post_query(&addr, &body);
                    assert!(resp.contains("200 OK"), "{resp}");
                    (i, Json::parse(body_of(&resp).trim()).unwrap())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    srv.join().unwrap();
    for (i, line) in responses {
        let hits = parse_hits(&line);
        let want = oracle.topk(&oracle.project(a.row(i * 15)), 3);
        assert_eq!(
            hits.iter().map(|h| h.0).collect::<Vec<_>>(),
            want.iter().map(|h| h.0).collect::<Vec<_>>(),
            "client {i}"
        );
        assert_eq!(hits[0].0, i * 15);
    }
}

/// The zero-downtime lifecycle: a serving process answers queries against
/// generation 0, an incremental update lands generation 1 on disk, a
/// `reload` line hot-swaps the live engine, and subsequent responses show
/// the generation (and row count) advancing — all on one server, never
/// restarted.
#[test]
fn queries_survive_hot_swap_and_generation_advances() {
    let d = dir("hotswap");
    let (a, _) = gen_exact(
        160,
        16,
        4,
        Spectrum::Geometric { scale: 9.0, decay: 0.55 },
        0.0,
        13,
    )
    .unwrap();
    let base = InputSpec::csv(d.join("A0.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a.slice_rows(0, 120), &base).unwrap();
    let batch = InputSpec::csv(d.join("A1.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a.slice_rows(120, 160), &batch).unwrap();

    let model_dir = d.join("model");
    Svd::over(&base)
        .unwrap()
        .rank(6)
        .oversample(6)
        .workers(2)
        .block(32)
        .work_dir(d.join("work").to_string_lossy().into_owned())
        .backend(Arc::new(NativeBackend::new()))
        .save_model(model_dir.to_string_lossy().into_owned())
        .run()
        .unwrap();

    let engines = Arc::new(
        EngineHandle::open(&model_dir, 2, Arc::new(NativeBackend::new())).unwrap(),
    );
    let server = ModelServer::bind(
        engines,
        &ServeOptions {
            addr: "127.0.0.1:0".into(),
            max_requests: Some(3),
            // Deterministic swap points: only the explicit reload op below
            // may advance the generation, never a background poll.
            reload_poll: None,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run().unwrap());

    // 1. before the update: generation 0, 120 rows, queries answer.
    let row_json = Json::from_f64s(a.row(5)).render();
    let body =
        format!("{{\"op\":\"info\"}}\n{{\"op\":\"similar\",\"row\":{row_json},\"k\":3}}\n");
    let resp = http_post_query(&addr, &body);
    let lines: Vec<Json> = body_of(&resp).lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(lines[0].get("generation").and_then(Json::as_usize), Some(0));
    assert_eq!(lines[0].get("m").and_then(Json::as_usize), Some(120));
    assert_eq!(parse_hits(&lines[1])[0].0, 5);

    // 2. the update lands generation 1 on disk while the server runs.
    let next = Update::of(&model_dir)
        .unwrap()
        .rows(&batch)
        .workers(2)
        .block(32)
        .seed(3)
        .work_dir(d.join("work_update").to_string_lossy().into_owned())
        .backend(Arc::new(NativeBackend::new()))
        .run()
        .unwrap();
    assert_eq!(next.generation, 1);

    // 3. reload hot-swaps; the same body then queries the new generation —
    //    including a similarity hit on a row that only exists post-update.
    let new_row_json = Json::from_f64s(a.row(150)).render();
    let body = format!(
        "{{\"op\":\"reload\"}}\n{{\"op\":\"info\"}}\n{{\"op\":\"similar\",\"row\":{new_row_json},\"k\":3}}\n"
    );
    let resp = http_post_query(&addr, &body);
    let lines: Vec<Json> = body_of(&resp).lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(lines[0].get("ok"), Some(&Json::Bool(true)));
    assert_eq!(lines[0].get("swapped"), Some(&Json::Bool(true)));
    assert_eq!(lines[0].get("generation").and_then(Json::as_usize), Some(1));
    // The info line of the same body still answers from its snapshot
    // (generation 0) — in-flight bodies are never torn mid-generation.
    assert_eq!(lines[1].get("generation").and_then(Json::as_usize), Some(0));
    // Batched queries go through the handle and see the new generation:
    // row 150 exists only in generation 1 (index 150 of 160).
    let hits = parse_hits(&lines[2]);
    assert_eq!(hits[0].0, 150, "new-generation row must be its own nearest neighbor");

    // 4. a fresh body sees generation 1 everywhere.
    let resp = http_post_query(&addr, "{\"op\":\"info\"}\n");
    let info = Json::parse(body_of(&resp).trim()).unwrap();
    assert_eq!(info.get("generation").and_then(Json::as_usize), Some(1));
    assert_eq!(info.get("m").and_then(Json::as_usize), Some(160));
    srv.join().unwrap();

    // 5. serve_reloads flowed into the registry.
    let reloads = tallfat::coordinator::server::MetricsRegistry::global()
        .get("serve_reloads")
        .unwrap_or(0.0);
    assert!(reloads >= 1.0, "serve_reloads = {reloads}");
}

/// Cumulative `tallfat_serve_request_ms_bucket{le="..."}` counts parsed
/// from one exposition render (`text`), plus the series `_count`.
fn parse_request_ms_buckets(text: &str) -> (Vec<(f64, u64)>, u64) {
    let mut buckets = Vec::new();
    let mut count = 0u64;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("tallfat_serve_request_ms_bucket{le=\"") {
            let (le, c) = rest.split_once("\"} ").unwrap();
            if le != "+Inf" {
                buckets.push((le.parse::<f64>().unwrap(), c.trim().parse::<u64>().unwrap()));
            }
        } else if let Some(rest) = line.strip_prefix("tallfat_serve_request_ms_count ") {
            count = rest.trim().parse::<u64>().unwrap();
        }
    }
    (buckets, count)
}

/// Acceptance: the p99 recomputed from `/metrics`' cumulative `_bucket`
/// counts must agree with the registry's `quantile(0.99)` to within one
/// bucket width. The registry is process-global and other serve tests
/// observe into the same series concurrently, so the check only compares
/// snapshots whose `_count` did not move between renders.
#[test]
fn serve_request_ms_p99_from_rendered_buckets_matches_quantile() {
    let d = dir("p99");
    let (a, _) = gen_exact(
        80,
        10,
        3,
        Spectrum::Geometric { scale: 5.0, decay: 0.6 },
        0.0,
        11,
    )
    .unwrap();
    let spec = InputSpec::csv(d.join("A.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &spec).unwrap();
    let result = Svd::over(&spec)
        .unwrap()
        .rank(3)
        .oversample(4)
        .workers(2)
        .block(16)
        .work_dir(d.join("work").to_string_lossy().into_owned())
        .backend(Arc::new(NativeBackend::new()))
        .run()
        .unwrap();
    let model_dir = d.join("model");
    result.save_model(&model_dir, Some(0)).unwrap();
    let store = Arc::new(ModelStore::open(&model_dir, 2).unwrap());
    let engine = Arc::new(QueryEngine::new(store, Arc::new(NativeBackend::new())).unwrap());
    let server = ModelServer::bind(
        Arc::new(EngineHandle::fixed(engine)),
        &ServeOptions {
            addr: "127.0.0.1:0".into(),
            max_requests: Some(4),
            ..ServeOptions::default()
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run().unwrap());

    // Three bodies of varied sizes so the histogram sees a spread of
    // per-line latencies rather than one repeated value.
    for lines in [1usize, 8, 20] {
        let mut body = String::new();
        for i in 0..lines {
            let row_json = Json::from_f64s(a.row(i * 3)).render();
            body.push_str(&format!("{{\"op\":\"project\",\"row\":{row_json}}}\n"));
        }
        let resp = http_post_query(&addr, &body);
        assert!(resp.contains("200 OK"), "{resp}");
    }

    // The live endpoint exposes the histogram series.
    let resp =
        http_request(&addr, "GET /metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
    srv.join().unwrap();
    assert!(resp.contains("tallfat_serve_request_ms_bucket{le="), "{resp}");

    // Recompute p99 from the exposition and compare against quantile(),
    // retrying until a quiescent snapshot (count stable across renders).
    let reg = tallfat::coordinator::server::MetricsRegistry::global();
    let mut checked = false;
    for _ in 0..50 {
        let text = reg.render();
        let q99 = reg.quantile("serve_request_ms", 0.99).unwrap();
        let (buckets, count) = parse_request_ms_buckets(&text);
        let (buckets2, count2) = parse_request_ms_buckets(&reg.render());
        if count == 0 || count != count2 || buckets != buckets2 {
            std::thread::sleep(std::time::Duration::from_millis(20));
            continue;
        }
        // Nearest-cross rule over the cumulative counts, exactly what a
        // Prometheus histogram_quantile would resolve to at bucket level.
        let target = ((0.99 * count as f64).ceil() as u64).max(1);
        let hit = buckets.iter().position(|&(_, c)| c >= target).unwrap();
        let edge = buckets[hit].0;
        let prev = if hit == 0 { 0.0 } else { buckets[hit - 1].0 };
        let width = edge - prev;
        assert!(
            q99 >= prev - 1e-9 && q99 <= edge + 1e-9,
            "quantile(0.99) = {q99} outside its exposition bucket ({prev}, {edge}]"
        );
        assert!((q99 - edge).abs() <= width + 1e-9, "p99 off by more than one bucket width");
        checked = true;
        break;
    }
    assert!(checked, "serve_request_ms never quiesced for a stable snapshot");
}

/// Malformed or truncated ND-JSON bodies must come back as per-line JSON
/// parse errors — never a killed connection thread — and must not poison
/// any serve-layer state for later requests.
#[test]
fn malformed_bodies_get_json_errors_and_server_survives() {
    let d = dir("malformed");
    let (a, _) = gen_exact(
        60,
        8,
        3,
        Spectrum::Geometric { scale: 5.0, decay: 0.6 },
        0.0,
        13,
    )
    .unwrap();
    let spec = InputSpec::csv(d.join("A.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &spec).unwrap();
    let result = Svd::over(&spec)
        .unwrap()
        .rank(3)
        .oversample(4)
        .workers(2)
        .block(16)
        .work_dir(d.join("work").to_string_lossy().into_owned())
        .backend(Arc::new(NativeBackend::new()))
        .run()
        .unwrap();
    let model_dir = d.join("model");
    result.save_model(&model_dir, Some(0)).unwrap();

    let store = Arc::new(ModelStore::open(&model_dir, 2).unwrap());
    let engine = Arc::new(QueryEngine::new(store, Arc::new(NativeBackend::new())).unwrap());
    let server = ModelServer::bind(
        Arc::new(EngineHandle::fixed(engine)),
        &ServeOptions {
            addr: "127.0.0.1:0".into(),
            max_requests: Some(2),
            ..ServeOptions::default()
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run().unwrap());

    // One body mixing truncated JSON, bad escapes, an unterminated string,
    // a valid-JSON-but-failing op, and finally a healthy query.
    let good = format!("{{\"op\":\"project\",\"row\":{}}}", Json::from_f64s(a.row(0)).render());
    let bads = [
        r#"{"op":"similar","row":[1.0"#,       // truncated mid-array
        r#"{"op":"project","row":"\u12"}"#,    // truncated \u escape
        r#""unterminated"#,                    // unterminated string
        r#"{"op":"reconstruct","row_id":99999}"#, // parses; engine rejects
    ];
    let body = format!("{}\n{good}\n", bads.join("\n"));
    let resp = http_post_query(&addr, &body);
    assert!(resp.contains("200 OK"), "{resp}");
    let lines: Vec<Json> =
        body_of(&resp).lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(lines.len(), 5, "one response object per input line");
    for (i, line) in lines.iter().take(4).enumerate() {
        assert_eq!(
            line.get("ok").and_then(Json::as_bool),
            Some(false),
            "line {i} should be an error: {line:?}"
        );
        assert!(line.get("error").is_some(), "line {i} has no error field");
    }
    assert_eq!(lines[4].get("ok").and_then(Json::as_bool), Some(true), "{:?}", lines[4]);

    // A second connection still serves — nothing was poisoned or killed.
    let resp = http_post_query(&addr, "{\"op\":\"info\"}\n");
    assert!(resp.contains("200 OK"), "{resp}");
    let info = Json::parse(body_of(&resp).trim()).unwrap();
    assert_eq!(info.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(info.get("m").and_then(Json::as_usize), Some(60));
    srv.join().unwrap();
}
