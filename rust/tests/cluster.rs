//! Distributed-mode integration: leader + N workers as real TCP peers
//! (worker threads in-process; the protocol and phase execution are the
//! same code paths the `tallfat worker` process runs), driven through the
//! builder API with a [`ClusterExecutor`] and verified against the local
//! executor.

use tallfat::cluster::leader::PhaseSpec;
use tallfat::cluster::proto::PhaseKind;
use tallfat::cluster::{ClusterExecutor, DistributedLeader};
use tallfat::config::InputFormat;
use tallfat::io::dataset::{gen_exact, Spectrum};
use tallfat::io::InputSpec;
use tallfat::linalg::Matrix;
use tallfat::svd::{validate, Svd};

mod harness;
use harness::{free_addr, spawn_flaky_worker, spawn_workers};

fn dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("tallfat_cluster_it").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Builder with the shared fixture defaults; generic over the executor
/// lifetime so each call site infers its own. Chain route-specific options
/// (`oversample`, `power_iters`, `exact_gram`, …) at the call site.
fn build<'a>(input: &InputSpec, work: String, k: usize) -> Svd<'a> {
    Svd::over(input).unwrap().rank(k).block(64).work_dir(work)
}

#[test]
fn distributed_svd_matches_local() {
    let d = dir("svd");
    let (a, sigma_true) = gen_exact(
        600,
        48,
        8,
        Spectrum::Geometric { scale: 10.0, decay: 0.6 },
        0.0,
        21,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();

    let addr = free_addr();
    let handles = spawn_workers(&addr, 3);
    let mut cluster = ClusterExecutor::accept(&addr, 3).unwrap();

    let work = |name: &str| d.join(name).to_string_lossy().into_owned();
    let dist = build(&input, work("dist"), 8)
        .oversample(8)
        .workers(3)
        .seed(5)
        .executor(&mut cluster)
        .run()
        .unwrap();
    cluster.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }

    // vs ground truth
    for i in 0..8 {
        let rel = (dist.sigma[i] - sigma_true[i]).abs() / sigma_true[i];
        assert!(rel < 1e-8, "sigma[{i}] {} vs {}", dist.sigma[i], sigma_true[i]);
    }
    // vs local pipeline (identical seed => identical sketch)
    let local = build(&input, work("local"), 8)
        .oversample(8)
        .workers(3)
        .seed(5)
        .run()
        .unwrap();
    for i in 0..8 {
        let rel = (dist.sigma[i] - local.sigma[i]).abs() / local.sigma[i];
        assert!(rel < 1e-10, "dist vs local sigma[{i}]");
    }
    // U shards valid + orthonormal
    let err = validate::reconstruction_error_streaming(&input, &dist).unwrap();
    assert!(err < 1e-7, "reconstruction {err}");
    let ortho = validate::u_orthonormality_residual(&dist.u_shards, dist.shards, dist.k).unwrap();
    assert!(ortho < 1e-8, "orthonormality {ortho}");
}

#[test]
fn distributed_svd_with_power_iterations() {
    let d = dir("power");
    let (a, _) = gen_exact(300, 32, 32, Spectrum::Power { scale: 10.0 }, 0.0, 22).unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();

    let addr = free_addr();
    let handles = spawn_workers(&addr, 2);
    let mut cluster = ClusterExecutor::accept(&addr, 2).unwrap();
    let work = |name: &str| d.join(name).to_string_lossy().into_owned();
    let dist = build(&input, work("dist"), 6)
        .oversample(6)
        .power_iters(2)
        .workers(2)
        .seed(1)
        .executor(&mut cluster)
        .run()
        .unwrap();
    cluster.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }
    let local = build(&input, work("local"), 6)
        .oversample(6)
        .power_iters(2)
        .workers(2)
        .seed(1)
        .run()
        .unwrap();
    for i in 0..6 {
        let rel = (dist.sigma[i] - local.sigma[i]).abs() / local.sigma[i];
        assert!(rel < 1e-9, "power-iter dist vs local sigma[{i}]");
    }
}

/// The exact-Gram route also runs distributed now — same builder, same
/// executor seam (the old hand-written distributed driver never could).
#[test]
fn distributed_gram_route_matches_local() {
    let d = dir("gram");
    let (a, _) = gen_exact(
        240,
        14,
        14,
        Spectrum::Geometric { scale: 6.0, decay: 0.8 },
        0.002,
        24,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();

    let addr = free_addr();
    let handles = spawn_workers(&addr, 2);
    let mut cluster = ClusterExecutor::accept(&addr, 2).unwrap();
    let work = |name: &str| d.join(name).to_string_lossy().into_owned();
    let dist = build(&input, work("dist"), 14)
        .exact_gram(true)
        .workers(2)
        .executor(&mut cluster)
        .run()
        .unwrap();
    cluster.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }
    let local = build(&input, work("local"), 14)
        .exact_gram(true)
        .workers(2)
        .run()
        .unwrap();
    for i in 0..14 {
        let rel = (dist.sigma[i] - local.sigma[i]).abs() / local.sigma[i].max(1e-12);
        assert!(rel < 1e-10, "gram dist vs local sigma[{i}]");
    }
    let err = validate::reconstruction_error_streaming(&input, &dist).unwrap();
    assert!(err < 1e-2, "gram reconstruction {err}");
}

#[test]
fn distributed_ata_phase() {
    let d = dir("ata");
    let (a, _) = gen_exact(
        200,
        12,
        12,
        Spectrum::Geometric { scale: 3.0, decay: 0.9 },
        0.05,
        23,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();

    let addr = free_addr();
    let handles = spawn_workers(&addr, 2);
    let mut leader = DistributedLeader::accept(&addr, 2).unwrap();
    // Chunk-grained: 6 chunks over 2 workers, scheduled dynamically.
    let w = d.join("w").to_string_lossy().into_owned();
    let zero = Matrix::zeros(0, 0);
    let (rows, partials, stats) = leader
        .run_phase(&PhaseSpec {
            kind: PhaseKind::Ata,
            input: &input,
            work_dir: &w,
            block: 64,
            seed: 0,
            kp: 12,
            cols: 12,
            shard_format: InputFormat::Bin,
            shard_epoch: 0,
            operand: &zero,
            means: &zero,
            chunk_total: 6,
            max_retries: 0,
        })
        .unwrap();
    leader.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(rows, 200);
    assert_eq!(stats.chunks, 6);
    assert_eq!(partials.len(), 6, "one partial per chunk, in chunk order");
    let g = tallfat::splitproc::reduce_partials(partials).unwrap();
    let want = tallfat::linalg::gram(&a);
    assert!(g.max_abs_diff(&want) < 1e-9);
}

#[test]
fn worker_failure_is_reported_to_leader() {
    let d = dir("fail");
    // Input the leader can see but with a bogus path sent to workers: the
    // chunk fails on every attempt, so after the retry budget the pass
    // must fail naming the chunk — not hang or kill the connection.
    let addr = free_addr();
    let handles = spawn_workers(&addr, 1);
    let mut leader = DistributedLeader::accept(&addr, 1).unwrap();
    let bogus = InputSpec::csv("/nonexistent/a.csv".to_string());
    let w = d.join("w").to_string_lossy().into_owned();
    let zero = Matrix::zeros(0, 0);
    let r = leader.run_phase(&PhaseSpec {
        kind: PhaseKind::Ata,
        input: &bogus,
        work_dir: &w,
        block: 64,
        seed: 0,
        kp: 4,
        cols: 4,
        shard_format: InputFormat::Bin,
        shard_epoch: 0,
        operand: &zero,
        means: &zero,
        chunk_total: 1,
        max_retries: 1,
    });
    let err = r.expect_err("leader must surface the worker failure").to_string();
    assert!(err.contains("chunk 0"), "error should name the chunk: {err}");
    assert!(err.contains("2 attempts"), "error should count attempts: {err}");
    // The worker stays up after reporting failures; shutdown still works.
    leader.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }
}

/// Acceptance for distributed `svd --trace`: the leader's trace file holds
/// one merged timeline where every executed chunk of every phase appears
/// with worker attribution, chunk spans nest inside their phase span and
/// phases inside the run span, no chunk is silently executed twice (a
/// duplicate must carry a `retry` or `speculative` tag), and the chunk a
/// dying worker dropped comes back visibly tagged as a retry.
#[test]
fn distributed_svd_trace_merges_worker_chunks_exactly_once() {
    use tallfat::serve::Json;

    let d = dir("trace");
    let (a, _) = gen_exact(
        400,
        24,
        6,
        Spectrum::Geometric { scale: 8.0, decay: 0.6 },
        0.0,
        29,
    )
    .unwrap();
    let input = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();

    let addr = free_addr();
    // Two steady workers plus one that completes a single chunk and then
    // dies with its next assignment in flight: that chunk must be
    // reassigned to a survivor and show up retry-tagged in the timeline.
    let flaky = spawn_flaky_worker(&addr, 1);
    let good = spawn_workers(&addr, 2);
    let mut cluster = ClusterExecutor::accept(&addr, 3).unwrap();

    let trace_path = d.join("trace.json").to_string_lossy().into_owned();
    tallfat::obs::trace::install(&trace_path).unwrap();
    let (root_trace, root_span_hex);
    {
        // What `svd --trace` does: a root run span over the whole pipeline.
        let mut root = tallfat::obs::trace::Span::root("run svd", "run");
        let ctx = root.ctx();
        root_trace = format!("{:016x}", ctx.trace);
        root_span_hex = format!("{:016x}", ctx.span);
        root.arg_str("command", "svd");
        build(&input, d.join("work").to_string_lossy().into_owned(), 6)
            .oversample(6)
            .workers(3)
            .seed(9)
            .executor(&mut cluster)
            .run()
            .unwrap();
    }
    cluster.shutdown().unwrap();
    flaky.join().unwrap();
    for h in good {
        h.join().unwrap();
    }
    tallfat::obs::trace::finish();

    let text = std::fs::read_to_string(&trace_path).unwrap();
    let doc = Json::parse(&text).unwrap();
    let events = doc.as_array().unwrap();
    let astr = |e: &Json, k: &str| {
        e.get("args").and_then(|a| a.get(k)).and_then(Json::as_str).map(str::to_string)
    };
    let abool =
        |e: &Json, k: &str| e.get("args").and_then(|a| a.get(k)) == Some(&Json::Bool(true));
    let ts = |e: &Json| e.get("ts").unwrap().as_f64().unwrap();
    let dur = |e: &Json| e.get("dur").unwrap().as_f64().unwrap();
    let cat = |e: &Json| e.get("cat").and_then(Json::as_str).unwrap_or("");
    // Only this run's events: the registry/sink are process globals shared
    // with concurrently running tests, so filter by our trace id.
    let ours: Vec<&Json> = events
        .iter()
        .filter(|&e| {
            e.get("ph").and_then(Json::as_str) == Some("X")
                && astr(e, "trace").as_deref() == Some(root_trace.as_str())
        })
        .collect();

    let run = ours
        .iter()
        .copied()
        .find(|&e| cat(e) == "run")
        .expect("run span missing from trace");
    assert_eq!(astr(run, "span").as_deref(), Some(root_span_hex.as_str()));

    // Phase spans: children of the run, executor=cluster, chunk count arg.
    let mut phases: std::collections::BTreeMap<String, (f64, f64, usize)> = Default::default();
    for e in ours.iter().copied().filter(|&e| cat(e) == "phase") {
        assert_eq!(astr(e, "parent").as_deref(), Some(root_span_hex.as_str()), "phase⊄run");
        assert_eq!(astr(e, "executor").as_deref(), Some("cluster"));
        assert!(ts(e) >= ts(run) - 10.0 && ts(e) + dur(e) <= ts(run) + dur(run) + 10.0);
        let chunks =
            e.get("args").unwrap().get("chunks").unwrap().as_f64().unwrap() as usize;
        phases.insert(astr(e, "span").unwrap(), (ts(e), dur(e), chunks));
    }
    assert!(!phases.is_empty(), "no cluster phase spans in trace");

    // The merged chunk events are the ones carrying worker attribution
    // (in-process test workers also emit their own local chunk spans into
    // the shared sink; a real deployment's workers have no sink).
    type ChunkEv = (bool, bool); // (retry, speculative)
    let mut per_chunk: std::collections::BTreeMap<(String, usize), Vec<ChunkEv>> =
        Default::default();
    let merged =
        ours.iter().copied().filter(|&e| cat(e) == "chunk" && astr(e, "worker").is_some());
    for e in merged {
        let worker = astr(e, "worker").unwrap();
        assert!(!worker.is_empty(), "chunk without worker attribution");
        let parent = astr(e, "parent").expect("chunk without parent span");
        let &(pts, pdur, _) =
            phases.get(&parent).expect("chunk's parent is not a phase span");
        assert!(
            ts(e) >= pts - 10.0 && ts(e) + dur(e) <= pts + pdur + 10.0,
            "chunk event outside its phase window"
        );
        let name = e.get("name").and_then(Json::as_str).unwrap();
        let idx: usize = name.strip_prefix("chunk ").unwrap().parse().unwrap();
        per_chunk
            .entry((parent, idx))
            .or_default()
            .push((abool(e, "retry"), abool(e, "speculative")));
    }

    // Exactly-once coverage: every chunk of every phase has one untagged
    // (or retry-tagged) completion; any extra completion must be a
    // visibly-tagged speculative duplicate.
    for (span, &(_, _, chunks)) in &phases {
        for c in 0..chunks {
            let evs = per_chunk
                .get(&(span.clone(), c))
                .unwrap_or_else(|| panic!("phase {span} chunk {c} has no timeline event"));
            let primary = evs.iter().filter(|(_, spec)| !spec).count();
            assert_eq!(primary, 1, "phase {span} chunk {c}: {evs:?}");
        }
    }
    let workers: std::collections::BTreeSet<String> = ours
        .iter()
        .copied()
        .filter(|&e| cat(e) == "chunk")
        .filter_map(|e| astr(e, "worker"))
        .collect();
    assert!(workers.len() >= 2, "expected several attributed workers, got {workers:?}");
    assert!(
        per_chunk.values().flatten().any(|&(retry, _)| retry),
        "the dead worker's reassigned chunk never surfaced as a retry"
    );
}

#[test]
fn version_mismatch_rejected() {
    use std::io::Write as _;
    use std::net::TcpStream;
    let addr = free_addr();
    let addr2 = addr.clone();
    let rogue = std::thread::spawn(move || {
        let mut s = loop {
            match TcpStream::connect(&addr2) {
                Ok(s) => break s,
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
            }
        };
        // Hand-written hello with a wrong version.
        let payload = 999u32.to_le_bytes();
        s.write_all(&(payload.len() as u32).to_le_bytes()).unwrap();
        s.write_all(&[0x10]).unwrap();
        s.write_all(&payload).unwrap();
    });
    let r = DistributedLeader::accept(&addr, 1);
    assert!(r.is_err());
    rogue.join().unwrap();
}
