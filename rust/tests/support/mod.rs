//! Hand-rolled property-test support (proptest is unavailable offline).

use std::fmt;
use tallfat::rng::splitmix64;

/// One generated case: a deterministic stream of draws from a seed.
pub struct Case {
    seed: u64,
    counter: u64,
    index: usize,
}

impl Case {
    /// The case's base seed (stable across draws).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    fn next(&mut self) -> u64 {
        self.counter += 1;
        splitmix64(self.seed ^ self.counter.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.next() % (hi - lo + 1) as u64) as usize
    }

    /// Uniform float in `[lo, hi)`.
    #[allow(dead_code)]
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * (self.next() as f64 / u64::MAX as f64)
    }

    /// Coin flip.
    #[allow(dead_code)]
    pub fn bool(&mut self) -> bool {
        self.next() & 1 == 1
    }
}

impl fmt::Display for Case {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[case #{} seed={:#x}]", self.index, self.seed)
    }
}

/// A deterministic sweep of `count` cases derived from a root seed.
/// On assertion failure the panic message carries `{case}` so the exact
/// failing parameters can be replayed.
pub struct Cases {
    count: usize,
    root: u64,
}

impl Cases {
    pub fn new(count: usize, root: u64) -> Self {
        Cases { count, root }
    }

    pub fn run(&self, mut f: impl FnMut(&mut Case)) {
        for index in 0..self.count {
            let mut case = Case {
                seed: splitmix64(self.root ^ (index as u64) << 32),
                counter: 0,
                index,
            };
            f(&mut case);
        }
    }
}
