//! `tallfat` leader binary — parses the command line and hands off to the
//! coordinator. See `tallfat help` (or [`tallfat::coordinator::USAGE`]).

use tallfat::coordinator;
use tallfat::util::Args;

fn main() {
    // Pin the log epoch (and TALLFAT_LOG/_FORMAT) before any work runs so
    // relative timestamps measure from process start.
    tallfat::util::logger::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            eprint!("{}", coordinator::USAGE);
            std::process::exit(2);
        }
    };
    if let Err(e) = coordinator::run_cli(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
