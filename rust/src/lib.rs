//! # tallfat — randomized rank-k SVD for tall-and-fat matrices
//!
//! A production-shaped reproduction of Bayramlı, *"SVD Factorization for
//! Tall-and-Fat Matrices on Parallel Architectures"* (cs.DC 2013).
//!
//! The paper reduces the SVD of a huge `m x n` matrix (m up to billions of
//! rows) to *streaming, embarrassingly-parallel* passes over the rows plus
//! dense math on tiny `k x k` matrices:
//!
//! 1. `A^T A = Σ_i A_i ⊗ A_i` — per-row outer products, summed locally per
//!    worker and reduced once ([`jobs::ata`], [`splitproc`]).
//! 2. `A^T A = V Σ² V^T` — a small symmetric eigenproblem recovers `V`, `Σ`
//!    ([`linalg::eigen`]); `U = A V Σ^{-1}` is one more streaming pass.
//! 3. For large `n` ("tall-and-**fat**"), first project `Y = A Ω` with a
//!    Gaussian `n x k` sketch (Johnson–Lindenstrauss), optionally *virtual*:
//!    Ω regenerated from a counter-based PRNG instead of stored ([`rng`]).
//! 4. Work is distributed by the **Split-Process** architecture: every
//!    worker seeks to a newline-aligned byte chunk of a shared input file
//!    and streams its rows ([`io::chunker`], [`splitproc`]).
//! 5. Sparse inputs (libsvm / sparse-CSV / binary CSR — [`io::sparse`])
//!    stream as CSR row blocks through `O(nnz)` kernels
//!    ([`linalg::sparse`], [`jobs::sparse`]): memory and FLOPs scale with
//!    the nonzeros, never `m·n`, and PCA centering applies as rank-1
//!    corrections instead of densifying rows. `tallfat svd big.libsvm`
//!    (or `--input-format libsvm|scsv|csr`) picks this path up
//!    automatically, locally and `--distributed`.
//!
//! ## One pipeline, many executors
//!
//! The public entry point is the [`svd::Svd`] builder:
//!
//! ```ignore
//! let result = Svd::over(&input)?        // validates dims up front
//!     .rank(16).oversample(8).center(true)
//!     .run()?;                           // local threads by default
//! ```
//!
//! The pass schedule (project+gram → k×k eigh → U-recovery → completion)
//! exists exactly once ([`svd::pipeline`]); *where* the streaming passes
//! run is a pluggable [`svd::Executor`]: [`svd::LocalExecutor`] fans out
//! over in-process Split-Process threads, [`cluster::ClusterExecutor`]
//! over remote TCP workers (`.executor(&mut cluster)`) — same seed, same
//! passes, same factors.
//!
//! Partials come back through a *reduction plan* ([`svd::reduce`]): by
//! default additive `k' x k'` partials tree-reduce pairwise across the
//! holders (workers in a cluster run), tall `W` partials fold as banded
//! TSQR R factors, and V row shards go straight to disk — the leader holds
//! `O(k'^2 log w)` state instead of an n-sized accumulate. `--reduce star`
//! keeps the old ship-to-leader fold; both topologies combine partials in
//! chunk-index order, so they agree bit for bit.
//!
//! ## Three-layer architecture
//!
//! The block-level compute (Gram, projection, fused project+gram, U
//! recovery, the k×k eigensolve) is authored as JAX/Pallas kernels
//! (`python/compile/`), AOT-lowered to HLO text once at build time, and
//! executed from rust through the PJRT C API ([`runtime`], [`backend::xla`];
//! gated behind the `xla` cargo feature — the default build is
//! dependency-free and serves natively). Python is never on the processing
//! path. A pure-rust [`backend::native`] implements the same `Backend`
//! trait for arbitrary shapes and as a cross-check oracle.
//!
//! ## Serving and the model lifecycle
//!
//! A factorization is not the end of the road: [`serve`] persists the
//! factors as a *versioned* model directory (immutable generations under a
//! `CURRENT` pointer; U stays sharded on disk, LRU-cached) and answers
//! project / top-k-cosine / reconstruct queries over HTTP with request
//! micro-batching — `tallfat svd --save-model DIR` then `tallfat serve DIR`.
//!
//! New rows never force a re-run over the full input: [`update`] streams
//! just the batch through the same Executor passes, merges on the leader
//! with `(k+r)`-sized math, and commits the next generation — which a
//! running server hot-swaps to with zero downtime (`tallfat update DIR
//! --rows NEW.csv`, then `{"op":"reload"}` or `--reload-poll-ms`).
//!
//! When the rows arrive over a source that cannot be re-read — stdin, a
//! pipe, a socket — the multi-pass schedule is off the table: [`stream`]
//! factors such a feed in *exactly one forward pass* ([`stream::StreamSvd`]),
//! holding only k-sized sketch accumulators and an adaptive sketch width
//! that grows until a residual estimate meets `--tol`. The one-pass factors
//! trade a little accuracy for never touching a row twice (exact on truly
//! low-rank data; approximate tails otherwise), land in the same
//! [`svd::SvdResult`] shape, and fold into a served model via
//! [`update::publish_stream_result`] — `tallfat stream - --tol 1e-3`.
//!
//! Every HTTP front end — `serve`, the daemon, `serve-metrics` — runs on
//! one shared connection runtime ([`net`]): an event-driven epoll/poll
//! readiness loop (no crates; thin `extern "C"` declarations), one
//! incremental keep-alive HTTP/1.1 parser, a warm fixed-size handler pool
//! behind a bounded queue, and semaphore-style admission control that
//! answers overload with an explicit `503` + `Retry-After` instead of
//! unbounded thread growth; stalled connections are reaped by deadline.
//!
//! [`daemon`] joins the lifecycle into one long-running control plane:
//! `tallfat daemon` owns a *fleet* of named models (registry persisted in a
//! manifest), routes ND-JSON queries by model name through one front door,
//! runs update and stream jobs as supervised background tasks (per-model queueing,
//! heartbeat health-probing, zombie reaping, retry, hot-swap on publish),
//! and drains gracefully — driven by `tallfat daemon-client` over the same
//! transport. Its [`daemon::Scenario`] harness scripts chaos cases (worker
//! killed mid-update, GC racing a reload, restart with a queued job) as
//! declarative, repeatable integration tests.
//!
//! See `examples/` for runnable end-to-end drivers and `rust/benches/` for
//! the experiment harnesses (EXPERIMENTS.md maps each to the paper).

pub mod backend;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod daemon;
pub mod error;
pub mod io;
pub mod jobs;
pub mod linalg;
pub mod mapreduce;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod simulator;
pub mod splitproc;
pub mod stream;
pub mod svd;
pub mod update;
pub mod util;

pub use error::{Error, Result};
