//! The shared connection runtime under every HTTP plane.
//!
//! `serve` (model queries), `daemon` (fleet control) and `serve-metrics`
//! (observability) used to each hand-roll a blocking, thread-per-connection
//! `Connection: close` server. This module replaces all three front ends
//! with one event-driven runtime:
//!
//! * [`poll`] — readiness without crates: epoll through thin
//!   `extern "C"` declarations on Linux, a portable `poll(2)` fallback
//!   everywhere (selectable via `TALLFAT_NET_POLL=poll`).
//! * [`http`] — the one incremental HTTP/1.1 parser (keep-alive,
//!   pipelining, hard head/body caps, clean errors on malformed input)
//!   and the response writer, shared by every plane.
//! * [`server`] — the [`server::NetServer`] loop: nonblocking accept,
//!   per-connection state machines, a warm fixed-size handler pool behind
//!   a bounded queue, semaphore-style admission control (503 +
//!   `Retry-After` + JSON overload body past the caps), idle/stalled
//!   connection reaping, and graceful drain on shutdown.
//!
//! A plane implements [`server::NetHandler`] — `handle` for pool-executed
//! work, `handle_inline` for never-shed event-loop answers (liveness,
//! metrics) — and calls `NetServer::bind(addr, opts).run(handler)`.

pub mod http;
pub mod poll;
pub mod server;

pub use http::{HttpLimits, HttpParser, HttpRequest, HttpResponse, ParseStatus};
pub use poll::Backend;
pub use server::{NetHandler, NetOptions, NetServer, NetServerHandle, NetStats};
