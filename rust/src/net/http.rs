//! The one HTTP/1.1 parser and response writer for every plane.
//!
//! [`HttpParser`] is incremental: the event loop appends whatever bytes a
//! nonblocking read produced and asks again — `NeedMore` until a full
//! head (and declared body) has arrived, then a complete [`HttpRequest`].
//! Pipelined requests parse one at a time from the same buffer; consumed
//! bytes are drained so the buffer never grows past one in-flight
//! request.
//!
//! Malformed input can never panic and never costs unbounded memory: the
//! head is capped ([`HttpError`] 431), the declared body length is capped
//! before any allocation (413), a non-numeric `Content-Length` is 400,
//! and `Transfer-Encoding: chunked` is an honest 501. Every error carries
//! the status to answer with; the runtime writes it and closes.

use std::borrow::Cow;

/// Default cap on a request head (request line + headers).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Default cap on a request body (matches the serve plane's historical
/// 32 MiB limit — the `Content-Length` header is client input and must
/// not size an allocation unchecked).
pub const MAX_BODY_BYTES: usize = 32 << 20;

/// Parser limits (head and body byte caps).
#[derive(Clone, Copy, Debug)]
pub struct HttpLimits {
    pub max_head: usize,
    pub max_body: usize,
}

impl Default for HttpLimits {
    fn default() -> Self {
        HttpLimits { max_head: MAX_HEAD_BYTES, max_body: MAX_BODY_BYTES }
    }
}

/// A complete parsed request.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// What the client asked for (`Connection:` header, HTTP/1.1 default
    /// keep-alive, HTTP/1.0 default close). The runtime may still close.
    pub keep_alive: bool,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn body_str(&self) -> Cow<'_, str> {
        String::from_utf8_lossy(&self.body)
    }
}

/// A protocol error: the status line to answer with, then close.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub msg: &'static str,
}

impl HttpError {
    fn new(status: u16, msg: &'static str) -> HttpError {
        HttpError { status, msg }
    }
}

/// One `parse` step: a full request, or "feed me more bytes".
#[derive(Debug)]
pub enum ParseStatus {
    NeedMore,
    Request(HttpRequest),
}

/// The head fields carried while waiting for the body to arrive.
#[derive(Debug)]
struct PendingHead {
    method: String,
    path: String,
    keep_alive: bool,
    content_length: usize,
}

/// Incremental request parser. One per connection; `parse` is called
/// after every read with the connection's accumulated buffer.
#[derive(Debug, Default)]
pub struct HttpParser {
    limits: HttpLimits,
    /// Head parsed, body still arriving.
    pending: Option<PendingHead>,
    /// How far the head-terminator scan has progressed (so repeated
    /// `NeedMore` calls stay O(new bytes), not O(buffer) each).
    scanned: usize,
}

impl HttpParser {
    pub fn new(limits: HttpLimits) -> HttpParser {
        HttpParser { limits, pending: None, scanned: 0 }
    }

    /// Try to complete one request from `buf`. Consumed bytes are drained
    /// from the front of `buf`; on `NeedMore` the buffer is left intact.
    pub fn parse(&mut self, buf: &mut Vec<u8>) -> Result<ParseStatus, HttpError> {
        if self.pending.is_none() {
            let Some((head_end, body_start)) = self.find_head_end(buf) else {
                if buf.len() > self.limits.max_head {
                    return Err(HttpError::new(431, "request head exceeds the size cap"));
                }
                return Ok(ParseStatus::NeedMore);
            };
            let head = parse_head(&buf[..head_end], self.limits.max_body)?;
            buf.drain(..body_start);
            self.scanned = 0;
            self.pending = Some(head);
        }
        let pending = self.pending.as_ref().expect("pending head set above");
        if buf.len() < pending.content_length {
            return Ok(ParseStatus::NeedMore);
        }
        let head = self.pending.take().expect("pending head set above");
        let rest = buf.split_off(head.content_length);
        let body = std::mem::replace(buf, rest);
        Ok(ParseStatus::Request(HttpRequest {
            method: head.method,
            path: head.path,
            keep_alive: head.keep_alive,
            body,
        }))
    }

    /// True while a request is partially buffered (a reaped connection
    /// with one is a mid-request stall, not an idle keep-alive).
    pub fn mid_request(&self, buf: &[u8]) -> bool {
        self.pending.is_some() || !buf.is_empty()
    }

    /// Find the blank line ending the head: `\r\n\r\n` (or a tolerant
    /// bare `\n\n`). Returns (head length, offset where the body starts).
    fn find_head_end(&mut self, buf: &[u8]) -> Option<(usize, usize)> {
        let start = self.scanned.saturating_sub(3);
        for (i, &byte) in buf.iter().enumerate().skip(start) {
            if byte != b'\n' {
                continue;
            }
            if i >= 3 && buf[i - 1] == b'\r' && buf[i - 2] == b'\n' && buf[i - 3] == b'\r' {
                return Some((i - 3, i + 1));
            }
            if i >= 1 && buf[i - 1] == b'\n' {
                return Some((i - 1, i + 1));
            }
        }
        self.scanned = buf.len();
        None
    }
}

fn parse_head(head: &[u8], max_body: usize) -> Result<PendingHead, HttpError> {
    let text = std::str::from_utf8(head)
        .map_err(|_| HttpError::new(400, "request head is not valid UTF-8"))?;
    let mut lines = text.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ').filter(|p| !p.is_empty());
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) => (m, p, v),
        _ => return Err(HttpError::new(400, "malformed request line")),
    };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::new(400, "malformed request method"));
    }
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new(400, "unsupported protocol version"));
    }
    // HTTP/1.1 defaults to keep-alive, HTTP/1.0 to close; the Connection
    // header overrides either way.
    let mut keep_alive = version != "HTTP/1.0";
    let mut content_length = 0usize;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::new(400, "malformed header line"));
        };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value
                .parse::<usize>()
                .map_err(|_| HttpError::new(400, "malformed Content-Length"))?;
        } else if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                keep_alive = true;
            }
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            return Err(HttpError::new(501, "Transfer-Encoding is not supported"));
        }
    }
    if content_length > max_body {
        return Err(HttpError::new(413, "body exceeds the request cap"));
    }
    Ok(PendingHead {
        method: method.to_string(),
        path: path.to_string(),
        keep_alive,
        content_length,
    })
}

/// A response ready to render. Construction helpers cover the planes'
/// shapes (JSON, ND-JSON, Prometheus text, the 503 overload envelope).
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// `Retry-After` seconds (503 sheds).
    pub retry_after: Option<u32>,
    /// Force `Connection: close` regardless of what the client asked.
    pub close: bool,
}

impl HttpResponse {
    pub fn new(status: u16, content_type: &'static str, body: impl Into<Vec<u8>>) -> HttpResponse {
        HttpResponse { status, content_type, body: body.into(), retry_after: None, close: false }
    }

    pub fn ok(content_type: &'static str, body: impl Into<Vec<u8>>) -> HttpResponse {
        HttpResponse::new(200, content_type, body)
    }

    pub fn json(status: u16, body: impl Into<Vec<u8>>) -> HttpResponse {
        HttpResponse::new(status, "application/json", body)
    }

    pub fn text(status: u16, body: impl Into<Vec<u8>>) -> HttpResponse {
        HttpResponse::new(status, "text/plain", body)
    }

    pub fn not_found(msg: &str) -> HttpResponse {
        HttpResponse::text(404, format!("{msg}\n"))
    }

    /// The admission-control shed: 503 + `Retry-After` + a JSON body that
    /// names the reason, so clients can tell overload from failure.
    pub fn overloaded(reason: &str, retry_after_s: u32) -> HttpResponse {
        let body = format!(
            "{{\"ok\":false,\"error\":\"overloaded\",\"reason\":\"{reason}\",\"retry_after_s\":{retry_after_s}}}\n"
        );
        HttpResponse { retry_after: Some(retry_after_s), ..HttpResponse::json(503, body) }
    }

    /// The response for a protocol error (always closes the connection:
    /// after malformed bytes the stream offset is untrustworthy).
    pub fn protocol_error(err: &HttpError) -> HttpResponse {
        HttpResponse { close: true, ..HttpResponse::text(err.status, format!("{}\n", err.msg)) }
    }

    /// Render the full wire bytes. `keep_alive` is the runtime's final
    /// decision (client wish AND server policy AND not shutting down).
    pub fn render(&self, keep_alive: bool) -> Vec<u8> {
        let keep = keep_alive && !self.close;
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
            self.status,
            reason_phrase(self.status),
            self.content_type,
            self.body.len()
        );
        if let Some(s) = self.retry_after {
            head.push_str(&format!("Retry-After: {s}\r\n"));
        }
        head.push_str(if keep {
            "Connection: keep-alive\r\n\r\n"
        } else {
            "Connection: close\r\n\r\n"
        });
        let mut out = head.into_bytes();
        out.extend_from_slice(&self.body);
        out
    }
}

pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Response",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_all(bytes: &[u8]) -> Result<Vec<HttpRequest>, HttpError> {
        let mut parser = HttpParser::new(HttpLimits::default());
        let mut buf = bytes.to_vec();
        let mut out = Vec::new();
        loop {
            match parser.parse(&mut buf)? {
                ParseStatus::Request(r) => out.push(r),
                ParseStatus::NeedMore => return Ok(out),
            }
        }
    }

    #[test]
    fn parses_request_with_body_and_keep_alive_default() {
        let wire = b"POST /query HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello";
        let reqs = parse_all(wire).unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].method, "POST");
        assert_eq!(reqs[0].path, "/query");
        assert!(reqs[0].keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(reqs[0].body, b"hello");
    }

    #[test]
    fn connection_close_and_http10_default() {
        let close =
            parse_all(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!close[0].keep_alive);
        let old = parse_all(b"GET / HTTP/1.0\r\nHost: x\r\n\r\n").unwrap();
        assert!(!old[0].keep_alive, "HTTP/1.0 defaults to close");
        let old_ka = parse_all(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap();
        assert!(old_ka[0].keep_alive);
    }

    #[test]
    fn byte_at_a_time_arrival_completes_exactly_once() {
        let wire = b"POST /q HTTP/1.1\r\nContent-Length: 3\r\n\r\nabc";
        let mut parser = HttpParser::new(HttpLimits::default());
        let mut buf = Vec::new();
        let mut done = 0;
        for (i, &b) in wire.iter().enumerate() {
            buf.push(b);
            match parser.parse(&mut buf).unwrap() {
                ParseStatus::Request(r) => {
                    assert_eq!(i, wire.len() - 1, "completed early at byte {i}");
                    assert_eq!(r.body, b"abc");
                    done += 1;
                }
                ParseStatus::NeedMore => assert!(i < wire.len() - 1),
            }
        }
        assert_eq!(done, 1);
        assert!(buf.is_empty(), "request bytes fully consumed");
    }

    #[test]
    fn pipelined_requests_parse_in_order() {
        let reqs = parse_all(
            b"POST /a HTTP/1.1\r\nContent-Length: 1\r\n\r\nXGET /b HTTP/1.1\r\n\r\n",
        )
        .unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].path, "/a");
        assert_eq!(reqs[0].body, b"X");
        assert_eq!(reqs[1].path, "/b");
    }

    #[test]
    fn bare_lf_head_terminator_tolerated() {
        let reqs = parse_all(b"GET /x HTTP/1.1\nHost: y\n\n").unwrap();
        assert_eq!(reqs[0].path, "/x");
    }

    #[test]
    fn malformed_inputs_error_cleanly() {
        let cases: &[(&[u8], u16)] = &[
            (b"NONSENSE\r\n\r\n", 400),                                        // no path/version
            (b"GET /x SMTP/9\r\n\r\n", 400),                                   // wrong protocol
            (b"get /x HTTP/1.1\r\n\r\n", 400),                                 // lowercase method
            (b"GET /x HTTP/1.1\r\nbroken header\r\n\r\n", 400),                // no colon
            (b"POST /x HTTP/1.1\r\nContent-Length: abc\r\n\r\n", 400),         // NaN length
            (b"POST /x HTTP/1.1\r\nContent-Length: -1\r\n\r\n", 400),          // negative
            (b"POST /x HTTP/1.1\r\nContent-Length: 99999999999999999999\r\n\r\n", 400),
            (b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", 501),  // chunked
            (b"\xff\xfe HTTP/1.1\r\n\r\n", 400),                               // not UTF-8
        ];
        for (wire, status) in cases {
            let err = parse_all(wire).unwrap_err();
            assert_eq!(err.status, *status, "for {:?}", String::from_utf8_lossy(wire));
        }
    }

    #[test]
    fn oversized_head_and_body_are_capped() {
        let mut parser = HttpParser::new(HttpLimits { max_head: 64, max_body: 8 });
        let mut buf = b"GET /".to_vec();
        buf.extend_from_slice(&[b'a'; 200]);
        assert_eq!(parser.parse(&mut buf).unwrap_err().status, 431);
        let mut parser = HttpParser::new(HttpLimits { max_head: 64, max_body: 8 });
        let mut buf = b"POST /x HTTP/1.1\r\nContent-Length: 9\r\n\r\n".to_vec();
        assert_eq!(parser.parse(&mut buf).unwrap_err().status, 413);
    }

    #[test]
    fn garbage_fuzz_never_panics() {
        // Deterministic pseudo-random bytes through the parser: any
        // outcome is fine except a panic or unbounded NeedMore past caps.
        let mut state = 0x243F6A8885A308D3u64;
        for round in 0..200 {
            let mut parser = HttpParser::new(HttpLimits { max_head: 256, max_body: 1024 });
            let mut buf = Vec::new();
            for _ in 0..(round % 97) + 3 {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                buf.push((state >> 33) as u8);
            }
            let _ = parser.parse(&mut buf);
        }
    }

    #[test]
    fn render_frames_status_length_and_connection() {
        let resp = HttpResponse::ok("application/json", "{\"ok\":true}");
        let wire = String::from_utf8(resp.render(true)).unwrap();
        assert!(wire.starts_with("HTTP/1.1 200 OK\r\n"), "{wire}");
        assert!(wire.contains("Content-Length: 11\r\n"));
        assert!(wire.contains("Connection: keep-alive\r\n"));
        let wire = String::from_utf8(resp.render(false)).unwrap();
        assert!(wire.contains("Connection: close\r\n"));
    }

    #[test]
    fn overload_response_is_well_formed_shed() {
        let resp = HttpResponse::overloaded("queue_full", 1);
        let wire = String::from_utf8(resp.render(true)).unwrap();
        assert!(wire.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(wire.contains("Retry-After: 1\r\n"));
        let body = wire.split("\r\n\r\n").nth(1).unwrap();
        let json = crate::serve::json::Json::parse(body.trim()).unwrap();
        assert_eq!(json.get("ok").and_then(crate::serve::json::Json::as_bool), Some(false));
        let reason = json.get("reason").and_then(crate::serve::json::Json::as_str);
        assert_eq!(reason, Some("queue_full"));
    }
}
