//! Readiness polling without crates.
//!
//! The runtime's event loop needs one primitive: "which of these sockets
//! can make progress?". On Linux that is epoll, reached through thin
//! `extern "C"` declarations against the libc already linked into every
//! Rust binary — no new dependencies. A portable `poll(2)` fallback keeps
//! the same [`Poller`] API working everywhere else (and is selectable on
//! Linux too, via [`Backend::Poll`] or `TALLFAT_NET_POLL=poll`, so tests
//! can pin both code paths).
//!
//! Registration is level-triggered: a readable socket keeps reporting
//! readable until drained, which pairs with the runtime's
//! read-until-`WouldBlock` loops and makes missed-edge bugs structurally
//! impossible. Tokens are caller-chosen `u64`s echoed back in [`Event`]s.

use std::io;
use std::os::raw::{c_int, c_short};
use std::os::unix::io::RawFd;
use std::time::Duration;

/// Which readiness syscall backs the [`Poller`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// epoll where available (Linux), `poll(2)` elsewhere.
    #[default]
    Auto,
    /// Force epoll (fails at construction off Linux).
    Epoll,
    /// Force the portable `poll(2)` path.
    Poll,
}

impl Backend {
    /// [`Backend::Auto`] unless `TALLFAT_NET_POLL=poll` pins the fallback.
    pub fn from_env() -> Backend {
        match std::env::var("TALLFAT_NET_POLL").as_deref() {
            Ok("poll") => Backend::Poll,
            Ok("epoll") => Backend::Epoll,
            _ => Backend::Auto,
        }
    }
}

/// What a registered fd is waiting for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    pub read: bool,
    pub write: bool,
}

impl Interest {
    pub const READ: Interest = Interest { read: true, write: false };
    pub const READ_WRITE: Interest = Interest { read: true, write: true };
}

/// One readiness report. Errors and hangups surface as `readable`: the
/// next `read()` observes the EOF/error and the connection is torn down
/// through the normal path.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

fn timeout_ms(timeout: Option<Duration>) -> c_int {
    match timeout {
        None => -1,
        Some(d) => d.as_millis().min(c_int::MAX as u128) as c_int,
    }
}

/// Readiness poller over a set of registered fds.
pub enum Poller {
    #[cfg(target_os = "linux")]
    Epoll(EpollPoller),
    Poll(PollPoller),
}

impl Poller {
    pub fn new(backend: Backend) -> io::Result<Poller> {
        match backend {
            Backend::Poll => Ok(Poller::Poll(PollPoller::new())),
            #[cfg(target_os = "linux")]
            Backend::Auto | Backend::Epoll => Ok(Poller::Epoll(EpollPoller::new()?)),
            #[cfg(not(target_os = "linux"))]
            Backend::Auto => Ok(Poller::Poll(PollPoller::new())),
            #[cfg(not(target_os = "linux"))]
            Backend::Epoll => Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "epoll backend requires Linux",
            )),
        }
    }

    /// Human name of the live backend (logged once at server start).
    pub fn name(&self) -> &'static str {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(_) => "epoll",
            Poller::Poll(_) => "poll",
        }
    }

    pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.ctl(EPOLL_CTL_ADD, fd, token, interest),
            Poller::Poll(p) => p.register(fd, token, interest),
        }
    }

    pub fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.ctl(EPOLL_CTL_MOD, fd, token, interest),
            Poller::Poll(p) => p.modify(fd, interest),
        }
    }

    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.ctl(EPOLL_CTL_DEL, fd, 0, Interest::READ),
            Poller::Poll(p) => {
                p.deregister(fd);
                Ok(())
            }
        }
    }

    /// Block up to `timeout` (None = forever) and append ready events.
    /// An interrupted wait (EINTR) reports zero events; callers loop.
    pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        events.clear();
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.wait(events, timeout),
            Poller::Poll(p) => p.wait(events, timeout),
        }
    }
}

// ---------------------------------------------------------------------------
// epoll (Linux)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
const EPOLL_CTL_ADD: c_int = 1;
#[cfg(target_os = "linux")]
const EPOLL_CTL_DEL: c_int = 2;
#[cfg(target_os = "linux")]
const EPOLL_CTL_MOD: c_int = 3;

#[cfg(target_os = "linux")]
const EPOLLIN: u32 = 0x001;
#[cfg(target_os = "linux")]
const EPOLLOUT: u32 = 0x004;
#[cfg(target_os = "linux")]
const EPOLLERR: u32 = 0x008;
#[cfg(target_os = "linux")]
const EPOLLHUP: u32 = 0x010;
#[cfg(target_os = "linux")]
const EPOLLRDHUP: u32 = 0x2000;

/// The kernel's `struct epoll_event`. On x86 the ABI packs the 12-byte
/// struct; on other architectures (aarch64 included) it is naturally
/// aligned — the `cfg_attr` mirrors the kernel headers exactly.
#[cfg(target_os = "linux")]
#[repr(C)]
#[cfg_attr(any(target_arch = "x86_64", target_arch = "x86"), repr(packed))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

#[cfg(target_os = "linux")]
extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
        -> c_int;
    fn close(fd: c_int) -> c_int;
}

#[cfg(target_os = "linux")]
pub struct EpollPoller {
    epfd: c_int,
    buf: Vec<EpollEvent>,
}

#[cfg(target_os = "linux")]
impl EpollPoller {
    fn new() -> io::Result<EpollPoller> {
        // EPOLL_CLOEXEC, so the fd never leaks into spawned processes.
        let epfd = unsafe { epoll_create1(0o2000000) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(EpollPoller { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 256] })
    }

    fn ctl(&mut self, op: c_int, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        let mut ev = EpollEvent { events: interest_bits(interest), data: token };
        let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        let max = self.buf.len() as c_int;
        let n = unsafe { epoll_wait(self.epfd, self.buf.as_mut_ptr(), max, timeout_ms(timeout)) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        for ev in &self.buf[..n as usize] {
            // Copy out of the (possibly packed) struct before inspecting.
            let (bits, token) = (ev.events, ev.data);
            events.push(Event {
                token,
                readable: bits & (EPOLLIN | EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                writable: bits & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
            });
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollPoller {
    fn drop(&mut self) {
        unsafe { close(self.epfd) };
    }
}

#[cfg(target_os = "linux")]
fn interest_bits(interest: Interest) -> u32 {
    let mut bits = EPOLLRDHUP;
    if interest.read {
        bits |= EPOLLIN;
    }
    if interest.write {
        bits |= EPOLLOUT;
    }
    bits
}

// ---------------------------------------------------------------------------
// poll(2) fallback
// ---------------------------------------------------------------------------

const POLLIN: c_short = 0x001;
const POLLOUT: c_short = 0x004;
const POLLERR: c_short = 0x008;
const POLLHUP: c_short = 0x010;

#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: c_int,
    events: c_short,
    revents: c_short,
}

#[cfg(target_os = "linux")]
type Nfds = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type Nfds = std::os::raw::c_uint;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: Nfds, timeout: c_int) -> c_int;
}

/// Rebuilds the `pollfd` array on every wait — O(fds) per call, which is
/// fine for the fallback's job (portability and test coverage of the
/// runtime without epoll).
pub struct PollPoller {
    entries: Vec<(RawFd, u64, Interest)>,
}

impl PollPoller {
    fn new() -> PollPoller {
        PollPoller { entries: Vec::new() }
    }

    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        if self.entries.iter().any(|(f, _, _)| *f == fd) {
            return Err(io::Error::new(io::ErrorKind::AlreadyExists, "fd already registered"));
        }
        self.entries.push((fd, token, interest));
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, interest: Interest) -> io::Result<()> {
        match self.entries.iter_mut().find(|(f, _, _)| *f == fd) {
            Some(e) => {
                e.2 = interest;
                Ok(())
            }
            None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
        }
    }

    fn deregister(&mut self, fd: RawFd) {
        self.entries.retain(|(f, _, _)| *f != fd);
    }

    fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        let mut fds: Vec<PollFd> = self
            .entries
            .iter()
            .map(|(fd, _, i)| {
                let mut want: c_short = 0;
                if i.read {
                    want |= POLLIN;
                }
                if i.write {
                    want |= POLLOUT;
                }
                PollFd { fd: *fd, events: want, revents: 0 }
            })
            .collect();
        let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as Nfds, timeout_ms(timeout)) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        for (pfd, (_, token, _)) in fds.iter().zip(&self.entries) {
            if pfd.revents == 0 {
                continue;
            }
            events.push(Event {
                token: *token,
                readable: pfd.revents & (POLLIN | POLLERR | POLLHUP) != 0,
                writable: pfd.revents & (POLLOUT | POLLERR | POLLHUP) != 0,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    fn roundtrip(backend: Backend) {
        let mut poller = Poller::new(backend).unwrap();
        let (mut a, b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        poller.register(b.as_raw_fd(), 7, Interest::READ).unwrap();
        let mut events = Vec::new();
        // Nothing readable yet: a zero-timeout wait reports no events.
        poller.wait(&mut events, Some(Duration::from_millis(0))).unwrap();
        assert!(events.iter().all(|e| e.token != 7 || !e.readable));
        a.write_all(b"x").unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable), "read readiness");
        // Level-triggered: still readable until drained.
        poller.wait(&mut events, Some(Duration::from_millis(0))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable), "level-triggered");
        let mut buf = [0u8; 8];
        let _ = (&b).read(&mut buf);
        poller.wait(&mut events, Some(Duration::from_millis(0))).unwrap();
        assert!(events.iter().all(|e| e.token != 7 || !e.readable), "drained");
        // Peer hangup surfaces as readable (EOF on the next read).
        drop(a);
        poller.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable), "hangup is readable");
        poller.deregister(b.as_raw_fd()).unwrap();
    }

    #[test]
    fn poll_backend_readiness_roundtrip() {
        roundtrip(Backend::Poll);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_backend_readiness_roundtrip() {
        roundtrip(Backend::Epoll);
    }

    #[test]
    fn write_interest_reports_writable() {
        for backend in [Backend::Poll, Backend::Auto] {
            let mut poller = Poller::new(backend).unwrap();
            let (a, _b) = UnixStream::pair().unwrap();
            a.set_nonblocking(true).unwrap();
            poller.register(a.as_raw_fd(), 3, Interest::READ_WRITE).unwrap();
            let mut events = Vec::new();
            poller.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
            assert!(events.iter().any(|e| e.token == 3 && e.writable), "{}", poller.name());
        }
    }
}
