//! The event-driven connection runtime behind all three HTTP planes.
//!
//! One nonblocking accept + readiness loop ([`super::poll::Poller`]) owns
//! every socket. Connections are per-socket state machines: reads feed the
//! incremental [`HttpParser`]; a completed request either answers *inline*
//! on the event loop (cheap, never-shed ops — liveness, metrics) or passes
//! the **admission gate** into a bounded queue consumed by a warm
//! fixed-size handler pool. The pool size *is* the concurrency semaphore:
//! at most `max_inflight` requests execute, at most `max_queue` wait, and
//! anything beyond that is answered immediately with `503` +
//! `Retry-After` + a JSON overload body ([`HttpResponse::overloaded`]) —
//! overload is an explicit, well-formed answer, never an unbounded thread
//! pile-up or a dropped connection.
//!
//! Keep-alive is the default (HTTP/1.1 semantics; `--no-keep-alive` or a
//! client `Connection: close` opt out). One request per connection is
//! outstanding at a time, so pipelined requests are answered strictly in
//! order. Connections that stall — half a request head, an unread
//! response — are reaped once `idle_timeout` passes without progress, so
//! slowloris clients can't pin pool workers or fds.
//!
//! Shutdown ([`NetServerHandle::shutdown`], or the `max_requests` cap) is
//! graceful: stop accepting, shed *new* requests with reason `draining`,
//! finish and flush in-flight responses, then join the pool.
//!
//! Published metrics (gauges, labeled `{plane="..."}`): `net_conns_open`,
//! `net_accept_total`, `net_requests_total`, `net_queue_depth`,
//! `net_inflight`, `net_reaped_total`, and `net_shed_total{reason}` with
//! reasons `queue_full` and `draining`.

use crate::coordinator::server::MetricsRegistry;
use crate::error::{Error, Result};
use crate::net::http::{HttpLimits, HttpParser, HttpRequest, HttpResponse, ParseStatus};
use crate::net::poll::{Backend, Interest, Poller};
use crate::util::{lock_unpoisoned, Args, Logger};
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

static LOG: Logger = Logger::new("net");

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKE: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// How long a graceful shutdown waits for in-flight responses to flush.
const DRAIN_GRACE: Duration = Duration::from_secs(10);

/// What overloaded clients are told to wait before retrying.
const RETRY_AFTER_S: u32 = 1;

/// Runtime knobs, shared by every plane. The CLI surface is uniform too:
/// `--max-inflight N`, `--max-queue N`, `--idle-timeout-ms MS`,
/// `--keep-alive` / `--no-keep-alive` ([`NetOptions::with_args`]).
#[derive(Clone, Debug)]
pub struct NetOptions {
    /// Handler pool size — the admission semaphore's concurrency cap.
    pub max_inflight: usize,
    /// Queued-request cap; beyond it requests shed with 503 `queue_full`.
    pub max_queue: usize,
    /// Reap a connection after this long without forward progress.
    pub idle_timeout: Duration,
    /// Server-side keep-alive policy (clients can still ask to close).
    pub keep_alive: bool,
    /// Parser head/body byte caps.
    pub limits: HttpLimits,
    /// Stop after this many responses are written (None = forever).
    pub max_requests: Option<u64>,
    /// Metrics label distinguishing the planes sharing a process.
    pub plane: &'static str,
    /// Readiness backend (epoll on Linux; `poll(2)` fallback).
    pub backend: Backend,
}

impl Default for NetOptions {
    fn default() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        NetOptions {
            max_inflight: (cores * 2).clamp(4, 64),
            max_queue: 256,
            idle_timeout: Duration::from_secs(10),
            keep_alive: true,
            limits: HttpLimits::default(),
            max_requests: None,
            plane: "net",
            backend: Backend::from_env(),
        }
    }
}

impl NetOptions {
    /// Apply the shared CLI flags on top of the current values.
    pub fn with_args(mut self, args: &Args) -> Result<Self> {
        self.max_inflight = args.usize_or("max-inflight", self.max_inflight)?;
        self.max_queue = args.usize_or("max-queue", self.max_queue)?;
        let idle_ms = args.u64_or("idle-timeout-ms", self.idle_timeout.as_millis() as u64)?;
        self.idle_timeout = Duration::from_millis(idle_ms);
        if args.flag("keep-alive") {
            self.keep_alive = true;
        }
        if args.flag("no-keep-alive") {
            self.keep_alive = false;
        }
        if self.max_inflight == 0 {
            return Err(Error::Config("--max-inflight must be at least 1".into()));
        }
        Ok(self)
    }
}

/// A plane's request handler. `handle` runs on a pool worker; requests
/// only reach it through the admission gate. `handle_inline` runs on the
/// event loop itself and must stay cheap — it exists so liveness probes
/// and metrics scrapes keep answering even when the pool is saturated.
pub trait NetHandler: Send + Sync {
    fn handle(&self, req: HttpRequest) -> HttpResponse;

    fn handle_inline(&self, req: &HttpRequest) -> Option<HttpResponse> {
        let _ = req;
        None
    }
}

/// Shared atomic counters — the runtime's observable state. `*_total`
/// counters are since process start.
#[derive(Debug, Default)]
pub struct NetStats {
    conns_open: AtomicU64,
    accepted: AtomicU64,
    served: AtomicU64,
    queue_depth: AtomicU64,
    inflight: AtomicU64,
    shed_queue: AtomicU64,
    shed_draining: AtomicU64,
    reaped: AtomicU64,
}

impl NetStats {
    pub fn conns_open(&self) -> u64 {
        self.conns_open.load(Ordering::Relaxed)
    }
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }
    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }
    pub fn shed_total(&self) -> u64 {
        self.shed_queue.load(Ordering::Relaxed) + self.shed_draining.load(Ordering::Relaxed)
    }
    pub fn reaped(&self) -> u64 {
        self.reaped.load(Ordering::Relaxed)
    }

    fn publish(&self, plane: &str) {
        let reg = MetricsRegistry::global();
        let l = [("plane", plane)];
        reg.set_labeled("net_conns_open", &l, self.conns_open() as f64);
        reg.set_labeled("net_accept_total", &l, self.accepted() as f64);
        reg.set_labeled("net_requests_total", &l, self.served() as f64);
        reg.set_labeled("net_queue_depth", &l, self.queue_depth() as f64);
        reg.set_labeled("net_inflight", &l, self.inflight() as f64);
        reg.set_labeled("net_reaped_total", &l, self.reaped() as f64);
        reg.set_labeled(
            "net_shed_total",
            &[("plane", plane), ("reason", "queue_full")],
            self.shed_queue.load(Ordering::Relaxed) as f64,
        );
        reg.set_labeled(
            "net_shed_total",
            &[("plane", plane), ("reason", "draining")],
            self.shed_draining.load(Ordering::Relaxed) as f64,
        );
    }
}

/// Wakes the event loop from another thread (pool completions, shutdown):
/// one byte down a nonblocking socketpair the loop polls. A full pipe
/// means a wake is already pending, so `WouldBlock` is success.
#[derive(Clone)]
struct Waker(Arc<UnixStream>);

impl Waker {
    fn wake(&self) {
        let _ = (&*self.0).write(&[1u8]);
    }
}

/// Clonable control/observation handle, valid before and during `run`.
#[derive(Clone)]
pub struct NetServerHandle {
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
    waker: Waker,
}

impl NetServerHandle {
    /// Begin a graceful shutdown: stop accepting, shed new requests,
    /// flush in-flight responses, return from `run`.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.waker.wake();
    }

    pub fn stats(&self) -> &NetStats {
        &self.stats
    }

    /// True once a shutdown has been requested (or the request cap hit) —
    /// background pollers use this to die with the server.
    pub fn is_shutdown(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

enum Job {
    Request { token: u64, req: HttpRequest },
    Shutdown,
}

#[derive(Default)]
struct JobQueue {
    jobs: Mutex<VecDeque<Job>>,
    ready: Condvar,
}

type Completions = Mutex<Vec<(u64, HttpResponse)>>;

/// A bound runtime, ready to `run` a handler. Binding is separate from
/// running so callers can read the real address (port 0) and take a
/// [`NetServerHandle`] first.
pub struct NetServer {
    listener: TcpListener,
    opts: NetOptions,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
    wake_rx: UnixStream,
    wake_tx: Arc<UnixStream>,
}

impl NetServer {
    pub fn bind(addr: &str, opts: NetOptions) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let (wake_rx, wake_tx) = UnixStream::pair()?;
        wake_rx.set_nonblocking(true)?;
        wake_tx.set_nonblocking(true)?;
        Ok(NetServer {
            listener,
            opts,
            stats: Arc::new(NetStats::default()),
            stop: Arc::new(AtomicBool::new(false)),
            wake_rx,
            wake_tx: Arc::new(wake_tx),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn handle(&self) -> NetServerHandle {
        NetServerHandle {
            stats: self.stats.clone(),
            stop: self.stop.clone(),
            waker: Waker(self.wake_tx.clone()),
        }
    }

    /// Run the event loop until shutdown (or the `max_requests` cap).
    pub fn run(self, handler: Arc<dyn NetHandler>) -> Result<()> {
        let mut poller = Poller::new(self.opts.backend).map_err(Error::Io)?;
        poller.register(self.listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)?;
        poller.register(self.wake_rx.as_raw_fd(), TOKEN_WAKE, Interest::READ)?;
        LOG.info(&format!(
            "{} plane: {} backend, pool {}, queue {}, idle timeout {:?}, keep-alive {}",
            self.opts.plane,
            poller.name(),
            self.opts.max_inflight,
            self.opts.max_queue,
            self.opts.idle_timeout,
            self.opts.keep_alive,
        ));
        let queue = Arc::new(JobQueue::default());
        let completions: Arc<Completions> = Arc::new(Mutex::new(Vec::new()));
        let waker = Waker(self.wake_tx.clone());
        let workers = spawn_pool(
            self.opts.max_inflight,
            self.opts.plane,
            handler.clone(),
            queue.clone(),
            completions.clone(),
            self.stats.clone(),
            waker,
        );
        let mut lp = EventLoop {
            listener: self.listener,
            wake_rx: self.wake_rx,
            poller,
            opts: self.opts,
            stats: self.stats,
            stop: self.stop,
            handler,
            queue: queue.clone(),
            completions,
            conns: HashMap::new(),
            next_token: FIRST_CONN_TOKEN,
            draining: false,
            grace_deadline: None,
        };
        let result = lp.run();
        // Release the pool: sentinels behind any still-queued work.
        {
            let mut jobs = lock_unpoisoned(&queue.jobs);
            for _ in 0..workers.len() {
                jobs.push_back(Job::Shutdown);
            }
        }
        queue.ready.notify_all();
        for w in workers {
            let _ = w.join();
        }
        lp.stats.publish(lp.opts.plane);
        result
    }
}

fn spawn_pool(
    size: usize,
    plane: &'static str,
    handler: Arc<dyn NetHandler>,
    queue: Arc<JobQueue>,
    completions: Arc<Completions>,
    stats: Arc<NetStats>,
    waker: Waker,
) -> Vec<JoinHandle<()>> {
    (0..size)
        .map(|i| {
            let (handler, queue, completions, stats, waker) = (
                handler.clone(),
                queue.clone(),
                completions.clone(),
                stats.clone(),
                waker.clone(),
            );
            std::thread::Builder::new()
                .name(format!("net-{plane}-{i}"))
                .spawn(move || worker_loop(&handler, &queue, &completions, &stats, &waker))
                .expect("spawn net pool worker")
        })
        .collect()
}

fn worker_loop(
    handler: &Arc<dyn NetHandler>,
    queue: &JobQueue,
    completions: &Completions,
    stats: &NetStats,
    waker: &Waker,
) {
    loop {
        let job = {
            let mut jobs = lock_unpoisoned(&queue.jobs);
            loop {
                match jobs.pop_front() {
                    Some(job) => break job,
                    None => jobs = queue.ready.wait(jobs).unwrap_or_else(|e| e.into_inner()),
                }
            }
        };
        let (token, req) = match job {
            Job::Shutdown => return,
            Job::Request { token, req } => (token, req),
        };
        stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
        stats.inflight.fetch_add(1, Ordering::Relaxed);
        let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| handler.handle(req)))
            .unwrap_or_else(|_| HttpResponse {
                close: true,
                ..HttpResponse::text(500, "handler panicked\n")
            });
        stats.inflight.fetch_sub(1, Ordering::Relaxed);
        lock_unpoisoned(completions).push((token, resp));
        waker.wake();
    }
}

/// One live connection's state machine.
struct Conn {
    stream: TcpStream,
    parser: HttpParser,
    buf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    interest: Interest,
    /// A request from this connection is queued or in a pool worker; no
    /// further reads are parsed until its response is written (this is
    /// what makes pipelined responses come back in order).
    busy: bool,
    req_keep_alive: bool,
    close_after_flush: bool,
    read_closed: bool,
    last_activity: Instant,
}

struct EventLoop {
    listener: TcpListener,
    wake_rx: UnixStream,
    poller: Poller,
    opts: NetOptions,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
    handler: Arc<dyn NetHandler>,
    queue: Arc<JobQueue>,
    completions: Arc<Completions>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    draining: bool,
    grace_deadline: Option<Instant>,
}

impl EventLoop {
    fn run(&mut self) -> Result<()> {
        let tick = (self.opts.idle_timeout / 4)
            .clamp(Duration::from_millis(10), Duration::from_millis(250));
        let mut events = Vec::new();
        loop {
            self.stats.publish(self.opts.plane);
            if self.stop.load(Ordering::SeqCst) && !self.draining {
                self.begin_drain();
            }
            if self.draining {
                let pending =
                    self.conns.values().any(|c| c.busy || c.wpos < c.wbuf.len());
                let expired = self.grace_deadline.is_some_and(|d| Instant::now() >= d);
                if !pending || expired {
                    if expired && pending {
                        LOG.warn("drain grace expired with responses still in flight");
                    }
                    return Ok(());
                }
            }
            self.poller.wait(&mut events, Some(tick))?;
            for ev in std::mem::take(&mut events) {
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKE => self.drain_waker(),
                    token => {
                        if ev.readable {
                            self.read_ready(token);
                        }
                        if ev.writable && self.conns.contains_key(&token) {
                            self.try_flush(token);
                        }
                    }
                }
            }
            self.drain_completions();
            self.reap(Instant::now());
        }
    }

    fn begin_drain(&mut self) {
        self.draining = true;
        self.grace_deadline = Some(Instant::now() + DRAIN_GRACE);
        let _ = self.poller.deregister(self.listener.as_raw_fd());
        // Idle connections close now; busy ones flush their response first.
        let idle: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| !c.busy && c.wpos >= c.wbuf.len())
            .map(|(t, _)| *t)
            .collect();
        for token in idle {
            self.close(token);
        }
    }

    fn drain_waker(&mut self) {
        let mut sink = [0u8; 64];
        while matches!((&self.wake_rx).read(&mut sink), Ok(n) if n > 0) {}
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if self.draining {
                        continue; // accepted-then-dropped: we are going away
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let token = self.next_token;
                    self.next_token += 1;
                    if self.poller.register(stream.as_raw_fd(), token, Interest::READ).is_err() {
                        continue;
                    }
                    self.stats.accepted.fetch_add(1, Ordering::Relaxed);
                    self.stats.conns_open.fetch_add(1, Ordering::Relaxed);
                    self.conns.insert(
                        token,
                        Conn {
                            stream,
                            parser: HttpParser::new(self.opts.limits),
                            buf: Vec::new(),
                            wbuf: Vec::new(),
                            wpos: 0,
                            interest: Interest::READ,
                            busy: false,
                            req_keep_alive: true,
                            close_after_flush: false,
                            read_closed: false,
                            last_activity: Instant::now(),
                        },
                    );
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    LOG.warn(&format!("accept failed: {e}"));
                    return;
                }
            }
        }
    }

    fn read_ready(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        let mut tmp = [0u8; 16 * 1024];
        loop {
            // While a request is in flight we stop pulling more bytes —
            // level-triggered readiness re-reports them once it resolves.
            if conn.busy {
                break;
            }
            match conn.stream.read(&mut tmp) {
                Ok(0) => {
                    conn.read_closed = true;
                    break;
                }
                Ok(n) => {
                    conn.buf.extend_from_slice(&tmp[..n]);
                    conn.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close(token);
                    return;
                }
            }
        }
        self.try_parse(token);
    }

    /// Pull as many complete requests as the connection's buffer holds
    /// (at most one proceeds past the admission gate at a time).
    fn try_parse(&mut self, token: u64) {
        loop {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if conn.busy || conn.close_after_flush {
                return;
            }
            match conn.parser.parse(&mut conn.buf) {
                Ok(ParseStatus::Request(req)) => self.dispatch(token, req),
                Ok(ParseStatus::NeedMore) => {
                    if conn.read_closed {
                        if conn.wpos < conn.wbuf.len() {
                            conn.close_after_flush = true;
                        } else {
                            self.close(token);
                        }
                    } else {
                        self.update_interest(token);
                    }
                    return;
                }
                Err(e) => {
                    let resp = HttpResponse::protocol_error(&e);
                    self.write_response(token, resp, false);
                    return;
                }
            }
        }
    }

    fn dispatch(&mut self, token: u64, req: HttpRequest) {
        let req_keep_alive = req.keep_alive;
        // Inline fast path: liveness and metrics answer on the event loop,
        // bypassing admission — load balancers can still see a saturated
        // server, and the overload metrics stay scrapeable.
        if let Some(resp) = self.handler.handle_inline(&req) {
            self.write_response(token, resp, req_keep_alive);
            return;
        }
        if self.draining || self.stop.load(Ordering::SeqCst) {
            self.stats.shed_draining.fetch_add(1, Ordering::Relaxed);
            let resp = HttpResponse::overloaded("draining", RETRY_AFTER_S);
            self.write_response(token, resp, req_keep_alive);
            return;
        }
        if self.stats.queue_depth() >= self.opts.max_queue as u64 {
            self.stats.shed_queue.fetch_add(1, Ordering::Relaxed);
            let resp = HttpResponse::overloaded("queue_full", RETRY_AFTER_S);
            self.write_response(token, resp, req_keep_alive);
            return;
        }
        let Some(conn) = self.conns.get_mut(&token) else { return };
        conn.busy = true;
        conn.req_keep_alive = req_keep_alive;
        self.stats.queue_depth.fetch_add(1, Ordering::Relaxed);
        lock_unpoisoned(&self.queue.jobs).push_back(Job::Request { token, req });
        self.queue.ready.notify_one();
        self.update_interest(token);
    }

    fn drain_completions(&mut self) {
        let done = std::mem::take(&mut *lock_unpoisoned(&self.completions));
        for (token, resp) in done {
            let Some(conn) = self.conns.get_mut(&token) else { continue };
            conn.busy = false;
            let keep = conn.req_keep_alive;
            self.write_response(token, resp, keep);
            // The connection (if still open) may hold pipelined requests.
            self.try_parse(token);
        }
    }

    /// Render and enqueue a response; counts toward `max_requests` and
    /// decides keep-alive (client wish AND server policy AND not
    /// draining). Flushes opportunistically.
    fn write_response(&mut self, token: u64, resp: HttpResponse, req_keep_alive: bool) {
        let served = self.stats.served.fetch_add(1, Ordering::Relaxed) + 1;
        if self.opts.max_requests.is_some_and(|max| served >= max) {
            self.stop.store(true, Ordering::SeqCst);
        }
        let stopping = self.draining || self.stop.load(Ordering::SeqCst);
        let Some(conn) = self.conns.get_mut(&token) else { return };
        let keep = self.opts.keep_alive
            && req_keep_alive
            && !resp.close
            && !stopping
            && !conn.close_after_flush;
        conn.wbuf.extend_from_slice(&resp.render(keep));
        if !keep {
            conn.close_after_flush = true;
        }
        self.try_flush(token);
    }

    fn try_flush(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        while conn.wpos < conn.wbuf.len() {
            match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                Ok(0) => {
                    self.close(token);
                    return;
                }
                Ok(n) => {
                    conn.wpos += n;
                    conn.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close(token);
                    return;
                }
            }
        }
        if conn.wpos >= conn.wbuf.len() {
            conn.wbuf.clear();
            conn.wpos = 0;
            if conn.close_after_flush {
                self.close(token);
                return;
            }
        }
        self.update_interest(token);
    }

    fn update_interest(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        let desired = Interest {
            read: !conn.busy && !conn.read_closed,
            write: conn.wpos < conn.wbuf.len(),
        };
        if desired != conn.interest {
            if self.poller.modify(conn.stream.as_raw_fd(), token, desired).is_err() {
                self.close(token);
                return;
            }
            if let Some(conn) = self.conns.get_mut(&token) {
                conn.interest = desired;
            }
        }
    }

    /// Drop connections that made no forward progress for `idle_timeout`:
    /// idle keep-alives, half-sent heads (slowloris), unread responses.
    /// Busy connections are never reaped — their response is coming.
    fn reap(&mut self, now: Instant) {
        let timeout = self.opts.idle_timeout;
        let dead: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| !c.busy && now.duration_since(c.last_activity) > timeout)
            .map(|(t, _)| *t)
            .collect();
        for token in dead {
            self.stats.reaped.fetch_add(1, Ordering::Relaxed);
            self.close(token);
        }
    }

    fn close(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
            self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl NetHandler for Echo {
        fn handle(&self, req: HttpRequest) -> HttpResponse {
            HttpResponse::ok("text/plain", req.body)
        }
        fn handle_inline(&self, req: &HttpRequest) -> Option<HttpResponse> {
            (req.path == "/healthz").then(|| HttpResponse::text(200, "ok\n"))
        }
    }

    type ServerJoin = std::thread::JoinHandle<Result<()>>;

    fn start(opts: NetOptions) -> (SocketAddr, NetServerHandle, ServerJoin) {
        let server = NetServer::bind("127.0.0.1:0", opts).unwrap();
        let addr = server.local_addr().unwrap();
        let handle = server.handle();
        let join = std::thread::spawn(move || server.run(Arc::new(Echo)));
        (addr, handle, join)
    }

    /// Read exactly one framed HTTP response off the stream.
    fn read_response(s: &mut TcpStream) -> (String, String) {
        let mut buf = Vec::new();
        let mut tmp = [0u8; 1024];
        let head_end = loop {
            if let Some(i) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break i;
            }
            let n = s.read(&mut tmp).unwrap();
            assert!(n > 0, "eof before response head: {:?}", String::from_utf8_lossy(&buf));
            buf.extend_from_slice(&tmp[..n]);
        };
        let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
        let clen: usize = head
            .lines()
            .find_map(|l| {
                let lower = l.to_ascii_lowercase();
                let v = lower.strip_prefix("content-length:")?;
                Some(v.trim().parse().unwrap())
            })
            .unwrap();
        let mut body = buf[head_end + 4..].to_vec();
        while body.len() < clen {
            let n = s.read(&mut tmp).unwrap();
            assert!(n > 0, "eof mid-body");
            body.extend_from_slice(&tmp[..n]);
        }
        (head, String::from_utf8_lossy(&body[..clen]).into_owned())
    }

    #[test]
    fn keep_alive_serves_sequential_requests_on_one_connection() {
        let (addr, handle, join) = start(NetOptions::default());
        let mut s = TcpStream::connect(addr).unwrap();
        for i in 0..3 {
            let body = format!("ping-{i}");
            let req = format!(
                "POST /echo HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            s.write_all(req.as_bytes()).unwrap();
            let (head, got) = read_response(&mut s);
            assert!(head.starts_with("HTTP/1.1 200"), "{head}");
            assert!(head.contains("Connection: keep-alive"), "{head}");
            assert_eq!(got, body);
        }
        assert_eq!(handle.stats().accepted(), 1, "one connection carried all requests");
        handle.shutdown();
        join.join().unwrap().unwrap();
    }

    #[test]
    fn pipelined_requests_answered_in_order() {
        let (addr, handle, join) = start(NetOptions::default());
        let mut s = TcpStream::connect(addr).unwrap();
        let mut wire = String::new();
        for i in 0..3 {
            wire.push_str(&format!("POST /e HTTP/1.1\r\nContent-Length: 2\r\n\r\nr{i}"));
        }
        s.write_all(wire.as_bytes()).unwrap();
        for i in 0..3 {
            let (_, body) = read_response(&mut s);
            assert_eq!(body, format!("r{i}"), "pipeline order");
        }
        handle.shutdown();
        join.join().unwrap().unwrap();
    }

    #[test]
    fn max_requests_counts_responses_and_exits() {
        let opts = NetOptions { max_requests: Some(2), ..NetOptions::default() };
        let (addr, _handle, join) = start(opts);
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        let (head, _) = read_response(&mut s);
        assert!(head.contains("Connection: keep-alive"), "{head}");
        s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        let (head, _) = read_response(&mut s);
        assert!(head.contains("Connection: close"), "final response closes: {head}");
        join.join().unwrap().unwrap();
    }

    #[test]
    fn poll_fallback_roundtrip() {
        let opts = NetOptions { backend: Backend::Poll, ..NetOptions::default() };
        let (addr, handle, join) = start(opts);
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"POST /e HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi").unwrap();
        let (_, body) = read_response(&mut s);
        assert_eq!(body, "hi");
        handle.shutdown();
        join.join().unwrap().unwrap();
    }
}
