//! Thin Householder QR.
//!
//! Used by the power-iteration extension of the randomized SVD (re-orthonormalize
//! the sketch between passes, Halko et al. §4.5), by dataset generation (exact
//! low-rank factors need orthonormal columns), and by tests as an independent
//! orthonormality oracle.

use super::matrix::Matrix;
use super::ops::matmul;
use crate::error::{Error, Result};

/// Thin QR of a tall matrix `a` (m >= n): returns `(Q, R)` with `Q` `m x n`
/// orthonormal columns and `R` `n x n` upper triangular, `a = Q R`.
pub fn thin_qr(a: &Matrix) -> Result<(Matrix, Matrix)> {
    let (m, n) = a.shape();
    if m < n {
        return Err(Error::shape(format!("thin_qr: need m >= n, got {m}x{n}")));
    }
    // Householder vectors stored in-place below the diagonal of `r`.
    let mut r = a.clone();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);

    for j in 0..n {
        // Norm of the j-th column below (and including) the diagonal.
        let mut norm = 0.0f64;
        for i in j..m {
            norm += r.get(i, j).powi(2);
        }
        let norm = norm.sqrt();
        let mut v = vec![0.0; m - j];
        if norm == 0.0 {
            vs.push(v);
            continue;
        }
        let alpha = if r.get(j, j) >= 0.0 { -norm } else { norm };
        for i in j..m {
            v[i - j] = r.get(i, j);
        }
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 > 0.0 {
            // Apply H = I - 2 v v^T / (v^T v) to r[j.., j..].
            for col in j..n {
                let mut dot = 0.0;
                for i in j..m {
                    dot += v[i - j] * r.get(i, col);
                }
                let f = 2.0 * dot / vnorm2;
                for i in j..m {
                    let val = r.get(i, col) - f * v[i - j];
                    r.set(i, col, val);
                }
            }
        }
        vs.push(v);
    }

    // Build thin Q by applying the Householder reflectors to the first n
    // columns of the identity, in reverse order.
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q.set(j, j, 1.0);
    }
    for j in (0..n).rev() {
        let v = &vs[j];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        for col in 0..n {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i - j] * q.get(i, col);
            }
            let f = 2.0 * dot / vnorm2;
            for i in j..m {
                let val = q.get(i, col) - f * v[i - j];
                q.set(i, col, val);
            }
        }
    }

    // Zero out below-diagonal of R (it holds reflector debris).
    let mut r_clean = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_clean.set(i, j, r.get(i, j));
        }
    }
    Ok((q, r_clean))
}

/// Orthonormalize the columns of `a` (the Q factor only).
pub fn orthonormalize(a: &Matrix) -> Result<Matrix> {
    Ok(thin_qr(a)?.0)
}

/// Max deviation of `Q^T Q` from identity — orthonormality residual.
pub fn orthonormality_residual(q: &Matrix) -> f64 {
    let qtq = matmul(&q.t(), q).expect("square product");
    qtq.max_abs_diff(&Matrix::eye(q.cols()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Gaussian;

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let g = Gaussian::new(seed);
        Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
    }

    #[test]
    fn qr_reconstructs() {
        for (m, n, seed) in [(5, 3, 1), (20, 20, 2), (100, 7, 3), (64, 32, 4)] {
            let a = random_matrix(m, n, seed);
            let (q, r) = thin_qr(&a).unwrap();
            let qr = matmul(&q, &r).unwrap();
            assert!(qr.max_abs_diff(&a) < 1e-9, "{m}x{n}");
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let a = random_matrix(50, 10, 5);
        let (q, _) = thin_qr(&a).unwrap();
        assert!(orthonormality_residual(&q) < 1e-10);
    }

    #[test]
    fn r_is_upper_triangular() {
        let a = random_matrix(30, 8, 6);
        let (_, r) = thin_qr(&a).unwrap();
        for i in 0..8 {
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn rank_deficient_column() {
        // Second column = 2x first: R[1][1] should be ~0, no NaNs.
        let mut a = Matrix::zeros(10, 2);
        let g = Gaussian::new(7);
        for i in 0..10 {
            let v = g.sample(i as u64, 0);
            a.set(i, 0, v);
            a.set(i, 1, 2.0 * v);
        }
        let (q, r) = thin_qr(&a).unwrap();
        assert!(r.get(1, 1).abs() < 1e-10);
        assert!(!q.data().iter().any(|v| v.is_nan()));
        assert!(matmul(&q, &r).unwrap().max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn rejects_wide_matrix() {
        assert!(thin_qr(&Matrix::zeros(2, 5)).is_err());
    }

    #[test]
    fn identity_fixed_point() {
        let (q, r) = thin_qr(&Matrix::eye(6)).unwrap();
        assert!(q.max_abs_diff(&Matrix::eye(6)) < 1e-12 || {
            // sign flips are legal; check |Q| = I instead
            let mut ok = true;
            for i in 0..6 {
                for j in 0..6 {
                    let want = if i == j { 1.0 } else { 0.0 };
                    ok &= (q.get(i, j).abs() - want).abs() < 1e-12;
                }
            }
            ok
        });
        for i in 0..6 {
            assert!((r.get(i, i).abs() - 1.0).abs() < 1e-12);
        }
    }
}
