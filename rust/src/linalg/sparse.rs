//! Sparse row-major matrix (CSR) and the sparse pass kernels.
//!
//! The paper's tall-and-fat user×feature logs are sparse in practice;
//! Halko–Martinsson–Tropp (0909.4061) only needs the operator applied to
//! blocks of vectors, which a CSR row stripe provides directly. Everything
//! here is `O(nnz)` work and memory where the dense kernels are `O(m·n)`:
//!
//! * [`sp_matmul`] — `Y = X W` (projection / `U = A M` recovery),
//! * [`sp_matmul_gram`] — fused `(Y, YᵀY)`, the pass-1 hot path,
//! * [`sp_tmul`] — `W = Xᵀ Z`, the pass-2 accumulation,
//! * [`sp_gram`] — `G = Xᵀ X` by per-row outer products over the
//!   nonzeros (the sparse form of the `outer_accumulate` path).
//!
//! Column indices are `u32` (4 billion feature columns is beyond the
//! leader-side `n × n` math anyway) and strictly ascending within a row.

use super::matrix::Matrix;
use crate::error::{Error, Result};

/// Compressed sparse row matrix over `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseMatrix {
    rows: usize,
    cols: usize,
    /// Row pointers, `rows + 1` entries; row `i` spans
    /// `indptr[i]..indptr[i+1]` of `indices`/`values`.
    indptr: Vec<usize>,
    /// Column indices, ascending within each row.
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl SparseMatrix {
    /// Empty matrix with zero rows and a fixed column count; rows are
    /// appended with [`SparseMatrix::push_row`].
    pub fn with_cols(cols: usize) -> Self {
        SparseMatrix { rows: 0, cols, indptr: vec![0], indices: Vec::new(), values: Vec::new() }
    }

    /// Build from raw CSR parts (validated).
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Result<Self> {
        if indptr.len() != rows + 1 {
            return Err(Error::shape(format!(
                "csr: indptr has {} entries for {rows} rows",
                indptr.len()
            )));
        }
        if indptr[0] != 0 || *indptr.last().unwrap() != indices.len() {
            return Err(Error::shape("csr: indptr does not span the index array"));
        }
        if indices.len() != values.len() {
            return Err(Error::shape("csr: indices/values length mismatch"));
        }
        for w in indptr.windows(2) {
            if w[1] < w[0] {
                return Err(Error::shape("csr: indptr not monotone"));
            }
            // Strictly ascending within each row — sp_gram's upper-triangle
            // walk and the validators' cursor scans rely on it.
            let row = &indices[w[0]..w[1]];
            for pair in row.windows(2) {
                if pair[1] <= pair[0] {
                    return Err(Error::parse(format!(
                        "csr: indices not ascending within a row ({} then {})",
                        pair[0], pair[1]
                    )));
                }
            }
        }
        for &j in &indices {
            if j as usize >= cols {
                return Err(Error::shape(format!("csr: column {j} out of range ({cols})")));
            }
        }
        Ok(SparseMatrix { rows, cols, indptr, indices, values })
    }

    /// Append one row given its nonzeros. Indices must be ascending,
    /// in-range, and duplicate-free; zero-valued entries are dropped.
    pub fn push_row(&mut self, indices: &[u32], values: &[f64]) -> Result<()> {
        if indices.len() != values.len() {
            return Err(Error::shape("csr push_row: indices/values length mismatch"));
        }
        let mut last: Option<u32> = None;
        for (&j, &v) in indices.iter().zip(values.iter()) {
            if j as usize >= self.cols {
                return Err(Error::shape(format!(
                    "csr push_row: column {j} out of range ({})",
                    self.cols
                )));
            }
            if let Some(prev) = last {
                if j <= prev {
                    return Err(Error::parse(format!(
                        "csr push_row: indices not ascending ({prev} then {j})"
                    )));
                }
            }
            last = Some(j);
            if v != 0.0 {
                self.indices.push(j);
                self.values.push(v);
            }
        }
        self.rows += 1;
        self.indptr.push(self.indices.len());
        Ok(())
    }

    /// Drop all rows (keeps allocations — the block-buffer reuse path).
    pub fn clear_rows(&mut self) {
        self.rows = 0;
        self.indptr.clear();
        self.indptr.push(0);
        self.indices.clear();
        self.values.clear();
    }

    /// Sparsify a dense matrix (entries with `|x| <= tol` dropped).
    pub fn from_dense(m: &Matrix, tol: f64) -> Self {
        let mut s = SparseMatrix::with_cols(m.cols());
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v.abs() > tol {
                    s.indices.push(j as u32);
                    s.values.push(v);
                }
            }
            s.rows += 1;
            s.indptr.push(s.indices.len());
        }
        s
    }

    /// Densify (the Backend trait's fallback path and a test oracle).
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            let out = m.row_mut(i);
            for (&j, &v) in idx.iter().zip(val.iter()) {
                out[j as usize] = v;
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Fraction of entries that are stored (`nnz / (rows * cols)`).
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Row `i` as `(indices, values)` slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Raw CSR parts `(indptr, indices, values)` — the serialization view.
    pub fn parts(&self) -> (&[usize], &[u32], &[f64]) {
        (&self.indptr, &self.indices, &self.values)
    }

    /// Per-column sums (the sparse ColStats partial).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut sums = vec![0.0; self.cols];
        for (&j, &v) in self.indices.iter().zip(self.values.iter()) {
            sums[j as usize] += v;
        }
        sums
    }
}

/// `Y = X W` for CSR `X` (`b x n`) and dense `W` (`n x k`) — `O(nnz * k)`.
pub fn sp_matmul(x: &SparseMatrix, w: &Matrix) -> Result<Matrix> {
    if x.cols() != w.rows() {
        return Err(Error::shape(format!(
            "sp_matmul: ({},{}) x ({},{})",
            x.rows(),
            x.cols(),
            w.rows(),
            w.cols()
        )));
    }
    let k = w.cols();
    let mut y = Matrix::zeros(x.rows(), k);
    let yd = y.data_mut();
    let wd = w.data();
    for i in 0..x.rows() {
        let (idx, val) = x.row(i);
        let yrow = &mut yd[i * k..(i + 1) * k];
        for (&j, &v) in idx.iter().zip(val.iter()) {
            let wrow = &wd[j as usize * k..(j as usize + 1) * k];
            for (yv, wv) in yrow.iter_mut().zip(wrow.iter()) {
                *yv += v * wv;
            }
        }
    }
    Ok(y)
}

/// Fused `(Y, YᵀY) = (X W, (X W)ᵀ (X W))` — the sparse pass-1 hot path.
/// Each produced row folds into the Gram upper triangle while cache-hot.
pub fn sp_matmul_gram(x: &SparseMatrix, w: &Matrix) -> Result<(Matrix, Matrix)> {
    if x.cols() != w.rows() {
        return Err(Error::shape(format!(
            "sp_matmul_gram: ({},{}) x ({},{})",
            x.rows(),
            x.cols(),
            w.rows(),
            w.cols()
        )));
    }
    let k = w.cols();
    let mut y = Matrix::zeros(x.rows(), k);
    let mut g = Matrix::zeros(k, k);
    {
        let yd = y.data_mut();
        let gd = g.data_mut();
        let wd = w.data();
        for i in 0..x.rows() {
            let (idx, val) = x.row(i);
            let yrow = &mut yd[i * k..(i + 1) * k];
            for (&j, &v) in idx.iter().zip(val.iter()) {
                let wrow = &wd[j as usize * k..(j as usize + 1) * k];
                for (yv, wv) in yrow.iter_mut().zip(wrow.iter()) {
                    *yv += v * wv;
                }
            }
            // Gram contribution of the finished row (upper triangle).
            for a in 0..k {
                let ya = yrow[a];
                if ya == 0.0 {
                    continue;
                }
                let grow = &mut gd[a * k + a..(a + 1) * k];
                for (gv, yv) in grow.iter_mut().zip(yrow[a..].iter()) {
                    *gv += ya * yv;
                }
            }
        }
        // mirror upper -> lower
        for a in 0..k {
            for b in 0..a {
                let v = gd[b * k + a];
                gd[a * k + b] = v;
            }
        }
    }
    Ok((y, g))
}

/// `W = Xᵀ Z` where CSR `X` and dense `Z` share their row count —
/// `O(nnz * k)` (the sparse pass-2 accumulation).
pub fn sp_tmul(x: &SparseMatrix, z: &Matrix) -> Result<Matrix> {
    if x.rows() != z.rows() {
        return Err(Error::shape(format!(
            "sp_tmul: {} vs {} rows",
            x.rows(),
            z.rows()
        )));
    }
    let (n, k) = (x.cols(), z.cols());
    let mut w = Matrix::zeros(n, k);
    let wd = w.data_mut();
    for i in 0..x.rows() {
        let (idx, val) = x.row(i);
        let zrow = z.row(i);
        for (&j, &v) in idx.iter().zip(val.iter()) {
            let wrow = &mut wd[j as usize * k..(j as usize + 1) * k];
            for (wv, zv) in wrow.iter_mut().zip(zrow.iter()) {
                *wv += v * zv;
            }
        }
    }
    Ok(w)
}

/// `G = Xᵀ X` by per-row outer products over the nonzeros —
/// `O(Σ nnz_i²)`, upper triangle then mirrored.
pub fn sp_gram(x: &SparseMatrix) -> Matrix {
    let n = x.cols();
    let mut g = Matrix::zeros(n, n);
    let gd = g.data_mut();
    for i in 0..x.rows() {
        let (idx, val) = x.row(i);
        for a in 0..idx.len() {
            let (ja, va) = (idx[a] as usize, val[a]);
            for b in a..idx.len() {
                gd[ja * n + idx[b] as usize] += va * val[b];
            }
        }
    }
    // mirror upper -> lower (ascending indices put every product in the
    // upper triangle)
    for i in 0..n {
        for j in 0..i {
            let v = gd[j * n + i];
            gd[i * n + j] = v;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gram, matmul, matmul_tn};
    use crate::rng::Gaussian;

    /// ~`density` sparse random matrix with deterministic pattern.
    fn sparse_fixture(rows: usize, cols: usize, density: f64, seed: u64) -> SparseMatrix {
        let g = Gaussian::new(seed);
        let mut dense = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                let u = crate::rng::splitmix::to_unit_open(crate::rng::splitmix::mix3(
                    seed ^ 0xDA7A,
                    i as u64,
                    j as u64,
                ));
                if u < density {
                    dense.set(i, j, g.sample(i as u64, j as u64));
                }
            }
        }
        SparseMatrix::from_dense(&dense, 0.0)
    }

    #[test]
    fn from_dense_roundtrip() {
        let m = Matrix::from_rows(&[
            vec![1.0, 0.0, -2.0],
            vec![0.0, 0.0, 0.0],
            vec![0.0, 3.5, 0.0],
        ])
        .unwrap();
        let s = SparseMatrix::from_dense(&m, 0.0);
        assert_eq!(s.shape(), (3, 3));
        assert_eq!(s.nnz(), 3);
        assert_eq!(s.to_dense(), m);
        let (idx, val) = s.row(0);
        assert_eq!(idx, &[0, 2]);
        assert_eq!(val, &[1.0, -2.0]);
        assert_eq!(s.row(1).0.len(), 0);
    }

    #[test]
    fn push_row_validates() {
        let mut s = SparseMatrix::with_cols(4);
        s.push_row(&[0, 3], &[1.0, 2.0]).unwrap();
        s.push_row(&[], &[]).unwrap(); // all-zero row
        assert_eq!(s.rows(), 2);
        assert!(s.push_row(&[2, 1], &[1.0, 1.0]).is_err(), "descending");
        assert!(s.push_row(&[4], &[1.0]).is_err(), "out of range");
        assert!(s.push_row(&[1], &[]).is_err(), "length mismatch");
    }

    #[test]
    fn push_row_drops_explicit_zeros() {
        let mut s = SparseMatrix::with_cols(3);
        s.push_row(&[0, 1, 2], &[1.0, 0.0, 2.0]).unwrap();
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.to_dense().row(0), &[1.0, 0.0, 2.0]);
    }

    #[test]
    fn clear_rows_resets() {
        let mut s = sparse_fixture(10, 6, 0.4, 1);
        assert!(s.nnz() > 0);
        s.clear_rows();
        assert_eq!(s.rows(), 0);
        assert_eq!(s.nnz(), 0);
        s.push_row(&[1], &[2.0]).unwrap();
        assert_eq!(s.to_dense().get(0, 1), 2.0);
    }

    #[test]
    fn from_parts_validates() {
        assert!(SparseMatrix::from_parts(2, 3, vec![0, 1, 2], vec![0, 2], vec![1.0, 2.0]).is_ok());
        assert!(SparseMatrix::from_parts(2, 3, vec![0, 1], vec![0], vec![1.0]).is_err());
        assert!(SparseMatrix::from_parts(1, 3, vec![0, 2], vec![0], vec![1.0]).is_err());
        assert!(SparseMatrix::from_parts(1, 2, vec![0, 1], vec![5], vec![1.0]).is_err());
        // Descending or duplicate indices within a row break sp_gram's
        // upper-triangle invariant and must be rejected.
        assert!(
            SparseMatrix::from_parts(1, 3, vec![0, 2], vec![2, 0], vec![1.0, 1.0]).is_err(),
            "descending"
        );
        assert!(
            SparseMatrix::from_parts(1, 3, vec![0, 2], vec![1, 1], vec![1.0, 1.0]).is_err(),
            "duplicate"
        );
        // Ascending across a row *boundary* is not required.
        assert!(
            SparseMatrix::from_parts(2, 3, vec![0, 1, 2], vec![2, 0], vec![1.0, 1.0]).is_ok()
        );
    }

    #[test]
    fn sp_matmul_matches_dense() {
        let x = sparse_fixture(40, 12, 0.15, 2);
        let g = Gaussian::new(3);
        let w = Matrix::from_fn(12, 5, |i, j| g.sample(100 + i as u64, j as u64));
        let y = sp_matmul(&x, &w).unwrap();
        let want = matmul(&x.to_dense(), &w).unwrap();
        assert!(y.max_abs_diff(&want) < 1e-12);
        assert!(sp_matmul(&x, &Matrix::zeros(5, 5)).is_err());
    }

    #[test]
    fn sp_matmul_gram_matches_oracle() {
        let x = sparse_fixture(50, 10, 0.2, 4);
        let g = Gaussian::new(5);
        let w = Matrix::from_fn(10, 4, |i, j| g.sample(200 + i as u64, j as u64));
        let (y, yty) = sp_matmul_gram(&x, &w).unwrap();
        let y_want = matmul(&x.to_dense(), &w).unwrap();
        assert!(y.max_abs_diff(&y_want) < 1e-12);
        assert!(yty.max_abs_diff(&gram(&y_want)) < 1e-10);
    }

    #[test]
    fn sp_tmul_matches_dense() {
        let x = sparse_fixture(30, 8, 0.25, 6);
        let g = Gaussian::new(7);
        let z = Matrix::from_fn(30, 3, |i, j| g.sample(300 + i as u64, j as u64));
        let w = sp_tmul(&x, &z).unwrap();
        let want = matmul_tn(&x.to_dense(), &z).unwrap();
        assert!(w.max_abs_diff(&want) < 1e-12);
        assert!(sp_tmul(&x, &Matrix::zeros(5, 3)).is_err());
    }

    #[test]
    fn sp_gram_matches_dense() {
        let x = sparse_fixture(60, 9, 0.3, 8);
        let got = sp_gram(&x);
        assert!(got.max_abs_diff(&gram(&x.to_dense())) < 1e-10);
    }

    #[test]
    fn all_zero_rows_contribute_nothing() {
        let mut s = SparseMatrix::with_cols(4);
        s.push_row(&[1], &[2.0]).unwrap();
        s.push_row(&[], &[]).unwrap();
        s.push_row(&[0, 3], &[1.0, -1.0]).unwrap();
        let w = Matrix::eye(4);
        let y = sp_matmul(&s, &w).unwrap();
        assert_eq!(y.row(1), &[0.0; 4]);
        let g = sp_gram(&s);
        assert!(g.max_abs_diff(&gram(&s.to_dense())) < 1e-12);
        assert_eq!(s.col_sums(), vec![1.0, 2.0, 0.0, -1.0]);
    }
}
