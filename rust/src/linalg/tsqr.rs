//! Streaming TSQR — the tall-and-skinny QR of the paper's reference [1]
//! (Gleich, Benson, Demmel: "Direct QR factorizations for tall-and-skinny
//! matrices in MapReduce architectures").
//!
//! Included as the numerically-stable alternative to the paper's Gram
//! route: `AᵀA` squares the condition number (singular values below
//! `sqrt(eps)·σ_max` drown in f64), while TSQR's R factor carries them at
//! working precision. The ablation bench (E9.a) quantifies exactly where
//! the paper's method loses digits and TSQR does not.
//!
//! Shape: workers stream row blocks, folding each into a running `n x n`
//! R factor (`R ← qr([R; block]).R`); the leader stacks the per-worker Rs
//! and QRs once more. `σ(A) = σ(R)` exactly, and `AᵀA = RᵀR` — so the same
//! leader-side eigen/svd machinery applies.

use super::{exact_svd, qr::thin_qr, ExactSvd, Matrix};
use crate::error::{Error, Result};

/// A streaming R-factor accumulator (one per worker).
#[derive(Debug)]
pub struct TsqrAccumulator {
    n: usize,
    r: Option<Matrix>,
}

impl TsqrAccumulator {
    pub fn new(n: usize) -> Self {
        TsqrAccumulator { n, r: None }
    }

    /// Fold a row block into the running R: `R ← qr([R; block]).R`.
    pub fn push_block(&mut self, block: &Matrix) -> Result<()> {
        if block.cols() != self.n {
            return Err(Error::shape(format!(
                "tsqr: block has {} cols, expected {}",
                block.cols(),
                self.n
            )));
        }
        if block.rows() == 0 {
            return Ok(());
        }
        let stacked = match self.r.take() {
            Some(r) => r.vstack(block)?,
            None => block.clone(),
        };
        // QR needs rows >= cols; buffer short prefixes until we have enough.
        if stacked.rows() < self.n {
            self.r = Some(stacked);
            return Ok(());
        }
        let (_, r) = thin_qr(&stacked)?;
        self.r = Some(r);
        Ok(())
    }

    /// The current R factor (`n x n`, or fewer rows if fewer than n rows
    /// were seen).
    pub fn r_factor(&self) -> Option<&Matrix> {
        self.r.as_ref()
    }

    /// Merge another accumulator (the leader-side tree reduce).
    pub fn merge(&mut self, other: TsqrAccumulator) -> Result<()> {
        if let Some(r) = other.r {
            self.push_block(&r)?;
        }
        Ok(())
    }

    /// Finish: the definitive `min(rows_seen, n) x n` R factor.
    pub fn finish(self) -> Result<Matrix> {
        self.r
            .ok_or_else(|| Error::Other("tsqr over zero rows".into()))
    }
}

/// Leader-side reduce over per-worker R factors, then the full SVD of the
/// definitive R: `σ(A) = σ(R)` exactly, and R's right singular vectors are
/// A's — which is what the distributed W reduction consumes as the
/// completion rotation ([`crate::svd::reduce`]). The returned `u` is R's
/// (small, square) — useful only for reconstructing R itself.
pub fn svd_from_partials(n: usize, partials: Vec<Matrix>) -> Result<ExactSvd> {
    let mut acc = TsqrAccumulator::new(n);
    for p in partials {
        acc.push_block(&p)?;
    }
    let r = acc.finish()?;
    // R may be rows < n if m < n (not tall) — exact_svd requires tall.
    let square = if r.rows() < n {
        let mut padded = Matrix::zeros(n, n);
        for i in 0..r.rows() {
            padded.row_mut(i).copy_from_slice(r.row(i));
        }
        padded
    } else {
        r
    };
    exact_svd(&square)
}

/// Leader-side reduce over per-worker R factors, then σ(A) = σ(R).
pub fn sigma_from_partials(n: usize, partials: Vec<Matrix>) -> Result<Vec<f64>> {
    Ok(svd_from_partials(n, partials)?.sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gram;
    use crate::rng::Gaussian;

    fn rand(rows: usize, cols: usize, seed: u64) -> Matrix {
        let g = Gaussian::new(seed);
        Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
    }

    #[test]
    fn r_satisfies_rtr_equals_ata() {
        let a = rand(200, 8, 1);
        let mut acc = TsqrAccumulator::new(8);
        for i in (0..200).step_by(32) {
            acc.push_block(&a.slice_rows(i, (i + 32).min(200))).unwrap();
        }
        let r = acc.finish().unwrap();
        let rtr = gram(&r);
        let ata = gram(&a);
        assert!(rtr.max_abs_diff(&ata) < 1e-9 * 200.0);
    }

    #[test]
    fn sigma_matches_exact_svd() {
        let a = rand(150, 6, 2);
        let want = exact_svd(&a).unwrap().sigma;
        let mut acc = TsqrAccumulator::new(6);
        acc.push_block(&a).unwrap();
        let got = sigma_from_partials(6, vec![acc.finish().unwrap()]).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9 * w.max(1.0), "{g} vs {w}");
        }
    }

    #[test]
    fn svd_from_partials_recovers_right_vectors() {
        let a = rand(150, 6, 2);
        let want = exact_svd(&a).unwrap();
        let mut acc = TsqrAccumulator::new(6);
        acc.push_block(&a).unwrap();
        let got = svd_from_partials(6, vec![acc.finish().unwrap()]).unwrap();
        for j in 0..6 {
            let dot: f64 = (0..6).map(|i| got.v.get(i, j) * want.v.get(i, j)).sum();
            let sign = if dot < 0.0 { -1.0 } else { 1.0 };
            for i in 0..6 {
                assert!(
                    (got.v.get(i, j) - sign * want.v.get(i, j)).abs() < 1e-8,
                    "v[{i},{j}]"
                );
            }
        }
    }

    #[test]
    fn merge_equals_single_stream() {
        let a = rand(120, 5, 3);
        // one stream
        let mut one = TsqrAccumulator::new(5);
        one.push_block(&a).unwrap();
        let sig_one = sigma_from_partials(5, vec![one.finish().unwrap()]).unwrap();
        // three workers + merge
        let parts: Vec<Matrix> = (0..3)
            .map(|w| {
                let mut acc = TsqrAccumulator::new(5);
                acc.push_block(&a.slice_rows(w * 40, (w + 1) * 40)).unwrap();
                acc.finish().unwrap()
            })
            .collect();
        let sig_merged = sigma_from_partials(5, parts).unwrap();
        for (x, y) in sig_one.iter().zip(&sig_merged) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn survives_ill_conditioning_where_gram_does_not() {
        // sigma spans 1e8: kappa^2 = 1e16 > 1/eps_f64 — the Gram route
        // must lose the tail; TSQR must keep ~8 digits of it.
        let n = 6;
        let m = 300;
        let (a, _) = crate::io::dataset::gen_exact(
            m,
            n,
            n,
            crate::io::dataset::Spectrum::Geometric { scale: 1.0, decay: 0.025 },
            0.0,
            7,
        )
        .unwrap();
        // ground truth from the dense Jacobi SVD (the generator's declared
        // sigma has its own f64 construction floor at this conditioning)
        let smin = exact_svd(&a).unwrap().sigma[n - 1]; // ~1e-8
        // TSQR route
        let mut acc = TsqrAccumulator::new(n);
        acc.push_block(&a).unwrap();
        let tsqr_sigma = sigma_from_partials(n, vec![acc.finish().unwrap()]).unwrap();
        let tsqr_rel = (tsqr_sigma[n - 1] - smin).abs() / smin;
        // Gram route
        let g = gram(&a);
        let (w, _) = crate::linalg::eigen::eigh(&g).unwrap();
        let gram_smin = w[n - 1].max(0.0).sqrt();
        let gram_rel = (gram_smin - smin).abs() / smin;
        assert!(tsqr_rel < 1e-4, "tsqr lost sigma_min: rel {tsqr_rel}");
        assert!(
            gram_rel > 1e-2,
            "gram route unexpectedly kept sigma_min (rel {gram_rel}) — test matrix not hard enough"
        );
    }

    #[test]
    fn fewer_rows_than_cols_buffered() {
        let a = rand(3, 5, 4);
        let mut acc = TsqrAccumulator::new(5);
        acc.push_block(&a).unwrap();
        let sig = sigma_from_partials(5, vec![acc.finish().unwrap()]).unwrap();
        let want = {
            // pad to square for the oracle too
            let mut p = Matrix::zeros(5, 5);
            for i in 0..3 {
                p.row_mut(i).copy_from_slice(a.row(i));
            }
            exact_svd(&p).unwrap().sigma
        };
        for (g, w) in sig.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_rows_is_error() {
        let acc = TsqrAccumulator::new(4);
        assert!(acc.finish().is_err());
    }

    #[test]
    fn wrong_width_rejected() {
        let mut acc = TsqrAccumulator::new(4);
        assert!(acc.push_block(&rand(10, 5, 5)).is_err());
    }
}
