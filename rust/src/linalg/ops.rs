//! Blocked dense products.
//!
//! The native [`crate::backend`] hot paths live here: `gram` (the paper's
//! `X^T X`), `matmul` (projection `X Ω`), and `matmul_tn` (`X^T Z`, the
//! pass-2 accumulation). All use cache-blocked ikj loops over the row-major
//! layout; `gram_outer` is the paper's literal per-row outer-product
//! formulation, kept for the E5 experiment and as a cross-check.

use super::matrix::Matrix;
use crate::error::{Error, Result};

/// Cache block edge for the ikj loops (elements, not bytes). 64x64 f64 tiles
/// (32 KiB working set) sit comfortably in L1 for the row-major layout.
const BLOCK: usize = 64;

/// `C = A B` — blocked ikj matmul.
pub fn matmul(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.cols() != b.rows() {
        return Err(Error::shape(format!(
            "matmul: ({},{}) x ({},{})",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let (m, n, p) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, p);
    let cd = c.data_mut();
    let ad = a.data();
    let bd = b.data();
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..n).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(n);
            for i in i0..i1 {
                let arow = &ad[i * n..(i + 1) * n];
                let crow = &mut cd[i * p..(i + 1) * p];
                for k in k0..k1 {
                    let aik = arow[k];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &bd[k * p..(k + 1) * p];
                    for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
    Ok(c)
}

/// Fused `(C, C^T C) = (A B, (A B)^T (A B))` — the pass-1 hot path.
///
/// Computes each `BLOCK`-row stripe of `C = A B` and immediately folds
/// those freshly produced rows into the Gram upper triangle while they are
/// still cache-hot — one sweep over C, instead of `matmul` followed by a
/// second full pass over the product (what `gram(matmul(..))`, the test
/// oracle, does).
pub fn matmul_gram(a: &Matrix, b: &Matrix) -> Result<(Matrix, Matrix)> {
    if a.cols() != b.rows() {
        return Err(Error::shape(format!(
            "matmul_gram: ({},{}) x ({},{})",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let (m, n, p) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, p);
    let mut g = Matrix::zeros(p, p);
    {
        let cd = c.data_mut();
        let gd = g.data_mut();
        let ad = a.data();
        let bd = b.data();
        for i0 in (0..m).step_by(BLOCK) {
            let i1 = (i0 + BLOCK).min(m);
            // Finish rows i0..i1 of C across all of B's columns...
            for k0 in (0..n).step_by(BLOCK) {
                let k1 = (k0 + BLOCK).min(n);
                for i in i0..i1 {
                    let arow = &ad[i * n..(i + 1) * n];
                    let crow = &mut cd[i * p..(i + 1) * p];
                    for k in k0..k1 {
                        let aik = arow[k];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &bd[k * p..(k + 1) * p];
                        for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
            // ...then accumulate their Gram contribution (upper triangle)
            // while the stripe is still hot.
            for i in i0..i1 {
                let crow = &cd[i * p..(i + 1) * p];
                for j in 0..p {
                    let cij = crow[j];
                    if cij == 0.0 {
                        continue;
                    }
                    let grow = &mut gd[j * p + j..(j + 1) * p];
                    for (gv, cv) in grow.iter_mut().zip(crow[j..].iter()) {
                        *gv += cij * cv;
                    }
                }
            }
        }
        // mirror upper -> lower
        for i in 0..p {
            for j in 0..i {
                let v = gd[j * p + i];
                gd[i * p + j] = v;
            }
        }
    }
    Ok((c, g))
}

/// `W = A^T B` where A and B share their row count — the pass-2 partial
/// (`W = sum_i a_i ⊗ b_i`, commutative across rows/workers).
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.rows() != b.rows() {
        return Err(Error::shape(format!(
            "matmul_tn: {} vs {} rows",
            a.rows(),
            b.rows()
        )));
    }
    let (m, n, k) = (a.rows(), a.cols(), b.cols());
    let mut w = Matrix::zeros(n, k);
    let wd = w.data_mut();
    for i in 0..m {
        let arow = a.row(i);
        let brow = b.row(i);
        for (j, &aij) in arow.iter().enumerate() {
            if aij == 0.0 {
                continue;
            }
            let wrow = &mut wd[j * k..(j + 1) * k];
            for (wv, bv) in wrow.iter_mut().zip(brow.iter()) {
                *wv += aij * bv;
            }
        }
    }
    Ok(w)
}

/// `G = X^T X` — symmetric rank-m update, computing the upper triangle and
/// mirroring. This is the native Gram hot path.
pub fn gram(x: &Matrix) -> Matrix {
    let (m, n) = x.shape();
    let mut g = Matrix::zeros(n, n);
    let gd = g.data_mut();
    for i in 0..m {
        let row = x.row(i);
        for j in 0..n {
            let xij = row[j];
            if xij == 0.0 {
                continue;
            }
            let grow = &mut gd[j * n + j..(j + 1) * n];
            for (gv, xv) in grow.iter_mut().zip(row[j..].iter()) {
                *gv += xij * xv;
            }
        }
    }
    // mirror upper -> lower
    for i in 0..n {
        for j in 0..i {
            let v = gd[j * n + i];
            gd[i * n + j] = v;
        }
    }
    g
}

/// The paper's §2.0.2 formulation, literally: `G = Σ_i x_i ⊗ x_i` with a full
/// (non-symmetric-aware) outer product per row. Used by E5 to measure what
/// exploiting symmetry buys, and by tests as an independent oracle.
pub fn gram_outer(x: &Matrix) -> Matrix {
    let (m, n) = x.shape();
    let mut g = Matrix::zeros(n, n);
    let gd = g.data_mut();
    for i in 0..m {
        let row = x.row(i);
        for j in 0..n {
            let xij = row[j];
            let grow = &mut gd[j * n..(j + 1) * n];
            for (gv, xv) in grow.iter_mut().zip(row.iter()) {
                *gv += xij * xv;
            }
        }
    }
    g
}

/// Accumulate one row's outer product into `g` (streaming form used by the
/// row-at-a-time ATA job mode).
pub fn outer_accumulate(g: &mut Matrix, row: &[f64]) {
    let n = row.len();
    debug_assert_eq!(g.shape(), (n, n));
    let gd = g.data_mut();
    for (j, &xj) in row.iter().enumerate() {
        if xj == 0.0 {
            continue;
        }
        let grow = &mut gd[j * n..(j + 1) * n];
        for (gv, xv) in grow.iter_mut().zip(row.iter()) {
            *gv += xj * xv;
        }
    }
}

/// `y += A x` for a row-major A (small helper for validation code).
pub fn matvec(a: &Matrix, x: &[f64]) -> Result<Vec<f64>> {
    if a.cols() != x.len() {
        return Err(Error::shape("matvec: dim mismatch"));
    }
    Ok((0..a.rows())
        .map(|i| a.row(i).iter().zip(x.iter()).map(|(u, v)| u * v).sum())
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Gaussian;

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let g = Gaussian::new(seed);
        Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
    }

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        for (m, n, p, seed) in [(5, 7, 3, 1), (64, 64, 64, 2), (100, 33, 17, 3), (1, 1, 1, 4)] {
            let a = random_matrix(m, n, seed);
            let b = random_matrix(n, p, seed + 100);
            let c = matmul(&a, &b).unwrap();
            assert!(c.max_abs_diff(&naive_matmul(&a, &b)) < 1e-10, "{m}x{n}x{p}");
        }
    }

    #[test]
    fn matmul_rejects_mismatch() {
        assert!(matmul(&Matrix::zeros(2, 3), &Matrix::zeros(4, 2)).is_err());
    }

    #[test]
    fn matmul_identity() {
        let a = random_matrix(10, 10, 5);
        let c = matmul(&a, &Matrix::eye(10)).unwrap();
        assert!(c.max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn gram_matches_t_times_self() {
        for (m, n, seed) in [(50, 8, 1), (200, 33, 2), (1, 5, 3), (128, 64, 4)] {
            let x = random_matrix(m, n, seed);
            let g = gram(&x);
            let want = matmul(&x.t(), &x).unwrap();
            assert!(g.max_abs_diff(&want) < 1e-9, "{m}x{n}");
        }
    }

    #[test]
    fn gram_outer_matches_gram() {
        let x = random_matrix(77, 13, 9);
        assert!(gram(&x).max_abs_diff(&gram_outer(&x)) < 1e-9);
    }

    #[test]
    fn gram_is_symmetric_and_psd_diag() {
        let x = random_matrix(40, 12, 11);
        let g = gram(&x);
        assert!(g.max_abs_diff(&g.t()) < 1e-12);
        for i in 0..12 {
            assert!(g.get(i, i) >= 0.0);
        }
    }

    #[test]
    fn outer_accumulate_streaming_equals_gram() {
        let x = random_matrix(30, 7, 13);
        let mut g = Matrix::zeros(7, 7);
        for i in 0..30 {
            outer_accumulate(&mut g, x.row(i));
        }
        assert!(g.max_abs_diff(&gram(&x)) < 1e-10);
    }

    #[test]
    fn matmul_gram_matches_oracle() {
        // The cross-check oracle is the unfused formulation: full matmul,
        // then a full gram sweep over the product.
        for (m, n, p, seed) in [(5, 7, 3, 1), (64, 64, 64, 2), (130, 33, 17, 3), (1, 1, 1, 4)] {
            let a = random_matrix(m, n, seed);
            let b = random_matrix(n, p, seed + 200);
            let (c, g) = matmul_gram(&a, &b).unwrap();
            let c_want = matmul(&a, &b).unwrap();
            let g_want = gram(&c_want);
            assert!(c.max_abs_diff(&c_want) < 1e-10, "C {m}x{n}x{p}");
            assert!(g.max_abs_diff(&g_want) < 1e-9, "G {m}x{n}x{p}");
        }
    }

    #[test]
    fn matmul_gram_rejects_mismatch() {
        assert!(matmul_gram(&Matrix::zeros(2, 3), &Matrix::zeros(4, 2)).is_err());
    }

    #[test]
    fn matmul_tn_matches_transpose_matmul() {
        let a = random_matrix(90, 14, 17);
        let b = random_matrix(90, 6, 18);
        let w = matmul_tn(&a, &b).unwrap();
        let want = matmul(&a.t(), &b).unwrap();
        assert!(w.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn matmul_tn_rejects_row_mismatch() {
        assert!(matmul_tn(&Matrix::zeros(3, 2), &Matrix::zeros(4, 2)).is_err());
    }

    #[test]
    fn matvec_basic() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(matvec(&a, &[1.0, 1.0]).unwrap(), vec![3.0, 7.0]);
    }

    #[test]
    fn zero_rows_contribute_nothing() {
        // The padding invariant the XLA backend relies on.
        let x = random_matrix(64, 9, 21);
        let padded = {
            let z = Matrix::zeros(64, 9);
            x.vstack(&z).unwrap()
        };
        assert!(gram(&x).max_abs_diff(&gram(&padded)) < 1e-12);
    }
}
