//! Symmetric eigendecomposition by cyclic Jacobi rotations.
//!
//! The paper's leader-side step: `A^T A = V Σ² V^T` (or `Y^T Y` after
//! projection) is a *small* symmetric matrix "computed on a single machine".
//! Cyclic Jacobi is the textbook-robust choice at these sizes (n ≤ a few
//! hundred): unconditionally convergent, eigenvectors accumulated for free.
//!
//! Mirrors `python/compile/model.py::jacobi_eigh` (the L2 artifact) so the
//! native and XLA backends agree.

use super::matrix::Matrix;
use crate::error::{Error, Result};

/// Options for [`jacobi_eigh`].
#[derive(Clone, Copy, Debug)]
pub struct EighOptions {
    /// Maximum number of full cyclic sweeps.
    pub max_sweeps: usize,
    /// Stop when the off-diagonal Frobenius norm falls below
    /// `tol * ||A||_F`.
    pub tol: f64,
}

impl Default for EighOptions {
    fn default() -> Self {
        EighOptions { max_sweeps: 30, tol: 1e-14 }
    }
}

/// Eigendecomposition of a symmetric matrix; returns `(eigvals, eigvecs)`
/// in **descending** eigenvalue order (`eigvecs` columns match).
pub fn jacobi_eigh(a: &Matrix, opts: EighOptions) -> Result<(Vec<f64>, Matrix)> {
    let n = a.rows();
    if a.cols() != n {
        return Err(Error::shape(format!("eigh: non-square {}x{}", n, a.cols())));
    }
    let sym_err = a.max_abs_diff(&a.t());
    let scale = a.max_abs().max(1e-300);
    if sym_err > 1e-8 * scale {
        return Err(Error::Numerical(format!(
            "eigh: matrix not symmetric (max asym {sym_err:.3e})"
        )));
    }

    let mut m = a.clone();
    let mut v = Matrix::eye(n);
    let fro = a.fro_norm().max(1e-300);

    for _sweep in 0..opts.max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m.get(p, q).powi(2);
            }
        }
        if (2.0 * off).sqrt() <= opts.tol * fro {
            break;
        }
        for p in 0..n.saturating_sub(1) {
            for q in (p + 1)..n {
                let apq = m.get(p, q);
                if apq == 0.0 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                // Rotation annihilating m[p][q] (Golub & Van Loan 8.4).
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // Rows p,q then columns p,q (two-sided, keeps symmetry).
                for j in 0..n {
                    let mpj = m.get(p, j);
                    let mqj = m.get(q, j);
                    m.set(p, j, c * mpj - s * mqj);
                    m.set(q, j, s * mpj + c * mqj);
                }
                for i in 0..n {
                    let mip = m.get(i, p);
                    let miq = m.get(i, q);
                    m.set(i, p, c * mip - s * miq);
                    m.set(i, q, s * mip + c * miq);
                }
                for i in 0..n {
                    let vip = v.get(i, p);
                    let viq = v.get(i, q);
                    v.set(i, p, c * vip - s * viq);
                    v.set(i, q, s * vip + c * viq);
                }
            }
        }
    }

    let mut eig: Vec<(f64, usize)> = (0..n).map(|i| (m.get(i, i), i)).collect();
    eig.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
    let perm: Vec<usize> = eig.iter().map(|&(_, i)| i).collect();
    let w: Vec<f64> = eig.iter().map(|&(val, _)| val).collect();
    Ok((w, v.permute_cols(&perm)))
}

/// Convenience: descending eigendecomposition with default options.
pub fn eigh(a: &Matrix) -> Result<(Vec<f64>, Matrix)> {
    jacobi_eigh(a, EighOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops::{gram, matmul};
    use crate::rng::Gaussian;

    fn random_sym(n: usize, seed: u64) -> Matrix {
        let g = Gaussian::new(seed);
        let a = Matrix::from_fn(n, n, |i, j| g.sample(i as u64, j as u64));
        let mut s = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                s.set(i, j, (a.get(i, j) + a.get(j, i)) / 2.0);
            }
        }
        s
    }

    fn check_decomposition(a: &Matrix, w: &[f64], v: &Matrix, tol: f64) {
        let n = a.rows();
        // A v_j = w_j v_j
        for j in 0..n {
            let vj = v.col(j);
            let av = crate::linalg::ops::matvec(a, &vj).unwrap();
            for i in 0..n {
                assert!(
                    (av[i] - w[j] * vj[i]).abs() < tol,
                    "eigenpair {j}: residual {:.3e}",
                    (av[i] - w[j] * vj[i]).abs()
                );
            }
        }
        // V orthonormal
        let vtv = matmul(&v.t(), v).unwrap();
        assert!(vtv.max_abs_diff(&Matrix::eye(n)) < tol);
    }

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::from_rows(&[
            vec![3.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![0.0, 0.0, 2.0],
        ])
        .unwrap();
        let (w, v) = eigh(&a).unwrap();
        assert_eq!(w, vec![3.0, 2.0, 1.0]);
        check_decomposition(&a, &w, &v, 1e-12);
    }

    #[test]
    fn two_by_two_known() {
        // eigenvalues of [[2,1],[1,2]] are 3 and 1
        let a = Matrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]).unwrap();
        let (w, v) = eigh(&a).unwrap();
        assert!((w[0] - 3.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
        check_decomposition(&a, &w, &v, 1e-12);
    }

    #[test]
    fn random_symmetric_various_sizes() {
        for (n, seed) in [(2usize, 1u64), (3, 2), (8, 3), (16, 4), (32, 5), (64, 6)] {
            let a = random_sym(n, seed);
            let (w, v) = eigh(&a).unwrap();
            check_decomposition(&a, &w, &v, 1e-8);
            // descending order
            for i in 1..n {
                assert!(w[i - 1] >= w[i] - 1e-12);
            }
        }
    }

    #[test]
    fn gram_matrix_nonnegative_eigs() {
        let g = Gaussian::new(77);
        let x = Matrix::from_fn(50, 12, |i, j| g.sample(i as u64, j as u64));
        let gm = gram(&x);
        let (w, _) = eigh(&gm).unwrap();
        for &wi in &w {
            assert!(wi >= -1e-9, "negative eigenvalue {wi}");
        }
    }

    #[test]
    fn trace_preserved() {
        let a = random_sym(20, 9);
        let trace: f64 = (0..20).map(|i| a.get(i, i)).sum();
        let (w, _) = eigh(&a).unwrap();
        assert!((w.iter().sum::<f64>() - trace).abs() < 1e-9);
    }

    #[test]
    fn clustered_eigenvalues() {
        // Near-degenerate spectrum: build Q diag(w) Q^T with known w.
        let g = Gaussian::new(31);
        let raw = Matrix::from_fn(12, 12, |i, j| g.sample(i as u64, j as u64));
        let (q, _) = crate::linalg::qr::thin_qr(&raw).unwrap();
        let w_true = [10.0, 10.0, 9.999, 9.999, 1.0, 1.0, 1.0, 0.5, 0.1, 0.1, 0.01, 0.0];
        let mut d = Matrix::zeros(12, 12);
        for i in 0..12 {
            d.set(i, i, w_true[i]);
        }
        let a = matmul(&matmul(&q, &d).unwrap(), &q.t()).unwrap();
        let (w, _) = eigh(&a).unwrap();
        for i in 0..12 {
            assert!((w[i] - w_true[i]).abs() < 1e-7, "{} vs {}", w[i], w_true[i]);
        }
    }

    #[test]
    fn rejects_asymmetric() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![0.0, 1.0]]).unwrap();
        assert!(eigh(&a).is_err());
    }

    #[test]
    fn rejects_nonsquare() {
        assert!(eigh(&Matrix::zeros(2, 3)).is_err());
    }
}
