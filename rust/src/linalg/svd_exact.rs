//! Exact SVD baseline: one-sided Jacobi (Hestenes).
//!
//! The accuracy experiments (E4, E6) compare the paper's randomized pipeline
//! against a dense exact SVD. One-sided Jacobi orthogonalizes the *columns*
//! of A directly — numerically robust for the tall `m x n` (n modest)
//! matrices the baselines run on, and needs no bidiagonalization machinery.

use super::matrix::Matrix;
use crate::error::{Error, Result};

/// Result of [`exact_svd`]: `a = u * diag(sigma) * v^T`.
pub struct ExactSvd {
    /// `m x n`, orthonormal columns (columns with `sigma = 0` are zero).
    pub u: Matrix,
    /// Descending singular values, length `n`.
    pub sigma: Vec<f64>,
    /// `n x n`, orthonormal.
    pub v: Matrix,
}

/// Exact SVD of a tall matrix (`m >= n`) by one-sided Jacobi.
pub fn exact_svd(a: &Matrix) -> Result<ExactSvd> {
    let (m, n) = a.shape();
    if m < n {
        return Err(Error::shape(format!("exact_svd: need m >= n, got {m}x{n}")));
    }
    let mut u = a.clone(); // columns rotated toward orthogonality
    let mut v = Matrix::eye(n);

    let max_sweeps = 60;
    let tol = 1e-15;
    let fro2: f64 = a.data().iter().map(|x| x * x).sum();
    let threshold = tol * fro2.max(1e-300);

    for _ in 0..max_sweeps {
        let mut rotated = false;
        for p in 0..n.saturating_sub(1) {
            for q in (p + 1)..n {
                // Gram entries for column pair (p, q).
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let up = u.get(i, p);
                    let uq = u.get(i, q);
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= threshold || apq.abs() <= 1e-15 * (app * aqq).sqrt() {
                    continue;
                }
                rotated = true;
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                for i in 0..m {
                    let up = u.get(i, p);
                    let uq = u.get(i, q);
                    u.set(i, p, c * up - s * uq);
                    u.set(i, q, s * up + c * uq);
                }
                for i in 0..n {
                    let vp = v.get(i, p);
                    let vq = v.get(i, q);
                    v.set(i, p, c * vp - s * vq);
                    v.set(i, q, s * vp + c * vq);
                }
            }
        }
        if !rotated {
            break;
        }
    }

    // Column norms are the singular values; normalize U's columns.
    let mut sig: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm: f64 = (0..m).map(|i| u.get(i, j).powi(2)).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    sig.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));

    let perm: Vec<usize> = sig.iter().map(|&(_, j)| j).collect();
    let sigma: Vec<f64> = sig.iter().map(|&(s, _)| s).collect();
    let u = u.permute_cols(&perm);
    let v = v.permute_cols(&perm);

    let mut u_out = Matrix::zeros(m, n);
    for j in 0..n {
        if sigma[j] > 0.0 {
            for i in 0..m {
                u_out.set(i, j, u.get(i, j) / sigma[j]);
            }
        }
    }
    Ok(ExactSvd { u: u_out, sigma, v })
}

/// Rank-k truncation of an [`ExactSvd`] reconstruction error:
/// `||A - U_k S_k V_k^T||_F`.
pub fn truncation_error(a: &Matrix, svd: &ExactSvd, k: usize) -> f64 {
    // tail energy: sqrt(sum_{i>=k} sigma_i^2) equals the truncation error.
    svd.sigma[k.min(svd.sigma.len())..]
        .iter()
        .map(|s| s * s)
        .sum::<f64>()
        .sqrt()
        .min(a.fro_norm())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops::matmul;
    use crate::linalg::qr::orthonormality_residual;
    use crate::rng::Gaussian;

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let g = Gaussian::new(seed);
        Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
    }

    fn reconstruct(svd: &ExactSvd) -> Matrix {
        let us = svd.u.scale_cols(&svd.sigma).unwrap();
        matmul(&us, &svd.v.t()).unwrap()
    }

    #[test]
    fn reconstructs_random_matrices() {
        for (m, n, seed) in [(10, 4, 1), (50, 20, 2), (30, 30, 3), (100, 5, 4)] {
            let a = random_matrix(m, n, seed);
            let svd = exact_svd(&a).unwrap();
            let err = reconstruct(&svd).max_abs_diff(&a);
            assert!(err < 1e-9, "{m}x{n}: {err}");
        }
    }

    #[test]
    fn factors_orthonormal() {
        let a = random_matrix(40, 12, 5);
        let svd = exact_svd(&a).unwrap();
        assert!(orthonormality_residual(&svd.u) < 1e-9);
        assert!(orthonormality_residual(&svd.v) < 1e-9);
    }

    #[test]
    fn sigma_descending_nonnegative() {
        let a = random_matrix(60, 15, 6);
        let svd = exact_svd(&a).unwrap();
        for i in 0..15 {
            assert!(svd.sigma[i] >= 0.0);
            if i > 0 {
                assert!(svd.sigma[i - 1] >= svd.sigma[i] - 1e-12);
            }
        }
    }

    #[test]
    fn known_singular_values_diag() {
        // A = diag(3, 2, 1) stacked on zeros.
        let mut a = Matrix::zeros(5, 3);
        a.set(0, 0, 3.0);
        a.set(1, 1, 2.0);
        a.set(2, 2, 1.0);
        let svd = exact_svd(&a).unwrap();
        assert!((svd.sigma[0] - 3.0).abs() < 1e-12);
        assert!((svd.sigma[1] - 2.0).abs() < 1e-12);
        assert!((svd.sigma[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn low_rank_matrix_has_zero_tail() {
        // rank-2: outer products of two fixed vectors
        let g = Gaussian::new(9);
        let u1: Vec<f64> = (0..30).map(|i| g.sample(i, 0)).collect();
        let u2: Vec<f64> = (0..30).map(|i| g.sample(i, 1)).collect();
        let v1: Vec<f64> = (0..8).map(|j| g.sample(100 + j, 0)).collect();
        let v2: Vec<f64> = (0..8).map(|j| g.sample(100 + j, 1)).collect();
        let a = Matrix::from_fn(30, 8, |i, j| 5.0 * u1[i] * v1[j] + 2.0 * u2[i] * v2[j]);
        let svd = exact_svd(&a).unwrap();
        assert!(svd.sigma[2] < 1e-9 * svd.sigma[0]);
    }

    #[test]
    fn matches_gram_eigenvalues() {
        // sigma^2 must equal eigenvalues of A^T A (the paper's §2.0.1 identity).
        let a = random_matrix(25, 6, 11);
        let svd = exact_svd(&a).unwrap();
        let g = crate::linalg::ops::gram(&a);
        let (w, _) = crate::linalg::eigen::eigh(&g).unwrap();
        for i in 0..6 {
            assert!((svd.sigma[i].powi(2) - w[i]).abs() < 1e-8 * w[0].max(1.0));
        }
    }

    #[test]
    fn truncation_error_is_tail_energy() {
        let a = random_matrix(40, 10, 13);
        let svd = exact_svd(&a).unwrap();
        let err = truncation_error(&a, &svd, 4);
        let want: f64 = svd.sigma[4..].iter().map(|s| s * s).sum::<f64>().sqrt();
        assert!((err - want).abs() < 1e-12);
    }

    #[test]
    fn rejects_wide() {
        assert!(exact_svd(&Matrix::zeros(3, 5)).is_err());
    }
}
