//! Linear algebra substrate (pure rust, no BLAS).
//!
//! Everything the coordinator needs natively: a row-major [`Matrix`], blocked
//! products, a CSR [`SparseMatrix`] with `O(nnz)` pass kernels for sparse
//! inputs, the symmetric Jacobi eigensolver the paper's leader-side
//! `k x k` math runs on, Householder QR (power-iteration extension), and a
//! one-sided Jacobi exact SVD used as the accuracy baseline in the
//! experiments (E4/E6).

pub mod eigen;
pub mod matrix;
pub mod ops;
pub mod qr;
pub mod sparse;
pub mod svd_exact;
pub mod tsqr;

pub use eigen::{jacobi_eigh, EighOptions};
pub use matrix::Matrix;
pub use ops::{gram, gram_outer, matmul, matmul_gram, matmul_tn};
pub use sparse::{sp_gram, sp_matmul, sp_matmul_gram, sp_tmul, SparseMatrix};
pub use qr::thin_qr;
pub use svd_exact::{exact_svd, truncation_error, ExactSvd};
