//! Row-major dense matrix over `f64`.
//!
//! Deliberately simple: contiguous `Vec<f64>`, row-major, with the handful of
//! views/accessors the streaming jobs and leader-side solvers need. Blocks
//! that cross the XLA boundary are converted to `f32` in
//! [`crate::runtime::literal`].

use crate::error::{Error, Result};

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a flat row-major vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "from_vec: {} elements for {}x{}",
                data.len(),
                rows,
                cols
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Build from nested rows (test convenience).
    pub fn from_rows(rows: &[Vec<f64>]) -> Result<Self> {
        if rows.is_empty() {
            return Ok(Self::zeros(0, 0));
        }
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            if r.len() != cols {
                return Err(Error::shape("from_rows: ragged rows"));
            }
            data.extend_from_slice(r);
        }
        Ok(Matrix { rows: rows.len(), cols, data })
    }

    /// Build an `rows x cols` matrix from a generator `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Immutable view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Flat row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Transposed copy.
    pub fn t(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// `self += other` (elementwise).
    pub fn add_assign(&mut self, other: &Matrix) -> Result<()> {
        if self.shape() != other.shape() {
            return Err(Error::shape(format!(
                "add_assign: {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
        Ok(())
    }

    /// `self * s` (scalar).
    pub fn scale(&self, s: f64) -> Matrix {
        let mut out = self.clone();
        for v in &mut out.data {
            *v *= s;
        }
        out
    }

    /// Rows `[r0, r1)` as a new matrix.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Columns `[c0, c1)` as a new matrix.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        Matrix::from_fn(self.rows, c1 - c0, |i, j| self.get(i, c0 + j))
    }

    /// Vertically stack `self` on top of `other`.
    pub fn vstack(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.cols {
            return Err(Error::shape("vstack: column mismatch"));
        }
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max absolute element.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }

    /// Max absolute difference against another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Euclidean norms of each column.
    pub fn col_norms(&self) -> Vec<f64> {
        let mut norms = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for (j, v) in row.iter().enumerate() {
                norms[j] += v * v;
            }
        }
        norms.into_iter().map(f64::sqrt).collect()
    }

    /// Scale each column `j` by `s[j]` (returns new matrix).
    pub fn scale_cols(&self, s: &[f64]) -> Result<Matrix> {
        if s.len() != self.cols {
            return Err(Error::shape("scale_cols: length mismatch"));
        }
        let mut out = self.clone();
        for i in 0..out.rows {
            let row = out.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v *= s[j];
            }
        }
        Ok(out)
    }

    /// Reorder columns by `perm` (out column `j` = self column `perm[j]`).
    pub fn permute_cols(&self, perm: &[usize]) -> Matrix {
        assert_eq!(perm.len(), self.cols);
        Matrix::from_fn(self.rows, self.cols, |i, j| self.get(i, perm[j]))
    }

    /// Flat data converted to `f32` (XLA boundary).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Build from `f32` data (XLA boundary).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape("from_f32: size mismatch"));
        }
        Ok(Matrix { rows, cols, data: data.iter().map(|&v| v as f64).collect() })
    }
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            let row = self.row(i);
            let cells: Vec<String> =
                row.iter().take(8).map(|v| format!("{v:10.4}")).collect();
            writeln!(f, "  [{}{}]", cells.join(", "), if self.cols > 8 { ", ..." } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let m = Matrix::zeros(3, 4);
        assert_eq!(m.shape(), (3, 4));
        assert_eq!(m.data().len(), 12);
        assert_eq!(m.fro_norm(), 0.0);
    }

    #[test]
    fn eye_diagonal() {
        let m = Matrix::eye(3);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(m.get(i, j), if i == j { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn from_vec_rejects_bad_len() {
        assert!(Matrix::from_vec(2, 2, vec![1.0; 5]).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]).unwrap();
        let t = m.t();
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t.get(2, 1), 6.0);
        assert_eq!(t.t(), m);
    }

    #[test]
    fn row_views() {
        let mut m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(m.row(1), &[3.0, 4.0]);
        m.row_mut(0)[1] = 9.0;
        assert_eq!(m.get(0, 1), 9.0);
    }

    #[test]
    fn add_assign_and_scale() {
        let mut a = Matrix::eye(2);
        let b = Matrix::eye(2).scale(2.0);
        a.add_assign(&b).unwrap();
        assert_eq!(a.get(0, 0), 3.0);
        assert!(a.add_assign(&Matrix::zeros(3, 3)).is_err());
    }

    #[test]
    fn slices_and_stack() {
        let m = Matrix::from_rows(&[
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
        ])
        .unwrap();
        let top = m.slice_rows(0, 1);
        let rest = m.slice_rows(1, 3);
        assert_eq!(top.vstack(&rest).unwrap(), m);
        let mid = m.slice_cols(1, 2);
        assert_eq!(mid.col(0), vec![2.0, 5.0, 8.0]);
    }

    #[test]
    fn col_norms_and_scale_cols() {
        let m = Matrix::from_rows(&[vec![3.0, 0.0], vec![4.0, 1.0]]).unwrap();
        let norms = m.col_norms();
        assert!((norms[0] - 5.0).abs() < 1e-12);
        let scaled = m.scale_cols(&[2.0, 10.0]).unwrap();
        assert_eq!(scaled.get(1, 1), 10.0);
    }

    #[test]
    fn permute_cols_reorders() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0, 3.0]]).unwrap();
        let p = m.permute_cols(&[2, 0, 1]);
        assert_eq!(p.row(0), &[3.0, 1.0, 2.0]);
    }

    #[test]
    fn f32_roundtrip() {
        let m = Matrix::from_rows(&[vec![1.5, -2.25]]).unwrap();
        let f = m.to_f32();
        let back = Matrix::from_f32(1, 2, &f).unwrap();
        assert_eq!(back, m);
    }
}
