//! The `tallfat serve` HTTP front end.
//!
//! Runs on the shared event-driven connection runtime ([`crate::net`]):
//! nonblocking accept + readiness loop, incremental keep-alive HTTP/1.1
//! parsing, a warm fixed-size handler pool behind a bounded queue, and
//! admission control — past the `--max-inflight`/`--max-queue` caps,
//! `POST /query` answers `503` + `Retry-After` instead of piling up
//! threads. `GET /healthz`, `GET /metrics` and `GET /model` answer inline
//! on the event loop and are never shed. Queries are line-delimited JSON
//! (`POST /query`, one request object per line, one response object per
//! line back); project and similarity lines are routed through the
//! [`Batcher`] so concurrent connections coalesce into shared backend
//! matmuls.
//!
//! ```text
//! POST /query        ND-JSON query lines (see below)
//! GET  /model        model dimensions/provenance as JSON
//! GET  /metrics      Prometheus text (the shared MetricsRegistry)
//! GET  /healthz      liveness probe
//! ```
//!
//! Query lines:
//!
//! ```text
//! {"op":"project","row":[...]}             -> {"ok":true,"latent":[...]}
//! {"op":"similar","row":[...],"k":10}      -> {"ok":true,"hits":[{"row":i,"score":s},...]}
//! {"op":"similar","latent":[...],"k":10}   -> same, skipping the projection
//! {"op":"reconstruct","row_id":7}          -> {"ok":true,"values":[...]}
//! {"op":"info"}                            -> {"ok":true,"m":...,"k":...,"generation":...}
//! {"op":"health"}                          -> {"ok":true,"generation":...,"admission":{...},...}
//! {"op":"reload"}                          -> {"ok":true,"generation":...,"swapped":...}
//! ```
//!
//! `project` and `similar` also take a sparse row — `"indices":[...]` plus
//! `"values":[...]` instead of `"row"` — densified against the model's n,
//! so sparse-model clients don't ship n floats per request. `health` is the
//! probe the `tallfatd` fleet daemon's health loop consumes: generation,
//! uptime, shard-cache hit stats, the in-flight batch depth, and the
//! connection runtime's admission state (in-flight, queue depth, sheds).
//!
//! The model is held through an [`EngineHandle`], so a `reload` line (or
//! the `--reload-poll-ms` background poll, on by default) hot-swaps to the
//! root's live generation with zero downtime. Inline ops of a body answer
//! from the generation the body started on, and every coalesced batch runs
//! against a single generation — no operation is ever torn across a swap.
//!
//! Metrics published per request: the counter `serve_requests_total`, the
//! gauge `serve_qps`, and the end-to-end histogram `serve_request_ms`
//! (parse → reply, per query line; `quantile(0.5)`/`quantile(0.99)` give
//! p50/p99). The batcher adds `serve_batch_size` and the per-op split
//! `serve_queue_ms{op}` / `serve_compute_ms{op}`; engine reloads bump
//! `serve_reloads`; the runtime publishes the `net_*{plane="serve"}`
//! family (`net_conns_open`, `net_queue_depth`, `net_shed_total`, ...).

use crate::coordinator::server::MetricsRegistry;
use crate::error::{Error, Result};
use crate::net::http::{HttpRequest, HttpResponse};
use crate::net::{NetHandler, NetOptions, NetServer, NetServerHandle, NetStats};
use crate::serve::batcher::{BatchOptions, Batcher, BatcherHandle, Request, Response};
use crate::serve::json::Json;
use crate::serve::query::{EngineHandle, Hit, QueryEngine};
use crate::serve::store::ModelStore;
use crate::util::{Args, Logger};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

static LOG: Logger = Logger::new("serve.http");

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    pub addr: String,
    pub batch: BatchOptions,
    /// Answer this many requests, then exit (None = forever). `--once` is 1.
    pub max_requests: Option<u64>,
    /// Poll the model root's `CURRENT` pointer at this interval and
    /// hot-swap when it advances (None = reload only on `{"op":"reload"}`).
    /// Defaults to 5s: a server that never advances would keep reading
    /// generation directories that `tallfat update`'s garbage collection
    /// is entitled to delete once `keep_generations` newer ones exist.
    pub reload_poll: Option<Duration>,
    /// Connection-runtime knobs: pool size (= in-flight cap), queue bound,
    /// idle reap deadline, keep-alive policy ([`crate::net::NetOptions`]).
    pub net: NetOptions,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:9925".into(),
            batch: BatchOptions::default(),
            max_requests: None,
            reload_poll: Some(Duration::from_secs(5)),
            net: NetOptions::default(),
        }
    }
}

/// Per-model serving state: the hot-swappable engine handle, a batcher
/// handle, and request counters. One per [`ModelServer`]; the `tallfatd`
/// fleet daemon holds one per registered model.
pub(crate) struct ServerState {
    pub(crate) engines: Arc<EngineHandle>,
    pub(crate) handle: BatcherHandle,
    pub(crate) started: Instant,
    pub(crate) queries: AtomicU64,
}

impl ServerState {
    pub(crate) fn new(engines: Arc<EngineHandle>, handle: BatcherHandle) -> Self {
        ServerState { engines, handle, started: Instant::now(), queries: AtomicU64::new(0) }
    }
}

/// A bound model server (separate from `run` so tests can bind port 0 and
/// read the real address before serving).
pub struct ModelServer {
    net: NetServer,
    state: Arc<ServerState>,
    // Keeps the batching worker alive for the server's lifetime.
    _batcher: Batcher,
}

impl ModelServer {
    pub fn bind(engines: Arc<EngineHandle>, opts: &ServeOptions) -> Result<Self> {
        let batcher = Batcher::start(engines.clone(), opts.batch)?;
        let mut nopts = opts.net.clone();
        nopts.plane = "serve";
        nopts.max_requests = opts.max_requests;
        let net = NetServer::bind(&opts.addr, nopts)?;
        if let Some(every) = opts.reload_poll.filter(|_| engines.is_reloadable()) {
            spawn_reload_poller(Arc::downgrade(&engines), every);
        }
        let state = Arc::new(ServerState::new(engines, batcher.handle()));
        Ok(ModelServer { net, state, _batcher: batcher })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.net.local_addr()
    }

    /// Control/observation handle (graceful shutdown, admission stats).
    pub fn handle(&self) -> NetServerHandle {
        self.net.handle()
    }

    /// Run the connection runtime until shutdown or the request cap.
    pub fn run(self) -> Result<()> {
        let ModelServer { net, state, _batcher } = self;
        let handler = Arc::new(ServeHandler { state, net: net.handle() });
        net.run(handler)
    }
}

/// The serve plane's [`NetHandler`]: query bodies go through the admission
/// gate to the pool; liveness, metrics and model info answer inline.
struct ServeHandler {
    state: Arc<ServerState>,
    net: NetServerHandle,
}

impl NetHandler for ServeHandler {
    fn handle(&self, req: HttpRequest) -> HttpResponse {
        match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/query") => {
                let out = process_body(&self.state, &req.body_str(), Some(self.net.stats()));
                HttpResponse::ok("application/x-ndjson", out)
            }
            _ => {
                HttpResponse::not_found("unknown route (POST /query, GET /healthz /metrics /model)")
            }
        }
    }

    fn handle_inline(&self, req: &HttpRequest) -> Option<HttpResponse> {
        if req.method != "GET" {
            return None;
        }
        match req.path.as_str() {
            "/healthz" => Some(HttpResponse::text(200, "ok\n")),
            "/metrics" => Some(HttpResponse::ok(
                "text/plain; version=0.0.4",
                MetricsRegistry::global().render(),
            )),
            "/model" => {
                let body = model_info(self.state.engines.current().as_ref()).render();
                Some(HttpResponse::json(200, body))
            }
            _ => None,
        }
    }
}

/// Background `CURRENT` poller: holds only a weak handle, so it dies with
/// the server instead of pinning the model in memory forever.
fn spawn_reload_poller(engines: Weak<EngineHandle>, every: Duration) {
    std::thread::Builder::new()
        .name("serve-reload-poll".into())
        .spawn(move || loop {
            std::thread::sleep(every);
            match engines.upgrade() {
                Some(h) => {
                    if let Err(e) = h.reload() {
                        LOG.warn(&format!("reload poll failed: {e}"));
                    }
                }
                None => return,
            }
        })
        .ok();
}

pub(crate) fn model_info(engine: &QueryEngine) -> Json {
    let store = engine.store();
    let mut pairs = vec![
        ("ok", Json::Bool(true)),
        ("m", Json::num(store.m() as f64)),
        ("n", Json::num(store.n() as f64)),
        ("k", Json::num(store.k() as f64)),
        ("shards", Json::num(store.shards() as f64)),
        ("centered", Json::Bool(store.centered())),
        ("generation", Json::num(store.generation() as f64)),
    ];
    if let Some(seed) = store.seed() {
        pairs.push(("seed", Json::num(seed as f64)));
    }
    Json::obj(pairs)
}

pub(crate) fn error_json(msg: impl std::fmt::Display) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg.to_string()))])
}

fn hits_json(hits: &[Hit]) -> Json {
    Json::arr(
        hits.iter()
            .map(|h| {
                Json::obj(vec![("row", Json::num(h.row as f64)), ("score", Json::num(h.score))])
            })
            .collect(),
    )
}

/// The `{"op":"health"}` reply: the probe the fleet daemon's health loop
/// consumes. Generation, uptime, per-process shard-cache hit stats, the
/// batcher's in-flight depth, and — when the query arrived through a
/// connection runtime — its admission state.
pub(crate) fn health_json(
    state: &ServerState,
    engine: &QueryEngine,
    net: Option<&NetStats>,
) -> Json {
    let reg = MetricsRegistry::global();
    let sum = |keys: &[&str]| keys.iter().filter_map(|k| reg.get(k)).sum::<f64>();
    let mut pairs = vec![
        ("ok", Json::Bool(true)),
        ("generation", Json::num(engine.store().generation() as f64)),
        ("uptime_ms", Json::num(state.started.elapsed().as_secs_f64() * 1e3)),
        ("queries", Json::num(state.queries.load(Ordering::Relaxed) as f64)),
        (
            "cache_hits",
            Json::num(sum(&["serve_shard_cache_hits", "serve_embedding_cache_hits"])),
        ),
        (
            "cache_misses",
            Json::num(sum(&["serve_shard_cache_misses", "serve_embedding_cache_misses"])),
        ),
        ("in_flight", Json::num(state.handle.in_flight() as f64)),
    ];
    if let Some(net) = net {
        pairs.push(("admission", admission_json(net)));
    }
    Json::obj(pairs)
}

/// The runtime's admission state as a JSON object — shared by
/// `{"op":"health"}` here and the daemon's `/healthz`.
pub(crate) fn admission_json(net: &NetStats) -> Json {
    Json::obj(vec![
        ("in_flight", Json::num(net.inflight() as f64)),
        ("queue_depth", Json::num(net.queue_depth() as f64)),
        ("shed_total", Json::num(net.shed_total() as f64)),
        ("conns_open", Json::num(net.conns_open() as f64)),
    ])
}

/// Extract the query row of a `project`/`similar` line: dense `"row":[...]`
/// or sparse `"indices":[...]` + `"values":[...]` (densified against the
/// model's n). `None` = neither form present.
fn query_row(req: &Json, n: usize) -> Option<Result<Vec<f64>>> {
    if let Some(row) = req.get("row").and_then(Json::as_f64_array) {
        return Some(Ok(row));
    }
    let (indices, values) = match (req.get("indices"), req.get("values")) {
        (Some(i), Some(v)) => (i, v),
        (None, None) => return None,
        _ => return Some(Err(Error::parse("sparse row needs both `indices` and `values`"))),
    };
    let idx = match indices.as_array() {
        Some(items) => {
            let mut out = Vec::with_capacity(items.len());
            for item in items {
                match item.as_usize() {
                    Some(i) => out.push(i),
                    None => {
                        return Some(Err(Error::parse(
                            "sparse row: `indices` must be non-negative integers",
                        )))
                    }
                }
            }
            out
        }
        None => return Some(Err(Error::parse("sparse row: `indices` must be an array"))),
    };
    let vals = match values.as_f64_array() {
        Some(v) => v,
        None => return Some(Err(Error::parse("sparse row: `values` must be numeric"))),
    };
    if idx.len() != vals.len() {
        return Some(Err(Error::shape(format!(
            "sparse row: {} indices vs {} values",
            idx.len(),
            vals.len()
        ))));
    }
    let mut row = vec![0.0; n];
    for (&i, &v) in idx.iter().zip(&vals) {
        if i >= n {
            return Some(Err(Error::shape(format!(
                "sparse row: index {i} out of range for model n={n}"
            ))));
        }
        row[i] += v;
    }
    Some(Ok(row))
}

/// What a planned query line is waiting on from the batcher.
pub(crate) enum Expect {
    Latent,
    Hits,
}

/// A parsed query line: answered inline, or deferred to the batcher.
pub(crate) enum Planned {
    Done(Json),
    Batch(Request, Expect),
}

/// Turn a batcher reply into the response object for its query line.
pub(crate) fn render_reply(reply: Result<Response>, expect: &Expect) -> Json {
    match (reply, expect) {
        (Ok(Response::Latent(l)), Expect::Latent) => {
            Json::obj(vec![("ok", Json::Bool(true)), ("latent", Json::from_f64s(&l))])
        }
        (Ok(Response::Hits(hits)), Expect::Hits) => {
            Json::obj(vec![("ok", Json::Bool(true)), ("hits", hits_json(&hits))])
        }
        (Ok(_), _) => error_json("internal: wrong response kind"),
        (Err(e), _) => error_json(e),
    }
}

/// Process one POST body of ND-JSON query lines. Every batcher-bound line
/// is submitted *before* blocking on any reply, so the lines of a body
/// coalesce with each other (and with concurrent connections) into shared
/// backend matmuls. Never panics; every line gets a JSON object with an
/// `ok` field, in input order. Updates the serve metrics.
fn process_body(state: &ServerState, text: &str, net: Option<&NetStats>) -> String {
    let t0 = Instant::now();
    // One engine snapshot per body for the *inline* ops (reconstruct,
    // info): they answer from the generation the body started on even if a
    // reload lands mid-body. Batcher-bound lines instead share the batch's
    // own snapshot — so a reload line in the same body affects them, but
    // never tears a single operation across generations.
    let engine = state.engines.current();
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let mut outputs: Vec<Option<Json>> = vec![None; lines.len()];
    let mut planned: Vec<(usize, Expect)> = Vec::new();
    let mut reqs: Vec<Request> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        match Json::parse(line) {
            Err(e) => outputs[i] = Some(error_json(e)),
            Ok(req) => match plan_query(state, engine.as_ref(), &req, net) {
                Planned::Done(json) => outputs[i] = Some(json),
                Planned::Batch(r, expect) => {
                    planned.push((i, expect));
                    reqs.push(r);
                }
            },
        }
    }
    if !reqs.is_empty() {
        let replies = state.handle.call_many(reqs);
        for ((i, expect), reply) in planned.into_iter().zip(replies) {
            outputs[i] = Some(render_reply(reply, &expect));
        }
    }
    record_metrics(state, lines.len() as u64, t0);
    let mut out = String::new();
    for o in outputs {
        out.push_str(&o.unwrap_or_else(|| error_json("internal: line fell through")).render());
        out.push('\n');
    }
    out
}

pub(crate) fn plan_query(
    state: &ServerState,
    engine: &QueryEngine,
    req: &Json,
    net: Option<&NetStats>,
) -> Planned {
    let op = match req.get("op").and_then(Json::as_str) {
        Some(op) => op,
        None => return Planned::Done(error_json("missing `op`")),
    };
    match op {
        "project" => match query_row(req, engine.store().n()) {
            Some(Ok(row)) => Planned::Batch(Request::Project { row }, Expect::Latent),
            Some(Err(e)) => Planned::Done(error_json(e)),
            None => Planned::Done(error_json(
                "project: missing numeric `row` (or sparse `indices`/`values`)",
            )),
        },
        "similar" => {
            let topk = req.get("k").and_then(Json::as_usize).unwrap_or(10);
            match query_row(req, engine.store().n()) {
                Some(Ok(row)) => Planned::Batch(Request::Similar { row, topk }, Expect::Hits),
                Some(Err(e)) => Planned::Done(error_json(e)),
                None => match req.get("latent").and_then(Json::as_f64_array) {
                    Some(latent) => {
                        Planned::Batch(Request::SimilarLatent { latent, topk }, Expect::Hits)
                    }
                    None => Planned::Done(error_json(
                        "similar: need numeric `row`, sparse `indices`/`values`, or `latent`",
                    )),
                },
            }
        }
        "reconstruct" => {
            let row_id = match req.get("row_id").and_then(Json::as_usize) {
                Some(r) => r,
                None => return Planned::Done(error_json("reconstruct: missing integer `row_id`")),
            };
            Planned::Done(match engine.reconstruct_row(row_id) {
                Ok(values) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("values", Json::from_f64s(&values)),
                ]),
                Err(e) => error_json(e),
            })
        }
        "info" => Planned::Done(model_info(engine)),
        "health" => Planned::Done(health_json(state, engine, net)),
        "reload" => Planned::Done(match state.engines.reload() {
            Ok(swapped) => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("generation", Json::num(state.engines.generation() as f64)),
                ("swapped", Json::Bool(swapped.is_some())),
            ]),
            Err(e) => error_json(e),
        }),
        other => Planned::Done(error_json(format!("unknown op `{other}`"))),
    }
}

pub(crate) fn record_metrics(state: &ServerState, nlines: u64, t0: Instant) {
    if nlines == 0 {
        return;
    }
    let total = state.queries.fetch_add(nlines, Ordering::Relaxed) + nlines;
    let elapsed = state.started.elapsed().as_secs_f64().max(1e-9);
    let ms = t0.elapsed().as_secs_f64() * 1e3 / nlines as f64;
    let reg = MetricsRegistry::global();
    reg.add("serve_requests_total", nlines as f64);
    reg.set("serve_qps", total as f64 / elapsed);
    // One observation per query line (the body's per-line mean), so the
    // histogram's `_count` tracks `serve_requests_total` and its quantiles
    // answer "what does one request cost end to end".
    for _ in 0..nlines {
        reg.observe("serve_request_ms", ms);
    }
}

/// `serve <model-dir>`: load a saved model and answer queries over HTTP.
///
/// `--addr HOST:PORT` (default 127.0.0.1:9925, port 0 = ephemeral),
/// `--backend native|xla|auto`, `--cache-shards N`, `--batch-window-ms MS`,
/// `--max-batch N`, `--reload-poll-ms MS` (default 5000; 0 = only
/// `{"op":"reload"}`), `--max-requests N` / `--once` (tests),
/// `--trace FILE` (Chrome trace-event timeline of the serving process),
/// plus the shared connection-runtime flags `--max-inflight N`,
/// `--max-queue N`, `--idle-timeout-ms MS`, `--keep-alive`/`--no-keep-alive`
/// ([`NetOptions::with_args`]).
pub fn serve(args: &Args) -> Result<()> {
    let dir = args
        .opt_str("model-dir")
        .map(str::to_string)
        .or_else(|| args.positional.first().cloned())
        .ok_or_else(|| {
            Error::Config("serve: model directory required (positional or --model-dir)".into())
        })?;
    let cache_shards = args.usize_or("cache-shards", ModelStore::DEFAULT_CACHE_SHARDS)?;
    let cfg = crate::coordinator::commands::load_config(args)?;
    let backend = crate::backend::make_backend(&cfg)?;
    let engines = Arc::new(EngineHandle::open(&dir, cache_shards, backend)?);
    let max_requests = match args.u64_or("max-requests", 0)? {
        0 if args.flag("once") => Some(1),
        0 => None,
        n => Some(n),
    };
    let opts = ServeOptions {
        addr: args.str_or("addr", "127.0.0.1:9925"),
        batch: BatchOptions {
            window: Duration::from_millis(args.u64_or("batch-window-ms", 2)?),
            max_batch: args.usize_or("max-batch", 64)?,
        },
        max_requests,
        reload_poll: match args.u64_or("reload-poll-ms", 5000)? {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        },
        net: NetOptions::default().with_args(args)?,
    };
    let _trace = crate::obs::trace::TraceGuard::start(args.opt_str("trace"), "serve")?;
    {
        let engine = engines.current();
        let store = engine.store();
        LOG.info(&format!(
            "model {} generation {}: {}x{} k={} ({} shards, cache {cache_shards})",
            dir,
            store.generation(),
            store.m(),
            store.n(),
            store.k(),
            store.shards()
        ));
    }
    let server = ModelServer::bind(engines, &opts)?;
    LOG.info(&format!("serving queries on http://{}/query", server.local_addr()?));
    server.run()
}
