//! Persisted SVD model directories and lazy loading.
//!
//! [`save_model`] turns a completed [`SvdResult`] into a self-contained
//! directory; [`ModelStore::open`] loads it back for serving. The small
//! factors (σ, V, means, the row-norm sidecar) live in memory; `U` is
//! `m x k` and stays sharded on disk (Demchik-style out-of-core layout),
//! pulled through an LRU shard cache on demand.
//!
//! Directory layout (all matrices in the `io::binmat` format):
//!
//! ```text
//! <dir>/model.manifest   key=value: version m n k shards shard_rows centered [seed]
//! <dir>/sigma.csv        descending singular values, one per line
//! <dir>/V.bin            right singular vectors, n x k
//! <dir>/means.bin        column means, 1 x n (PCA mode only)
//! <dir>/U-<i>.bin        U shards, row order preserved
//! <dir>/norms.bin        m x 1 sidecar: ||u_i ∘ σ||₂ per row, precomputed
//!                        at save time so cosine queries never rescan U
//! ```
//!
//! The manifest is written last, so a directory with a readable manifest is
//! a complete model.

use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::manifest::KvManifest;
use crate::io::writer::ShardSet;
use crate::linalg::Matrix;
use crate::coordinator::server::MetricsRegistry;
use crate::svd::SvdResult;
use crate::util::Logger;
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

static LOG: Logger = Logger::new("serve.store");

/// Current model directory format version.
pub const MODEL_VERSION: usize = 1;

/// Persist a finished factorization as a servable model directory.
///
/// Streams the `U` shards into the directory (recomputing nothing), writes
/// the row-norm sidecar for cosine queries along the way, and commits by
/// writing `model.manifest` last. Requires `V` (serving projects through
/// it); pass the run's seed for provenance if known.
pub fn save_model(result: &SvdResult, dir: impl AsRef<Path>, seed: Option<u64>) -> Result<()> {
    let dir = dir.as_ref();
    let v = result
        .v
        .as_ref()
        .ok_or_else(|| Error::Config("save_model: V not computed (rerun without --no-v)".into()))?;
    if v.shape() != (result.n, result.k) {
        return Err(Error::shape(format!(
            "save_model: V is {:?}, expected ({}, {})",
            v.shape(),
            result.n,
            result.k
        )));
    }
    std::fs::create_dir_all(dir)?;
    // Invalidate any previous model in this directory up front: the
    // manifest is the commit marker, so it must not survive a partial
    // overwrite of the other files.
    match std::fs::remove_file(dir.join("model.manifest")) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
        Err(e) => return Err(e.into()),
    }

    // σ, V, means — small, eager.
    let sigma_text: String = result.sigma.iter().map(|s| format!("{s}\n")).collect();
    std::fs::write(dir.join("sigma.csv"), sigma_text)?;
    crate::io::binmat::write_matrix_bin(v, &path_str(dir.join("V.bin"))?)?;
    if let Some(means) = &result.means {
        let mrow = Matrix::from_rows(std::slice::from_ref(means))?;
        crate::io::binmat::write_matrix_bin(&mrow, &path_str(dir.join("means.bin"))?)?;
    }

    // U shards: stream-copy into the model dir, counting rows per shard and
    // accumulating the embedding row norms ||u_i ∘ σ||.
    let dst = ShardSet::new(dir, "U", InputFormat::Bin)?;
    if result.shards > 0 && dst.shard_path(0) == result.u_shards.shard_path(0) {
        return Err(Error::Config(
            "save_model: model dir equals the run's work dir; choose a separate directory".into(),
        ));
    }
    let mut norms = crate::io::binmat::BinMatWriter::create(
        &path_str(dir.join("norms.bin"))?,
        1,
        crate::io::binmat::DType::F64,
    )?;
    let mut shard_rows = Vec::with_capacity(result.shards);
    let mut total_rows = 0usize;
    for i in 0..result.shards {
        let mut reader = result.u_shards.open_reader(i)?;
        let mut writer = dst.open_writer(i, result.k)?;
        let mut row = Vec::new();
        let mut count = 0usize;
        while reader.next_row(&mut row)? {
            if row.len() != result.k {
                return Err(Error::shape(format!(
                    "save_model: U shard {i} row has {} cols, expected {}",
                    row.len(),
                    result.k
                )));
            }
            writer.write_row(&row)?;
            let norm: f64 = row
                .iter()
                .zip(result.sigma.iter())
                .map(|(u, s)| (u * s) * (u * s))
                .sum::<f64>()
                .sqrt();
            norms.write_row(&[norm])?;
            count += 1;
        }
        writer.finish()?;
        shard_rows.push(count);
        total_rows += count;
    }
    norms.finish()?;
    if total_rows != result.m {
        return Err(Error::Other(format!(
            "save_model: U shards hold {total_rows} rows, expected {}",
            result.m
        )));
    }

    // Manifest last — its presence marks the directory complete.
    let mut man = KvManifest::new();
    man.set("version", MODEL_VERSION);
    man.set("m", result.m);
    man.set("n", result.n);
    man.set("k", result.k);
    man.set("shards", result.shards);
    man.set(
        "shard_rows",
        shard_rows.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(","),
    );
    man.set("centered", usize::from(result.means.is_some()));
    man.set("format", "bin");
    if let Some(seed) = seed {
        man.set("seed", seed);
    }
    man.save(dir.join("model.manifest"))?;
    LOG.info(&format!(
        "saved model {}x{} k={} ({} shards) to {}",
        result.m,
        result.n,
        result.k,
        result.shards,
        dir.display()
    ));
    Ok(())
}

fn path_str(p: PathBuf) -> Result<String> {
    Ok(p.to_string_lossy().into_owned())
}

/// LRU cache of materialized U shards.
struct ShardCache {
    cap: usize,
    map: HashMap<usize, Arc<Matrix>>,
    order: VecDeque<usize>,
}

impl ShardCache {
    fn touch(&mut self, i: usize) {
        if let Some(pos) = self.order.iter().position(|&x| x == i) {
            self.order.remove(pos);
        }
        self.order.push_back(i);
    }
}

/// A loaded model: small factors in memory, U shards cached lazily.
pub struct ModelStore {
    dir: PathBuf,
    m: usize,
    n: usize,
    k: usize,
    shards: usize,
    /// Rows per shard (row order preserved across shards).
    shard_rows: Vec<usize>,
    /// Global row index of each shard's first row (len = shards + 1).
    row_offsets: Vec<usize>,
    centered: bool,
    seed: Option<u64>,
    sigma: Vec<f64>,
    v: Matrix,
    means: Option<Vec<f64>>,
    /// ||u_i ∘ σ||₂ per row (the cosine denominator sidecar).
    norms: Vec<f64>,
    u_shards: ShardSet,
    cache: Mutex<ShardCache>,
    /// Separate LRU of the scaled embedding shards `U_shard ∘ σ`, so the
    /// similarity hot path never rescales per query batch.
    embedding_cache: Mutex<ShardCache>,
}

impl ModelStore {
    /// Default number of U shards kept materialized.
    pub const DEFAULT_CACHE_SHARDS: usize = 4;

    /// Open a model directory written by [`save_model`]. `cache_shards`
    /// bounds how many U shards stay materialized (min 1).
    pub fn open(dir: impl AsRef<Path>, cache_shards: usize) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let man = KvManifest::load(dir.join("model.manifest"))?;
        let version = man.require_usize("version")?;
        if version != MODEL_VERSION {
            return Err(Error::parse(format!(
                "model {}: unsupported version {version}",
                dir.display()
            )));
        }
        let m = man.require_usize("m")?;
        let n = man.require_usize("n")?;
        let k = man.require_usize("k")?;
        let shards = man.require_usize("shards")?;
        let shard_rows = man.require_usize_list("shard_rows")?;
        if shard_rows.len() != shards {
            return Err(Error::parse(format!(
                "model {}: {} shard_rows entries for {shards} shards",
                dir.display(),
                shard_rows.len()
            )));
        }
        let mut row_offsets = Vec::with_capacity(shards + 1);
        let mut acc = 0usize;
        row_offsets.push(0);
        for &r in &shard_rows {
            acc += r;
            row_offsets.push(acc);
        }
        if acc != m {
            return Err(Error::parse(format!(
                "model {}: shard_rows sum to {acc}, manifest says m={m}",
                dir.display()
            )));
        }
        let centered = man.require_bool("centered")?;
        let seed = man.get_u64("seed")?;

        let sigma: Vec<f64> = std::fs::read_to_string(dir.join("sigma.csv"))?
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| {
                l.trim()
                    .parse::<f64>()
                    .map_err(|_| Error::parse(format!("sigma.csv: bad value `{l}`")))
            })
            .collect::<Result<_>>()?;
        if sigma.len() != k {
            return Err(Error::parse(format!(
                "model {}: {} sigma values for k={k}",
                dir.display(),
                sigma.len()
            )));
        }
        let v = crate::io::binmat::read_matrix_bin(&path_str(dir.join("V.bin"))?)?;
        if v.shape() != (n, k) {
            return Err(Error::shape(format!(
                "model {}: V is {:?}, expected ({n}, {k})",
                dir.display(),
                v.shape()
            )));
        }
        let means = if centered {
            let mrow = crate::io::binmat::read_matrix_bin(&path_str(dir.join("means.bin"))?)?;
            if mrow.shape() != (1, n) {
                return Err(Error::shape(format!(
                    "model {}: means is {:?}, expected (1, {n})",
                    dir.display(),
                    mrow.shape()
                )));
            }
            Some(mrow.row(0).to_vec())
        } else {
            None
        };
        let norm_mat = crate::io::binmat::read_matrix_bin(&path_str(dir.join("norms.bin"))?)?;
        if norm_mat.shape() != (m, 1) {
            return Err(Error::shape(format!(
                "model {}: norms is {:?}, expected ({m}, 1)",
                dir.display(),
                norm_mat.shape()
            )));
        }
        let norms = norm_mat.col(0);

        let u_shards = ShardSet::new(&dir, "U", InputFormat::Bin)?;
        Ok(ModelStore {
            dir,
            m,
            n,
            k,
            shards,
            shard_rows,
            row_offsets,
            centered,
            seed,
            sigma,
            v,
            means,
            norms,
            u_shards,
            cache: Mutex::new(ShardCache {
                cap: cache_shards.max(1),
                map: HashMap::new(),
                order: VecDeque::new(),
            }),
            embedding_cache: Mutex::new(ShardCache {
                cap: cache_shards.max(1),
                map: HashMap::new(),
                order: VecDeque::new(),
            }),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn shard_rows(&self) -> &[usize] {
        &self.shard_rows
    }

    pub fn centered(&self) -> bool {
        self.centered
    }

    pub fn seed(&self) -> Option<u64> {
        self.seed
    }

    pub fn sigma(&self) -> &[f64] {
        &self.sigma
    }

    /// Right singular vectors, `n x k`.
    pub fn v(&self) -> &Matrix {
        &self.v
    }

    pub fn means(&self) -> Option<&[f64]> {
        self.means.as_deref()
    }

    /// Precomputed `||u_i ∘ σ||₂` per row.
    pub fn norms(&self) -> &[f64] {
        &self.norms
    }

    /// Global row index of shard `i`'s first row.
    pub fn shard_base(&self, i: usize) -> usize {
        self.row_offsets[i.min(self.shards)]
    }

    /// Map a global row index to `(shard, offset-within-shard)`.
    pub fn row_location(&self, row: usize) -> Result<(usize, usize)> {
        if row >= self.m {
            return Err(Error::Config(format!("row {row} out of range (m={})", self.m)));
        }
        // row_offsets is sorted; find the shard whose range contains `row`.
        let shard = match self.row_offsets.binary_search(&row) {
            Ok(mut i) => {
                // Landed on a boundary; skip empty shards to the owning one.
                while i < self.shards && self.shard_rows[i] == 0 {
                    i += 1;
                }
                i
            }
            Err(i) => i - 1,
        };
        Ok((shard, row - self.row_offsets[shard]))
    }

    /// Materialize shard `i` (rows x k), via the LRU cache.
    pub fn shard(&self, i: usize) -> Result<Arc<Matrix>> {
        if i >= self.shards {
            return Err(Error::Config(format!("shard {i} out of range ({})", self.shards)));
        }
        cached(&self.cache, i, "serve_shard_cache", || self.load_shard(i))
    }

    /// Shard `i` as embedding rows `u ∘ σ`, via its own LRU — the
    /// similarity scan's hot input, scaled once per residency, not per
    /// query batch.
    pub fn embedding_shard(&self, i: usize) -> Result<Arc<Matrix>> {
        if i >= self.shards {
            return Err(Error::Config(format!("shard {i} out of range ({})", self.shards)));
        }
        cached(&self.embedding_cache, i, "serve_embedding_cache", || {
            self.shard(i)?.scale_cols(&self.sigma)
        })
    }

    fn load_shard(&self, i: usize) -> Result<Matrix> {
        let mut reader = self.u_shards.open_reader(i)?;
        let mut out = Matrix::zeros(self.shard_rows[i], self.k);
        let mut row = Vec::with_capacity(self.k);
        let mut at = 0usize;
        while reader.next_row(&mut row)? {
            if at >= self.shard_rows[i] || row.len() != self.k {
                return Err(Error::shape(format!(
                    "model {}: U shard {i} does not match manifest ({} rows x {} cols expected)",
                    self.dir.display(),
                    self.shard_rows[i],
                    self.k
                )));
            }
            out.row_mut(at).copy_from_slice(&row);
            at += 1;
        }
        if at != self.shard_rows[i] {
            return Err(Error::shape(format!(
                "model {}: U shard {i} has {at} rows, manifest says {}",
                self.dir.display(),
                self.shard_rows[i]
            )));
        }
        Ok(out)
    }

    /// Raw `u_row` (length k) for a global row index.
    pub fn u_row(&self, row: usize) -> Result<Vec<f64>> {
        let (shard, off) = self.row_location(row)?;
        let s = self.shard(shard)?;
        Ok(s.row(off).to_vec())
    }

    /// The row's latent embedding `u_row ∘ σ` (LSA document coordinates).
    pub fn embedding_row(&self, row: usize) -> Result<Vec<f64>> {
        let (shard, off) = self.row_location(row)?;
        let e = self.embedding_shard(shard)?;
        Ok(e.row(off).to_vec())
    }
}

/// Shared LRU get-or-load over one of the store's caches.
fn cached(
    cache: &Mutex<ShardCache>,
    i: usize,
    metric: &str,
    load: impl FnOnce() -> Result<Matrix>,
) -> Result<Arc<Matrix>> {
    let reg = MetricsRegistry::global();
    {
        let mut c = cache.lock().unwrap();
        if let Some(m) = c.map.get(&i).cloned() {
            c.touch(i);
            reg.add(&format!("{metric}_hits"), 1.0);
            return Ok(m);
        }
    }
    reg.add(&format!("{metric}_misses"), 1.0);
    let loaded = Arc::new(load()?);
    let mut c = cache.lock().unwrap();
    c.map.insert(i, loaded.clone());
    c.touch(i);
    while c.map.len() > c.cap {
        match c.order.pop_front() {
            Some(old) => {
                c.map.remove(&old);
            }
            None => break,
        }
    }
    Ok(loaded)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::io::dataset::{gen_exact, Spectrum};
    use crate::io::InputSpec;
    use crate::svd::Svd;

    fn model_fixture(name: &str, center: bool) -> (PathBuf, SvdResult, Matrix) {
        let dir = std::env::temp_dir().join("tallfat_test_store").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, _) = gen_exact(
            180,
            20,
            5,
            Spectrum::Geometric { scale: 8.0, decay: 0.6 },
            0.0,
            11,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("A.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        let result = Svd::over(&spec)
            .unwrap()
            .rank(6)
            .oversample(4)
            .workers(3)
            .block(32)
            .work_dir(dir.join("work").to_string_lossy().into_owned())
            .center(center)
            .backend(std::sync::Arc::new(NativeBackend::new()))
            .run()
            .unwrap();
        (dir, result, a)
    }

    #[test]
    fn save_open_roundtrip() {
        let (dir, result, _) = model_fixture("roundtrip", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, Some(42)).unwrap();
        let store = ModelStore::open(&model_dir, 2).unwrap();
        assert_eq!((store.m(), store.n(), store.k()), (180, 20, 6));
        assert_eq!(store.shards(), result.shards);
        assert_eq!(store.seed(), Some(42));
        assert_eq!(store.sigma(), &result.sigma[..]);
        assert_eq!(store.v(), result.v.as_ref().unwrap());
        assert!(!store.centered());
        assert!(store.means().is_none());
        assert_eq!(store.norms().len(), 180);
        assert_eq!(store.shard_rows().iter().sum::<usize>(), 180);

        // Shard content matches the original U row by row.
        let u = result.u_matrix().unwrap();
        for row in [0usize, 1, 89, 179] {
            let got = store.u_row(row).unwrap();
            assert_eq!(got.as_slice(), u.row(row), "row {row}");
            let emb = store.embedding_row(row).unwrap();
            let norm: f64 = emb.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!((norm - store.norms()[row]).abs() < 1e-12);
        }
    }

    #[test]
    fn centered_model_keeps_means() {
        let (dir, result, _) = model_fixture("centered", true);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, None).unwrap();
        let store = ModelStore::open(&model_dir, 1).unwrap();
        assert!(store.centered());
        assert_eq!(store.means().unwrap(), &result.means.as_ref().unwrap()[..]);
        assert_eq!(store.seed(), None);
    }

    #[test]
    fn lru_cache_evicts_but_stays_correct() {
        let (dir, result, _) = model_fixture("lru", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, None).unwrap();
        let store = ModelStore::open(&model_dir, 1).unwrap(); // cap 1: every alternation evicts
        let u = result.u_matrix().unwrap();
        for _ in 0..3 {
            for row in [0usize, 179] {
                assert_eq!(store.u_row(row).unwrap().as_slice(), u.row(row));
            }
        }
    }

    #[test]
    fn resave_over_existing_model_is_clean() {
        let (dir, result, _) = model_fixture("resave", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, Some(1)).unwrap();
        // Re-saving must fully replace the old model: the old manifest may
        // not survive alongside partially rewritten artifacts.
        save_model(&result, &model_dir, Some(2)).unwrap();
        let store = ModelStore::open(&model_dir, 2).unwrap();
        assert_eq!(store.seed(), Some(2));
        assert_eq!(store.m(), 180);
    }

    #[test]
    fn embedding_shard_matches_scaled_rows() {
        let (dir, result, _) = model_fixture("embshard", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, None).unwrap();
        let store = ModelStore::open(&model_dir, 2).unwrap();
        let raw = store.shard(0).unwrap();
        let emb = store.embedding_shard(0).unwrap();
        for r in 0..raw.rows().min(5) {
            for (j, (&u, &s)) in raw.row(r).iter().zip(store.sigma().iter()).enumerate() {
                assert!((emb.get(r, j) - u * s).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn open_rejects_damaged_dirs() {
        let (dir, result, _) = model_fixture("damaged", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, None).unwrap();
        std::fs::remove_file(model_dir.join("V.bin")).unwrap();
        assert!(ModelStore::open(&model_dir, 2).is_err());
        assert!(ModelStore::open(dir.join("nonexistent"), 2).is_err());
    }

    #[test]
    fn row_location_spans_shards() {
        let (dir, result, _) = model_fixture("rowloc", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, None).unwrap();
        let store = ModelStore::open(&model_dir, 2).unwrap();
        let mut seen = 0usize;
        for (i, &rows) in store.shard_rows().iter().enumerate() {
            if rows > 0 {
                assert_eq!(store.row_location(seen).unwrap(), (i, 0));
                assert_eq!(store.row_location(seen + rows - 1).unwrap(), (i, rows - 1));
            }
            seen += rows;
        }
        assert!(store.row_location(store.m()).is_err());
    }
}
