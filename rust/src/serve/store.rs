//! Persisted SVD model directories: versioned generations and lazy loading.
//!
//! [`save_model`] turns a completed [`SvdResult`] into a self-contained
//! *generation* directory under a model root; [`ModelStore::open`] resolves
//! the root's live generation and loads it back for serving. The small
//! factors (σ, V, means, the row-norm sidecar) live in memory; `U` is
//! `m x k` and stays sharded on disk (Demchik-style out-of-core layout),
//! pulled through an LRU shard cache on demand.
//!
//! Root layout (all matrices in the `io::binmat` format):
//!
//! ```text
//! <root>/CURRENT             one line naming the live generation (gen-000001)
//! <root>/gen-000000/         an immutable generation:
//!   model.manifest           key=value: version m n k shards shard_rows
//!                            centered generation [seed] [updated_from]
//!   sigma.csv                descending singular values, one per line
//!   V.bin                    right singular vectors, n x k
//!   means.bin                column means, 1 x n (PCA mode only)
//!   U-<i>.bin                U shards, row order preserved
//!   norms.bin                m x 1 sidecar: ||u_i ∘ σ||₂ per row, precomputed
//!                            at save time so cosine queries never rescan U
//! <root>/gen-000001/         the next generation (e.g. from `tallfat update`)
//! ```
//!
//! Within a generation the manifest is written last, so a generation with a
//! readable manifest is complete; the root's `CURRENT` pointer is replaced
//! atomically (write + rename), so readers always resolve to a complete
//! generation. Old generations are garbage-collected by
//! [`gc_generations`] — the update path keeps the newest few so in-flight
//! readers of the previous generation finish cleanly.
//!
//! Pre-generation model directories (a flat `model.manifest` at the root,
//! no `CURRENT`) still open as generation 0.

use crate::config::InputFormat;
use crate::coordinator::server::MetricsRegistry;
use crate::error::{Error, Result};
use crate::io::manifest::KvManifest;
use crate::io::writer::{ShardReader, ShardSet};
use crate::linalg::Matrix;
use crate::svd::SvdResult;
use crate::util::Logger;
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

static LOG: Logger = Logger::new("serve.store");

/// Current model directory format version.
pub const MODEL_VERSION: usize = 1;

/// Name of the root-level pointer file selecting the live generation.
pub const CURRENT_FILE: &str = "CURRENT";

/// Directory name of generation `g` (`gen-000042`).
pub fn generation_dir_name(generation: u64) -> String {
    format!("gen-{generation:06}")
}

/// Parse a `gen-NNNNNN` directory name back to its number.
fn parse_generation_name(name: &str) -> Option<u64> {
    name.strip_prefix("gen-")?.parse().ok()
}

/// List the generation directories under a model root, ascending by number.
pub fn list_generations(root: impl AsRef<Path>) -> Result<Vec<(u64, PathBuf)>> {
    let root = root.as_ref();
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(root) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e.into()),
    };
    for entry in entries {
        let entry = entry?;
        if !entry.file_type()?.is_dir() {
            continue;
        }
        if let Some(g) = parse_generation_name(&entry.file_name().to_string_lossy()) {
            out.push((g, entry.path()));
        }
    }
    out.sort_by_key(|(g, _)| *g);
    Ok(out)
}

/// Resolve a model root to the directory of its live generation: follow
/// `CURRENT` when present, fall back to the root itself for pre-generation
/// flat layouts (a `model.manifest` directly at the root).
pub fn resolve_current(root: impl AsRef<Path>) -> Result<PathBuf> {
    let root = root.as_ref();
    match std::fs::read_to_string(root.join(CURRENT_FILE)) {
        Ok(text) => {
            let name = text.trim();
            if parse_generation_name(name).is_none() {
                return Err(Error::parse(format!(
                    "model {}: CURRENT names `{name}`, expected gen-NNNNNN",
                    root.display()
                )));
            }
            Ok(root.join(name))
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            if root.join("model.manifest").exists() {
                Ok(root.to_path_buf())
            } else {
                Err(Error::Other(format!(
                    "model {}: no CURRENT pointer and no model.manifest (not a model directory)",
                    root.display()
                )))
            }
        }
        Err(e) => Err(Error::Other(format!(
            "model {}: cannot read CURRENT: {e}",
            root.display()
        ))),
    }
}

/// The number the next generation written under `root` should get: one
/// past the newest directory on disk *and* past `parent` — never reusing
/// an existing generation directory (generations are immutable; a reader
/// may hold one open even after `CURRENT` was rolled back past it).
pub fn next_generation(root: impl AsRef<Path>, parent: u64) -> Result<u64> {
    let newest = list_generations(root)?.last().map(|(g, _)| *g);
    Ok(newest.map_or(parent + 1, |g| g.max(parent) + 1))
}

/// Atomically point the root's `CURRENT` at `generation` (write + rename, so
/// concurrent readers see either the old or the new pointer, never a torn
/// one; the scratch name carries pid + a process-wide sequence so no two
/// publishers — across or within a process — share a staging file).
pub fn publish_generation(root: impl AsRef<Path>, generation: u64) -> Result<()> {
    let root = root.as_ref();
    static PUBLISH_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = PUBLISH_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = root.join(format!(".CURRENT.{}.{seq}.tmp", std::process::id()));
    std::fs::write(&tmp, format!("{}\n", generation_dir_name(generation)))?;
    std::fs::rename(&tmp, root.join(CURRENT_FILE))?;
    Ok(())
}

/// Claim a fresh generation directory for writing. Generations are
/// immutable and always get unused numbers ([`next_generation`]), so an
/// already-existing directory means another writer raced this one to the
/// same number — refuse instead of interleaving two writers' files into
/// one "committed" generation. (A crashed half-written directory is not
/// reclaimed either: it has no manifest, is skipped by numbering, and is
/// eventually garbage-collected.)
pub(crate) fn begin_generation(gen_dir: &Path) -> Result<()> {
    match std::fs::create_dir(gen_dir) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => Err(Error::Config(format!(
            "generation dir {} already exists — another writer racing this one? retry",
            gen_dir.display()
        ))),
        Err(e) => Err(e.into()),
    }
}

/// Delete all but the newest `keep` generations (min 1). The generation
/// `CURRENT` points at is never removed regardless of age. Returns how many
/// generation directories were deleted.
///
/// GC cannot see live readers — `keep` must cover the slowest reader's
/// lag. Servers poll `CURRENT` every 5s by default (see
/// [`crate::serve::ServeOptions`]), so the default `keep = 2` means a
/// reader would have to sleep through two full updates to lose its files.
pub fn gc_generations(root: impl AsRef<Path>, keep: usize) -> Result<usize> {
    let root = root.as_ref();
    let keep = keep.max(1);
    let gens = list_generations(root)?;
    if gens.len() <= keep {
        return Ok(0);
    }
    let live = resolve_current(root).ok();
    let mut removed = 0usize;
    for (_, dir) in &gens[..gens.len() - keep] {
        if live.as_deref() == Some(dir.as_path()) {
            continue;
        }
        std::fs::remove_dir_all(dir)?;
        removed += 1;
    }
    if removed > 0 {
        LOG.info(&format!("gc: removed {removed} old generation(s) under {}", root.display()));
    }
    Ok(removed)
}

/// Persist a finished factorization as a servable model root.
///
/// Writes a fresh, immutable generation directory (numbered after the
/// newest one already present, so re-saving never mutates a generation a
/// reader may hold open) and atomically repoints `CURRENT` at it. Requires
/// `V` (serving projects through it); pass the run's seed for provenance if
/// known.
pub fn save_model(result: &SvdResult, dir: impl AsRef<Path>, seed: Option<u64>) -> Result<()> {
    let root = dir.as_ref();
    std::fs::create_dir_all(root)?;
    let generation = match list_generations(root)?.last() {
        Some((g, _)) => g + 1,
        None => 0,
    };
    let gen_dir = root.join(generation_dir_name(generation));
    write_model_files(result, &gen_dir, seed, generation, None)?;
    publish_generation(root, generation)?;
    LOG.info(&format!(
        "saved model {}x{} k={} ({} shards) to {} (generation {generation})",
        result.m,
        result.n,
        result.k,
        result.shards,
        root.display()
    ));
    Ok(())
}

/// Write the files of one generation directory. The manifest goes last —
/// its presence marks the generation complete. `updated_from` records the
/// parent generation for incrementally-updated models.
pub(crate) fn write_model_files(
    result: &SvdResult,
    gen_dir: &Path,
    seed: Option<u64>,
    generation: u64,
    updated_from: Option<u64>,
) -> Result<()> {
    let v = result
        .v
        .as_ref()
        .ok_or_else(|| Error::Config("save_model: V not computed (rerun without --no-v)".into()))?;
    if v.shape() != (result.n, result.k) {
        return Err(Error::shape(format!(
            "save_model: V is {:?}, expected ({}, {})",
            v.shape(),
            result.n,
            result.k
        )));
    }
    begin_generation(gen_dir)?;

    // σ, V, means — small, eager.
    let sigma_text: String = result.sigma.iter().map(|s| format!("{s}\n")).collect();
    std::fs::write(gen_dir.join("sigma.csv"), sigma_text)?;
    crate::io::binmat::write_matrix_bin(v, &path_str(gen_dir.join("V.bin"))?)?;
    if let Some(means) = &result.means {
        let mrow = Matrix::from_rows(std::slice::from_ref(means))?;
        crate::io::binmat::write_matrix_bin(&mrow, &path_str(gen_dir.join("means.bin"))?)?;
    }

    // U shards: stream-copy into the generation dir, counting rows per
    // shard and accumulating the embedding row norms ||u_i ∘ σ||.
    let dst = ShardSet::new(gen_dir, "U", InputFormat::Bin)?;
    if result.shards > 0 && dst.shard_path(0) == result.u_shards.shard_path(0) {
        return Err(Error::Config(
            "save_model: model dir equals the run's work dir; choose a separate directory".into(),
        ));
    }
    let mut norms = crate::io::binmat::BinMatWriter::create(
        &path_str(gen_dir.join("norms.bin"))?,
        1,
        crate::io::binmat::DType::F64,
    )?;
    let mut shard_rows = Vec::with_capacity(result.shards);
    let mut total_rows = 0usize;
    for i in 0..result.shards {
        let mut reader = result.u_shards.open_reader(i)?;
        let mut writer = dst.open_writer(i, result.k)?;
        let mut row = Vec::new();
        let mut count = 0usize;
        while reader.next_row(&mut row)? {
            if row.len() != result.k {
                return Err(Error::shape(format!(
                    "save_model: U shard {i} row has {} cols, expected {}",
                    row.len(),
                    result.k
                )));
            }
            writer.write_row(&row)?;
            norms.write_row(&[embedding_norm(&row, &result.sigma)])?;
            count += 1;
        }
        writer.finish()?;
        shard_rows.push(count);
        total_rows += count;
    }
    norms.finish()?;
    if total_rows != result.m {
        return Err(Error::Other(format!(
            "save_model: U shards hold {total_rows} rows, expected {}",
            result.m
        )));
    }

    // Manifest last — its presence marks the generation complete.
    model_manifest(
        result.m,
        result.n,
        result.k,
        &shard_rows,
        result.means.is_some(),
        generation,
        updated_from,
        seed,
    )
    .save(gen_dir.join("model.manifest"))?;
    Ok(())
}

/// Assemble a generation's `model.manifest` — the single definition of the
/// key set, shared by the factorization save path and the update path so
/// the two can never drift apart.
#[allow(clippy::too_many_arguments)]
pub(crate) fn model_manifest(
    m: usize,
    n: usize,
    k: usize,
    shard_rows: &[usize],
    centered: bool,
    generation: u64,
    updated_from: Option<u64>,
    seed: Option<u64>,
) -> KvManifest {
    let mut man = KvManifest::new();
    man.set("version", MODEL_VERSION);
    man.set("m", m);
    man.set("n", n);
    man.set("k", k);
    man.set("shards", shard_rows.len());
    man.set(
        "shard_rows",
        shard_rows.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(","),
    );
    man.set("centered", usize::from(centered));
    man.set("format", "bin");
    man.set("generation", generation);
    if let Some(parent) = updated_from {
        man.set("updated_from", parent);
    }
    if let Some(seed) = seed {
        man.set("seed", seed);
    }
    man
}

/// `||u ∘ σ||₂` — the cosine-denominator entry for one U row.
pub(crate) fn embedding_norm(u_row: &[f64], sigma: &[f64]) -> f64 {
    u_row
        .iter()
        .zip(sigma.iter())
        .map(|(u, s)| (u * s) * (u * s))
        .sum::<f64>()
        .sqrt()
}

fn path_str(p: PathBuf) -> Result<String> {
    Ok(p.to_string_lossy().into_owned())
}

/// LRU cache of materialized U shards.
struct ShardCache {
    cap: usize,
    map: HashMap<usize, Arc<Matrix>>,
    order: VecDeque<usize>,
}

impl ShardCache {
    fn touch(&mut self, i: usize) {
        if let Some(pos) = self.order.iter().position(|&x| x == i) {
            self.order.remove(pos);
        }
        self.order.push_back(i);
    }
}

/// A loaded model generation: small factors in memory, U shards cached
/// lazily.
pub struct ModelStore {
    /// The model root [`ModelStore::open`] was given.
    root: PathBuf,
    /// The resolved generation directory the factors were loaded from.
    dir: PathBuf,
    generation: u64,
    m: usize,
    n: usize,
    k: usize,
    shards: usize,
    /// Rows per shard (row order preserved across shards).
    shard_rows: Vec<usize>,
    /// Global row index of each shard's first row (len = shards + 1).
    row_offsets: Vec<usize>,
    centered: bool,
    seed: Option<u64>,
    sigma: Vec<f64>,
    v: Matrix,
    means: Option<Vec<f64>>,
    /// ||u_i ∘ σ||₂ per row (the cosine denominator sidecar), loaded on
    /// first use — it is O(m) and only the similarity path needs it (the
    /// update path opens stores without paying for it).
    norms: std::sync::OnceLock<Vec<f64>>,
    u_shards: ShardSet,
    cache: Mutex<ShardCache>,
    /// Separate LRU of the scaled embedding shards `U_shard ∘ σ`, so the
    /// similarity hot path never rescales per query batch.
    embedding_cache: Mutex<ShardCache>,
}

impl ModelStore {
    /// Default number of U shards kept materialized.
    pub const DEFAULT_CACHE_SHARDS: usize = 4;

    /// Open a model root written by [`save_model`], resolving its live
    /// generation (or a bare generation / legacy flat directory).
    /// `cache_shards` bounds how many U shards stay materialized (min 1).
    pub fn open(dir: impl AsRef<Path>, cache_shards: usize) -> Result<Self> {
        let root = dir.as_ref().to_path_buf();
        let dir = resolve_current(&root)?;
        // Every manifest-level failure names the generation directory it
        // came from — with several generations on disk, "missing key `m`"
        // alone is useless.
        let in_dir = |e: Error| Error::parse(format!("model {}: {e}", dir.display()));
        let man = KvManifest::load(dir.join("model.manifest"))?;
        let version = man.require_usize("version").map_err(in_dir)?;
        if version != MODEL_VERSION {
            return Err(Error::parse(format!(
                "model {}: unsupported version {version}",
                dir.display()
            )));
        }
        let m = man.require_usize("m").map_err(in_dir)?;
        let n = man.require_usize("n").map_err(in_dir)?;
        let k = man.require_usize("k").map_err(in_dir)?;
        let shards = man.require_usize("shards").map_err(in_dir)?;
        let shard_rows = man.require_usize_list("shard_rows").map_err(in_dir)?;
        if shard_rows.len() != shards {
            return Err(Error::parse(format!(
                "model {}: {} shard_rows entries for {shards} shards",
                dir.display(),
                shard_rows.len()
            )));
        }
        let mut row_offsets = Vec::with_capacity(shards + 1);
        let mut acc = 0usize;
        row_offsets.push(0);
        for &r in &shard_rows {
            acc += r;
            row_offsets.push(acc);
        }
        if acc != m {
            return Err(Error::parse(format!(
                "model {}: shard_rows sum to {acc}, manifest says m={m}",
                dir.display()
            )));
        }
        let centered = man.require_bool("centered").map_err(in_dir)?;
        let seed = man.get_u64("seed").map_err(in_dir)?;
        let generation = man.get_u64("generation").map_err(in_dir)?.unwrap_or(0);

        let sigma: Vec<f64> = std::fs::read_to_string(dir.join("sigma.csv"))
            .map_err(|e| Error::Other(format!("model {}: cannot read sigma.csv: {e}", dir.display())))?
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| {
                l.trim().parse::<f64>().map_err(|_| {
                    Error::parse(format!("model {}: sigma.csv: bad value `{l}`", dir.display()))
                })
            })
            .collect::<Result<_>>()?;
        if sigma.len() != k {
            return Err(Error::parse(format!(
                "model {}: {} sigma values for k={k}",
                dir.display(),
                sigma.len()
            )));
        }
        let v = crate::io::binmat::read_matrix_bin(&path_str(dir.join("V.bin"))?)
            .map_err(|e| Error::Other(format!("model {}: V.bin: {e}", dir.display())))?;
        if v.shape() != (n, k) {
            return Err(Error::shape(format!(
                "model {}: V is {:?}, expected ({n}, {k})",
                dir.display(),
                v.shape()
            )));
        }
        let means = if centered {
            let mrow = crate::io::binmat::read_matrix_bin(&path_str(dir.join("means.bin"))?)
                .map_err(|e| Error::Other(format!("model {}: means.bin: {e}", dir.display())))?;
            if mrow.shape() != (1, n) {
                return Err(Error::shape(format!(
                    "model {}: means is {:?}, expected (1, {n})",
                    dir.display(),
                    mrow.shape()
                )));
            }
            Some(mrow.row(0).to_vec())
        } else {
            None
        };
        // The norms payload is O(m) and loaded lazily (only the similarity
        // path needs it), but a missing/mis-shaped sidecar must still fail
        // here, eagerly — the header read costs a few bytes.
        let norms_header =
            crate::io::binmat::BinMatHeader::read_from(&path_str(dir.join("norms.bin"))?)
                .map_err(|e| Error::Other(format!("model {}: norms.bin: {e}", dir.display())))?;
        if (norms_header.rows as usize, norms_header.cols as usize) != (m, 1) {
            return Err(Error::shape(format!(
                "model {}: norms is {}x{}, expected ({m}, 1)",
                dir.display(),
                norms_header.rows,
                norms_header.cols
            )));
        }

        let u_shards = ShardSet::new(&dir, "U", InputFormat::Bin)?;
        Ok(ModelStore {
            root,
            dir,
            generation,
            m,
            n,
            k,
            shards,
            shard_rows,
            row_offsets,
            centered,
            seed,
            sigma,
            v,
            means,
            norms: std::sync::OnceLock::new(),
            u_shards,
            cache: Mutex::new(ShardCache {
                cap: cache_shards.max(1),
                map: HashMap::new(),
                order: VecDeque::new(),
            }),
            embedding_cache: Mutex::new(ShardCache {
                cap: cache_shards.max(1),
                map: HashMap::new(),
                order: VecDeque::new(),
            }),
        })
    }

    /// The model root this store was opened from (holds `CURRENT` and the
    /// generation directories).
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The resolved generation directory the factors live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Generation number of the loaded factors.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn shard_rows(&self) -> &[usize] {
        &self.shard_rows
    }

    pub fn centered(&self) -> bool {
        self.centered
    }

    pub fn seed(&self) -> Option<u64> {
        self.seed
    }

    pub fn sigma(&self) -> &[f64] {
        &self.sigma
    }

    /// Right singular vectors, `n x k`.
    pub fn v(&self) -> &Matrix {
        &self.v
    }

    pub fn means(&self) -> Option<&[f64]> {
        self.means.as_deref()
    }

    /// Precomputed `||u_i ∘ σ||₂` per row — the cosine-denominator
    /// sidecar, read from `norms.bin` and shape-checked on first use.
    pub fn norms(&self) -> Result<&[f64]> {
        if let Some(n) = self.norms.get() {
            return Ok(n);
        }
        let norm_mat =
            crate::io::binmat::read_matrix_bin(&path_str(self.dir.join("norms.bin"))?)
                .map_err(|e| {
                    Error::Other(format!("model {}: norms.bin: {e}", self.dir.display()))
                })?;
        if norm_mat.shape() != (self.m, 1) {
            return Err(Error::shape(format!(
                "model {}: norms is {:?}, expected ({}, 1)",
                self.dir.display(),
                norm_mat.shape(),
                self.m
            )));
        }
        // A concurrent first access may have raced us here; get_or_init
        // keeps exactly one copy either way.
        Ok(self.norms.get_or_init(|| norm_mat.col(0)))
    }

    /// Global row index of shard `i`'s first row.
    pub fn shard_base(&self, i: usize) -> usize {
        self.row_offsets[i.min(self.shards)]
    }

    /// Map a global row index to `(shard, offset-within-shard)`.
    pub fn row_location(&self, row: usize) -> Result<(usize, usize)> {
        if row >= self.m {
            return Err(Error::Config(format!("row {row} out of range (m={})", self.m)));
        }
        // row_offsets is sorted; find the shard whose range contains `row`.
        let shard = match self.row_offsets.binary_search(&row) {
            Ok(mut i) => {
                // Landed on a boundary; skip empty shards to the owning one.
                while i < self.shards && self.shard_rows[i] == 0 {
                    i += 1;
                }
                i
            }
            Err(i) => i - 1,
        };
        Ok((shard, row - self.row_offsets[shard]))
    }

    /// Materialize shard `i` (rows x k), via the LRU cache.
    pub fn shard(&self, i: usize) -> Result<Arc<Matrix>> {
        if i >= self.shards {
            return Err(Error::Config(format!("shard {i} out of range ({})", self.shards)));
        }
        cached(&self.cache, i, "serve_shard_cache", || self.load_shard(i))
    }

    /// Shard `i` as embedding rows `u ∘ σ`, via its own LRU — the
    /// similarity scan's hot input, scaled once per cache residency, not per
    /// query batch.
    pub fn embedding_shard(&self, i: usize) -> Result<Arc<Matrix>> {
        if i >= self.shards {
            return Err(Error::Config(format!("shard {i} out of range ({})", self.shards)));
        }
        cached(&self.embedding_cache, i, "serve_embedding_cache", || {
            self.shard(i)?.scale_cols(&self.sigma)
        })
    }

    /// Open a streaming reader over U shard `i` (the update path's
    /// rotation input — no cache pollution).
    pub fn u_shard_reader(&self, i: usize) -> Result<ShardReader> {
        if i >= self.shards {
            return Err(Error::Config(format!("shard {i} out of range ({})", self.shards)));
        }
        self.u_shards.open_reader(i)
    }

    fn load_shard(&self, i: usize) -> Result<Matrix> {
        let mut reader = self.u_shards.open_reader(i)?;
        let mut out = Matrix::zeros(self.shard_rows[i], self.k);
        let mut row = Vec::with_capacity(self.k);
        let mut at = 0usize;
        while reader.next_row(&mut row)? {
            if at >= self.shard_rows[i] || row.len() != self.k {
                return Err(Error::shape(format!(
                    "model {}: U shard {i} does not match manifest ({} rows x {} cols expected)",
                    self.dir.display(),
                    self.shard_rows[i],
                    self.k
                )));
            }
            out.row_mut(at).copy_from_slice(&row);
            at += 1;
        }
        if at != self.shard_rows[i] {
            return Err(Error::shape(format!(
                "model {}: U shard {i} has {at} rows, manifest says {}",
                self.dir.display(),
                self.shard_rows[i]
            )));
        }
        Ok(out)
    }

    /// Raw `u_row` (length k) for a global row index.
    pub fn u_row(&self, row: usize) -> Result<Vec<f64>> {
        let (shard, off) = self.row_location(row)?;
        let s = self.shard(shard)?;
        Ok(s.row(off).to_vec())
    }

    /// The row's latent embedding `u_row ∘ σ` (LSA document coordinates).
    pub fn embedding_row(&self, row: usize) -> Result<Vec<f64>> {
        let (shard, off) = self.row_location(row)?;
        let e = self.embedding_shard(shard)?;
        Ok(e.row(off).to_vec())
    }
}

/// Shared LRU get-or-load over one of the store's caches. Locks recover
/// from poisoning: a query thread that panicked while holding the cache
/// must cost one request, not every request after it (the map/order pair
/// is consistent at every step, so the recovered guard is safe to use).
fn cached(
    cache: &Mutex<ShardCache>,
    i: usize,
    metric: &str,
    load: impl FnOnce() -> Result<Matrix>,
) -> Result<Arc<Matrix>> {
    let reg = MetricsRegistry::global();
    {
        let mut c = crate::util::lock_unpoisoned(cache);
        if let Some(m) = c.map.get(&i).cloned() {
            c.touch(i);
            reg.add(&format!("{metric}_hits"), 1.0);
            return Ok(m);
        }
    }
    reg.add(&format!("{metric}_misses"), 1.0);
    let loaded = Arc::new(load()?);
    let mut c = crate::util::lock_unpoisoned(cache);
    c.map.insert(i, loaded.clone());
    c.touch(i);
    while c.map.len() > c.cap {
        match c.order.pop_front() {
            Some(old) => {
                c.map.remove(&old);
            }
            None => break,
        }
    }
    Ok(loaded)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::io::dataset::{gen_exact, Spectrum};
    use crate::io::InputSpec;
    use crate::svd::Svd;

    fn model_fixture(name: &str, center: bool) -> (PathBuf, SvdResult, Matrix) {
        let dir = std::env::temp_dir().join("tallfat_test_store").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, _) = gen_exact(
            180,
            20,
            5,
            Spectrum::Geometric { scale: 8.0, decay: 0.6 },
            0.0,
            11,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("A.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        let result = Svd::over(&spec)
            .unwrap()
            .rank(6)
            .oversample(4)
            .workers(3)
            .block(32)
            .work_dir(dir.join("work").to_string_lossy().into_owned())
            .center(center)
            .backend(std::sync::Arc::new(NativeBackend::new()))
            .run()
            .unwrap();
        (dir, result, a)
    }

    #[test]
    fn save_open_roundtrip() {
        let (dir, result, _) = model_fixture("roundtrip", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, Some(42)).unwrap();
        // Generation layout: a CURRENT pointer plus an immutable gen dir.
        assert!(model_dir.join(CURRENT_FILE).exists());
        assert!(model_dir.join("gen-000000").join("model.manifest").exists());
        let store = ModelStore::open(&model_dir, 2).unwrap();
        assert_eq!((store.m(), store.n(), store.k()), (180, 20, 6));
        assert_eq!(store.generation(), 0);
        assert_eq!(store.root(), model_dir.as_path());
        assert_eq!(store.dir(), model_dir.join("gen-000000").as_path());
        assert_eq!(store.shards(), result.shards);
        assert_eq!(store.seed(), Some(42));
        assert_eq!(store.sigma(), &result.sigma[..]);
        assert_eq!(store.v(), result.v.as_ref().unwrap());
        assert!(!store.centered());
        assert!(store.means().is_none());
        assert_eq!(store.norms().unwrap().len(), 180);
        assert_eq!(store.shard_rows().iter().sum::<usize>(), 180);

        // Shard content matches the original U row by row.
        let u = result.u_matrix().unwrap();
        for row in [0usize, 1, 89, 179] {
            let got = store.u_row(row).unwrap();
            assert_eq!(got.as_slice(), u.row(row), "row {row}");
            let emb = store.embedding_row(row).unwrap();
            let norm: f64 = emb.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!((norm - store.norms().unwrap()[row]).abs() < 1e-12);
        }
    }

    #[test]
    fn centered_model_keeps_means() {
        let (dir, result, _) = model_fixture("centered", true);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, None).unwrap();
        let store = ModelStore::open(&model_dir, 1).unwrap();
        assert!(store.centered());
        assert_eq!(store.means().unwrap(), &result.means.as_ref().unwrap()[..]);
        assert_eq!(store.seed(), None);
    }

    #[test]
    fn lru_cache_evicts_but_stays_correct() {
        let (dir, result, _) = model_fixture("lru", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, None).unwrap();
        let store = ModelStore::open(&model_dir, 1).unwrap(); // cap 1: every alternation evicts
        let u = result.u_matrix().unwrap();
        for _ in 0..3 {
            for row in [0usize, 179] {
                assert_eq!(store.u_row(row).unwrap().as_slice(), u.row(row));
            }
        }
    }

    #[test]
    fn resave_creates_a_new_generation() {
        let (dir, result, _) = model_fixture("resave", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, Some(1)).unwrap();
        // Re-saving appends a fresh generation and repoints CURRENT —
        // existing generations stay immutable for in-flight readers.
        save_model(&result, &model_dir, Some(2)).unwrap();
        let gens = list_generations(&model_dir).unwrap();
        assert_eq!(gens.iter().map(|(g, _)| *g).collect::<Vec<_>>(), vec![0, 1]);
        let store = ModelStore::open(&model_dir, 2).unwrap();
        assert_eq!(store.generation(), 1);
        assert_eq!(store.seed(), Some(2));
        assert_eq!(store.m(), 180);
    }

    #[test]
    fn gc_keeps_newest_and_live_generations() {
        let (dir, result, _) = model_fixture("gc", false);
        let model_dir = dir.join("model");
        for seed in 0..4 {
            save_model(&result, &model_dir, Some(seed)).unwrap();
        }
        assert_eq!(list_generations(&model_dir).unwrap().len(), 4);
        let removed = gc_generations(&model_dir, 2).unwrap();
        assert_eq!(removed, 2);
        let left: Vec<u64> =
            list_generations(&model_dir).unwrap().iter().map(|(g, _)| *g).collect();
        assert_eq!(left, vec![2, 3]);
        // The live generation survives even when it is old: point CURRENT
        // back at gen 2 and gc down to 1.
        publish_generation(&model_dir, 2).unwrap();
        gc_generations(&model_dir, 1).unwrap();
        let left: Vec<u64> =
            list_generations(&model_dir).unwrap().iter().map(|(g, _)| *g).collect();
        assert_eq!(left, vec![2, 3]);
        assert_eq!(ModelStore::open(&model_dir, 1).unwrap().generation(), 2);
    }

    #[test]
    fn legacy_flat_layout_still_opens() {
        let (dir, result, _) = model_fixture("flat", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, Some(9)).unwrap();
        // Simulate a pre-generation model: files directly at the root, no
        // CURRENT pointer.
        let flat = dir.join("flat_model");
        std::fs::create_dir_all(&flat).unwrap();
        for entry in std::fs::read_dir(model_dir.join("gen-000000")).unwrap() {
            let entry = entry.unwrap();
            std::fs::copy(entry.path(), flat.join(entry.file_name())).unwrap();
        }
        // Strip the generation key the legacy writer never produced.
        let man_path = flat.join("model.manifest");
        let text = std::fs::read_to_string(&man_path).unwrap();
        let stripped: String =
            text.lines().filter(|l| !l.starts_with("generation=")).map(|l| format!("{l}\n")).collect();
        std::fs::write(&man_path, stripped).unwrap();

        let store = ModelStore::open(&flat, 1).unwrap();
        assert_eq!(store.generation(), 0);
        assert_eq!(store.dir(), flat.as_path());
        assert_eq!(store.m(), 180);
    }

    #[test]
    fn embedding_shard_matches_scaled_rows() {
        let (dir, result, _) = model_fixture("embshard", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, None).unwrap();
        let store = ModelStore::open(&model_dir, 2).unwrap();
        let raw = store.shard(0).unwrap();
        let emb = store.embedding_shard(0).unwrap();
        for r in 0..raw.rows().min(5) {
            for (j, (&u, &s)) in raw.row(r).iter().zip(store.sigma().iter()).enumerate() {
                assert!((emb.get(r, j) - u * s).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn open_rejects_damaged_dirs() {
        let (dir, result, _) = model_fixture("damaged", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, None).unwrap();
        std::fs::remove_file(model_dir.join("gen-000000").join("V.bin")).unwrap();
        assert!(ModelStore::open(&model_dir, 2).is_err());
        assert!(ModelStore::open(dir.join("nonexistent"), 2).is_err());
    }

    #[test]
    fn load_errors_name_the_generation_dir() {
        let (dir, result, _) = model_fixture("errctx", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, None).unwrap();
        let gen_dir = model_dir.join("gen-000000");
        // Corrupt a manifest integer: the error must name the directory,
        // not just the key.
        let man_path = gen_dir.join("model.manifest");
        let text = std::fs::read_to_string(&man_path).unwrap();
        std::fs::write(&man_path, text.replace("m=180", "m=banana")).unwrap();
        let err = ModelStore::open(&model_dir, 1).unwrap_err().to_string();
        assert!(err.contains("gen-000000"), "error lacks dir context: {err}");
        // Missing key: same requirement.
        let stripped: String =
            text.lines().filter(|l| !l.starts_with("shards=")).map(|l| format!("{l}\n")).collect();
        std::fs::write(&man_path, stripped).unwrap();
        let err = ModelStore::open(&model_dir, 1).unwrap_err().to_string();
        assert!(err.contains("gen-000000"), "error lacks dir context: {err}");
        // Corrupt sigma.csv: still named.
        std::fs::write(&man_path, &text).unwrap();
        std::fs::write(gen_dir.join("sigma.csv"), "not-a-number\n").unwrap();
        let err = ModelStore::open(&model_dir, 1).unwrap_err().to_string();
        assert!(err.contains("gen-000000"), "error lacks dir context: {err}");
    }

    #[test]
    fn poisoned_cache_degrades_instead_of_cascading() {
        // A query thread that panics while holding the shard-cache lock
        // must cost that one request — every later request on the store
        // still answers (the un-poisoned accessor recovers the guard).
        let (dir, result, _) = model_fixture("poison", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, None).unwrap();
        let store = ModelStore::open(&model_dir, 2).unwrap();
        let before = store.u_row(3).unwrap();
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = store.cache.lock().unwrap();
            panic!("query thread dies mid-cache-access");
        }));
        assert!(store.cache.is_poisoned());
        assert_eq!(store.u_row(3).unwrap(), before, "cache read after poison");
        assert!(store.embedding_row(3).is_ok());
    }

    #[test]
    fn row_location_spans_shards() {
        let (dir, result, _) = model_fixture("rowloc", false);
        let model_dir = dir.join("model");
        save_model(&result, &model_dir, None).unwrap();
        let store = ModelStore::open(&model_dir, 2).unwrap();
        let mut seen = 0usize;
        for (i, &rows) in store.shard_rows().iter().enumerate() {
            if rows > 0 {
                assert_eq!(store.row_location(seen).unwrap(), (i, 0));
                assert_eq!(store.row_location(seen + rows - 1).unwrap(), (i, rows - 1));
            }
            seen += rows;
        }
        assert!(store.row_location(store.m()).is_err());
    }
}
