//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers the serve protocol's needs: objects, arrays, numbers (all as
//! `f64`), strings with standard escapes (including `\uXXXX` and surrogate
//! pairs), booleans, null. Object key order is preserved. Non-finite
//! numbers render as `null` so output is always valid JSON.

use crate::error::{Error, Result};
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::parse(format!("json: trailing content at byte {}", p.i)));
        }
        Ok(v)
    }

    /// Serialize to a compact string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    let _ = write!(out, "{v}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- constructors ----------------------------------------------------

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn from_f64s(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|&v| Json::Num(v)).collect())
    }

    // ---- accessors -------------------------------------------------------

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Integer accessor (rejects fractional values).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= usize::MAX as f64 => {
                Some(*v as usize)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// A numeric array as `Vec<f64>`.
    pub fn as_f64_array(&self) -> Option<Vec<f64>> {
        self.as_array()?.iter().map(Json::as_f64).collect()
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::parse(format!(
                "json: expected `{}` at byte {}",
                c as char, self.i
            )))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::parse(format!("json: bad literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            None => Err(Error::parse("json: unexpected end of input")),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(Error::parse(format!(
                "json: unexpected `{}` at byte {}",
                c as char, self.i
            ))),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(Error::parse(format!("json: expected , or ] at byte {}", self.i))),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            pairs.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(Error::parse(format!("json: expected , or }} at byte {}", self.i))),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            return Err(Error::parse("json: truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| Error::parse("json: bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| Error::parse("json: bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::parse("json: unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| Error::parse("json: truncated escape"))?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: expect \uXXXX low half.
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(Error::parse("json: bad low surrogate"));
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(Error::parse("json: lone high surrogate"));
                                }
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| Error::parse("json: bad codepoint"))?,
                            );
                        }
                        other => {
                            return Err(Error::parse(format!(
                                "json: unknown escape \\{}",
                                other as char
                            )))
                        }
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 encoded char (multi-byte safe). A
                    // truncated or invalid sequence is a parse error — this
                    // path must never panic, it runs on raw request bodies.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| Error::parse("json: invalid utf-8 in string"))?;
                    let c = rest
                        .chars()
                        .next()
                        .ok_or_else(|| Error::parse("json: truncated string"))?;
                    if (c as u32) < 0x20 {
                        return Err(Error::parse("json: raw control char in string"));
                    }
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| Error::parse("json: bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::parse(format!("json: bad number `{s}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"op":"similar","row":[1.5,-2,3e2],"k":10,"deep":{"a":[true,false,null]}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("op").unwrap().as_str(), Some("similar"));
        assert_eq!(v.get("k").unwrap().as_usize(), Some(10));
        assert_eq!(v.get("row").unwrap().as_f64_array(), Some(vec![1.5, -2.0, 300.0]));
        let back = Json::parse(&v.render()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA😀"));
        let rendered = Json::str("tab\tquote\"").render();
        assert_eq!(Json::parse(&rendered).unwrap().as_str(), Some("tab\tquote\""));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-0.5e-2").unwrap().as_f64(), Some(-0.005));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("42.5").unwrap().as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn malformed_inputs_error() {
        for bad in ["{", "[1,]", "{\"a\" 1}", "tru", "\"unterminated", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn truncated_inputs_error_never_panic() {
        // Every prefix of a valid document must parse or error — the parse
        // path runs on raw request bodies and must never panic.
        let full = r#"{"op":"similar","row":[1.5,-2],"k":10,"s":"aé😀\n"}"#;
        for cut in 1..full.len() {
            if !full.is_char_boundary(cut) {
                continue;
            }
            let prefix = &full[..cut];
            let _ = Json::parse(prefix); // Ok or Err, both fine; panic is not
        }
        for bad in ["\"abc", "\"a\\", "\"a\\u12", "\"a\\ud834", "\"a\\ud834\\u0020\""] {
            assert!(Json::parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn object_helpers() {
        let v = Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("hits", Json::arr(vec![Json::num(1.0)])),
        ]);
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        assert!(v.get("absent").is_none());
        assert_eq!(v.render(), r#"{"ok":true,"hits":[1]}"#);
    }
}
