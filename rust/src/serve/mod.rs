//! The factor-model serving subsystem.
//!
//! The pipeline's output (`SvdResult`) is only useful downstream if the
//! factors survive the process and can answer queries cheaply — LSA
//! similarity, folding unseen rows into latent space, rank-k row
//! reconstruction. This layer turns a completed factorization into a
//! long-lived, queryable model:
//!
//! * [`store`] — persisted, *versioned* model roots: immutable generation
//!   directories behind an atomically-renamed `CURRENT` pointer, small
//!   factors (σ, V, means) in memory, `U` sharded on disk behind an LRU
//!   cache, and a precomputed row-norm sidecar so cosine scans never
//!   rescan U (`save_model` / [`store::ModelStore`] / [`store::gc_generations`]).
//! * [`query`] — project / top-k cosine similarity / reconstruct, all
//!   through the [`crate::backend::Backend`] trait so native and XLA both
//!   serve ([`query::QueryEngine`]); plus [`query::EngineHandle`], the
//!   atomically swappable engine that hot-swaps to a newly updated
//!   generation with zero downtime.
//! * [`batcher`] — channel-RPC micro-batching: concurrent requests
//!   coalesce into single backend matmuls ([`batcher::Batcher`]); the
//!   engine is snapshotted per batch, so reloads land between batches.
//! * [`http`] — the `tallfat serve <model-dir>` front end: line-delimited
//!   JSON queries riding the shared [`crate::net`] connection runtime
//!   (event-driven accept loop, keep-alive, admission control via
//!   `--max-inflight` / `--max-queue`, idle reaping), publishing
//!   QPS/latency/batch gauges into the shared `MetricsRegistry`
//!   ([`http::ModelServer`]), with `{"op":"reload"}` / `--reload-poll-ms`
//!   triggering the hot swap.
//! * [`json`] — the minimal JSON parser/serializer backing the protocol.
//!
//! ```text
//! tallfat svd --input A.csv --k 16 --save-model /models/m1
//! tallfat serve /models/m1 --addr 0.0.0.0:9925
//! echo '{"op":"similar","row":[...],"k":5}' | curl -s --data-binary @- localhost:9925/query
//! tallfat update /models/m1 --rows new_rows.csv     # then {"op":"reload"}
//! ```

pub mod batcher;
pub mod http;
pub mod json;
pub mod query;
pub mod store;

pub use batcher::{BatchOptions, Batcher, BatcherHandle, Request, Response};
pub use http::{serve, ModelServer, ServeOptions};
pub use json::Json;
pub use query::{EngineHandle, Hit, QueryEngine};
pub use store::{
    gc_generations, generation_dir_name, list_generations, next_generation, publish_generation,
    resolve_current, save_model, ModelStore,
};
