//! Micro-batching request broker for the query engine.
//!
//! Concurrent connections each hold a cloneable [`BatcherHandle`] and make
//! synchronous call-response RPCs over channels — the same pattern as
//! [`crate::runtime::service`]'s XLA service thread. The worker thread
//! coalesces every request that arrives within a micro-batch window (or up
//! to `max_batch`) and executes them as *single* backend matmuls: all
//! projections of a batch share one `X · VΣ⁻¹`, and all similarity queries
//! share one scan of the U shards.
//!
//! Published metrics: `serve_batch_size` (last batch), `serve_batches`,
//! `serve_batched_requests`, plus two labeled histograms that split each
//! request's life inside the batcher: `serve_queue_ms{op}` (submit →
//! batch start, i.e. window wait plus any backlog) and
//! `serve_compute_ms{op}` (the backend stages the op actually rode:
//! projection matmul, shard scan, or both).

use crate::coordinator::server::MetricsRegistry;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::serve::query::{EngineHandle, Hit, QueryEngine};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A query request (one line of the HTTP ND-JSON protocol).
#[derive(Clone, Debug)]
pub enum Request {
    /// Project a raw row (length n) to latent coordinates.
    Project { row: Vec<f64> },
    /// Project a raw row, then return its top-k similar model rows.
    Similar { row: Vec<f64>, topk: usize },
    /// Top-k similar model rows for an already-latent query (length k).
    SimilarLatent { latent: Vec<f64>, topk: usize },
}

impl Request {
    /// Stable `op` label for the per-op serve histograms.
    pub fn op_name(&self) -> &'static str {
        match self {
            Request::Project { .. } => "project",
            Request::Similar { .. } => "similar",
            Request::SimilarLatent { .. } => "similar_latent",
        }
    }
}

/// A query response.
#[derive(Clone, Debug)]
pub enum Response {
    Latent(Vec<f64>),
    Hits(Vec<Hit>),
}

/// Batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchOptions {
    /// How long the worker waits for co-arriving requests after the first.
    pub window: Duration,
    /// Hard batch-size cap (flush regardless of the window).
    pub max_batch: usize,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions { window: Duration::from_millis(2), max_batch: 64 }
    }
}

type Reply = mpsc::SyncSender<Result<Response>>;

struct Job {
    req: Request,
    reply: Reply,
    /// When the request entered the batcher's queue — the base of the
    /// `serve_queue_ms{op}` observation taken at batch start.
    enqueued: Instant,
}

enum Message {
    Job(Job),
    Shutdown,
}

/// Cloneable, thread-safe handle for submitting requests.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: mpsc::Sender<Message>,
    depth: Arc<AtomicU64>,
}

impl BatcherHandle {
    /// Submit one request and block for its response.
    pub fn call(&self, req: Request) -> Result<Response> {
        self.call_many(vec![req]).pop().expect("one reply per request")
    }

    /// Requests currently submitted (across every clone of this handle)
    /// whose replies have not yet been collected — the in-flight batch
    /// depth reported by the `health` query op.
    pub fn in_flight(&self) -> u64 {
        self.depth.load(Ordering::Relaxed)
    }

    /// Submit a group of requests *before* blocking on any reply, so they
    /// coalesce with each other (and with other callers) into one batch.
    /// Replies come back in request order, one per request.
    pub fn call_many(&self, reqs: Vec<Request>) -> Vec<Result<Response>> {
        let submitted = reqs.len() as u64;
        self.depth.fetch_add(submitted, Ordering::Relaxed);
        let mut pending = Vec::with_capacity(reqs.len());
        for req in reqs {
            let (reply_tx, reply_rx) = mpsc::sync_channel(1);
            let job = Job { req, reply: reply_tx, enqueued: Instant::now() };
            match self.tx.send(Message::Job(job)) {
                Ok(()) => pending.push(Some(reply_rx)),
                Err(_) => pending.push(None),
            }
        }
        let replies: Vec<Result<Response>> = pending
            .into_iter()
            .map(|rx| match rx {
                None => Err(Error::Other("serve batcher is gone".into())),
                Some(rx) => rx
                    .recv()
                    .map_err(|_| Error::Other("serve batcher dropped the reply".into()))?,
            })
            .collect();
        self.depth.fetch_sub(submitted, Ordering::Relaxed);
        replies
    }
}

/// Owns the batching worker thread; dropping shuts it down.
pub struct Batcher {
    handle: BatcherHandle,
    tx: mpsc::Sender<Message>,
    join: Option<JoinHandle<()>>,
}

impl Batcher {
    /// Spawn the worker over a (possibly hot-swappable) engine handle. The
    /// engine is snapshotted once per coalesced batch, so a reload lands
    /// between batches — never inside one.
    pub fn start(engines: Arc<EngineHandle>, opts: BatchOptions) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Message>();
        let join = std::thread::Builder::new()
            .name("serve-batcher".into())
            .spawn(move || worker_loop(engines, rx, opts))
            .map_err(|e| Error::Other(format!("cannot spawn serve batcher: {e}")))?;
        Ok(Batcher {
            handle: BatcherHandle { tx: tx.clone(), depth: Arc::new(AtomicU64::new(0)) },
            tx,
            join: Some(join),
        })
    }

    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone()
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        let _ = self.tx.send(Message::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn worker_loop(engines: Arc<EngineHandle>, rx: mpsc::Receiver<Message>, opts: BatchOptions) {
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(Message::Job(j)) => j,
            Ok(Message::Shutdown) | Err(_) => return,
        };
        let mut jobs = vec![first];
        let mut shutdown = false;
        // Then coalesce whatever arrives within the window.
        let deadline = Instant::now() + opts.window;
        while jobs.len() < opts.max_batch.max(1) {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Message::Job(j)) => jobs.push(j),
                Ok(Message::Shutdown) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
            }
        }
        let reg = MetricsRegistry::global();
        reg.set("serve_batch_size", jobs.len() as f64);
        reg.add("serve_batches", 1.0);
        reg.add("serve_batched_requests", jobs.len() as f64);
        execute_batch(&engines.current(), jobs);
        if shutdown {
            return;
        }
    }
}

enum Kind {
    Project,
    Similar { topk: usize, latent: Option<Vec<f64>> },
}

struct Slot {
    reply: Reply,
    kind: Kind,
    /// `op` label of the originating request, for `serve_compute_ms{op}`.
    op: &'static str,
    result: Option<Result<Response>>,
}

/// Run one coalesced batch: a single projection matmul for every raw row in
/// the batch, then a single shard scan for every similarity query. Observes
/// `serve_queue_ms{op}` per job at batch start and `serve_compute_ms{op}`
/// per job at the end (the sum of the stages that op rode).
fn execute_batch(engine: &QueryEngine, jobs: Vec<Job>) {
    let reg = MetricsRegistry::global();
    let n = engine.store().n();
    let k = engine.store().k();
    let mut slots: Vec<Slot> = Vec::with_capacity(jobs.len());
    // (slot index, raw row) pairs that need projection.
    let mut to_project: Vec<(usize, Vec<f64>)> = Vec::new();
    for job in jobs {
        let idx = slots.len();
        let op = job.req.op_name();
        reg.observe_labeled(
            "serve_queue_ms",
            &[("op", op)],
            job.enqueued.elapsed().as_secs_f64() * 1e3,
        );
        match job.req {
            Request::Project { row } => {
                let result = (row.len() != n).then(|| {
                    Err(Error::shape(format!("project: row has {} cols, model n={n}", row.len())))
                });
                if result.is_none() {
                    to_project.push((idx, row));
                }
                slots.push(Slot { reply: job.reply, kind: Kind::Project, op, result });
            }
            Request::Similar { row, topk } => {
                let result = (row.len() != n).then(|| {
                    Err(Error::shape(format!("similar: row has {} cols, model n={n}", row.len())))
                });
                if result.is_none() {
                    to_project.push((idx, row));
                }
                slots.push(Slot {
                    reply: job.reply,
                    kind: Kind::Similar { topk, latent: None },
                    op,
                    result,
                });
            }
            Request::SimilarLatent { latent, topk } => {
                let result = (latent.len() != k).then(|| {
                    Err(Error::shape(format!(
                        "similar: latent has {} dims, model k={k}",
                        latent.len()
                    )))
                });
                slots.push(Slot {
                    reply: job.reply,
                    kind: Kind::Similar { topk, latent: Some(latent) },
                    op,
                    result,
                });
            }
        }
    }

    // Stage 1: one projection matmul covers project + similar-by-row jobs.
    let mut proj_ms = 0.0;
    if !to_project.is_empty() {
        let t_proj = Instant::now();
        let rows: Vec<Vec<f64>> = to_project.iter().map(|(_, r)| r.clone()).collect();
        match Matrix::from_rows(&rows).and_then(|x| engine.project_batch(&x)) {
            Ok(latents) => {
                for (i, (slot, _)) in to_project.iter().enumerate() {
                    let l = latents.row(i).to_vec();
                    let s = &mut slots[*slot];
                    match &mut s.kind {
                        Kind::Project => s.result = Some(Ok(Response::Latent(l))),
                        Kind::Similar { latent, .. } => *latent = Some(l),
                    }
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for (slot, _) in &to_project {
                    slots[*slot].result = Some(Err(Error::Other(msg.clone())));
                }
            }
        }
        proj_ms = t_proj.elapsed().as_secs_f64() * 1e3;
    }

    // Stage 2: one shard scan covers every similarity query of the batch.
    let mut scan_ms = 0.0;
    let mut sim_slots: Vec<usize> = Vec::new();
    let mut sim_latents: Vec<Vec<f64>> = Vec::new();
    let mut sim_topks: Vec<usize> = Vec::new();
    for (i, slot) in slots.iter().enumerate() {
        if slot.result.is_some() {
            continue;
        }
        if let Kind::Similar { topk, latent: Some(l) } = &slot.kind {
            sim_slots.push(i);
            sim_latents.push(l.clone());
            sim_topks.push(*topk);
        }
    }
    if !sim_slots.is_empty() {
        let t_scan = Instant::now();
        match Matrix::from_rows(&sim_latents)
            .and_then(|l| engine.similar_batch(&l, &sim_topks))
        {
            Ok(all_hits) => {
                for (slot, hits) in sim_slots.iter().zip(all_hits) {
                    slots[*slot].result = Some(Ok(Response::Hits(hits)));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for slot in &sim_slots {
                    slots[*slot].result = Some(Err(Error::Other(msg.clone())));
                }
            }
        }
        scan_ms = t_scan.elapsed().as_secs_f64() * 1e3;
    }

    for slot in slots {
        // Each op rode a subset of the batch's stages: project → matmul
        // only, similar-by-row → matmul + scan, similar_latent → scan only.
        let compute_ms = match slot.op {
            "project" => proj_ms,
            "similar" => proj_ms + scan_ms,
            _ => scan_ms,
        };
        reg.observe_labeled("serve_compute_ms", &[("op", slot.op)], compute_ms);
        let out = slot
            .result
            .unwrap_or_else(|| Err(Error::Other("serve batcher: request fell through".into())));
        let _ = slot.reply.send(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::io::dataset::{gen_exact, Spectrum};
    use crate::io::InputSpec;
    use crate::serve::store::{save_model, ModelStore};
    use crate::svd::Svd;

    fn batcher_fixture(name: &str) -> (Arc<QueryEngine>, Matrix) {
        let dir = std::env::temp_dir().join("tallfat_test_batcher").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, _) = gen_exact(
            120,
            16,
            5,
            Spectrum::Geometric { scale: 7.0, decay: 0.5 },
            0.0,
            5,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("A.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        let result = Svd::over(&spec)
            .unwrap()
            .rank(5)
            .oversample(4)
            .workers(2)
            .block(32)
            .work_dir(dir.join("work").to_string_lossy().into_owned())
            .backend(Arc::new(NativeBackend::new()))
            .run()
            .unwrap();
        save_model(&result, dir.join("model"), None).unwrap();
        let store = Arc::new(ModelStore::open(dir.join("model"), 2).unwrap());
        (Arc::new(QueryEngine::new(store, Arc::new(NativeBackend::new())).unwrap()), a)
    }

    #[test]
    fn batched_results_match_direct_engine_calls() {
        let (engine, a) = batcher_fixture("parity");
        let batcher =
            Batcher::start(
                Arc::new(EngineHandle::fixed(engine.clone())),
                BatchOptions { window: Duration::from_millis(5), max_batch: 16 },
            )
            .unwrap();
        let handle = batcher.handle();
        // Fire concurrent mixed requests so they actually coalesce.
        let results: Vec<(usize, Response)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let h = handle.clone();
                    let row = a.row(i * 10).to_vec();
                    scope.spawn(move || {
                        let req = if i % 2 == 0 {
                            Request::Project { row }
                        } else {
                            Request::Similar { row, topk: 4 }
                        };
                        (i, h.call(req).unwrap())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (i, resp) in results {
            let row = a.row(i * 10);
            match resp {
                Response::Latent(l) => {
                    let want = engine.project_one(row).unwrap();
                    assert_eq!(i % 2, 0);
                    for (g, w) in l.iter().zip(want.iter()) {
                        assert!((g - w).abs() < 1e-9);
                    }
                }
                Response::Hits(hits) => {
                    let want = engine.similar_row(row, 4).unwrap();
                    assert_eq!(i % 2, 1);
                    assert_eq!(
                        hits.iter().map(|h| h.row).collect::<Vec<_>>(),
                        want.iter().map(|h| h.row).collect::<Vec<_>>()
                    );
                }
            }
        }
    }

    #[test]
    fn invalid_rows_fail_individually_without_poisoning_batch() {
        let (engine, a) = batcher_fixture("mixed_errors");
        let batcher =
            Batcher::start(Arc::new(EngineHandle::fixed(engine.clone())), BatchOptions::default())
                .unwrap();
        let handle = batcher.handle();
        assert!(handle.call(Request::Project { row: vec![1.0, 2.0] }).is_err());
        let ok = handle.call(Request::Project { row: a.row(0).to_vec() });
        assert!(ok.is_ok());
        assert!(handle
            .call(Request::SimilarLatent { latent: vec![0.0], topk: 2 })
            .is_err());
    }

    #[test]
    fn call_many_replies_in_request_order() {
        let (engine, a) = batcher_fixture("many");
        let batcher =
            Batcher::start(Arc::new(EngineHandle::fixed(engine.clone())), BatchOptions::default())
                .unwrap();
        let reqs = vec![
            Request::Project { row: a.row(0).to_vec() },
            Request::Similar { row: a.row(10).to_vec(), topk: 2 },
            Request::Project { row: vec![1.0] }, // wrong width
        ];
        let replies = batcher.handle().call_many(reqs);
        assert_eq!(replies.len(), 3);
        assert!(matches!(replies[0], Ok(Response::Latent(_))));
        assert!(matches!(replies[1], Ok(Response::Hits(_))));
        assert!(replies[2].is_err());
    }

    #[test]
    fn latent_queries_round_trip() {
        let (engine, a) = batcher_fixture("latent");
        let batcher =
            Batcher::start(Arc::new(EngineHandle::fixed(engine.clone())), BatchOptions::default())
                .unwrap();
        let latent = engine.project_one(a.row(30)).unwrap();
        match batcher.handle().call(Request::SimilarLatent { latent, topk: 3 }).unwrap() {
            Response::Hits(hits) => {
                assert_eq!(hits.len(), 3);
                assert_eq!(hits[0].row, 30); // self-similarity wins
            }
            other => panic!("expected hits, got {other:?}"),
        }
    }
}
