//! Latent-space queries over a loaded model, and the hot-swap handle.
//!
//! Three operations, all dispatched through the [`crate::backend::Backend`] trait so the
//! native and XLA backends both serve:
//!
//! * **project** — fold an unseen row into latent space: `q = (x - μ) V Σ⁻¹`
//!   (Halko's sketch guarantees the subspace; μ only in PCA mode).
//! * **similar** — top-k cosine similarity between a latent query and the
//!   row embeddings `u_i ∘ σ`, via a streaming scan of the U shards with a
//!   bounded min-heap. Row norms come from the precomputed sidecar, and all
//!   queries of a batch share one matmul per shard.
//! * **reconstruct** — `â_i = (u_i ∘ σ) Vᵀ + μ`, the rank-k row estimate.
//!
//! A [`QueryEngine`] is immutable over one model generation; the serving
//! layer holds it through an [`EngineHandle`] — an atomically swappable
//! `Arc` that [`EngineHandle::reload`] repoints at the model root's live
//! generation, so an incremental update ([`crate::update`]) lands with zero
//! downtime while in-flight batches finish against the generation they
//! started on.

use crate::backend::BackendRef;
use crate::coordinator::server::MetricsRegistry;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::serve::store::{resolve_current, ModelStore};
use crate::util::Logger;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, RwLock};

static LOG: Logger = Logger::new("serve.query");

/// One similarity result: a model row and its cosine score.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    pub row: usize,
    pub score: f64,
}

/// Total order on hits: higher score first, ties broken by lower row id —
/// identical to the oracle ordering the tests pin.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Scored {
    score: f64,
    row: usize,
}

impl Eq for Scored {}

impl Ord for Scored {
    fn cmp(&self, other: &Self) -> Ordering {
        // Greater = better: higher score, then *smaller* row index.
        self.score
            .total_cmp(&other.score)
            .then_with(|| other.row.cmp(&self.row))
    }
}

impl PartialOrd for Scored {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Bounded min-heap keeping the best `cap` hits seen so far.
struct TopK {
    cap: usize,
    heap: BinaryHeap<std::cmp::Reverse<Scored>>,
}

impl TopK {
    fn new(cap: usize) -> Self {
        TopK { cap, heap: BinaryHeap::with_capacity(cap + 1) }
    }

    fn push(&mut self, s: Scored) {
        if self.cap == 0 {
            return;
        }
        if self.heap.len() < self.cap {
            self.heap.push(std::cmp::Reverse(s));
        } else if let Some(worst) = self.heap.peek() {
            if s > worst.0 {
                self.heap.pop();
                self.heap.push(std::cmp::Reverse(s));
            }
        }
    }

    /// Fold another heap's survivors in. The order on [`Scored`] is total
    /// (score, then row id), so the surviving top-k set is a function of
    /// the pushed *set* alone — merging per-thread heaps in any order
    /// yields exactly the serial scan's result.
    fn merge(&mut self, other: TopK) {
        for std::cmp::Reverse(s) in other.heap {
            self.push(s);
        }
    }

    fn into_hits(self) -> Vec<Hit> {
        let mut out: Vec<Scored> = self.heap.into_iter().map(|r| r.0).collect();
        out.sort_by(|a, b| b.cmp(a)); // best first
        out.into_iter().map(|s| Hit { row: s.row, score: s.score }).collect()
    }
}

/// Query engine over a [`ModelStore`] and a block [`crate::backend::Backend`].
pub struct QueryEngine {
    store: Arc<ModelStore>,
    backend: BackendRef,
    /// `V Σ⁻¹` (n x k), precomputed with the pipeline's guarded inverse.
    projection: Matrix,
}

impl QueryEngine {
    pub fn new(store: Arc<ModelStore>, backend: BackendRef) -> Result<Self> {
        let inv = crate::svd::pipeline::guarded_inverse(store.sigma(), 1e-12);
        let projection = store.v().scale_cols(&inv)?;
        Ok(QueryEngine { store, backend, projection })
    }

    pub fn store(&self) -> &Arc<ModelStore> {
        &self.store
    }

    /// The `n x k` projection matrix `V Σ⁻¹` (exposed for oracles/tests).
    pub fn projection_matrix(&self) -> &Matrix {
        &self.projection
    }

    /// Center a batch of raw rows in place (PCA mode is a no-op otherwise).
    fn center(&self, x: &mut Matrix) {
        if let Some(means) = self.store.means() {
            for i in 0..x.rows() {
                for (v, mu) in x.row_mut(i).iter_mut().zip(means.iter()) {
                    *v -= mu;
                }
            }
        }
    }

    /// Project a batch of raw rows (`b x n`) to latent coordinates (`b x k`)
    /// in one backend matmul.
    pub fn project_batch(&self, rows: &Matrix) -> Result<Matrix> {
        if rows.cols() != self.store.n() {
            return Err(Error::shape(format!(
                "project: row has {} cols, model n={}",
                rows.cols(),
                self.store.n()
            )));
        }
        let mut x = rows.clone();
        self.center(&mut x);
        self.backend.project_block(&x, &self.projection)
    }

    /// Project one raw row (length n) to latent coordinates (length k).
    pub fn project_one(&self, row: &[f64]) -> Result<Vec<f64>> {
        let x = Matrix::from_rows(std::slice::from_ref(&row.to_vec()))?;
        Ok(self.project_batch(&x)?.row(0).to_vec())
    }

    /// Top-k cosine similarity for a batch of latent queries (`q x k`).
    /// One streaming pass over the U shards, fanned out across up to
    /// `available_parallelism` scoped threads (strided shard assignment);
    /// every shard is scored against all queries with a single backend
    /// matmul and each thread keeps its own bounded heaps, merged at the
    /// end — bit-identical to the serial scan because the hit order is
    /// total. `topks[j]` bounds query `j`'s result list.
    pub fn similar_batch(&self, latent: &Matrix, topks: &[usize]) -> Result<Vec<Vec<Hit>>> {
        let q = latent.rows();
        if q != topks.len() {
            return Err(Error::shape("similar: one topk per query required"));
        }
        if latent.cols() != self.store.k() {
            return Err(Error::shape(format!(
                "similar: latent has {} dims, model k={}",
                latent.cols(),
                self.store.k()
            )));
        }
        let qnorms: Vec<f64> = (0..q)
            .map(|j| latent.row(j).iter().map(|v| v * v).sum::<f64>().sqrt())
            .collect();
        // Queries as columns: scores_shard = E_shard (rows x k) · Qᵀ (k x q).
        let qt = latent.t();
        let norms = self.store.norms()?;
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(self.store.shards().max(1));
        if threads <= 1 {
            let heaps = self.scan_shards(&qt, &qnorms, norms, topks, 0, 1)?;
            return Ok(heaps.into_iter().map(TopK::into_hits).collect());
        }
        let mut merged: Vec<TopK> = topks.iter().map(|&t| TopK::new(t)).collect();
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(threads);
            for t in 0..threads {
                let (qt, qnorms) = (&qt, &qnorms);
                handles.push(
                    scope.spawn(move || self.scan_shards(qt, qnorms, norms, topks, t, threads)),
                );
            }
            // Merge in thread order; any order gives the same top-k set.
            for h in handles {
                let heaps = h
                    .join()
                    .map_err(|_| Error::Other("similar: shard-scan thread panicked".into()))??;
                for (m, part) in merged.iter_mut().zip(heaps) {
                    m.merge(part);
                }
            }
            Ok(())
        })?;
        Ok(merged.into_iter().map(TopK::into_hits).collect())
    }

    /// Score shards `offset, offset+stride, ...` against all queries —
    /// one thread's share of the [`QueryEngine::similar_batch`] scan.
    fn scan_shards(
        &self,
        qt: &Matrix,
        qnorms: &[f64],
        norms: &[f64],
        topks: &[usize],
        offset: usize,
        stride: usize,
    ) -> Result<Vec<TopK>> {
        let mut heaps: Vec<TopK> = topks.iter().map(|&t| TopK::new(t)).collect();
        let mut s = offset;
        while s < self.store.shards() {
            let base = self.store.shard_base(s);
            // Embedding rows e_i = u_i ∘ σ, scaled once per cache residency.
            let emb = self.store.embedding_shard(s)?;
            if emb.rows() == 0 {
                s += stride;
                continue;
            }
            let scores = self.backend.project_block(&emb, qt)?; // rows x q
            for r in 0..scores.rows() {
                let row = base + r;
                let denom_row = norms[row];
                let srow = scores.row(r);
                for (j, (heap, qn)) in heaps.iter_mut().zip(qnorms.iter()).enumerate() {
                    let denom = denom_row * qn;
                    let score = if denom > 0.0 { srow[j] / denom } else { 0.0 };
                    heap.push(Scored { score, row });
                }
            }
            s += stride;
        }
        Ok(heaps)
    }

    /// Top-k similar rows for one latent query.
    pub fn similar_latent(&self, latent: &[f64], topk: usize) -> Result<Vec<Hit>> {
        let l = Matrix::from_rows(std::slice::from_ref(&latent.to_vec()))?;
        Ok(self.similar_batch(&l, &[topk])?.pop().unwrap_or_default())
    }

    /// Project a raw row and return its top-k similar model rows.
    pub fn similar_row(&self, row: &[f64], topk: usize) -> Result<Vec<Hit>> {
        let latent = self.project_one(row)?;
        self.similar_latent(&latent, topk)
    }

    /// Rank-k reconstruction of model row `i`: `(u_i ∘ σ) Vᵀ + μ`.
    pub fn reconstruct_row(&self, i: usize) -> Result<Vec<f64>> {
        let e = self.store.embedding_row(i)?;
        let v = self.store.v();
        let n = self.store.n();
        let k = self.store.k();
        let mut out = vec![0.0f64; n];
        for (j, o) in out.iter_mut().enumerate() {
            let vrow = v.row(j);
            let mut acc = 0.0;
            for kk in 0..k {
                acc += vrow[kk] * e[kk];
            }
            *o = acc;
        }
        if let Some(means) = self.store.means() {
            for (o, mu) in out.iter_mut().zip(means.iter()) {
                *o += mu;
            }
        }
        Ok(out)
    }
}

/// How a reloadable [`EngineHandle`] rebuilds its engine.
struct ReloadSpec {
    root: PathBuf,
    backend: BackendRef,
    cache_shards: usize,
}

/// An atomically swappable [`QueryEngine`] — the zero-downtime seam of the
/// serve layer.
///
/// Callers snapshot the engine once per unit of work
/// ([`EngineHandle::current`] clones an `Arc` under a read lock) and keep
/// using that snapshot even if a reload swaps the handle mid-flight; the
/// old generation's store stays alive until its last batch drops it.
/// [`EngineHandle::reload`] re-resolves the model root's `CURRENT` pointer
/// and swaps only when it names a different generation directory, bumping
/// the `serve_reloads` gauge.
pub struct EngineHandle {
    engine: RwLock<Arc<QueryEngine>>,
    reload: Option<ReloadSpec>,
    /// Serializes whole reloads (resolve → open → swap) so a slow reload
    /// that resolved an older generation can never overwrite the engine a
    /// concurrent reload installed from a newer one. Readers never touch
    /// this lock.
    reload_lock: Mutex<()>,
}

impl EngineHandle {
    /// A handle pinned to one engine forever — for embedders and tests
    /// that do not own a reloadable model root. [`EngineHandle::reload`]
    /// is a no-op.
    pub fn fixed(engine: Arc<QueryEngine>) -> Self {
        EngineHandle { engine: RwLock::new(engine), reload: None, reload_lock: Mutex::new(()) }
    }

    /// Open the live generation of the model at `root` and remember how to
    /// reload it.
    pub fn open(
        root: impl Into<PathBuf>,
        cache_shards: usize,
        backend: BackendRef,
    ) -> Result<Self> {
        let root = root.into();
        let store = Arc::new(ModelStore::open(&root, cache_shards)?);
        let engine = Arc::new(QueryEngine::new(store, backend.clone())?);
        Ok(EngineHandle {
            engine: RwLock::new(engine),
            reload: Some(ReloadSpec { root, backend, cache_shards }),
            reload_lock: Mutex::new(()),
        })
    }

    /// Snapshot the live engine. The snapshot stays valid across swaps.
    /// Recovers from lock poisoning — the handle only ever holds a whole
    /// `Arc`, so a panicked holder cannot leave it half-swapped.
    pub fn current(&self) -> Arc<QueryEngine> {
        crate::util::read_unpoisoned(&self.engine).clone()
    }

    /// Whether this handle was opened from a model root (i.e. `reload` can
    /// ever do anything).
    pub fn is_reloadable(&self) -> bool {
        self.reload.is_some()
    }

    /// Generation number currently being served.
    pub fn generation(&self) -> u64 {
        self.current().store().generation()
    }

    /// Re-resolve the model root's live generation and swap to it if it
    /// changed. Returns `Some(generation)` when a swap happened, `None`
    /// when already current (or the handle is fixed).
    ///
    /// An update's garbage collection can delete the very generation
    /// `CURRENT` named between our resolve and the store open (it publishes
    /// the new pointer first, then prunes) — each attempt therefore
    /// re-resolves from scratch, and a failed open is retried a few times
    /// before the error is surfaced. The handle keeps serving its old
    /// snapshot either way.
    pub fn reload(&self) -> Result<Option<u64>> {
        const GC_RACE_RETRIES: usize = 3;
        let Some(spec) = &self.reload else { return Ok(None) };
        // One reload at a time: poll thread and `{"op":"reload"}` lines can
        // race, and the loser of an unserialized race could install the
        // older generation. The engine RwLock is only held for the final
        // pointer swap, so queries keep flowing during the (slow) open.
        let _serialize = crate::util::lock_unpoisoned(&self.reload_lock);
        let mut last_err: Option<Error> = None;
        for attempt in 0..GC_RACE_RETRIES {
            let live_dir = resolve_current(&spec.root)?;
            if live_dir.as_path() == self.current().store().dir() {
                return Ok(None);
            }
            let store = match ModelStore::open(&spec.root, spec.cache_shards) {
                Ok(s) => Arc::new(s),
                Err(e) => {
                    LOG.warn(&format!(
                        "reload open raced gc (attempt {}/{GC_RACE_RETRIES}): {e}",
                        attempt + 1
                    ));
                    last_err = Some(e);
                    continue;
                }
            };
            let engine = Arc::new(QueryEngine::new(store, spec.backend.clone())?);
            let generation = engine.store().generation();
            *crate::util::write_unpoisoned(&self.engine) = engine;
            MetricsRegistry::global().add("serve_reloads", 1.0);
            LOG.info(&format!(
                "hot-swapped to generation {generation} ({})",
                live_dir.display()
            ));
            return Ok(Some(generation));
        }
        Err(last_err.unwrap_or_else(|| Error::Other("reload: retries exhausted".into())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::io::dataset::{gen_exact, Spectrum};
    use crate::io::InputSpec;
    use crate::linalg::matmul;
    use crate::serve::store::save_model;
    use crate::svd::Svd;

    fn engine_fixture(name: &str, center: bool) -> (QueryEngine, Matrix) {
        let dir = std::env::temp_dir().join("tallfat_test_query").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, _) = gen_exact(
            160,
            18,
            6,
            Spectrum::Geometric { scale: 9.0, decay: 0.55 },
            0.001,
            23,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("A.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        let result = Svd::over(&spec)
            .unwrap()
            .rank(6)
            .oversample(6)
            .workers(3)
            .block(32)
            .work_dir(dir.join("work").to_string_lossy().into_owned())
            .center(center)
            .backend(Arc::new(NativeBackend::new()))
            .run()
            .unwrap();
        save_model(&result, dir.join("model"), None).unwrap();
        let store = Arc::new(ModelStore::open(dir.join("model"), 2).unwrap());
        let engine = QueryEngine::new(store, Arc::new(NativeBackend::new())).unwrap();
        (engine, a)
    }

    /// Oracle top-k: brute-force cosine over all embeddings with `linalg`.
    fn oracle_topk(engine: &QueryEngine, latent: &[f64], topk: usize) -> Vec<Hit> {
        let store = engine.store();
        let qnorm: f64 = latent.iter().map(|v| v * v).sum::<f64>().sqrt();
        let mut scored: Vec<Scored> = (0..store.m())
            .map(|row| {
                let e = store.embedding_row(row).unwrap();
                let dot: f64 = e.iter().zip(latent.iter()).map(|(a, b)| a * b).sum();
                let denom = store.norms().unwrap()[row] * qnorm;
                Scored { score: if denom > 0.0 { dot / denom } else { 0.0 }, row }
            })
            .collect();
        scored.sort_by(|a, b| b.cmp(a));
        scored.truncate(topk);
        scored.into_iter().map(|s| Hit { row: s.row, score: s.score }).collect()
    }

    #[test]
    fn project_matches_linalg_oracle() {
        let (engine, a) = engine_fixture("project", false);
        let rows = a.slice_rows(10, 14);
        let got = engine.project_batch(&rows).unwrap();
        let want = matmul(&rows, engine.projection_matrix()).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-9);
        let one = engine.project_one(a.row(10)).unwrap();
        for (g, w) in one.iter().zip(want.row(0).iter()) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
        assert_eq!(one.len(), engine.store().k());
    }

    #[test]
    fn project_honors_centering() {
        let (engine, a) = engine_fixture("center", true);
        let means = engine.store().means().unwrap().to_vec();
        let raw = a.row(7).to_vec();
        let centered: Vec<f64> = raw.iter().zip(means.iter()).map(|(x, mu)| x - mu).collect();
        let got = engine.project_one(&raw).unwrap();
        let cm = Matrix::from_rows(&[centered]).unwrap();
        let want = matmul(&cm, engine.projection_matrix()).unwrap();
        for (g, w) in got.iter().zip(want.row(0).iter()) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }

    #[test]
    fn similar_matches_brute_force_oracle() {
        let (engine, a) = engine_fixture("similar", false);
        for &qrow in &[0usize, 42, 111] {
            let latent = engine.project_one(a.row(qrow)).unwrap();
            let got = engine.similar_latent(&latent, 10).unwrap();
            let want = oracle_topk(&engine, &latent, 10);
            let got_rows: Vec<usize> = got.iter().map(|h| h.row).collect();
            let want_rows: Vec<usize> = want.iter().map(|h| h.row).collect();
            assert_eq!(got_rows, want_rows, "query row {qrow}");
            for (g, w) in got.iter().zip(want.iter()) {
                assert!((g.score - w.score).abs() < 1e-9);
            }
            // A row projected back should be its own nearest neighbor.
            assert_eq!(got[0].row, qrow);
            assert!(got[0].score > 0.999, "self-score {}", got[0].score);
        }
    }

    #[test]
    fn similar_batch_matches_single_queries() {
        let (engine, a) = engine_fixture("batch", false);
        let latents = engine.project_batch(&a.slice_rows(20, 24)).unwrap();
        let batched = engine.similar_batch(&latents, &[5, 5, 5, 5]).unwrap();
        for j in 0..4 {
            let single = engine.similar_latent(latents.row(j), 5).unwrap();
            assert_eq!(
                batched[j].iter().map(|h| h.row).collect::<Vec<_>>(),
                single.iter().map(|h| h.row).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn reconstruct_row_approximates_input() {
        let (engine, a) = engine_fixture("recon", false);
        for &row in &[0usize, 80, 159] {
            let got = engine.reconstruct_row(row).unwrap();
            let mut err = 0.0f64;
            let mut scale = 0.0f64;
            for (g, w) in got.iter().zip(a.row(row).iter()) {
                err += (g - w) * (g - w);
                scale += w * w;
            }
            assert!(err.sqrt() < 1e-2 * scale.sqrt().max(1.0), "row {row}: {err}");
        }
    }

    #[test]
    fn shape_errors_are_reported() {
        let (engine, _) = engine_fixture("shapes", false);
        assert!(engine.project_one(&[1.0, 2.0]).is_err());
        assert!(engine.similar_latent(&[1.0], 3).is_err());
        assert!(engine.reconstruct_row(100_000).is_err());
    }

    #[test]
    fn fixed_handle_never_swaps() {
        let (engine, _) = engine_fixture("fixed_handle", false);
        let engine = Arc::new(engine);
        let handle = EngineHandle::fixed(engine.clone());
        assert!(Arc::ptr_eq(&handle.current(), &engine));
        assert_eq!(handle.reload().unwrap(), None);
        assert!(Arc::ptr_eq(&handle.current(), &engine));
    }

    #[test]
    fn reloadable_handle_swaps_to_new_generation() {
        use crate::serve::store::publish_generation;
        let dir = std::env::temp_dir().join("tallfat_test_query").join("reload");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, _) = gen_exact(
            90,
            10,
            4,
            Spectrum::Geometric { scale: 5.0, decay: 0.6 },
            0.0,
            31,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("A.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        let result = Svd::over(&spec)
            .unwrap()
            .rank(4)
            .workers(2)
            .block(32)
            .work_dir(dir.join("work").to_string_lossy().into_owned())
            .backend(Arc::new(NativeBackend::new()))
            .run()
            .unwrap();
        let model = dir.join("model");
        save_model(&result, &model, Some(1)).unwrap();

        let handle =
            EngineHandle::open(&model, 2, Arc::new(NativeBackend::new())).unwrap();
        assert_eq!(handle.generation(), 0);
        let snapshot = handle.current();
        // Reload with nothing new: no swap.
        assert_eq!(handle.reload().unwrap(), None);

        // A second save appends generation 1; reload must swap, while the
        // old snapshot keeps answering against generation 0.
        save_model(&result, &model, Some(2)).unwrap();
        assert_eq!(handle.reload().unwrap(), Some(1));
        assert_eq!(handle.generation(), 1);
        assert_eq!(snapshot.store().generation(), 0);
        assert!(snapshot.project_one(a.row(0)).is_ok());

        // Rolling back CURRENT swaps back too (the pointer is the truth).
        publish_generation(&model, 0).unwrap();
        assert_eq!(handle.reload().unwrap(), Some(0));
    }

    #[test]
    fn reload_survives_current_naming_a_missing_generation() {
        use crate::serve::store::{publish_generation, CURRENT_FILE};
        let dir = std::env::temp_dir().join("tallfat_test_query").join("gc_race");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, _) = gen_exact(
            80,
            10,
            4,
            Spectrum::Geometric { scale: 5.0, decay: 0.6 },
            0.0,
            37,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("A.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        let result = Svd::over(&spec)
            .unwrap()
            .rank(4)
            .workers(2)
            .block(32)
            .work_dir(dir.join("work").to_string_lossy().into_owned())
            .backend(Arc::new(NativeBackend::new()))
            .run()
            .unwrap();
        let model = dir.join("model");
        save_model(&result, &model, Some(1)).unwrap();
        let handle = EngineHandle::open(&model, 2, Arc::new(NativeBackend::new())).unwrap();

        // The worst-case GC race frozen in place: CURRENT names a
        // generation whose directory is gone. Every open attempt fails, the
        // reload reports the error, and the handle keeps serving the old
        // snapshot rather than panicking or serving a torn model.
        std::fs::write(model.join(CURRENT_FILE), "gen-000042\n").unwrap();
        assert!(handle.reload().is_err());
        assert_eq!(handle.generation(), 0);
        assert!(handle.current().project_one(a.row(3)).is_ok());

        // Once the pointer heals (the next publish), reload recovers.
        save_model(&result, &model, Some(2)).unwrap();
        publish_generation(&model, 1).unwrap();
        assert_eq!(handle.reload().unwrap(), Some(1));
        assert_eq!(handle.generation(), 1);
    }
}
