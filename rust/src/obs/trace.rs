//! Span tracing with a Chrome trace-event sink.
//!
//! Three layers, each usable without the ones above it:
//!
//! 1. **Context** — a process-wide span stack (thread-local) of
//!    [`TraceCtx`] values. [`current`] exposes the innermost context so
//!    logs and wire frames can attribute themselves to a run even when no
//!    sink is installed. A `TraceCtx` is 16 bytes (trace id + span id) and
//!    is what cluster proto v5 ships in `Phase`/`Assign` frames.
//! 2. **Spans** — [`Span`] is an RAII guard: it pushes its context on
//!    construction and, when a sink is installed, emits one Chrome
//!    `"ph":"X"` complete event on drop with its duration and arguments.
//! 3. **Sink** — [`TraceSink`] appends trace events to a file as a JSON
//!    array with one event per line (Chrome trace-event format; open the
//!    file in chrome://tracing or Perfetto). [`install`] wires a sink into
//!    the process global used by spans; [`TraceGuard`] does install +
//!    root-span + finish for CLI commands.
//!
//! Timestamps are microseconds since sink installation (Chrome wants a
//! single monotonic µs clock per process). Leader-side merged events for
//! worker chunks are back-dated from their measured durations, so the
//! whole cluster timeline shares the leader's clock.
//!
//! With no sink installed everything degrades to near-zero cost: spans
//! keep the context stack working (ids still flow into JSON logs and
//! wire frames) but nothing is formatted or written.

use crate::error::Result;
use crate::util::lock::lock_unpoisoned;
use std::cell::{Cell, RefCell};
use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

// ---------------------------------------------------------------------------
// context
// ---------------------------------------------------------------------------

/// 16-byte cross-process trace context: a run-unique trace id plus the id
/// of the span under which the carrying message was sent. `trace == 0`
/// means "not traced".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace: u64,
    pub span: u64,
}

impl TraceCtx {
    /// The absent context (tracing off).
    pub const NONE: TraceCtx = TraceCtx { trace: 0, span: 0 };

    pub fn is_none(&self) -> bool {
        self.trace == 0
    }
}

/// Allocate a process-unique, never-zero id. Seeded from wall clock + pid
/// so ids from different processes in one cluster run don't collide.
pub fn next_id() -> u64 {
    static SEED: OnceLock<u64> = OnceLock::new();
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    let seed = *SEED.get_or_init(|| {
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x9E37_79B9);
        nanos ^ ((std::process::id() as u64) << 48)
    });
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    // SplitMix64 finalizer: decorrelates consecutive counters.
    let mut z = seed.wrapping_add(n.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    (z ^ (z >> 31)) | 1
}

thread_local! {
    static STACK: RefCell<Vec<TraceCtx>> = const { RefCell::new(Vec::new()) };
    static TID: Cell<u64> = const { Cell::new(0) };
}

/// The innermost active context on this thread ([`TraceCtx::NONE`] when
/// nothing is being traced here).
pub fn current() -> TraceCtx {
    STACK.with(|s| s.borrow().last().copied().unwrap_or(TraceCtx::NONE))
}

/// Small stable per-thread lane id for trace events (assigned on first use;
/// not the OS tid, which Chrome would render as huge meaningless numbers).
pub fn lane_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    TID.with(|t| {
        if t.get() == 0 {
            t.set(NEXT.fetch_add(1, Ordering::Relaxed));
        }
        t.get()
    })
}

// ---------------------------------------------------------------------------
// events
// ---------------------------------------------------------------------------

/// An argument value attached to a trace event.
#[derive(Clone, Debug)]
pub enum ArgValue {
    Num(f64),
    Str(String),
    Bool(bool),
}

/// One Chrome trace event. `ph` is the phase letter: `X` = complete event
/// (ts + dur), `M` = metadata (e.g. `thread_name`).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: String,
    pub cat: String,
    pub ph: char,
    pub ts_us: u64,
    pub dur_us: u64,
    pub tid: u64,
    pub args: Vec<(String, ArgValue)>,
}

impl TraceEvent {
    /// A complete ("X") event.
    pub fn complete(name: &str, cat: &str, ts_us: u64, dur_us: u64, tid: u64) -> Self {
        TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            ph: 'X',
            ts_us,
            dur_us,
            tid,
            args: Vec::new(),
        }
    }

    /// A `thread_name` metadata event — names lane `tid` in the viewer.
    pub fn thread_name(tid: u64, name: &str) -> Self {
        TraceEvent {
            name: "thread_name".to_string(),
            cat: String::new(),
            ph: 'M',
            ts_us: 0,
            dur_us: 0,
            tid,
            args: vec![("name".to_string(), ArgValue::Str(name.to_string()))],
        }
    }

    pub fn arg_str(mut self, key: &str, val: &str) -> Self {
        self.args.push((key.to_string(), ArgValue::Str(val.to_string())));
        self
    }

    pub fn arg_num(mut self, key: &str, val: f64) -> Self {
        self.args.push((key.to_string(), ArgValue::Num(val)));
        self
    }

    pub fn arg_bool(mut self, key: &str, val: bool) -> Self {
        self.args.push((key.to_string(), ArgValue::Bool(val)));
        self
    }

    fn render(&self, pid: u32) -> String {
        let mut s = String::with_capacity(128);
        s.push_str("{\"name\":\"");
        s.push_str(&json_escape(&self.name));
        s.push_str("\",\"cat\":\"");
        s.push_str(&json_escape(if self.cat.is_empty() { "meta" } else { &self.cat }));
        s.push_str("\",\"ph\":\"");
        s.push(self.ph);
        s.push_str("\",\"ts\":");
        s.push_str(&self.ts_us.to_string());
        if self.ph == 'X' {
            s.push_str(",\"dur\":");
            s.push_str(&self.dur_us.to_string());
        }
        s.push_str(",\"pid\":");
        s.push_str(&pid.to_string());
        s.push_str(",\"tid\":");
        s.push_str(&self.tid.to_string());
        s.push_str(",\"args\":{");
        for (i, (k, v)) in self.args.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push('"');
            s.push_str(&json_escape(k));
            s.push_str("\":");
            match v {
                ArgValue::Num(x) if x.is_finite() => s.push_str(&format!("{x}")),
                ArgValue::Num(_) => s.push('0'),
                ArgValue::Str(x) => {
                    s.push('"');
                    s.push_str(&json_escape(x));
                    s.push('"');
                }
                ArgValue::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            }
        }
        s.push_str("}}");
        s
    }
}

/// Escape a string for inclusion in a JSON string literal.
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

// ---------------------------------------------------------------------------
// sink
// ---------------------------------------------------------------------------

struct SinkInner {
    w: BufWriter<File>,
    wrote_any: bool,
    events: u64,
}

/// Appends trace events to a file as Chrome trace-event JSON: a top-level
/// array, one event object per line. The closing `]` is written by
/// [`TraceSink::close`]; Chrome and Perfetto tolerate its absence, so a
/// crashed run still yields an openable trace.
pub struct TraceSink {
    inner: Mutex<SinkInner>,
    epoch: Instant,
}

impl TraceSink {
    /// Create (truncate) `path` and write the array opener.
    pub fn create(path: &str) -> Result<TraceSink> {
        let f = File::create(path)?;
        let mut w = BufWriter::new(f);
        w.write_all(b"[")?;
        w.flush()?;
        Ok(TraceSink {
            inner: Mutex::new(SinkInner { w, wrote_any: false, events: 0 }),
            epoch: Instant::now(),
        })
    }

    /// Microseconds since this sink was installed (the trace clock).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Append one event. IO errors are swallowed — tracing must never
    /// fail the traced work.
    pub fn emit(&self, ev: &TraceEvent) {
        let line = ev.render(std::process::id());
        let mut g = lock_unpoisoned(&self.inner);
        let sep: &[u8] = if g.wrote_any { b",\n" } else { b"\n" };
        g.wrote_any = true;
        g.events += 1;
        let _ = g.w.write_all(sep);
        let _ = g.w.write_all(line.as_bytes());
        let _ = g.w.flush();
    }

    /// Number of events emitted so far.
    pub fn events(&self) -> u64 {
        lock_unpoisoned(&self.inner).events
    }

    /// Write the closing bracket, making the file strict JSON.
    pub fn close(&self) {
        let mut g = lock_unpoisoned(&self.inner);
        let _ = g.w.write_all(b"\n]\n");
        let _ = g.w.flush();
    }
}

static GLOBAL: Mutex<Option<Arc<TraceSink>>> = Mutex::new(None);
static ACTIVE: AtomicBool = AtomicBool::new(false);

/// Install a process-global sink writing to `path`. Replaces any previous
/// sink (the old one is closed).
pub fn install(path: &str) -> Result<()> {
    let sink = Arc::new(TraceSink::create(path)?);
    let old = lock_unpoisoned(&GLOBAL).replace(sink);
    ACTIVE.store(true, Ordering::Release);
    if let Some(old) = old {
        old.close();
    }
    Ok(())
}

/// Whether a global sink is installed (cheap: one atomic load).
pub fn active() -> bool {
    ACTIVE.load(Ordering::Acquire)
}

/// The current global sink, if any.
pub fn sink() -> Option<Arc<TraceSink>> {
    if !active() {
        return None;
    }
    lock_unpoisoned(&GLOBAL).clone()
}

/// Close and remove the global sink.
pub fn finish() {
    ACTIVE.store(false, Ordering::Release);
    if let Some(s) = lock_unpoisoned(&GLOBAL).take() {
        s.close();
    }
}

/// Emit an event through the global sink (no-op when tracing is off).
pub fn emit_global(ev: &TraceEvent) {
    if let Some(s) = sink() {
        s.emit(ev);
    }
}

/// `now_us` on the global sink, if installed.
pub fn global_now_us() -> Option<u64> {
    sink().map(|s| s.now_us())
}

// ---------------------------------------------------------------------------
// spans
// ---------------------------------------------------------------------------

/// RAII span: pushes its [`TraceCtx`] on construction, pops and (when a
/// sink is installed) emits one `"X"` event on drop.
pub struct Span {
    name: String,
    cat: String,
    ctx: TraceCtx,
    parent_span: u64,
    start_us: u64,
    started: Instant,
    recording: bool,
    args: Vec<(String, ArgValue)>,
}

impl Span {
    /// Start a new root span (fresh trace id). Inert when tracing is off.
    pub fn root(name: &str, cat: &str) -> Span {
        Span::build(name, cat, TraceCtx::NONE, true)
    }

    /// Start a child of this thread's current span; inherits its trace id.
    /// Inert when tracing is off and nothing is on the stack.
    pub fn child(name: &str, cat: &str) -> Span {
        Span::build(name, cat, current(), false)
    }

    /// Start a span under a context received from another process (the
    /// worker side of proto v5). Keeps the foreign trace id flowing into
    /// this process's logs even when no local sink is installed.
    pub fn with_parent(name: &str, cat: &str, parent: TraceCtx) -> Span {
        Span::build(name, cat, parent, false)
    }

    fn build(name: &str, cat: &str, parent: TraceCtx, force_root: bool) -> Span {
        let recording = active();
        let live = recording || (!force_root && !parent.is_none());
        if !live {
            return Span {
                name: String::new(),
                cat: String::new(),
                ctx: TraceCtx::NONE,
                parent_span: 0,
                start_us: 0,
                started: Instant::now(),
                recording: false,
                args: Vec::new(),
            };
        }
        let trace = if parent.is_none() { next_id() } else { parent.trace };
        let ctx = TraceCtx { trace, span: next_id() };
        STACK.with(|s| s.borrow_mut().push(ctx));
        Span {
            name: name.to_string(),
            cat: cat.to_string(),
            ctx,
            parent_span: parent.span,
            start_us: global_now_us().unwrap_or(0),
            started: Instant::now(),
            recording,
            args: Vec::new(),
        }
    }

    /// This span's context (what gets put on the wire). NONE when inert.
    pub fn ctx(&self) -> TraceCtx {
        self.ctx
    }

    pub fn arg_str(&mut self, key: &str, val: &str) {
        if !self.ctx.is_none() {
            self.args.push((key.to_string(), ArgValue::Str(val.to_string())));
        }
    }

    pub fn arg_num(&mut self, key: &str, val: f64) {
        if !self.ctx.is_none() {
            self.args.push((key.to_string(), ArgValue::Num(val)));
        }
    }

    pub fn arg_bool(&mut self, key: &str, val: bool) {
        if !self.ctx.is_none() {
            self.args.push((key.to_string(), ArgValue::Bool(val)));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.ctx.is_none() {
            return;
        }
        let ctx = self.ctx;
        STACK.with(|s| {
            let mut st = s.borrow_mut();
            if st.last() == Some(&ctx) {
                st.pop();
            } else {
                st.retain(|c| *c != ctx);
            }
        });
        if !self.recording {
            return;
        }
        if let Some(sink) = sink() {
            let dur = self.started.elapsed().as_micros() as u64;
            let mut ev = TraceEvent::complete(&self.name, &self.cat, self.start_us, dur, lane_id());
            ev = ev
                .arg_str("trace", &format!("{:016x}", ctx.trace))
                .arg_str("span", &format!("{:016x}", ctx.span))
                .arg_str("parent", &format!("{:016x}", self.parent_span));
            ev.args.extend(self.args.drain(..));
            sink.emit(&ev);
        }
    }
}

/// CLI-level RAII: when `path` is given, installs the global sink, opens a
/// root span named after the command, and on drop closes both (so error
/// returns still produce a readable trace file).
pub struct TraceGuard {
    span: Option<Span>,
    installed: bool,
}

impl TraceGuard {
    /// `path = None` yields an inert guard (tracing off).
    pub fn start(path: Option<&str>, command: &str) -> Result<TraceGuard> {
        let Some(path) = path else {
            return Ok(TraceGuard { span: None, installed: false });
        };
        install(path)?;
        let mut span = Span::root(&format!("run {command}"), "run");
        span.arg_str("command", command);
        Ok(TraceGuard { span: Some(span), installed: true })
    }

    /// Attach an argument to the run's root span.
    pub fn arg(&mut self, key: &str, val: &str) {
        if let Some(s) = self.span.as_mut() {
            s.arg_str(key, val);
        }
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        self.span.take();
        if self.installed {
            finish();
        }
    }
}

// ---------------------------------------------------------------------------
// chunk section timers (decode / compute / encode)
// ---------------------------------------------------------------------------

/// The three measured sections of a chunk execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Section {
    /// Reading + parsing input rows.
    Decode,
    /// The numerical kernel (sketch, Gram, multiply...).
    Compute,
    /// Writing output shards.
    Encode,
}

/// Accumulated per-chunk section timings, in microseconds. Shipped to the
/// leader in proto v5 `ChunkDone` frames.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChunkSections {
    pub decode_us: u64,
    pub compute_us: u64,
    pub encode_us: u64,
}

thread_local! {
    static SECTIONS: Cell<Option<ChunkSections>> = const { Cell::new(None) };
}

/// Start accumulating section timings on this thread (one chunk).
pub fn sections_begin() {
    SECTIONS.with(|s| s.set(Some(ChunkSections::default())));
}

/// Whether a section accumulator is open on this thread (cheap gate for
/// hot paths that would otherwise call `Instant::now` per row).
pub fn sections_active() -> bool {
    SECTIONS.with(|s| s.get().is_some())
}

/// Add time to one section (no-op if [`sections_begin`] wasn't called).
pub fn sections_add(section: Section, d: Duration) {
    SECTIONS.with(|s| {
        if let Some(mut cur) = s.get() {
            let us = d.as_micros() as u64;
            match section {
                Section::Decode => cur.decode_us += us,
                Section::Compute => cur.compute_us += us,
                Section::Encode => cur.encode_us += us,
            }
            s.set(Some(cur));
        }
    });
}

/// Time a closure into `section` (skips the clock when no accumulator).
pub fn time_section<T>(section: Section, f: impl FnOnce() -> T) -> T {
    if !sections_active() {
        return f();
    }
    let t0 = Instant::now();
    let out = f();
    sections_add(section, t0.elapsed());
    out
}

/// Close the accumulator and return what it gathered. Shard writes run
/// *nested inside* compute-timed code (a job's `exec_row`/`post`), so the
/// compute figure is reported net of the encode time accrued within it —
/// the three sections are disjoint in the returned split.
pub fn sections_take() -> Option<ChunkSections> {
    SECTIONS.with(|s| s.take()).map(|mut c| {
        c.compute_us = c.compute_us.saturating_sub(c.encode_us);
        c
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::json::Json;
    use std::sync::Mutex as StdMutex;

    /// Tests that install/inspect the process-global sink serialize here
    /// so parallel test threads can't interleave foreign spans.
    static GLOBAL_TEST: StdMutex<()> = StdMutex::new(());

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join(format!("tallfat-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    fn parse_events(path: &str) -> Vec<Json> {
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.trim_start().starts_with('['), "not a JSON array: {text:?}");
        let mut out = Vec::new();
        for line in text.lines() {
            let line = line.trim().trim_end_matches(',');
            if line.is_empty() || line == "[" || line == "]" {
                continue;
            }
            out.push(Json::parse(line).expect("event line parses"));
        }
        out
    }

    #[test]
    fn ids_are_unique_and_nonzero() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            let id = next_id();
            assert_ne!(id, 0);
            assert!(seen.insert(id), "duplicate id");
        }
    }

    #[test]
    fn json_escape_covers_specials() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn inert_span_without_sink_or_parent() {
        let _g = GLOBAL_TEST.lock().unwrap_or_else(|p| p.into_inner());
        finish();
        let s = Span::root("r", "run");
        assert!(s.ctx().is_none());
        assert!(current().is_none());
        drop(s);
        assert!(current().is_none());
    }

    #[test]
    fn wire_parent_propagates_context_without_sink() {
        let _g = GLOBAL_TEST.lock().unwrap_or_else(|p| p.into_inner());
        finish();
        let parent = TraceCtx { trace: 7, span: 9 };
        let s = Span::with_parent("chunk", "chunk", parent);
        assert_eq!(s.ctx().trace, 7);
        assert_ne!(s.ctx().span, 9);
        assert_eq!(current(), s.ctx());
        drop(s);
        assert!(current().is_none());
    }

    #[test]
    fn spans_nest_and_events_carry_lineage() {
        let _g = GLOBAL_TEST.lock().unwrap_or_else(|p| p.into_inner());
        let path = tmp("nesting.json");
        install(&path).unwrap();
        let root_ctx;
        let child_ctx;
        {
            let mut root = Span::root("run svd", "run");
            root.arg_str("input", "a.csv");
            root_ctx = root.ctx();
            {
                let child = Span::child("phase ata", "phase");
                child_ctx = child.ctx();
                assert_eq!(child.ctx().trace, root_ctx.trace);
                assert_eq!(current(), child.ctx());
            }
            assert_eq!(current(), root.ctx());
        }
        emit_global(&TraceEvent::thread_name(42, "worker-0"));
        finish();
        assert!(!active());

        let events = parse_events(&path);
        assert_eq!(events.len(), 3);
        let find = |name: &str| -> &Json {
            events
                .iter()
                .find(|e| e.get("name").and_then(|n| n.as_str()) == Some(name))
                .unwrap()
        };
        let run = find("run svd");
        let phase = find("phase ata");
        let args = |e: &Json, k: &str| e.get("args").unwrap().get(k).unwrap().as_str().unwrap();
        assert_eq!(args(run, "span"), format!("{:016x}", root_ctx.span));
        assert_eq!(args(phase, "parent"), format!("{:016x}", root_ctx.span));
        assert_eq!(args(phase, "trace"), format!("{:016x}", child_ctx.trace));
        assert_eq!(args(run, "input"), "a.csv");
        // child drops first, so its ts window sits inside the root's.
        let ts = |e: &Json| e.get("ts").unwrap().as_f64().unwrap();
        let dur = |e: &Json| e.get("dur").unwrap().as_f64().unwrap();
        assert!(ts(phase) >= ts(run));
        assert!(ts(phase) + dur(phase) <= ts(run) + dur(run) + 10.0);
        let meta = find("thread_name");
        assert_eq!(meta.get("ph").unwrap().as_str().unwrap(), "M");
        assert_eq!(args(meta, "name"), "worker-0");
    }

    #[test]
    fn closed_file_is_strict_json_array() {
        let _g = GLOBAL_TEST.lock().unwrap_or_else(|p| p.into_inner());
        let path = tmp("strict.json");
        let sink = TraceSink::create(&path).unwrap();
        sink.emit(&TraceEvent::complete("a", "c", 1, 2, 3).arg_num("x", 1.5));
        sink.emit(&TraceEvent::complete("b", "c", 4, 5, 6).arg_bool("retry", true));
        assert_eq!(sink.events(), 2);
        sink.close();
        let text = std::fs::read_to_string(&path).unwrap();
        // Strict whole-file parse (what `json.load` in CI does).
        let all = Json::parse(&text).expect("whole file is one JSON array");
        let arr = match all {
            Json::Arr(a) => a,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[1].get("args").unwrap().get("retry").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn sections_accumulate_and_take_clears() {
        assert!(sections_take().is_none());
        let skipped = time_section(Section::Decode, || 5);
        assert_eq!(skipped, 5);
        sections_begin();
        assert!(sections_active());
        let v = time_section(Section::Encode, || 7);
        assert_eq!(v, 7);
        sections_take();
        sections_begin();
        sections_add(Section::Decode, Duration::from_micros(100));
        sections_add(Section::Decode, Duration::from_micros(50));
        sections_add(Section::Compute, Duration::from_micros(700));
        sections_add(Section::Encode, Duration::from_micros(40));
        let got = sections_take().unwrap();
        assert_eq!(got.decode_us, 150);
        // Encode runs nested inside compute-timed code, so take() reports
        // compute net of encode — the split is disjoint.
        assert_eq!(got.compute_us, 660);
        assert_eq!(got.encode_us, 40);
        assert!(sections_take().is_none());
        assert!(!sections_active());
    }
}
