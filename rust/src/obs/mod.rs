//! Observability: cross-process span tracing and trace rendering.
//!
//! The metrics plane ([`crate::coordinator::server::MetricsRegistry`])
//! answers "how much / how fast" in aggregate; this module answers *where
//! a specific run's wall-time went*:
//!
//! * [`trace`] — lightweight span tracing. A process-local span stack
//!   carries run/phase/chunk identity, and an optional [`trace::TraceSink`]
//!   (installed by `--trace FILE` on the CLI) writes Chrome trace-event
//!   JSON that chrome://tracing and Perfetto open directly. Cluster runs
//!   propagate a 16-byte [`trace::TraceCtx`] through the v5 wire protocol
//!   so workers' per-chunk timings (decode/compute/encode) come back on
//!   `ChunkDone` and the leader emits one merged timeline attributing
//!   every chunk to the worker that ran it.
//! * [`summary`] — `tallfat trace-summary FILE`: per-phase critical path,
//!   the top slowest chunks, and a worker utilization table, read back
//!   from a captured trace file.
//!
//! Everything is dependency-free and cheap when disabled: with no sink
//! installed, spans are inert values and the chunk section timers are a
//! thread-local flag test.

pub mod summary;
pub mod trace;

pub use trace::{Span, TraceCtx, TraceSink};
