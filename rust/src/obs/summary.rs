//! `tallfat trace-summary FILE` — read a captured trace back as text.
//!
//! Parses the Chrome trace-event file written by [`super::trace`] and
//! renders three tables: per-phase critical path (wall time vs the
//! busiest worker's serial time), the top slowest chunks with their
//! decode/compute/encode split, and worker utilization. Tolerates a
//! missing closing `]` (crashed run): unparseable trailing lines are
//! counted and skipped, everything salvageable is summarized.

use crate::error::Result;
use crate::serve::json::Json;
use std::collections::BTreeMap;

/// One decoded trace event (only the fields the summary needs).
struct Ev {
    name: String,
    cat: String,
    ts_ms: f64,
    dur_ms: f64,
    args: Json,
}

impl Ev {
    fn arg_str(&self, key: &str) -> Option<&str> {
        self.args.get(key).and_then(Json::as_str)
    }

    fn arg_num(&self, key: &str) -> f64 {
        self.args.get(key).and_then(Json::as_f64).unwrap_or(0.0)
    }

    fn arg_bool(&self, key: &str) -> bool {
        self.args.get(key).and_then(Json::as_bool).unwrap_or(false)
    }
}

/// Parse the one-event-per-line array format; returns (events, skipped).
fn parse_events(text: &str) -> (Vec<Ev>, usize) {
    let mut out = Vec::new();
    let mut skipped = 0;
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        if line.is_empty() || line == "[" || line == "]" {
            continue;
        }
        let Ok(j) = Json::parse(line) else {
            skipped += 1;
            continue;
        };
        if j.get("ph").and_then(Json::as_str) != Some("X") {
            continue; // metadata events carry no timing
        }
        out.push(Ev {
            name: j.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
            cat: j.get("cat").and_then(Json::as_str).unwrap_or("").to_string(),
            ts_ms: j.get("ts").and_then(Json::as_f64).unwrap_or(0.0) / 1000.0,
            dur_ms: j.get("dur").and_then(Json::as_f64).unwrap_or(0.0) / 1000.0,
            args: j,
        });
    }
    (out, skipped)
}

/// Render the summary of the trace file at `path`.
pub fn render_summary(path: &str) -> Result<String> {
    let text = std::fs::read_to_string(path)?;
    let (events, skipped) = parse_events(&text);

    let runs: Vec<&Ev> = events.iter().filter(|e| e.cat == "run").collect();
    let mut phases: Vec<&Ev> = events.iter().filter(|e| e.cat == "phase").collect();
    phases.sort_by(|a, b| a.ts_ms.total_cmp(&b.ts_ms));
    let chunks: Vec<&Ev> = events.iter().filter(|e| e.cat == "chunk").collect();

    let mut out = String::new();
    out.push_str(&format!(
        "trace summary: {path}\n  events: {} ({} run, {} phases, {} chunks{})\n",
        events.len(),
        runs.len(),
        phases.len(),
        chunks.len(),
        if skipped > 0 { format!(", {skipped} unparseable lines skipped") } else { String::new() },
    ));
    if events.is_empty() {
        return Ok(out);
    }
    if let Some(run) = runs.first() {
        out.push_str(&format!("  run \"{}\": {:.1} ms wall\n", run.name, run.dur_ms));
    }

    // Chunks attribute to a phase via the parent span id (same-process
    // spans and leader-merged worker chunks both carry it).
    let phase_of = |c: &Ev| -> String {
        if let Some(p) = c.arg_str("parent") {
            for ph in &phases {
                if ph.arg_str("span") == Some(p) {
                    return ph.name.clone();
                }
            }
        }
        c.arg_str("phase").unwrap_or("?").to_string()
    };

    // --- per-phase critical path -----------------------------------------
    out.push_str("\nper-phase critical path\n");
    out.push_str(&format!(
        "  {:<26} {:>9} {:>7} {:>9} {:>9} {:>6}\n",
        "phase", "wall ms", "chunks", "busy ms", "crit ms", "eff%"
    ));
    for ph in &phases {
        let mine: Vec<&&Ev> = chunks.iter().filter(|c| phase_of(c) == ph.name).collect();
        let busy: f64 = mine.iter().map(|c| c.dur_ms).sum();
        let mut per_worker: BTreeMap<String, f64> = BTreeMap::new();
        for c in &mine {
            *per_worker.entry(c.arg_str("worker").unwrap_or("?").to_string()).or_default() +=
                c.dur_ms;
        }
        // Critical path: the busiest worker's serial time — the floor on
        // phase wall time no scheduler reshuffle could beat.
        let crit = per_worker.values().fold(0.0_f64, |a, &b| a.max(b));
        let lanes = per_worker.len().max(1) as f64;
        let eff = if ph.dur_ms > 0.0 { 100.0 * busy / (ph.dur_ms * lanes) } else { 0.0 };
        out.push_str(&format!(
            "  {:<26} {:>9.1} {:>7} {:>9.1} {:>9.1} {:>6.1}\n",
            ph.name,
            ph.dur_ms,
            mine.len(),
            busy,
            crit,
            eff.min(100.0),
        ));
    }

    // --- top slowest chunks ----------------------------------------------
    let mut by_dur: Vec<&&Ev> = chunks.iter().collect();
    by_dur.sort_by(|a, b| b.dur_ms.total_cmp(&a.dur_ms));
    out.push_str("\ntop slowest chunks\n");
    out.push_str(&format!(
        "  {:>9} {:<22} {:<18} {:>8} {:>8} {:>8}  {}\n",
        "dur ms", "phase", "worker", "dec ms", "cmp ms", "enc ms", "flags"
    ));
    for c in by_dur.iter().take(10) {
        let mut flags = String::new();
        if c.arg_bool("retry") {
            flags.push_str("retried ");
        }
        if c.arg_bool("speculative") {
            flags.push_str("speculated ");
        }
        out.push_str(&format!(
            "  {:>9.1} {:<22} {:<18} {:>8.1} {:>8.1} {:>8.1}  {}\n",
            c.dur_ms,
            format!("{}/{}", phase_of(c), c.name),
            c.arg_str("worker").unwrap_or("?"),
            c.arg_num("decode_ms"),
            c.arg_num("compute_ms"),
            c.arg_num("encode_ms"),
            flags.trim_end(),
        ));
    }

    // --- worker utilization ----------------------------------------------
    struct W {
        chunks: usize,
        busy: f64,
        retried: usize,
        speculated: usize,
    }
    let mut workers: BTreeMap<String, W> = BTreeMap::new();
    for c in &chunks {
        let w = workers
            .entry(c.arg_str("worker").unwrap_or("?").to_string())
            .or_insert(W { chunks: 0, busy: 0.0, retried: 0, speculated: 0 });
        w.chunks += 1;
        w.busy += c.dur_ms;
        if c.arg_bool("retry") {
            w.retried += 1;
        }
        if c.arg_bool("speculative") {
            w.speculated += 1;
        }
    }
    let span: f64 = if let Some(run) = runs.first() {
        run.dur_ms
    } else {
        phases.iter().map(|p| p.dur_ms).sum()
    };
    out.push_str("\nworker utilization\n");
    out.push_str(&format!(
        "  {:<18} {:>7} {:>9} {:>6} {:>8} {:>11}\n",
        "worker", "chunks", "busy ms", "util%", "retried", "speculated"
    ));
    for (name, w) in &workers {
        let util = if span > 0.0 { 100.0 * w.busy / span } else { 0.0 };
        out.push_str(&format!(
            "  {:<18} {:>7} {:>9.1} {:>6.1} {:>8} {:>11}\n",
            name,
            w.chunks,
            w.busy,
            util.min(100.0),
            w.retried,
            w.speculated,
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{TraceEvent, TraceSink};

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join(format!("tallfat-summary-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    fn chunk(name: &str, ts: u64, dur: u64, worker: &str, parent: &str) -> TraceEvent {
        TraceEvent::complete(name, "chunk", ts, dur, 101)
            .arg_str("worker", worker)
            .arg_str("parent", parent)
            .arg_num("decode_ms", 1.0)
            .arg_num("compute_ms", 2.0)
            .arg_num("encode_ms", 0.5)
    }

    #[test]
    fn summarizes_phases_chunks_and_workers() {
        let path = tmp("ok.json");
        let sink = TraceSink::create(&path).unwrap();
        sink.emit(
            &TraceEvent::complete("run svd", "run", 0, 10_000_000, 1).arg_str("span", "aa"),
        );
        sink.emit(
            &TraceEvent::complete("projectgram#1", "phase", 100, 8_000_000, 1)
                .arg_str("span", "bb")
                .arg_str("parent", "aa"),
        );
        sink.emit(&chunk("chunk 0", 200, 3_000_000, "w1:7001", "bb"));
        sink.emit(&chunk("chunk 1", 300, 4_000_000, "w2:7002", "bb"));
        sink.emit(&chunk("chunk 2", 3_400, 2_000_000, "w1:7001", "bb").arg_bool("retry", true));
        sink.close();

        let text = render_summary(&path).unwrap();
        assert!(text.contains("1 run, 1 phases, 3 chunks"), "{text}");
        assert!(text.contains("projectgram#1"), "{text}");
        assert!(text.contains("w1:7001"), "{text}");
        assert!(text.contains("w2:7002"), "{text}");
        assert!(text.contains("retried"), "{text}");
        // busiest worker: w1 with 3s + 2s = 5s serial — the critical path.
        assert!(text.contains("5000.0"), "{text}");
    }

    #[test]
    fn tolerates_truncated_file() {
        let path = tmp("truncated.json");
        let sink = TraceSink::create(&path).unwrap();
        sink.emit(&TraceEvent::complete("run svd", "run", 0, 500, 1));
        sink.close();
        // Simulate a crash mid-write: re-append half an event, no bracket.
        let mut text = std::fs::read_to_string(&path).unwrap();
        text = text.replace("\n]\n", ",\n{\"name\":\"half");
        std::fs::write(&path, text).unwrap();
        let out = render_summary(&path).unwrap();
        assert!(out.contains("1 run"), "{out}");
        assert!(out.contains("unparseable lines skipped"), "{out}");
    }

    #[test]
    fn empty_trace_renders_header_only() {
        let path = tmp("empty.json");
        TraceSink::create(&path).unwrap().close();
        let out = render_summary(&path).unwrap();
        assert!(out.contains("events: 0"), "{out}");
    }
}
