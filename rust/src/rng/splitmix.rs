//! SplitMix64 — the mixing core of the counter-based generator.
//!
//! Fast (a handful of arithmetic ops), passes BigCrush as a stream, and —
//! crucial here — is a *stateless* bijective mixer: feeding it structured
//! counters `(seed, i, j)` yields independent-looking streams, which is all
//! the Johnson–Lindenstrauss sketch needs.

/// One SplitMix64 mixing step (Steele, Lea & Flood 2014).
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Mix three words into one — the `(seed, i, j)` counter hash.
#[inline]
pub fn mix3(seed: u64, i: u64, j: u64) -> u64 {
    // Chain the mixer; each stage is bijective in its input so distinct
    // counters cannot collide "for free".
    splitmix64(splitmix64(splitmix64(seed) ^ i).wrapping_add(j))
}

/// Map a u64 to the open unit interval (0, 1).
#[inline]
pub fn to_unit_open(bits: u64) -> f64 {
    // Use the top 53 bits; add 0.5 ulp offset to exclude exact 0.
    (((bits >> 11) as f64) + 0.5) * (1.0 / 9007199254740992.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(splitmix64(42), splitmix64(42));
        assert_eq!(mix3(1, 2, 3), mix3(1, 2, 3));
    }

    #[test]
    fn distinct_counters_differ() {
        assert_ne!(mix3(0, 0, 0), mix3(0, 0, 1));
        assert_ne!(mix3(0, 0, 0), mix3(0, 1, 0));
        assert_ne!(mix3(0, 0, 0), mix3(1, 0, 0));
        // (i, j) vs (j, i) must not be symmetric
        assert_ne!(mix3(7, 3, 5), mix3(7, 5, 3));
    }

    #[test]
    fn unit_open_range() {
        for x in [0u64, 1, u64::MAX, 0xDEADBEEF, 1 << 63] {
            let u = to_unit_open(splitmix64(x));
            assert!(u > 0.0 && u < 1.0, "{u}");
        }
    }

    #[test]
    fn rough_uniformity() {
        // 10k samples into 10 bins: each bin within 3x sqrt expectations.
        let mut bins = [0usize; 10];
        for i in 0..10_000u64 {
            let u = to_unit_open(mix3(99, i, 0));
            bins[(u * 10.0) as usize] += 1;
        }
        for &b in &bins {
            assert!((b as i64 - 1000).abs() < 150, "bin count {b}");
        }
    }

    #[test]
    fn avalanche_single_bit() {
        // Flipping one input bit should flip ~half the output bits.
        let a = splitmix64(0x12345678);
        let b = splitmix64(0x12345679);
        let flipped = (a ^ b).count_ones();
        assert!(flipped > 16 && flipped < 48, "{flipped}");
    }
}
