//! Counter-based standard Gaussian sampling (Box–Muller over SplitMix64).

use super::splitmix::{mix3, splitmix64, to_unit_open};

/// A stateless N(0,1) source: `sample(i, j)` is a pure function of
/// `(seed, i, j)`.
#[derive(Clone, Copy, Debug)]
pub struct Gaussian {
    seed: u64,
}

impl Gaussian {
    pub fn new(seed: u64) -> Self {
        Gaussian { seed }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Standard normal deviate for counter `(i, j)`.
    #[inline]
    pub fn sample(&self, i: u64, j: u64) -> f64 {
        let h = mix3(self.seed, i, j);
        // Two independent uniforms from one mixed word + one extra round.
        let u1 = to_unit_open(h);
        let u2 = to_unit_open(splitmix64(h ^ 0xA5A5_A5A5_5A5A_5A5A));
        // Box–Muller (cosine branch).
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a row-major buffer with N(0,1) * `scale` for rows
    /// `[row0, row0+rows)` and `cols` columns.
    pub fn fill_block(&self, buf: &mut [f64], row0: u64, rows: usize, cols: usize, scale: f64) {
        debug_assert_eq!(buf.len(), rows * cols);
        for r in 0..rows {
            let i = row0 + r as u64;
            let out = &mut buf[r * cols..(r + 1) * cols];
            for (j, v) in out.iter_mut().enumerate() {
                *v = self.sample(i, j as u64) * scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_order_independent() {
        let g = Gaussian::new(7);
        let a = g.sample(123, 45);
        let _ = g.sample(999, 1); // interleave other draws
        assert_eq!(a, g.sample(123, 45));
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Gaussian::new(1).sample(0, 0), Gaussian::new(2).sample(0, 0));
    }

    #[test]
    fn moments() {
        let g = Gaussian::new(42);
        let n = 100_000u64;
        let (mut sum, mut sum2, mut sum3, mut sum4) = (0.0, 0.0, 0.0, 0.0);
        for i in 0..n {
            let x = g.sample(i, 0);
            sum += x;
            sum2 += x * x;
            sum3 += x * x * x;
            sum4 += x * x * x * x;
        }
        let nf = n as f64;
        let mean = sum / nf;
        let var = sum2 / nf - mean * mean;
        let skew = sum3 / nf;
        let kurt = sum4 / nf;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        assert!(skew.abs() < 0.05, "skew {skew}");
        assert!((kurt - 3.0).abs() < 0.15, "kurtosis {kurt}");
    }

    #[test]
    fn no_pathological_values() {
        let g = Gaussian::new(0);
        for i in 0..10_000u64 {
            let x = g.sample(i, i % 64);
            assert!(x.is_finite());
            assert!(x.abs() < 10.0, "|x| = {x} implausibly large");
        }
    }

    #[test]
    fn fill_block_matches_elementwise() {
        let g = Gaussian::new(3);
        let mut buf = vec![0.0; 4 * 5];
        g.fill_block(&mut buf, 10, 4, 5, 2.0);
        for r in 0..4 {
            for c in 0..5 {
                assert_eq!(buf[r * 5 + c], 2.0 * g.sample(10 + r as u64, c as u64));
            }
        }
    }

    #[test]
    fn row_correlation_small() {
        // Adjacent rows of a virtual Omega must be (nearly) uncorrelated.
        let g = Gaussian::new(11);
        let dim = 10_000;
        let (mut dot, mut n1, mut n2) = (0.0, 0.0, 0.0);
        for j in 0..dim {
            let a = g.sample(0, j);
            let b = g.sample(1, j);
            dot += a * b;
            n1 += a * a;
            n2 += b * b;
        }
        let corr = dot / (n1.sqrt() * n2.sqrt());
        assert!(corr.abs() < 0.03, "corr {corr}");
    }
}
