//! Deterministic, counter-based pseudo-randomness — the "virtual random B"
//! substrate (paper §2.1).
//!
//! The paper regenerates rows of the Gaussian projection matrix Ω by
//! re-seeding `numpy.random.seed(0)` per row instead of storing Ω. We keep
//! the idea (same bits every time, O(1) memory) but use a *counter-based*
//! generator: element `Ω[i,j]` is a pure function of `(seed, i, j)`. That
//! strictly dominates the sequential re-seeding trick — any worker can
//! materialize any block of Ω in any order, with no shared state.

pub mod gaussian;
pub mod splitmix;
pub mod virtual_matrix;

pub use gaussian::Gaussian;
pub use splitmix::{mix3, splitmix64};
pub use virtual_matrix::VirtualMatrix;
