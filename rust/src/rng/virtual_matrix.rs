//! The virtual random projection matrix Ω (paper §2.1).
//!
//! Never materialized in full: any element, row, or block is regenerated on
//! demand from `(seed, i, j)`. The JL-standard `1/sqrt(k)` column scaling is
//! baked in so `||Y row|| ≈ ||A row||` in expectation.

use super::gaussian::Gaussian;
use crate::linalg::Matrix;

/// A virtual `rows x cols` Gaussian matrix with entries
/// `scale * N(0,1)[seed; i, j]`.
#[derive(Clone, Copy, Debug)]
pub struct VirtualMatrix {
    gaussian: Gaussian,
    rows: usize,
    cols: usize,
    scale: f64,
}

impl VirtualMatrix {
    /// A JL projection sketch `n x k` with the standard `1/sqrt(k)` scaling.
    pub fn projection(seed: u64, n: usize, k: usize) -> Self {
        VirtualMatrix {
            gaussian: Gaussian::new(seed),
            rows: n,
            cols: k,
            scale: 1.0 / (k as f64).sqrt(),
        }
    }

    /// Unscaled variant (scale = 1).
    pub fn standard(seed: u64, rows: usize, cols: usize) -> Self {
        VirtualMatrix { gaussian: Gaussian::new(seed), rows, cols, scale: 1.0 }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// Element `(i, j)` — pure function, any order, any worker.
    #[inline]
    pub fn element(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.gaussian.sample(i as u64, j as u64) * self.scale
    }

    /// Materialize rows `[row0, row0 + nrows)` as a dense block.
    pub fn materialize_rows(&self, row0: usize, nrows: usize) -> Matrix {
        let nrows = nrows.min(self.rows - row0);
        let mut m = Matrix::zeros(nrows, self.cols);
        self.gaussian
            .fill_block(m.data_mut(), row0 as u64, nrows, self.cols, self.scale);
        m
    }

    /// Materialize the whole matrix (for the E3 "materialized" baseline and
    /// for handing Ω to the fixed-shape XLA artifacts).
    pub fn materialize(&self) -> Matrix {
        self.materialize_rows(0, self.rows)
    }

    /// Project one row of A: `y = a_row^T Ω` without materializing Ω.
    /// This is the paper's §2.1 inner loop (`s += elem * random_row`).
    pub fn project_row(&self, a_row: &[f64], out: &mut [f64]) {
        debug_assert_eq!(a_row.len(), self.rows);
        debug_assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        for (i, &a) in a_row.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            for (j, o) in out.iter_mut().enumerate() {
                *o += a * self.element(i, j);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_materialization_consistent_with_elements() {
        let v = VirtualMatrix::projection(5, 100, 8);
        let blk = v.materialize_rows(40, 10);
        for i in 0..10 {
            for j in 0..8 {
                assert_eq!(blk.get(i, j), v.element(40 + i, j));
            }
        }
    }

    #[test]
    fn overlapping_blocks_agree() {
        // Workers materializing overlapping row ranges see identical bits —
        // the whole point of virtual-B.
        let v = VirtualMatrix::projection(9, 64, 4);
        let b1 = v.materialize_rows(0, 48);
        let b2 = v.materialize_rows(32, 32);
        for i in 0..16 {
            for j in 0..4 {
                assert_eq!(b1.get(32 + i, j), b2.get(i, j));
            }
        }
    }

    #[test]
    fn project_row_matches_materialized() {
        let v = VirtualMatrix::projection(3, 32, 6);
        let omega = v.materialize();
        let a_row: Vec<f64> = (0..32).map(|i| (i as f64) * 0.1 - 1.0).collect();
        let mut out = vec![0.0; 6];
        v.project_row(&a_row, &mut out);
        for j in 0..6 {
            let want: f64 = (0..32).map(|i| a_row[i] * omega.get(i, j)).sum();
            assert!((out[j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn tail_block_clamped() {
        let v = VirtualMatrix::projection(1, 10, 3);
        let blk = v.materialize_rows(8, 5);
        assert_eq!(blk.shape(), (2, 3));
    }

    #[test]
    fn jl_scaling() {
        let v = VirtualMatrix::projection(0, 100, 25);
        assert!((v.scale() - 0.2).abs() < 1e-15);
    }

    #[test]
    fn norm_preservation_in_expectation() {
        // JL property: ||x Omega|| ~ ||x|| for the 1/sqrt(k) scaling.
        let n = 200;
        let k = 64;
        let v = VirtualMatrix::projection(13, n, k);
        let x: Vec<f64> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { -0.5 }).collect();
        let xnorm: f64 = x.iter().map(|a| a * a).sum::<f64>().sqrt();
        let mut y = vec![0.0; k];
        v.project_row(&x, &mut y);
        let ynorm: f64 = y.iter().map(|a| a * a).sum::<f64>().sqrt();
        let ratio = ynorm / xnorm;
        assert!((ratio - 1.0).abs() < 0.35, "ratio {ratio}");
    }
}
