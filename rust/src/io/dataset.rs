//! Synthetic dataset generators (the "simulate the data you don't have"
//! substitution — see DESIGN.md).
//!
//! Two regimes:
//! * **exact**: small enough to build in memory with QR-orthonormalized
//!   factors, so singular values are *known exactly* (accuracy experiments).
//! * **streamed**: arbitrarily tall, written block-by-block without ever
//!   holding A (throughput/scalability experiments).

use crate::config::InputFormat;
use crate::error::Result;
use crate::io::binmat::{BinMatWriter, DType};
use crate::io::InputSpec;
use crate::linalg::{matmul, qr::thin_qr, Matrix};
use crate::rng::Gaussian;
use std::io::Write;

/// Spectrum shapes for synthetic matrices.
#[derive(Clone, Copy, Debug)]
pub enum Spectrum {
    /// `sigma_i = scale * decay^i` — fast decay, the randomized-SVD sweet spot.
    Geometric { scale: f64, decay: f64 },
    /// `sigma_i = scale / (1 + i)` — slow polynomial decay (hard case).
    Power { scale: f64 },
    /// First `r` values = scale, rest 0 — exact low rank.
    LowRank { scale: f64, r: usize },
}

impl Spectrum {
    pub fn value(&self, i: usize) -> f64 {
        match *self {
            Spectrum::Geometric { scale, decay } => scale * decay.powi(i as i32),
            Spectrum::Power { scale } => scale / (1.0 + i as f64),
            Spectrum::LowRank { scale, r } => {
                if i < r {
                    scale
                } else {
                    0.0
                }
            }
        }
    }

    pub fn values(&self, n: usize) -> Vec<f64> {
        (0..n).map(|i| self.value(i)).collect()
    }
}

/// Exact synthetic matrix `A = U diag(sigma) V^T + noise` with orthonormal
/// U (m x r) and V (n x r). Returns `(A, sigma)`; `sigma` are A's exact
/// singular values when `noise = 0`.
pub fn gen_exact(
    m: usize,
    n: usize,
    rank: usize,
    spectrum: Spectrum,
    noise: f64,
    seed: u64,
) -> Result<(Matrix, Vec<f64>)> {
    assert!(rank <= n.min(m));
    let g = Gaussian::new(seed);
    let gu = Matrix::from_fn(m, rank, |i, j| g.sample(i as u64, j as u64));
    let gv = Matrix::from_fn(n, rank, |i, j| g.sample((m + i) as u64, j as u64));
    let (u, _) = thin_qr(&gu)?;
    let (v, _) = thin_qr(&gv)?;
    let sigma = spectrum.values(rank);
    let us = u.scale_cols(&sigma)?;
    let mut a = matmul(&us, &v.t())?;
    if noise > 0.0 {
        let gn = Gaussian::new(seed ^ NOISE_STREAM);
        for i in 0..m {
            let row = a.row_mut(i);
            for (j, val) in row.iter_mut().enumerate() {
                *val += noise * gn.sample(i as u64, j as u64);
            }
        }
    }
    Ok((a, sigma))
}

/// Decorrelates the noise stream from the factor streams.
const NOISE_STREAM: u64 = 0x5EED_0000_000A_11CE;

/// Stream a tall pseudo-low-rank matrix to disk without materializing it:
/// each row block is `G_blk (r x n factor)` with `G_blk` i.i.d. Gaussian and
/// the factor `F = diag(sigma) V^T` fixed. Singular values are approximately
/// `sigma * sqrt(m/r)`-scaled; exact values don't matter for throughput runs.
pub fn gen_streamed(
    spec: &InputSpec,
    m: usize,
    n: usize,
    rank: usize,
    spectrum: Spectrum,
    noise: f64,
    seed: u64,
) -> Result<()> {
    let g = Gaussian::new(seed);
    let gv = Matrix::from_fn(n, rank, |i, j| g.sample((1_000_000 + i) as u64, j as u64));
    let (v, _) = thin_qr(&gv)?;
    let sigma = spectrum.values(rank);
    // F = diag(sigma) V^T, scaled so row norms stay O(1).
    let scale = 1.0 / (rank as f64).sqrt();
    let f = {
        let vt = v.t();
        let mut f = Matrix::zeros(rank, n);
        for i in 0..rank {
            for j in 0..n {
                f.set(i, j, sigma[i] * vt.get(i, j) * scale);
            }
        }
        f
    };
    let gn = Gaussian::new(seed ^ NOISE_STREAM);

    let block = 1024usize;
    let mut csv_writer: Option<std::io::BufWriter<Box<dyn std::io::Write>>> = None;
    let mut bin_writer: Option<BinMatWriter> = None;
    match spec.format {
        InputFormat::Csv => {
            // `-` streams rows to stdout, so the generator can feed a pipe
            // (`tallfat gen-data --out - | tallfat stream -`).
            let sink: Box<dyn std::io::Write> = if spec.path == "-" {
                Box::new(std::io::stdout())
            } else {
                Box::new(std::fs::File::create(&spec.path)?)
            };
            csv_writer = Some(std::io::BufWriter::with_capacity(1 << 20, sink));
        }
        InputFormat::Bin => {
            bin_writer = Some(BinMatWriter::create(&spec.path, n, DType::F32)?);
        }
        InputFormat::Libsvm | InputFormat::SparseCsv | InputFormat::Csr => {
            return Err(crate::error::Error::Config(
                "gen_streamed writes dense rows; use gen_sparse_streamed for sparse outputs"
                    .into(),
            ));
        }
    }

    let mut row_out = vec![0.0f64; n];
    for b0 in (0..m).step_by(block) {
        let rows = block.min(m - b0);
        for r in 0..rows {
            let i = b0 + r;
            // row = g_i (1 x rank) @ F (rank x n) + noise
            row_out.fill(0.0);
            for t in 0..rank {
                let gi = g.sample(i as u64, (5_000_000 + t) as u64);
                if gi == 0.0 {
                    continue;
                }
                let frow = f.row(t);
                for (o, fv) in row_out.iter_mut().zip(frow.iter()) {
                    *o += gi * fv;
                }
            }
            if noise > 0.0 {
                for (j, o) in row_out.iter_mut().enumerate() {
                    *o += noise * gn.sample(i as u64, j as u64);
                }
            }
            if let Some(w) = csv_writer.as_mut() {
                crate::io::csv::write_row(w, &row_out)?;
            } else if let Some(w) = bin_writer.as_mut() {
                w.write_row(&row_out)?;
            }
        }
    }
    if let Some(mut w) = csv_writer {
        w.flush()?;
    }
    if let Some(w) = bin_writer {
        w.finish()?;
    }
    Ok(())
}

/// Stream a tall sparse matrix to disk at roughly `density` fill: a
/// deterministic hash picks the nonzero pattern, values are N(0, 1)
/// scaled. Memory stays `O(row)`. For the `scsv` format (which cannot
/// represent all-zero rows) every row gets at least one entry.
pub fn gen_sparse_streamed(
    spec: &InputSpec,
    m: usize,
    n: usize,
    density: f64,
    seed: u64,
) -> Result<u64> {
    use crate::io::sparse::{write_libsvm_row, write_scsv_row, CsrWriter};
    use crate::rng::splitmix::{mix3, to_unit_open};
    if !(0.0..=1.0).contains(&density) {
        return Err(crate::error::Error::Config(format!(
            "density must be in [0, 1], got {density}"
        )));
    }
    if n == 0 {
        return Err(crate::error::Error::Config(
            "sparse output needs cols >= 1".into(),
        ));
    }
    let g = Gaussian::new(seed);
    let mut text_writer: Option<std::io::BufWriter<std::fs::File>> = None;
    let mut csr_writer: Option<CsrWriter> = None;
    match spec.format {
        InputFormat::Libsvm | InputFormat::SparseCsv => {
            text_writer = Some(std::io::BufWriter::with_capacity(
                1 << 20,
                std::fs::File::create(&spec.path)?,
            ));
        }
        InputFormat::Csr => {
            csr_writer = Some(CsrWriter::create(&spec.path, m, n)?);
        }
        other => {
            return Err(crate::error::Error::Config(format!(
                "gen_sparse_streamed: {other:?} is not a sparse format"
            )));
        }
    }
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f64> = Vec::new();
    let mut nnz = 0u64;
    for i in 0..m {
        indices.clear();
        values.clear();
        for j in 0..n {
            let u = to_unit_open(mix3(seed ^ 0x5AA5_5AA5, i as u64, j as u64));
            if u < density {
                indices.push(j as u32);
                values.push(g.sample(i as u64, j as u64));
            }
        }
        if indices.is_empty() && spec.format == InputFormat::SparseCsv {
            // scsv cannot represent an all-zero row; pin one tiny entry.
            indices.push((i % n) as u32);
            values.push(1e-12);
        }
        nnz += indices.len() as u64;
        match spec.format {
            InputFormat::Libsvm => {
                write_libsvm_row(text_writer.as_mut().expect("text writer"), &indices, &values)?;
            }
            InputFormat::SparseCsv => {
                write_scsv_row(text_writer.as_mut().expect("text writer"), &indices, &values)?;
            }
            _ => {
                csr_writer.as_mut().expect("csr writer").write_row(&indices, &values)?;
            }
        }
    }
    if let Some(mut w) = text_writer {
        w.flush()?;
    }
    if let Some(w) = csr_writer {
        w.finish()?;
    }
    Ok(nnz)
}

/// Clustered "document vectors" for the LSA / similarity example (E4):
/// `clusters` centers, points scattered around them; returns `(A, labels)`.
pub fn gen_clustered(
    m: usize,
    n: usize,
    clusters: usize,
    spread: f64,
    seed: u64,
) -> (Matrix, Vec<usize>) {
    let g = Gaussian::new(seed);
    let centers = Matrix::from_fn(clusters, n, |c, j| 3.0 * g.sample(c as u64, j as u64));
    let mut labels = Vec::with_capacity(m);
    let a = Matrix::from_fn(m, n, |i, j| {
        let c = i % clusters;
        centers.get(c, j) + spread * g.sample((10_000 + i) as u64, j as u64)
    });
    for i in 0..m {
        labels.push(i % clusters);
    }
    (a, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::exact_svd;

    #[test]
    fn exact_generator_has_declared_spectrum() {
        let (a, sigma) =
            gen_exact(80, 20, 6, Spectrum::Geometric { scale: 5.0, decay: 0.5 }, 0.0, 1).unwrap();
        let svd = exact_svd(&a).unwrap();
        for i in 0..6 {
            assert!(
                (svd.sigma[i] - sigma[i]).abs() < 1e-8 * sigma[0],
                "sigma[{i}]: {} vs {}",
                svd.sigma[i],
                sigma[i]
            );
        }
        assert!(svd.sigma[6] < 1e-9);
    }

    #[test]
    fn noise_perturbs_but_preserves_top() {
        let (a, _) =
            gen_exact(100, 16, 4, Spectrum::LowRank { scale: 10.0, r: 4 }, 0.01, 2).unwrap();
        let svd = exact_svd(&a).unwrap();
        assert!(svd.sigma[0] > 9.0 && svd.sigma[0] < 11.0);
        assert!(svd.sigma[4] > 0.0 && svd.sigma[4] < 1.0);
    }

    #[test]
    fn streamed_writes_expected_dims() {
        let dir = std::env::temp_dir().join("tallfat_test_dataset");
        std::fs::create_dir_all(&dir).unwrap();
        for fmt in ["s.csv", "s.bin"] {
            let spec = InputSpec::auto(dir.join(fmt).to_string_lossy().into_owned());
            gen_streamed(&spec, 500, 12, 4, Spectrum::Geometric { scale: 2.0, decay: 0.7 }, 0.01, 3)
                .unwrap();
            assert_eq!(spec.dims().unwrap(), (500, 12));
        }
    }

    #[test]
    fn streamed_deterministic() {
        let dir = std::env::temp_dir().join("tallfat_test_dataset");
        std::fs::create_dir_all(&dir).unwrap();
        let s1 = InputSpec::csv(dir.join("d1.csv").to_string_lossy().into_owned());
        let s2 = InputSpec::csv(dir.join("d2.csv").to_string_lossy().into_owned());
        let sp = Spectrum::Power { scale: 1.0 };
        gen_streamed(&s1, 50, 8, 3, sp, 0.0, 7).unwrap();
        gen_streamed(&s2, 50, 8, 3, sp, 0.0, 7).unwrap();
        assert_eq!(
            std::fs::read(&s1.path).unwrap(),
            std::fs::read(&s2.path).unwrap()
        );
    }

    #[test]
    fn sparse_streamed_hits_density_and_roundtrips() {
        let dir = std::env::temp_dir().join("tallfat_test_dataset");
        std::fs::create_dir_all(&dir).unwrap();
        for name in ["s.libsvm", "s.csr"] {
            let spec = InputSpec::auto(dir.join(name).to_string_lossy().into_owned());
            let nnz = gen_sparse_streamed(&spec, 400, 32, 0.05, 11).unwrap();
            let density = nnz as f64 / (400.0 * 32.0);
            assert!((0.02..=0.09).contains(&density), "{name}: density {density}");
            let s = crate::io::read_sparse(&spec).unwrap();
            assert_eq!(s.rows(), 400);
            assert_eq!(s.nnz() as u64, nnz, "{name}");
        }
        // deterministic across calls
        let s1 = InputSpec::auto(dir.join("d1.libsvm").to_string_lossy().into_owned());
        let s2 = InputSpec::auto(dir.join("d2.libsvm").to_string_lossy().into_owned());
        gen_sparse_streamed(&s1, 60, 8, 0.2, 5).unwrap();
        gen_sparse_streamed(&s2, 60, 8, 0.2, 5).unwrap();
        assert_eq!(std::fs::read(&s1.path).unwrap(), std::fs::read(&s2.path).unwrap());
        // dense formats and zero-column outputs rejected
        let bad = InputSpec::csv(dir.join("bad.csv").to_string_lossy().into_owned());
        assert!(gen_sparse_streamed(&bad, 5, 3, 0.5, 1).is_err());
        let z = InputSpec::auto(dir.join("z.scsv").to_string_lossy().into_owned());
        assert!(gen_sparse_streamed(&z, 5, 0, 0.5, 1).is_err());
    }

    #[test]
    fn clustered_shapes_and_labels() {
        let (a, labels) = gen_clustered(30, 5, 3, 0.1, 4);
        assert_eq!(a.shape(), (30, 5));
        assert_eq!(labels.len(), 30);
        assert!(labels.iter().all(|&l| l < 3));
    }

    #[test]
    fn spectrum_shapes() {
        let g = Spectrum::Geometric { scale: 8.0, decay: 0.5 };
        assert_eq!(g.values(3), vec![8.0, 4.0, 2.0]);
        let p = Spectrum::Power { scale: 6.0 };
        assert_eq!(p.value(2), 2.0);
        let l = Spectrum::LowRank { scale: 3.0, r: 2 };
        assert_eq!(l.values(4), vec![3.0, 3.0, 0.0, 0.0]);
    }
}
