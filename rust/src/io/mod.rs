//! Matrix I/O: the paper's `;`-separated CSV, a binary row-major format,
//! sparse inputs (libsvm / sparse-CSV / binary CSR — [`sparse`]), the
//! byte-range chunker (`split_process`'s seek/realign logic), sharded
//! writers, compact byte codecs ([`codec`]: varints + XOR-delta floats,
//! shared by CSR v2 shards and the cluster's reduce frames), and synthetic
//! dataset generators.

pub mod binmat;
pub mod chunker;
pub mod codec;
pub mod csv;
pub mod dataset;
pub mod manifest;
pub mod sparse;
pub mod writer;

pub use binmat::{BinMatHeader, BinMatReader, BinMatWriter};
pub use chunker::{chunk_byte_ranges, chunk_row_ranges, ByteRange};
pub use csv::{parse_row, CsvRowReader};
pub use manifest::KvManifest;
pub use sparse::{CsrHeader, CsrReader, CsrWriter, SparseRowReader, SparseTextReader};
pub use writer::ShardSet;

use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::linalg::{Matrix, SparseMatrix};

/// Reject inputs the multi-pass pipeline cannot re-read: stdin (`-`),
/// FIFOs, sockets, character devices. Every seek-and-rescan entry point
/// (dimension scans, byte-range chunking, row estimation) calls this so a
/// piped input fails with a pointer at the streaming route instead of a
/// confusing I/O error or a garbage row estimate.
pub fn ensure_seekable(path: &str) -> Result<()> {
    if path == "-" {
        return Err(Error::Config(
            "input `-` (stdin) is not seekable — use `tallfat stream`".into(),
        ));
    }
    let meta = std::fs::metadata(path)?;
    if !meta.is_file() {
        return Err(Error::Config(format!(
            "input {path} is not seekable (pipe/FIFO/device?) — use `tallfat stream`"
        )));
    }
    Ok(())
}

/// An input matrix file plus its format — what the splitproc engine reads.
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub path: String,
    pub format: InputFormat,
}

impl InputSpec {
    pub fn csv(path: impl Into<String>) -> Self {
        InputSpec { path: path.into(), format: InputFormat::Csv }
    }

    pub fn bin(path: impl Into<String>) -> Self {
        InputSpec { path: path.into(), format: InputFormat::Bin }
    }

    pub fn libsvm(path: impl Into<String>) -> Self {
        InputSpec { path: path.into(), format: InputFormat::Libsvm }
    }

    pub fn csr(path: impl Into<String>) -> Self {
        InputSpec { path: path.into(), format: InputFormat::Csr }
    }

    pub fn auto(path: impl Into<String>) -> Self {
        let path = path.into();
        let format = InputFormat::from_path(&path);
        InputSpec { path, format }
    }

    /// Count rows and columns by scanning (text formats) or reading the
    /// header (bin/csr). For sparse text formats `cols` is the highest
    /// referenced column + 1.
    pub fn dims(&self) -> Result<(usize, usize)> {
        match self.format {
            InputFormat::Csv => csv::count_dims(&self.path),
            InputFormat::Bin => {
                let h = binmat::BinMatHeader::read_from(&self.path)?;
                Ok((h.rows as usize, h.cols as usize))
            }
            InputFormat::Libsvm | InputFormat::SparseCsv => {
                sparse::count_dims_text(&self.path, self.format)
            }
            InputFormat::Csr => {
                let h = sparse::CsrHeader::read_from(&self.path)?;
                Ok((h.rows as usize, h.cols as usize))
            }
        }
    }
}

/// Read an entire (small) matrix into memory — leader-side and test helper.
/// Sparse inputs densify here (this path is for small matrices only; the
/// streaming passes never call it).
pub fn read_matrix(spec: &InputSpec) -> Result<Matrix> {
    match spec.format {
        InputFormat::Csv => csv::read_matrix_csv(&spec.path),
        InputFormat::Bin => binmat::read_matrix_bin(&spec.path),
        InputFormat::Libsvm | InputFormat::SparseCsv | InputFormat::Csr => {
            Ok(sparse::read_sparse_matrix(&spec.path, spec.format)?.to_dense())
        }
    }
}

/// Read an entire sparse matrix into memory without densifying.
pub fn read_sparse(spec: &InputSpec) -> Result<SparseMatrix> {
    sparse::read_sparse_matrix(&spec.path, spec.format)
}

/// Write a matrix in the given format (dense matrices sparsify losslessly
/// into the sparse formats — exact zeros become absent entries).
pub fn write_matrix(m: &Matrix, spec: &InputSpec) -> Result<()> {
    match spec.format {
        InputFormat::Csv => csv::write_matrix_csv(m, &spec.path),
        InputFormat::Bin => binmat::write_matrix_bin(m, &spec.path),
        InputFormat::Libsvm | InputFormat::SparseCsv | InputFormat::Csr => {
            sparse::write_sparse_matrix(&SparseMatrix::from_dense(m, 0.0), &spec.path, spec.format)
        }
    }
}
