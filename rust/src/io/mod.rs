//! Matrix I/O: the paper's `;`-separated CSV, a binary row-major format,
//! the byte-range chunker (`split_process`'s seek/realign logic), sharded
//! writers, and synthetic dataset generators.

pub mod binmat;
pub mod chunker;
pub mod csv;
pub mod dataset;
pub mod manifest;
pub mod writer;

pub use binmat::{BinMatHeader, BinMatReader, BinMatWriter};
pub use chunker::{chunk_byte_ranges, chunk_row_ranges, ByteRange};
pub use csv::{parse_row, CsvRowReader};
pub use manifest::KvManifest;
pub use writer::ShardSet;

use crate::config::InputFormat;
use crate::error::Result;
use crate::linalg::Matrix;

/// An input matrix file plus its format — what the splitproc engine reads.
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub path: String,
    pub format: InputFormat,
}

impl InputSpec {
    pub fn csv(path: impl Into<String>) -> Self {
        InputSpec { path: path.into(), format: InputFormat::Csv }
    }

    pub fn bin(path: impl Into<String>) -> Self {
        InputSpec { path: path.into(), format: InputFormat::Bin }
    }

    pub fn auto(path: impl Into<String>) -> Self {
        let path = path.into();
        let format = InputFormat::from_path(&path);
        InputSpec { path, format }
    }

    /// Count rows and columns by scanning (CSV) or reading the header (bin).
    pub fn dims(&self) -> Result<(usize, usize)> {
        match self.format {
            InputFormat::Csv => csv::count_dims(&self.path),
            InputFormat::Bin => {
                let h = binmat::BinMatHeader::read_from(&self.path)?;
                Ok((h.rows as usize, h.cols as usize))
            }
        }
    }
}

/// Read an entire (small) matrix into memory — leader-side and test helper.
pub fn read_matrix(spec: &InputSpec) -> Result<Matrix> {
    match spec.format {
        InputFormat::Csv => csv::read_matrix_csv(&spec.path),
        InputFormat::Bin => binmat::read_matrix_bin(&spec.path),
    }
}

/// Write a matrix in the given format.
pub fn write_matrix(m: &Matrix, spec: &InputSpec) -> Result<()> {
    match spec.format {
        InputFormat::Csv => csv::write_matrix_csv(m, &spec.path),
        InputFormat::Bin => binmat::write_matrix_bin(m, &spec.path),
    }
}
