//! The paper's `split_process` partitioning (§3), at arbitrary granularity.
//!
//! For text inputs: divide the file into N byte ranges, then slide each
//! boundary forward to the next newline so no row is split — the
//! `f.seek(s); f.readline(); end = f.tell()-1` logic in the paper's
//! listing, except that a boundary already sitting at a line start is kept
//! as-is (the paper's unconditional skip would donate one extra row to the
//! previous chunk). For binary inputs: exact row-range division (no
//! realignment needed).
//!
//! N is no longer the worker count: the dynamic scheduler
//! ([`crate::splitproc::sched`]) plans many more chunks than workers
//! (`chunks_per_worker`, or a row cap via [`chunk_count_for_rows`]) and
//! feeds them through a work queue.

use crate::error::Result;
use std::fs::File;
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom};

/// A half-open byte range `[start, end)` of an input file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ByteRange {
    pub start: u64,
    pub end: u64,
}

impl ByteRange {
    pub fn len(&self) -> u64 {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.end <= self.start
    }
}

/// Split a text file into at most `n` newline-aligned byte ranges.
///
/// Every byte of the file belongs to exactly one range; ranges never split
/// a line. Fewer than `n` ranges are returned when the file is small enough
/// that some ideal boundaries collapse.
pub fn chunk_byte_ranges(path: &str, n: usize) -> Result<Vec<ByteRange>> {
    assert!(n > 0);
    let file_size = std::fs::metadata(path)?.len();
    if file_size == 0 {
        return Ok(vec![]);
    }
    let mut f = BufReader::new(File::open(path)?);
    let mut boundaries = vec![0u64];
    for i in 1..n {
        let ideal = file_size * i as u64 / n as u64;
        let prev = *boundaries.last().unwrap();
        if ideal <= prev {
            continue;
        }
        // Realign only when the ideal split lands mid-line: if the byte
        // before `ideal` is a newline the boundary already sits at a line
        // start, and the paper's unconditional "skip one line" step would
        // wrongly push a whole extra row into the previous chunk.
        f.seek(SeekFrom::Start(ideal - 1))?;
        let mut before = [0u8; 1];
        f.read_exact(&mut before)?;
        let aligned = if before[0] == b'\n' {
            ideal
        } else {
            let mut skipped = Vec::new();
            f.read_until(b'\n', &mut skipped)?;
            ideal + skipped.len() as u64
        };
        if aligned > prev && aligned < file_size {
            boundaries.push(aligned);
        }
    }
    boundaries.push(file_size);
    Ok(boundaries
        .windows(2)
        .map(|w| ByteRange { start: w[0], end: w[1] })
        .filter(|r| !r.is_empty())
        .collect())
}

/// How many chunks cap each chunk at `chunk_rows` rows (the
/// `RunConfig::chunk_rows` knob; min 1 so empty inputs still plan).
pub fn chunk_count_for_rows(rows: u64, chunk_rows: usize) -> usize {
    assert!(chunk_rows > 0);
    (rows.div_ceil(chunk_rows as u64) as usize).max(1)
}

/// Split `rows` into `n` contiguous row ranges `[start, end)`, balanced to
/// within one row. Used for binary inputs and the simulator.
pub fn chunk_row_ranges(rows: u64, n: usize) -> Vec<(u64, u64)> {
    assert!(n > 0);
    let n = n as u64;
    let base = rows / n;
    let extra = rows % n;
    let mut out = Vec::with_capacity(n as usize);
    let mut start = 0u64;
    for i in 0..n {
        let len = base + if i < extra { 1 } else { 0 };
        if len == 0 {
            continue;
        }
        out.push((start, start + len));
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp_file(name: &str, contents: &str) -> String {
        let dir = std::env::temp_dir().join("tallfat_test_chunker");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut f = File::create(&path).unwrap();
        f.write_all(contents.as_bytes()).unwrap();
        path.to_string_lossy().into_owned()
    }

    fn read_range(path: &str, r: ByteRange) -> String {
        use std::io::Read;
        let mut f = File::open(path).unwrap();
        f.seek(SeekFrom::Start(r.start)).unwrap();
        let mut buf = vec![0u8; r.len() as usize];
        f.read_exact(&mut buf).unwrap();
        String::from_utf8(buf).unwrap()
    }

    #[test]
    fn ranges_cover_file_exactly() {
        let content: String = (0..100).map(|i| format!("{i};{i};{i}\n")).collect();
        let path = tmp_file("cover.csv", &content);
        for n in [1, 2, 3, 4, 7, 16] {
            let ranges = chunk_byte_ranges(&path, n).unwrap();
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, content.len() as u64);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "gap/overlap at n={n}");
            }
        }
    }

    #[test]
    fn no_line_is_split() {
        let content: String = (0..57).map(|i| format!("{};{}\n", i, i * i)).collect();
        let path = tmp_file("nosplit.csv", &content);
        let ranges = chunk_byte_ranges(&path, 4).unwrap();
        let mut total_lines = 0;
        for r in &ranges {
            let text = read_range(&path, *r);
            assert!(text.ends_with('\n') || r.end == content.len() as u64);
            assert!(!text.starts_with(';'));
            // each piece parses as whole lines
            for line in text.lines() {
                let parts: Vec<&str> = line.split(';').collect();
                assert_eq!(parts.len(), 2, "split line: {line:?}");
                total_lines += 1;
            }
        }
        assert_eq!(total_lines, 57);
    }

    #[test]
    fn every_row_seen_exactly_once() {
        let content: String = (0..997).map(|i| format!("{i}\n")).collect();
        let path = tmp_file("once.csv", &content);
        let ranges = chunk_byte_ranges(&path, 8).unwrap();
        let mut seen = vec![false; 997];
        for r in &ranges {
            for line in read_range(&path, *r).lines() {
                let i: usize = line.parse().unwrap();
                assert!(!seen[i], "row {i} seen twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn more_workers_than_lines() {
        let path = tmp_file("tiny.csv", "1;2\n3;4\n");
        let ranges = chunk_byte_ranges(&path, 10).unwrap();
        assert!(ranges.len() <= 2);
        let total: u64 = ranges.iter().map(|r| r.len()).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn empty_file() {
        let path = tmp_file("empty.csv", "");
        assert!(chunk_byte_ranges(&path, 4).unwrap().is_empty());
    }

    #[test]
    fn single_long_line() {
        let path = tmp_file("one.csv", "1;2;3;4;5;6;7;8;9;10\n");
        let ranges = chunk_byte_ranges(&path, 4).unwrap();
        assert_eq!(ranges.len(), 1);
    }

    #[test]
    fn boundary_on_newline_stays_balanced() {
        // 8 fixed-width lines, 4 chunks: every ideal boundary lands exactly
        // on a line start. The old unconditional realignment consumed one
        // whole extra line per boundary (3/2/2/1 instead of 2/2/2/2).
        let content: String = (0..8).map(|i| format!("{i};{i}\n")).collect();
        assert_eq!(content.len() % 4, 0, "fixture must split evenly");
        let path = tmp_file("aligned.csv", &content);
        let ranges = chunk_byte_ranges(&path, 4).unwrap();
        assert_eq!(ranges.len(), 4);
        for (i, r) in ranges.iter().enumerate() {
            let lines = read_range(&path, *r).lines().count();
            assert_eq!(lines, 2, "chunk {i} has {lines} lines: {ranges:?}");
        }
    }

    #[test]
    fn midline_boundary_still_realigns() {
        // Uneven widths: ideal boundaries fall mid-line and must slide
        // forward to the next newline — the paper's original behavior.
        let content = "a_long_first_line;1\nb;2\nc;3\nd;4\ne;5\n";
        let path = tmp_file("midline.csv", content);
        let ranges = chunk_byte_ranges(&path, 3).unwrap();
        let mut total = 0;
        for r in &ranges {
            let text = read_range(&path, *r);
            assert!(text.ends_with('\n'));
            for line in text.lines() {
                assert_eq!(line.split(';').count(), 2, "split line {line:?}");
                total += 1;
            }
        }
        assert_eq!(total, 5);
    }

    #[test]
    fn chunk_count_caps_rows() {
        assert_eq!(chunk_count_for_rows(100, 16), 7);
        assert_eq!(chunk_count_for_rows(16, 16), 1);
        assert_eq!(chunk_count_for_rows(17, 16), 2);
        assert_eq!(chunk_count_for_rows(0, 16), 1);
    }

    #[test]
    fn row_ranges_balanced() {
        let r = chunk_row_ranges(10, 3);
        assert_eq!(r, vec![(0, 4), (4, 7), (7, 10)]);
        let r = chunk_row_ranges(3, 5);
        assert_eq!(r, vec![(0, 1), (1, 2), (2, 3)]);
        assert!(chunk_row_ranges(0, 3).is_empty());
    }
}
