//! Tiny `key=value` text manifests.
//!
//! The same one-fact-per-line format as `artifacts/manifest.txt`, reused by
//! the serve layer's model directories (`model.manifest`). One `key=value`
//! pair per line, `#` comments and blank lines ignored, keys rendered in
//! sorted order so the file is diff-stable. Values must not contain
//! newlines; spaces are preserved.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Display;
use std::path::Path;

/// An ordered `key=value` manifest.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KvManifest {
    map: BTreeMap<String, String>,
}

impl KvManifest {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set a key (any `Display` value).
    pub fn set(&mut self, key: &str, value: impl Display) {
        self.map.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(String::as_str)
    }

    /// Required string value.
    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key)
            .ok_or_else(|| Error::parse(format!("manifest: missing key `{key}`")))
    }

    /// Required `usize` value.
    pub fn require_usize(&self, key: &str) -> Result<usize> {
        self.require(key)?
            .parse()
            .map_err(|_| Error::parse(format!("manifest: `{key}` is not an integer")))
    }

    /// Optional `u64` value.
    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|_| Error::parse(format!("manifest: `{key}` is not an integer"))),
        }
    }

    /// Required bool (`0`/`1`/`true`/`false`).
    pub fn require_bool(&self, key: &str) -> Result<bool> {
        match self.require(key)? {
            "1" | "true" => Ok(true),
            "0" | "false" => Ok(false),
            other => Err(Error::parse(format!("manifest: `{key}`: bad bool `{other}`"))),
        }
    }

    /// Comma-separated list of `usize`.
    pub fn require_usize_list(&self, key: &str) -> Result<Vec<usize>> {
        let raw = self.require(key)?;
        if raw.is_empty() {
            return Ok(Vec::new());
        }
        raw.split(',')
            .map(|t| {
                t.trim()
                    .parse()
                    .map_err(|_| Error::parse(format!("manifest: `{key}`: bad entry `{t}`")))
            })
            .collect()
    }

    /// Parse manifest text.
    pub fn parse_str(text: &str) -> Result<Self> {
        let mut m = KvManifest::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::parse(format!("manifest line {}: expected key=value", lineno + 1))
            })?;
            m.map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(m)
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            Error::Other(format!("cannot read manifest {}: {e}", path.as_ref().display()))
        })?;
        Self::parse_str(&text)
    }

    /// Render as sorted `key=value` lines.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.map {
            out.push_str(k);
            out.push('=');
            out.push_str(v);
            out.push('\n');
        }
        out
    }

    /// Write to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.render())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_text() {
        let mut m = KvManifest::new();
        m.set("m", 1000usize);
        m.set("format", "bin");
        m.set("shard_rows", "300,300,400");
        m.set("centered", 1);
        let back = KvManifest::parse_str(&m.render()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.require_usize("m").unwrap(), 1000);
        assert_eq!(back.require("format").unwrap(), "bin");
        assert_eq!(back.require_usize_list("shard_rows").unwrap(), vec![300, 300, 400]);
        assert!(back.require_bool("centered").unwrap());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let m = KvManifest::parse_str("# header\n\nk = 8\n").unwrap();
        assert_eq!(m.require_usize("k").unwrap(), 8);
    }

    #[test]
    fn missing_and_malformed_error() {
        let m = KvManifest::parse_str("a=1\n").unwrap();
        assert!(m.require("b").is_err());
        assert!(m.require_usize("a").is_ok());
        assert!(KvManifest::parse_str("no_equals_here\n").is_err());
        assert!(m.require_bool("a").is_ok()); // "1" is a valid bool
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("tallfat_test_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.manifest");
        let mut m = KvManifest::new();
        m.set("n", 64usize);
        m.save(&path).unwrap();
        assert_eq!(KvManifest::load(&path).unwrap().require_usize("n").unwrap(), 64);
        assert!(KvManifest::load(dir.join("absent")).is_err());
    }
}
