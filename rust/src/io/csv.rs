//! `;`-separated text rows — the paper's interchange format.

use crate::error::{Error, Result};
use crate::linalg::Matrix;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Seek, SeekFrom, Write};

/// Parse one `;`-separated row into `out`, returning the column count.
/// `out` is cleared first; parsing reuses its capacity (no per-row alloc).
pub fn parse_row(line: &str, out: &mut Vec<f64>) -> Result<usize> {
    parse_row_bytes(line.as_bytes(), out)
}

/// Byte-level row parser — the hot path. Tokenizes on `;` without UTF-8
/// validation of the whole line (tokens are validated individually, and
/// only when handed to the float parser), trims ASCII whitespace in place.
/// Measured ~1.5x the throughput of the `&str`/`split` formulation on the
/// E6 CSV workload (§Perf).
pub fn parse_row_bytes(line: &[u8], out: &mut Vec<f64>) -> Result<usize> {
    out.clear();
    // trim trailing newline / CR / spaces, leading spaces
    let mut end = line.len();
    while end > 0 && matches!(line[end - 1], b'\n' | b'\r' | b' ' | b'\t') {
        end -= 1;
    }
    let mut start = 0;
    while start < end && matches!(line[start], b' ' | b'\t') {
        start += 1;
    }
    if start >= end {
        return Ok(0);
    }
    let mut tok_start = start;
    let bytes = &line[..end];
    loop {
        // find the next ';' (memchr-style scan; LLVM vectorizes this loop)
        let mut i = tok_start;
        while i < end && bytes[i] != b';' {
            i += 1;
        }
        let mut t0 = tok_start;
        let mut t1 = i;
        while t0 < t1 && matches!(bytes[t0], b' ' | b'\t') {
            t0 += 1;
        }
        while t1 > t0 && matches!(bytes[t1 - 1], b' ' | b'\t') {
            t1 -= 1;
        }
        let tok = &bytes[t0..t1];
        let s = std::str::from_utf8(tok)
            .map_err(|_| Error::parse("non-utf8 bytes in csv token".to_string()))?;
        let v: f64 = s
            .parse()
            .map_err(|_| Error::parse(format!("bad float `{s}`")))?;
        out.push(v);
        if i >= end {
            break;
        }
        tok_start = i + 1;
    }
    Ok(out.len())
}

/// Streaming row reader over a byte range of a CSV file.
///
/// Reads `[start, end)` of the file; the range must be newline-aligned
/// (produced by [`crate::io::chunker::chunk_byte_ranges`]).
pub struct CsvRowReader {
    reader: BufReader<File>,
    pos: u64,
    end: u64,
    line_buf: Vec<u8>,
}

impl CsvRowReader {
    /// Open the whole file.
    pub fn open(path: &str) -> Result<Self> {
        let len = std::fs::metadata(path)?.len();
        Self::open_range(path, 0, len)
    }

    /// Open a byte range `[start, end)`.
    pub fn open_range(path: &str, start: u64, end: u64) -> Result<Self> {
        let mut f = File::open(path)?;
        f.seek(SeekFrom::Start(start))?;
        Ok(CsvRowReader {
            reader: BufReader::with_capacity(1 << 20, f),
            pos: start,
            end,
            line_buf: Vec::with_capacity(4096),
        })
    }

    /// Read the next row into `row`. Returns `Ok(false)` at end of range.
    pub fn next_row(&mut self, row: &mut Vec<f64>) -> Result<bool> {
        loop {
            if self.pos >= self.end {
                return Ok(false);
            }
            self.line_buf.clear();
            let n = self.reader.read_until(b'\n', &mut self.line_buf)?;
            if n == 0 {
                return Ok(false);
            }
            self.pos += n as u64;
            if parse_row_bytes(&self.line_buf, row)? > 0 {
                return Ok(true);
            }
            // skip blank lines
        }
    }
}

/// Count `(rows, cols)` of a CSV matrix by scanning once.
pub fn count_dims(path: &str) -> Result<(usize, usize)> {
    let mut reader = CsvRowReader::open(path)?;
    let mut row = Vec::new();
    let mut rows = 0usize;
    let mut cols = 0usize;
    while reader.next_row(&mut row)? {
        if rows == 0 {
            cols = row.len();
        } else if row.len() != cols {
            return Err(Error::parse(format!(
                "ragged csv: row {rows} has {} cols, expected {cols}",
                row.len()
            )));
        }
        rows += 1;
    }
    Ok((rows, cols))
}

/// Read a whole CSV matrix into memory.
pub fn read_matrix_csv(path: &str) -> Result<Matrix> {
    let mut reader = CsvRowReader::open(path)?;
    let mut row = Vec::new();
    let mut data = Vec::new();
    let mut rows = 0usize;
    let mut cols = 0usize;
    while reader.next_row(&mut row)? {
        if rows == 0 {
            cols = row.len();
        } else if row.len() != cols {
            return Err(Error::parse("ragged csv".to_string()));
        }
        data.extend_from_slice(&row);
        rows += 1;
    }
    Matrix::from_vec(rows, cols, data)
}

/// Write a matrix as `;`-separated text (the paper's `%1.6f`-style format,
/// but with full precision to round-trip losslessly).
pub fn write_matrix_csv(m: &Matrix, path: &str) -> Result<()> {
    let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
    for i in 0..m.rows() {
        write_row(&mut w, m.row(i))?;
    }
    w.flush()?;
    Ok(())
}

/// Write one row to an open writer.
pub fn write_row<W: Write>(w: &mut W, row: &[f64]) -> Result<()> {
    let mut first = true;
    for v in row {
        if !first {
            w.write_all(b";")?;
        }
        first = false;
        // Shortest round-trip float formatting.
        let mut buf = String::with_capacity(24);
        {
            use std::fmt::Write as _;
            write!(buf, "{v}").expect("write to String");
        }
        w.write_all(buf.as_bytes())?;
    }
    w.write_all(b"\n")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("tallfat_test_csv");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    #[test]
    fn parse_row_basics() {
        let mut out = Vec::new();
        assert_eq!(parse_row("1.5;2;-3.25\n", &mut out).unwrap(), 3);
        assert_eq!(out, vec![1.5, 2.0, -3.25]);
        assert_eq!(parse_row("\n", &mut out).unwrap(), 0);
        assert!(parse_row("1;x;3", &mut out).is_err());
    }

    #[test]
    fn roundtrip_matrix() {
        let m = Matrix::from_rows(&[
            vec![1.0, -2.5, 3.0e-7],
            vec![0.1 + 0.2, 1e10, -0.0],
        ])
        .unwrap();
        let path = tmp("roundtrip.csv");
        write_matrix_csv(&m, &path).unwrap();
        let back = read_matrix_csv(&path).unwrap();
        assert_eq!(back.shape(), (2, 3));
        assert!(back.max_abs_diff(&m) == 0.0, "lossless roundtrip expected");
    }

    #[test]
    fn count_dims_works() {
        let path = tmp("dims.csv");
        std::fs::write(&path, "1;2;3\n4;5;6\n\n7;8;9\n").unwrap();
        assert_eq!(count_dims(&path).unwrap(), (3, 3));
    }

    #[test]
    fn ragged_rejected() {
        let path = tmp("ragged.csv");
        std::fs::write(&path, "1;2;3\n4;5\n").unwrap();
        assert!(count_dims(&path).is_err());
    }

    #[test]
    fn range_reader_respects_end() {
        let path = tmp("range.csv");
        std::fs::write(&path, "1;1\n2;2\n3;3\n").unwrap();
        // First row is bytes [0,4): "1;1\n"
        let mut r = CsvRowReader::open_range(&path, 0, 4).unwrap();
        let mut row = Vec::new();
        assert!(r.next_row(&mut row).unwrap());
        assert_eq!(row, vec![1.0, 1.0]);
        assert!(!r.next_row(&mut row).unwrap());
    }

    #[test]
    fn windows_line_endings() {
        let path = tmp("crlf.csv");
        std::fs::write(&path, "1;2\r\n3;4\r\n").unwrap();
        let m = read_matrix_csv(&path).unwrap();
        assert_eq!(m.shape(), (2, 2));
        assert_eq!(m.get(1, 1), 4.0);
    }
}
