//! Sharded output writers.
//!
//! The paper's workers write per-chunk outputs (`/tmp/Y-%d.csv`,
//! `/tmp/C-%d.csv`) that the leader merges. [`ShardSet`] names, creates,
//! enumerates, merges, and cleans those shard files.

use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::binmat::{BinMatReader, BinMatWriter, DType};
use crate::io::csv::CsvRowReader;
use crate::linalg::Matrix;
use std::path::{Path, PathBuf};

/// A family of shard files `<dir>/<stem>-<i>.<ext>` (one per worker).
#[derive(Clone, Debug)]
pub struct ShardSet {
    dir: PathBuf,
    stem: String,
    format: InputFormat,
}

impl ShardSet {
    pub fn new(dir: impl AsRef<Path>, stem: &str, format: InputFormat) -> Result<Self> {
        std::fs::create_dir_all(dir.as_ref())?;
        Ok(ShardSet {
            dir: dir.as_ref().to_path_buf(),
            stem: stem.to_string(),
            format,
        })
    }

    pub fn format(&self) -> InputFormat {
        self.format
    }

    /// Path of shard `i`.
    pub fn shard_path(&self, i: usize) -> String {
        let ext = match self.format {
            InputFormat::Csv => "csv",
            InputFormat::Bin => "bin",
        };
        self.dir
            .join(format!("{}-{i}.{ext}", self.stem))
            .to_string_lossy()
            .into_owned()
    }

    /// Open a streaming row writer for shard `i` (binary shards need `cols`).
    pub fn open_writer(&self, i: usize, cols: usize) -> Result<ShardWriter> {
        match self.format {
            InputFormat::Csv => {
                let f = std::fs::File::create(self.shard_path(i))?;
                Ok(ShardWriter::Csv(std::io::BufWriter::with_capacity(1 << 20, f)))
            }
            InputFormat::Bin => Ok(ShardWriter::Bin(BinMatWriter::create(
                &self.shard_path(i),
                cols,
                DType::F64,
            )?)),
        }
    }

    /// Existing shard indices, sorted.
    pub fn existing(&self, max: usize) -> Vec<usize> {
        (0..max)
            .filter(|&i| Path::new(&self.shard_path(i)).exists())
            .collect()
    }

    /// Open a streaming reader over shard `i`.
    pub fn open_reader(&self, i: usize) -> Result<ShardReader> {
        match self.format {
            InputFormat::Csv => Ok(ShardReader::Csv(CsvRowReader::open(&self.shard_path(i))?)),
            InputFormat::Bin => Ok(ShardReader::Bin(BinMatReader::open(&self.shard_path(i))?)),
        }
    }

    /// Concatenate shards `0..n` into one in-memory matrix (row order =
    /// shard order = original row order, since chunks are contiguous).
    pub fn merge_to_matrix(&self, n: usize) -> Result<Matrix> {
        let mut rows: Vec<Vec<f64>> = Vec::new();
        for i in 0..n {
            if !Path::new(&self.shard_path(i)).exists() {
                return Err(Error::Other(format!("missing shard {}", self.shard_path(i))));
            }
            let mut r = self.open_reader(i)?;
            let mut row = Vec::new();
            while r.next_row(&mut row)? {
                rows.push(row.clone());
            }
        }
        Matrix::from_rows(&rows)
    }

    /// Delete shards `0..n` (ignore missing).
    pub fn cleanup(&self, n: usize) {
        for i in 0..n {
            let _ = std::fs::remove_file(self.shard_path(i));
        }
    }
}

/// Row writer over either format.
pub enum ShardWriter {
    Csv(std::io::BufWriter<std::fs::File>),
    Bin(BinMatWriter),
}

impl ShardWriter {
    pub fn write_row(&mut self, row: &[f64]) -> Result<()> {
        match self {
            ShardWriter::Csv(w) => crate::io::csv::write_row(w, row),
            ShardWriter::Bin(w) => w.write_row(row),
        }
    }

    pub fn finish(self) -> Result<()> {
        match self {
            ShardWriter::Csv(mut w) => {
                use std::io::Write;
                w.flush()?;
                Ok(())
            }
            ShardWriter::Bin(w) => {
                w.finish()?;
                Ok(())
            }
        }
    }
}

/// Row reader over either format.
pub enum ShardReader {
    Csv(CsvRowReader),
    Bin(BinMatReader),
}

impl ShardReader {
    pub fn next_row(&mut self, row: &mut Vec<f64>) -> Result<bool> {
        match self {
            ShardReader::Csv(r) => r.next_row(row),
            ShardReader::Bin(r) => r.next_row(row),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("tallfat_test_writer").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn csv_shards_roundtrip() {
        let set = ShardSet::new(tmp_dir("csv"), "Y", InputFormat::Csv).unwrap();
        for i in 0..3 {
            let mut w = set.open_writer(i, 2).unwrap();
            w.write_row(&[i as f64, 1.0]).unwrap();
            w.write_row(&[i as f64, 2.0]).unwrap();
            w.finish().unwrap();
        }
        let merged = set.merge_to_matrix(3).unwrap();
        assert_eq!(merged.shape(), (6, 2));
        assert_eq!(merged.get(4, 0), 2.0);
        assert_eq!(set.existing(5), vec![0, 1, 2]);
        set.cleanup(3);
        assert!(set.existing(5).is_empty());
    }

    #[test]
    fn bin_shards_roundtrip() {
        let set = ShardSet::new(tmp_dir("bin"), "U", InputFormat::Bin).unwrap();
        for i in 0..2 {
            let mut w = set.open_writer(i, 3).unwrap();
            w.write_row(&[i as f64, -1.5, 0.25]).unwrap();
            w.finish().unwrap();
        }
        let merged = set.merge_to_matrix(2).unwrap();
        assert_eq!(merged.shape(), (2, 3));
        assert_eq!(merged.get(1, 2), 0.25);
    }

    #[test]
    fn missing_shard_errors() {
        let set = ShardSet::new(tmp_dir("missing"), "Z", InputFormat::Csv).unwrap();
        assert!(set.merge_to_matrix(1).is_err());
    }
}
