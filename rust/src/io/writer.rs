//! Sharded output writers.
//!
//! The paper's workers write per-chunk outputs (`/tmp/Y-%d.csv`,
//! `/tmp/C-%d.csv`) that the leader merges. [`ShardSet`] names, creates,
//! enumerates, merges, and cleans those shard files.
//!
//! Writes are *staged*: each [`ShardWriter`] streams into a uniquely named
//! `.tmp-*` sibling and atomically renames it over the final path at
//! [`ShardWriter::finish`]. Under the dynamic chunk scheduler the same
//! shard may be produced twice (retry after a partial write, or a
//! speculative duplicate of a straggling chunk); staging makes every
//! publish all-or-nothing, so duplicates — which compute identical bytes —
//! are harmless and a failed attempt never leaves a torn shard behind.

use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::binmat::{BinMatReader, BinMatWriter, DType};
use crate::io::csv::CsvRowReader;
use crate::linalg::Matrix;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Unique suffix for staged shard files: process id plus a process-wide
/// counter (distinct across the threads of one worker; the pid separates
/// concurrent worker processes on a shared filesystem).
fn stage_suffix() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    format!("tmp-{}-{seq}", std::process::id())
}

/// Best-effort removal of leftover `*.tmp-*` staged files under `dir` —
/// the litter of writers whose process was killed before `Drop` could
/// clean up. Call only when no writers can be active in `dir` (e.g. at
/// run start, before any pass).
pub fn sweep_stale_stages(dir: impl AsRef<Path>) {
    let Ok(entries) = std::fs::read_dir(dir.as_ref()) else { return };
    for entry in entries.flatten() {
        let name = entry.file_name();
        if name.to_string_lossy().contains(".tmp-") {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

/// A family of shard files `<dir>/<stem>-<i>.<ext>` (one per worker).
#[derive(Clone, Debug)]
pub struct ShardSet {
    dir: PathBuf,
    stem: String,
    format: InputFormat,
}

impl ShardSet {
    pub fn new(dir: impl AsRef<Path>, stem: &str, format: InputFormat) -> Result<Self> {
        // Shards hold dense k-wide factor rows (Y/U0/U) — a sparse format
        // buys nothing there and the readers below don't speak it.
        if format.is_sparse() {
            return Err(Error::Config(format!(
                "shard format must be csv or bin, got {format:?}"
            )));
        }
        std::fs::create_dir_all(dir.as_ref())?;
        Ok(ShardSet {
            dir: dir.as_ref().to_path_buf(),
            stem: stem.to_string(),
            format,
        })
    }

    pub fn format(&self) -> InputFormat {
        self.format
    }

    /// Path of shard `i`.
    pub fn shard_path(&self, i: usize) -> String {
        let ext = match self.format {
            InputFormat::Csv => "csv",
            // Constructor rejects sparse formats, so everything else is Bin.
            _ => "bin",
        };
        self.dir
            .join(format!("{}-{i}.{ext}", self.stem))
            .to_string_lossy()
            .into_owned()
    }

    /// Open a streaming row writer for shard `i` (binary shards need `cols`).
    /// The writer stages into a `.tmp-*` sibling and renames into place at
    /// `finish()` — see the module docs.
    pub fn open_writer(&self, i: usize, cols: usize) -> Result<ShardWriter> {
        let dst = self.shard_path(i);
        let tmp = format!("{dst}.{}", stage_suffix());
        let inner = match self.format {
            InputFormat::Csv => {
                let f = std::fs::File::create(&tmp)?;
                WriterInner::Csv(std::io::BufWriter::with_capacity(1 << 20, f))
            }
            _ => WriterInner::Bin(BinMatWriter::create(&tmp, cols, DType::F64)?),
        };
        Ok(ShardWriter { inner: Some(inner), tmp, dst })
    }

    /// Existing shard indices, sorted.
    pub fn existing(&self, max: usize) -> Vec<usize> {
        (0..max)
            .filter(|&i| Path::new(&self.shard_path(i)).exists())
            .collect()
    }

    /// Open a streaming reader over shard `i`.
    pub fn open_reader(&self, i: usize) -> Result<ShardReader> {
        match self.format {
            InputFormat::Csv => Ok(ShardReader::Csv(CsvRowReader::open(&self.shard_path(i))?)),
            _ => Ok(ShardReader::Bin(BinMatReader::open(&self.shard_path(i))?)),
        }
    }

    /// Concatenate shards `0..n` into one in-memory matrix (row order =
    /// shard order = original row order, since chunks are contiguous).
    pub fn merge_to_matrix(&self, n: usize) -> Result<Matrix> {
        let mut rows: Vec<Vec<f64>> = Vec::new();
        for i in 0..n {
            if !Path::new(&self.shard_path(i)).exists() {
                return Err(Error::Other(format!("missing shard {}", self.shard_path(i))));
            }
            let mut r = self.open_reader(i)?;
            let mut row = Vec::new();
            while r.next_row(&mut row)? {
                rows.push(row.clone());
            }
        }
        Matrix::from_rows(&rows)
    }

    /// Delete shards `0..n` (ignore missing).
    pub fn cleanup(&self, n: usize) {
        for i in 0..n {
            let _ = std::fs::remove_file(self.shard_path(i));
        }
    }
}

enum WriterInner {
    Csv(std::io::BufWriter<std::fs::File>),
    Bin(BinMatWriter),
}

/// Staged row writer over either format: rows stream into a temp sibling,
/// `finish()` publishes it atomically over the final shard path. Dropping
/// an unfinished writer removes the temp file (best effort) so a failed
/// chunk attempt leaves nothing behind.
pub struct ShardWriter {
    /// `Some` until `finish()` takes it; `None` afterwards (the Drop
    /// cleanup keys off this).
    inner: Option<WriterInner>,
    tmp: String,
    dst: String,
}

impl ShardWriter {
    pub fn write_row(&mut self, row: &[f64]) -> Result<()> {
        // Shard writes are the Encode section of a chunk's
        // decode/compute/encode split; the timing gate is a thread-local
        // check, so untraced runs skip the clock entirely.
        crate::obs::trace::time_section(crate::obs::trace::Section::Encode, || {
            match self.inner.as_mut() {
                Some(WriterInner::Csv(w)) => crate::io::csv::write_row(w, row),
                Some(WriterInner::Bin(w)) => w.write_row(row),
                None => Err(Error::Other("write_row on finished shard writer".into())),
            }
        })
    }

    fn flush_and_publish(&mut self) -> Result<()> {
        match self.inner.take() {
            Some(WriterInner::Csv(mut w)) => {
                use std::io::Write;
                w.flush()?;
            }
            Some(WriterInner::Bin(w)) => {
                w.finish()?;
            }
            None => {}
        }
        std::fs::rename(&self.tmp, &self.dst)?;
        Ok(())
    }

    /// Flush and atomically rename the staged file over the final path.
    pub fn finish(mut self) -> Result<()> {
        let res = crate::obs::trace::time_section(crate::obs::trace::Section::Encode, || {
            self.flush_and_publish()
        });
        if res.is_err() {
            let _ = std::fs::remove_file(&self.tmp);
        }
        res
    }
}

impl Drop for ShardWriter {
    fn drop(&mut self) {
        // Reached with the inner writer still present only when `finish()`
        // was never called (failed attempt): close the handle, then drop
        // the partial staged file so retries and readers never see it.
        if let Some(w) = self.inner.take() {
            drop(w);
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

/// Row reader over either format.
pub enum ShardReader {
    Csv(CsvRowReader),
    Bin(BinMatReader),
}

impl ShardReader {
    pub fn next_row(&mut self, row: &mut Vec<f64>) -> Result<bool> {
        match self {
            ShardReader::Csv(r) => r.next_row(row),
            ShardReader::Bin(r) => r.next_row(row),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("tallfat_test_writer").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn csv_shards_roundtrip() {
        let set = ShardSet::new(tmp_dir("csv"), "Y", InputFormat::Csv).unwrap();
        for i in 0..3 {
            let mut w = set.open_writer(i, 2).unwrap();
            w.write_row(&[i as f64, 1.0]).unwrap();
            w.write_row(&[i as f64, 2.0]).unwrap();
            w.finish().unwrap();
        }
        let merged = set.merge_to_matrix(3).unwrap();
        assert_eq!(merged.shape(), (6, 2));
        assert_eq!(merged.get(4, 0), 2.0);
        assert_eq!(set.existing(5), vec![0, 1, 2]);
        set.cleanup(3);
        assert!(set.existing(5).is_empty());
    }

    #[test]
    fn bin_shards_roundtrip() {
        let set = ShardSet::new(tmp_dir("bin"), "U", InputFormat::Bin).unwrap();
        for i in 0..2 {
            let mut w = set.open_writer(i, 3).unwrap();
            w.write_row(&[i as f64, -1.5, 0.25]).unwrap();
            w.finish().unwrap();
        }
        let merged = set.merge_to_matrix(2).unwrap();
        assert_eq!(merged.shape(), (2, 3));
        assert_eq!(merged.get(1, 2), 0.25);
    }

    #[test]
    fn sparse_shard_format_rejected() {
        for fmt in [InputFormat::Libsvm, InputFormat::SparseCsv, InputFormat::Csr] {
            assert!(ShardSet::new(tmp_dir("sparse"), "Y", fmt).is_err(), "{fmt:?}");
        }
    }

    #[test]
    fn missing_shard_errors() {
        let set = ShardSet::new(tmp_dir("missing"), "Z", InputFormat::Csv).unwrap();
        assert!(set.merge_to_matrix(1).is_err());
    }

    #[test]
    fn unfinished_writer_publishes_nothing() {
        let dir = tmp_dir("staged");
        let set = ShardSet::new(&dir, "Y", InputFormat::Csv).unwrap();
        {
            let mut w = set.open_writer(0, 2).unwrap();
            w.write_row(&[1.0, 2.0]).unwrap();
            // dropped without finish(): a failed chunk attempt
        }
        assert!(set.existing(1).is_empty(), "torn shard visible");
        // No staged litter either.
        let leftovers: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert!(leftovers.is_empty(), "staged temp files left behind");
    }

    #[test]
    fn duplicate_writers_first_writer_wins_cleanly() {
        // Two concurrent attempts at the same shard (speculative duplicate):
        // both stage independently; each finish is an atomic publish of
        // identical content, so readers always see a complete shard.
        let set = ShardSet::new(tmp_dir("dup"), "U", InputFormat::Bin).unwrap();
        let mut a = set.open_writer(0, 2).unwrap();
        let mut b = set.open_writer(0, 2).unwrap();
        a.write_row(&[1.0, 2.0]).unwrap();
        b.write_row(&[1.0, 2.0]).unwrap();
        a.finish().unwrap();
        let first = set.merge_to_matrix(1).unwrap();
        b.finish().unwrap();
        let second = set.merge_to_matrix(1).unwrap();
        assert_eq!(first.shape(), (1, 2));
        assert_eq!(first.max_abs_diff(&second), 0.0);
    }
}
