//! Wire/shard byte codecs: LEB128 varints and XOR-delta `f64` byte
//! suppression.
//!
//! Both the cluster's reduce frames and the CSR v2 shard format ship
//! numeric streams whose neighbors are highly correlated (sorted column
//! indices, smooth factor entries). Two tiny, dependency-free codecs
//! exploit that:
//!
//! * **Varints** ([`write_uvarint`] / [`read_uvarint`]) — LEB128, 7 bits
//!   per byte, for lengths and ascending-index deltas.
//! * **XOR-delta floats** ([`encode_f64s`] / [`decode_f64s`]) — each
//!   value's bits are XORed with the previous value's bits; the XOR of
//!   similar doubles has many leading zero *bytes*, so we emit a
//!   1-byte significant-length prefix followed by only the significant
//!   little-endian bytes (Gorilla-style, byte-granular). Identical
//!   repeated values cost one byte; worst case is 9/8 of raw.
//!
//! Everything here is self-describing and versioned by its container
//! (proto matrix `enc` byte, CSR header version), so readers never guess.

use crate::error::{Error, Result};

/// Append `v` as a LEB128 varint (7 bits per byte, high bit = continue).
pub fn write_uvarint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Read a LEB128 varint from `bytes` at `*pos`, advancing `*pos`.
pub fn read_uvarint(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let byte = *bytes
            .get(*pos)
            .ok_or_else(|| Error::parse("varint truncated"))?;
        *pos += 1;
        if shift >= 64 || (shift == 63 && byte > 1) {
            return Err(Error::parse("varint overflows u64"));
        }
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Append one XOR-delta-coded `f64`: XOR the bits with `*prev`, emit a
/// significant-byte count then only those little-endian bytes, and update
/// `*prev`. Streams decode with [`decode_f64_into`] against the same
/// running `prev` (start both sides at 0).
pub fn encode_f64(buf: &mut Vec<u8>, value: f64, prev: &mut u64) {
    let bits = value.to_bits();
    let x = bits ^ *prev;
    *prev = bits;
    let sig = 8 - (x.leading_zeros() / 8) as usize;
    buf.push(sig as u8);
    buf.extend_from_slice(&x.to_le_bytes()[..sig]);
}

/// Decode one value previously written by [`encode_f64`].
pub fn decode_f64_into(bytes: &[u8], pos: &mut usize, prev: &mut u64) -> Result<f64> {
    let sig = *bytes
        .get(*pos)
        .ok_or_else(|| Error::parse("xor-delta stream truncated"))? as usize;
    *pos += 1;
    if sig > 8 {
        return Err(Error::parse(format!(
            "xor-delta significant-byte count {sig} out of range"
        )));
    }
    let end = *pos + sig;
    if end > bytes.len() {
        return Err(Error::parse("xor-delta stream truncated"));
    }
    let mut raw = [0u8; 8];
    raw[..sig].copy_from_slice(&bytes[*pos..end]);
    *pos = end;
    let bits = u64::from_le_bytes(raw) ^ *prev;
    *prev = bits;
    Ok(f64::from_bits(bits))
}

/// XOR-delta encode a whole slice (running `prev` starts at 0).
pub fn encode_f64s(vals: &[f64]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(vals.len() * 3);
    let mut prev = 0u64;
    for &v in vals {
        encode_f64(&mut buf, v, &mut prev);
    }
    buf
}

/// Decode exactly `count` values from an [`encode_f64s`] stream, erroring
/// on truncation or trailing bytes.
pub fn decode_f64s(bytes: &[u8], count: usize) -> Result<Vec<f64>> {
    let mut out = Vec::with_capacity(count);
    let mut pos = 0usize;
    let mut prev = 0u64;
    for _ in 0..count {
        out.push(decode_f64_into(bytes, &mut pos, &mut prev)?);
    }
    if pos != bytes.len() {
        return Err(Error::parse(format!(
            "xor-delta stream has {} trailing bytes",
            bytes.len() - pos
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uvarint_roundtrip() {
        let cases = [0u64, 1, 127, 128, 300, 16_383, 16_384, u32::MAX as u64, u64::MAX];
        let mut buf = Vec::new();
        for &v in &cases {
            write_uvarint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &cases {
            assert_eq!(read_uvarint(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
        // sizes: 1 byte below 128, 2 below 16384...
        let mut one = Vec::new();
        write_uvarint(&mut one, 127);
        assert_eq!(one.len(), 1);
        one.clear();
        write_uvarint(&mut one, 128);
        assert_eq!(one.len(), 2);
    }

    #[test]
    fn uvarint_truncated_and_overlong() {
        assert!(read_uvarint(&[0x80], &mut 0).is_err());
        assert!(read_uvarint(&[], &mut 0).is_err());
        // 11 continuation bytes can't fit in a u64.
        let overlong = [0xffu8; 11];
        assert!(read_uvarint(&overlong, &mut 0).is_err());
    }

    #[test]
    fn f64_roundtrip_exact_bits() {
        let vals = [
            0.0,
            -0.0,
            1.0,
            1.0000001,
            -3.5e300,
            5e-324,
            f64::INFINITY,
            f64::NEG_INFINITY,
            std::f64::consts::PI,
            std::f64::consts::PI, // repeat: 1 byte
        ];
        let coded = encode_f64s(&vals);
        let back = decode_f64s(&coded, vals.len()).unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn similar_values_compress_identical_values_one_byte() {
        // A smooth ramp: low-order mantissa bytes churn, high bytes agree.
        let vals: Vec<f64> = (0..256).map(|i| 1.0 + i as f64 * 1e-9).collect();
        let coded = encode_f64s(&vals);
        assert!(coded.len() < vals.len() * 8, "{} bytes", coded.len());
        // All-equal stream: first value full, rest 1 byte each.
        let same = vec![42.125f64; 100];
        let coded = encode_f64s(&same);
        assert_eq!(coded.len(), 9 + 99);
        assert_eq!(decode_f64s(&coded, 100).unwrap(), same);
    }

    #[test]
    fn decode_rejects_corruption() {
        let coded = encode_f64s(&[1.0, 2.0, 3.0]);
        assert!(decode_f64s(&coded[..coded.len() - 1], 3).is_err());
        assert!(decode_f64s(&coded, 2).is_err()); // trailing bytes
        let mut bad = coded.clone();
        bad[0] = 9; // sig count out of range is fine (9>8)
        assert!(decode_f64s(&bad, 3).is_err());
    }
}
