//! Sparse matrix I/O: libsvm text, `;`-separated sparse text, and the
//! tallfat binary CSR format (`.csr`).
//!
//! Three interchange formats for tall-and-fat sparse inputs:
//!
//! * **libsvm** (`.libsvm` / `.svm`): `[label] idx:val idx:val ...` per
//!   line, whitespace-separated, **1-based** indices, `#` comments. The
//!   leading label token (any token without a `:`) is ignored — the
//!   pipeline factorizes the feature matrix only. A line holding just a
//!   label is a legitimate all-zero row.
//! * **sparse-CSV** (`.scsv`): `idx:val;idx:val` per line, **0-based**
//!   indices — the paper's `;` idiom, sparsified. Blank lines are skipped,
//!   so this format cannot represent all-zero rows (use libsvm or csr).
//! * **CSR binary** (`.csr`): seekable row ranges without newline
//!   realignment, the sparse sibling of [`crate::io::binmat`]:
//!
//! ```text
//! offset  size        field
//! 0       4           magic "TFSC"
//! 4       4           version (u32 le) = 2 (v1 still readable)
//! 8       8           rows (u64 le)
//! 16      8           cols (u64 le)
//! 24      8           nnz (u64 le)
//! 32      (rows+1)*8  indptr (u64 le each)
//! ...                 row payloads (see below)
//! ```
//!
//! **v2** (written by [`CsrWriter`]): `indptr` holds cumulative payload
//! *byte* offsets — row `r`'s payload spans
//! `[data_start + indptr[r], data_start + indptr[r+1])`. Each payload is
//! delta/varint coded ([`crate::io::codec`]): a varint nonzero count, the
//! ascending indices as varint deltas, then the values XOR-delta coded
//! (the running previous-value resets per row, so any row range decodes
//! standalone). Sorted indices make the deltas small and factor values are
//! smooth, so shards shrink well below the raw 12 bytes/nnz.
//!
//! **v1** (legacy, read-only): `indptr` holds cumulative nonzero *counts*;
//! row `r`'s payload starts at `data_start + indptr[r]*12` as raw
//! `nnz_i * u32` indices then `nnz_i * f64` values.
//!
//! Either way a chunk `[start, end)` of rows opens with two seeks — exact
//! row-range chunking, like the dense binmat.
//!
//! All readers yield **0-based ascending** `u32` indices; the libsvm
//! reader converts from 1-based on the way in.

use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::linalg::SparseMatrix;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};

pub const CSR_MAGIC: &[u8; 4] = b"TFSC";
/// Version written by [`CsrWriter`] (delta/varint row payloads).
pub const CSR_VERSION: u32 = 2;
/// Legacy raw-payload version, still accepted by every reader.
pub const CSR_VERSION_V1: u32 = 1;

/// Bytes per stored nonzero in a **v1** CSR payload (`u32` index + `f64`
/// value); v2 rows are variable-length.
const NNZ_BYTES: u64 = 12;

// ---------------------------------------------------------------------------
// v2 row payload codec (shared with the stream-source CSR reader)
// ---------------------------------------------------------------------------

/// Encode one CSR v2 row payload into `buf` (cleared first): varint
/// nonzero count, ascending indices as varint deltas, values XOR-delta
/// coded with the running previous-value starting at 0.
pub(crate) fn encode_v2_row(buf: &mut Vec<u8>, indices: &[u32], values: &[f64]) {
    buf.clear();
    crate::io::codec::write_uvarint(buf, indices.len() as u64);
    let mut prev = 0u64;
    for (i, &j) in indices.iter().enumerate() {
        let d = if i == 0 { j as u64 } else { j as u64 - prev };
        crate::io::codec::write_uvarint(buf, d);
        prev = j as u64;
    }
    let mut prev_bits = 0u64;
    for &v in values {
        crate::io::codec::encode_f64(buf, v, &mut prev_bits);
    }
}

/// Decode one CSR v2 row payload written by [`encode_v2_row`]. Errors on
/// truncation, trailing bytes, non-ascending indices, or columns at or
/// beyond `cols`.
pub(crate) fn decode_v2_row(
    bytes: &[u8],
    cols: u64,
    indices: &mut Vec<u32>,
    values: &mut Vec<f64>,
) -> Result<()> {
    indices.clear();
    values.clear();
    let mut pos = 0usize;
    let k = crate::io::codec::read_uvarint(bytes, &mut pos)? as usize;
    let mut prev = 0u64;
    for i in 0..k {
        let d = crate::io::codec::read_uvarint(bytes, &mut pos)?;
        if i > 0 && d == 0 {
            return Err(Error::parse(
                "csr: indices not ascending within a row".to_string(),
            ));
        }
        let j = if i == 0 { d } else { prev.saturating_add(d) };
        if j >= cols || j > u32::MAX as u64 {
            return Err(Error::parse(format!("csr: column {j} out of range ({cols})")));
        }
        prev = j;
        indices.push(j as u32);
    }
    let mut prev_bits = 0u64;
    for _ in 0..k {
        values.push(crate::io::codec::decode_f64_into(bytes, &mut pos, &mut prev_bits)?);
    }
    if pos != bytes.len() {
        return Err(Error::parse(format!(
            "csr: row payload has {} trailing bytes",
            bytes.len() - pos
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// text row parsing
// ---------------------------------------------------------------------------

/// Parse one libsvm line into `(indices, values)` (0-based on output).
/// Returns `Ok(false)` when the line is blank or comment-only (not a row).
/// A bare label with no pairs is a valid all-zero row (`Ok(true)`, empty).
pub fn parse_libsvm_row(
    line: &[u8],
    indices: &mut Vec<u32>,
    values: &mut Vec<f64>,
) -> Result<bool> {
    indices.clear();
    values.clear();
    let line = strip_comment(line);
    let text = std::str::from_utf8(line)
        .map_err(|_| Error::parse("libsvm: non-utf8 line".to_string()))?;
    let mut saw_token = false;
    let mut last: Option<u32> = None;
    for (t, tok) in text.split_ascii_whitespace().enumerate() {
        saw_token = true;
        let Some((key, val)) = tok.split_once(':') else {
            if t == 0 {
                continue; // leading label, ignored
            }
            return Err(Error::parse(format!("libsvm: bare token `{tok}` after features")));
        };
        if key == "qid" {
            continue; // ranking qualifier, ignored
        }
        let idx: u64 = key
            .parse()
            .map_err(|_| Error::parse(format!("libsvm: bad index `{key}`")))?;
        if idx == 0 {
            return Err(Error::parse("libsvm: index 0 in a 1-based file".to_string()));
        }
        if idx > u32::MAX as u64 {
            return Err(Error::parse(format!("libsvm: index {idx} exceeds u32")));
        }
        let idx = (idx - 1) as u32;
        if let Some(prev) = last {
            if idx <= prev {
                return Err(Error::parse(format!(
                    "libsvm: indices not ascending ({} then {})",
                    prev + 1,
                    idx + 1
                )));
            }
        }
        last = Some(idx);
        let v: f64 = val
            .parse()
            .map_err(|_| Error::parse(format!("libsvm: bad value `{val}`")))?;
        indices.push(idx);
        values.push(v);
    }
    Ok(saw_token)
}

/// Parse one sparse-CSV line (`idx:val;idx:val`, 0-based). Returns
/// `Ok(false)` for blank lines.
pub fn parse_sparse_csv_row(
    line: &[u8],
    indices: &mut Vec<u32>,
    values: &mut Vec<f64>,
) -> Result<bool> {
    indices.clear();
    values.clear();
    let text = std::str::from_utf8(line)
        .map_err(|_| Error::parse("scsv: non-utf8 line".to_string()))?
        .trim();
    if text.is_empty() {
        return Ok(false);
    }
    let mut last: Option<u32> = None;
    for tok in text.split(';') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let Some((key, val)) = tok.split_once(':') else {
            return Err(Error::parse(format!("scsv: token `{tok}` is not idx:val")));
        };
        let idx: u32 = key
            .trim()
            .parse()
            .map_err(|_| Error::parse(format!("scsv: bad index `{key}`")))?;
        if let Some(prev) = last {
            if idx <= prev {
                return Err(Error::parse(format!(
                    "scsv: indices not ascending ({prev} then {idx})"
                )));
            }
        }
        last = Some(idx);
        let v: f64 = val
            .trim()
            .parse()
            .map_err(|_| Error::parse(format!("scsv: bad value `{val}`")))?;
        indices.push(idx);
        values.push(v);
    }
    Ok(true)
}

fn strip_comment(line: &[u8]) -> &[u8] {
    match line.iter().position(|&b| b == b'#') {
        Some(p) => &line[..p],
        None => line,
    }
}

// ---------------------------------------------------------------------------
// streaming readers
// ---------------------------------------------------------------------------

/// Streaming sparse-row reader over a newline-aligned byte range of a
/// libsvm or sparse-CSV file (the sparse sibling of
/// [`crate::io::csv::CsvRowReader`]).
pub struct SparseTextReader {
    reader: BufReader<File>,
    format: InputFormat,
    pos: u64,
    end: u64,
    line_buf: Vec<u8>,
}

impl SparseTextReader {
    pub fn open(path: &str, format: InputFormat) -> Result<Self> {
        let len = std::fs::metadata(path)?.len();
        Self::open_range(path, format, 0, len)
    }

    /// Open a byte range `[start, end)` (must be newline-aligned).
    pub fn open_range(path: &str, format: InputFormat, start: u64, end: u64) -> Result<Self> {
        if !matches!(format, InputFormat::Libsvm | InputFormat::SparseCsv) {
            return Err(Error::Config(format!(
                "SparseTextReader: {format:?} is not a sparse text format"
            )));
        }
        let mut f = File::open(path)?;
        f.seek(SeekFrom::Start(start))?;
        Ok(SparseTextReader {
            reader: BufReader::with_capacity(1 << 20, f),
            format,
            pos: start,
            end,
            line_buf: Vec::with_capacity(4096),
        })
    }

    /// Read the next row. Returns `Ok(false)` at end of range.
    pub fn next_row(&mut self, indices: &mut Vec<u32>, values: &mut Vec<f64>) -> Result<bool> {
        loop {
            if self.pos >= self.end {
                return Ok(false);
            }
            self.line_buf.clear();
            let n = self.reader.read_until(b'\n', &mut self.line_buf)?;
            if n == 0 {
                return Ok(false);
            }
            self.pos += n as u64;
            let is_row = match self.format {
                InputFormat::Libsvm => parse_libsvm_row(&self.line_buf, indices, values)?,
                _ => parse_sparse_csv_row(&self.line_buf, indices, values)?,
            };
            if is_row {
                return Ok(true);
            }
            // skip blank / comment-only lines
        }
    }
}

/// Parsed CSR header.
#[derive(Clone, Copy, Debug)]
pub struct CsrHeader {
    /// Format version (1 = raw payloads, 2 = delta/varint payloads).
    pub version: u32,
    pub rows: u64,
    pub cols: u64,
    pub nnz: u64,
}

impl CsrHeader {
    pub const SIZE: u64 = 32;

    pub fn read_from(path: &str) -> Result<Self> {
        let mut f = File::open(path)?;
        let mut buf = [0u8; Self::SIZE as usize];
        f.read_exact(&mut buf)?;
        if &buf[0..4] != CSR_MAGIC {
            return Err(Error::parse("csr: bad magic"));
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
        if version != CSR_VERSION && version != CSR_VERSION_V1 {
            return Err(Error::parse(format!("csr: unsupported version {version}")));
        }
        Ok(CsrHeader {
            version,
            rows: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            cols: u64::from_le_bytes(buf[16..24].try_into().unwrap()),
            nnz: u64::from_le_bytes(buf[24..32].try_into().unwrap()),
        })
    }

    fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        let mut buf = [0u8; Self::SIZE as usize];
        buf[0..4].copy_from_slice(CSR_MAGIC);
        buf[4..8].copy_from_slice(&self.version.to_le_bytes());
        buf[8..16].copy_from_slice(&self.rows.to_le_bytes());
        buf[16..24].copy_from_slice(&self.cols.to_le_bytes());
        buf[24..32].copy_from_slice(&self.nnz.to_le_bytes());
        w.write_all(&buf)?;
        Ok(())
    }

    /// Byte offset where the row payload region begins.
    fn data_start(&self) -> u64 {
        Self::SIZE + (self.rows + 1) * 8
    }
}

/// Streaming CSR writer (always emits **v2**). The row count must be
/// declared up front (the indptr region is reserved before the payload);
/// rows append in order and `finish` back-fills nnz + indptr. Memory is
/// `O(rows)` for the indptr, never `O(nnz)`.
pub struct CsrWriter {
    w: BufWriter<File>,
    rows_declared: u64,
    cols: u64,
    /// Cumulative payload byte offsets (v2 indptr semantics).
    indptr: Vec<u64>,
    nnz: u64,
    bytes: u64,
    row_buf: Vec<u8>,
}

impl CsrWriter {
    pub fn create(path: &str, rows: usize, cols: usize) -> Result<Self> {
        let f = File::create(path)?;
        let mut w = BufWriter::with_capacity(1 << 20, f);
        let header =
            CsrHeader { version: CSR_VERSION, rows: rows as u64, cols: cols as u64, nnz: 0 };
        header.write_to(&mut w)?;
        // Reserve the indptr region (back-filled at finish).
        let zeros = vec![0u8; 1 << 12];
        let mut remaining = (rows as u64 + 1) * 8;
        while remaining > 0 {
            let take = (zeros.len() as u64).min(remaining) as usize;
            w.write_all(&zeros[..take])?;
            remaining -= take as u64;
        }
        Ok(CsrWriter {
            w,
            rows_declared: rows as u64,
            cols: cols as u64,
            indptr: vec![0],
            nnz: 0,
            bytes: 0,
            row_buf: Vec::new(),
        })
    }

    /// Append one row's nonzeros (0-based ascending indices).
    pub fn write_row(&mut self, indices: &[u32], values: &[f64]) -> Result<()> {
        if indices.len() != values.len() {
            return Err(Error::shape("csr write_row: indices/values length mismatch"));
        }
        if self.indptr.len() as u64 > self.rows_declared {
            return Err(Error::shape(format!(
                "csr write_row: more than the declared {} rows",
                self.rows_declared
            )));
        }
        let mut last: Option<u32> = None;
        for &j in indices {
            if j as u64 >= self.cols {
                return Err(Error::shape(format!(
                    "csr write_row: column {j} out of range ({})",
                    self.cols
                )));
            }
            if let Some(prev) = last {
                if j <= prev {
                    return Err(Error::parse("csr write_row: indices not ascending".into()));
                }
            }
            last = Some(j);
        }
        let mut row_buf = std::mem::take(&mut self.row_buf);
        encode_v2_row(&mut row_buf, indices, values);
        self.w.write_all(&row_buf)?;
        self.bytes += row_buf.len() as u64;
        self.row_buf = row_buf;
        self.nnz += indices.len() as u64;
        self.indptr.push(self.bytes);
        Ok(())
    }

    pub fn finish(mut self) -> Result<u64> {
        if self.indptr.len() as u64 != self.rows_declared + 1 {
            return Err(Error::shape(format!(
                "csr finish: {} rows written, {} declared",
                self.indptr.len() - 1,
                self.rows_declared
            )));
        }
        self.w.flush()?;
        let mut f = self.w.into_inner().map_err(|e| Error::Other(e.to_string()))?;
        f.seek(SeekFrom::Start(0))?;
        CsrHeader {
            version: CSR_VERSION,
            rows: self.rows_declared,
            cols: self.cols,
            nnz: self.nnz,
        }
        .write_to(&mut f)?;
        let mut buf = Vec::with_capacity(self.indptr.len() * 8);
        for &p in &self.indptr {
            buf.extend_from_slice(&p.to_le_bytes());
        }
        f.write_all(&buf)?;
        f.sync_all()?;
        Ok(self.rows_declared)
    }
}

/// Streaming CSR reader over a row range.
pub struct CsrReader {
    r: BufReader<File>,
    header: CsrHeader,
    /// indptr entries for rows `[start, end]` inclusive of the end fence.
    indptr: Vec<u64>,
    next: usize,
    /// Reusable raw-byte buffer (no per-row allocation on the hot path —
    /// the binmat reader's `byte_buf` discipline).
    byte_buf: Vec<u8>,
}

impl CsrReader {
    pub fn open(path: &str) -> Result<Self> {
        let header = CsrHeader::read_from(path)?;
        Self::open_rows(path, 0, header.rows)
    }

    /// Open rows `[start, end)`.
    pub fn open_rows(path: &str, start: u64, end: u64) -> Result<Self> {
        let header = CsrHeader::read_from(path)?;
        let end = end.min(header.rows);
        let start = start.min(end);
        let mut f = File::open(path)?;
        // indptr[start ..= end]
        f.seek(SeekFrom::Start(CsrHeader::SIZE + start * 8))?;
        let fence_count = (end - start + 1) as usize;
        let mut raw = vec![0u8; fence_count * 8];
        f.read_exact(&mut raw)?;
        let indptr: Vec<u64> = raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        for w in indptr.windows(2) {
            if w[1] < w[0] {
                return Err(Error::parse("csr: indptr not monotone".into()));
            }
        }
        if header.version == CSR_VERSION_V1 {
            // v1 indptr counts nonzeros, so nnz bounds it; v2 counts
            // payload bytes, which have no such invariant to check.
            if let Some(&last) = indptr.last() {
                if last > header.nnz {
                    return Err(Error::parse("csr: indptr exceeds nnz".into()));
                }
            }
        }
        let first_offset = match header.version {
            CSR_VERSION_V1 => indptr[0] * NNZ_BYTES,
            _ => indptr[0],
        };
        f.seek(SeekFrom::Start(header.data_start() + first_offset))?;
        Ok(CsrReader {
            r: BufReader::with_capacity(1 << 20, f),
            header,
            indptr,
            next: 0,
            byte_buf: Vec::new(),
        })
    }

    pub fn header(&self) -> &CsrHeader {
        &self.header
    }

    /// Read the next row's nonzeros. Returns `Ok(false)` at end of range.
    pub fn next_row(&mut self, indices: &mut Vec<u32>, values: &mut Vec<f64>) -> Result<bool> {
        if self.next + 1 >= self.indptr.len() {
            return Ok(false);
        }
        if self.header.version != CSR_VERSION_V1 {
            let nbytes = (self.indptr[self.next + 1] - self.indptr[self.next]) as usize;
            self.byte_buf.resize(nbytes, 0);
            self.r.read_exact(&mut self.byte_buf)?;
            decode_v2_row(&self.byte_buf, self.header.cols, indices, values)?;
            self.next += 1;
            return Ok(true);
        }
        let k = (self.indptr[self.next + 1] - self.indptr[self.next]) as usize;
        indices.clear();
        values.clear();
        self.byte_buf.resize(k * 4, 0);
        self.r.read_exact(&mut self.byte_buf)?;
        let mut last: Option<u32> = None;
        for c in self.byte_buf.chunks_exact(4) {
            let j = u32::from_le_bytes(c.try_into().unwrap());
            if j as u64 >= self.header.cols {
                return Err(Error::parse(format!(
                    "csr: column {j} out of range ({})",
                    self.header.cols
                )));
            }
            // The reader contract promises ascending duplicate-free
            // indices; a corrupt/foreign file must error, not silently
            // miscompute downstream cursor walks.
            if let Some(prev) = last {
                if j <= prev {
                    return Err(Error::parse(format!(
                        "csr: indices not ascending within a row ({prev} then {j})"
                    )));
                }
            }
            last = Some(j);
            indices.push(j);
        }
        self.byte_buf.resize(k * 8, 0);
        self.r.read_exact(&mut self.byte_buf)?;
        for c in self.byte_buf.chunks_exact(8) {
            values.push(f64::from_le_bytes(c.try_into().unwrap()));
        }
        self.next += 1;
        Ok(true)
    }
}

/// Row reader over any sparse input format (the facade
/// [`crate::splitproc::run_chunk_sparse`] streams through).
pub enum SparseRowReader {
    Text(SparseTextReader),
    Csr(CsrReader),
}

impl SparseRowReader {
    pub fn next_row(&mut self, indices: &mut Vec<u32>, values: &mut Vec<f64>) -> Result<bool> {
        match self {
            SparseRowReader::Text(r) => r.next_row(indices, values),
            SparseRowReader::Csr(r) => r.next_row(indices, values),
        }
    }
}

// ---------------------------------------------------------------------------
// dims + whole-matrix helpers
// ---------------------------------------------------------------------------

/// Count `(rows, cols)` of a sparse text matrix by scanning once. `cols` is
/// the highest referenced column + 1 (0-based internal indexing).
pub fn count_dims_text(path: &str, format: InputFormat) -> Result<(usize, usize)> {
    let mut reader = SparseTextReader::open(path, format)?;
    let mut indices = Vec::new();
    let mut values = Vec::new();
    let mut rows = 0usize;
    let mut cols = 0usize;
    while reader.next_row(&mut indices, &mut values)? {
        if let Some(&last) = indices.last() {
            cols = cols.max(last as usize + 1);
        }
        rows += 1;
    }
    Ok((rows, cols))
}

/// Read a whole sparse matrix into memory (leader-side and test helper).
pub fn read_sparse_matrix(path: &str, format: InputFormat) -> Result<SparseMatrix> {
    match format {
        InputFormat::Csr => {
            let mut r = CsrReader::open(path)?;
            let (rows, cols) = (r.header().rows as usize, r.header().cols as usize);
            let mut s = SparseMatrix::with_cols(cols);
            let mut indices = Vec::new();
            let mut values = Vec::new();
            for _ in 0..rows {
                if !r.next_row(&mut indices, &mut values)? {
                    return Err(Error::parse("csr: fewer rows than the header claims".into()));
                }
                s.push_row(&indices, &values)?;
            }
            Ok(s)
        }
        InputFormat::Libsvm | InputFormat::SparseCsv => {
            let (_, cols) = count_dims_text(path, format)?;
            let mut r = SparseTextReader::open(path, format)?;
            let mut s = SparseMatrix::with_cols(cols);
            let mut indices = Vec::new();
            let mut values = Vec::new();
            while r.next_row(&mut indices, &mut values)? {
                s.push_row(&indices, &values)?;
            }
            Ok(s)
        }
        other => Err(Error::Config(format!(
            "read_sparse_matrix: {other:?} is not a sparse format"
        ))),
    }
}

/// Write a sparse matrix in the given sparse format. SparseCsv rejects
/// all-zero rows (a blank line would be skipped on read — silent row loss).
pub fn write_sparse_matrix(s: &SparseMatrix, path: &str, format: InputFormat) -> Result<()> {
    match format {
        InputFormat::Csr => {
            let mut w = CsrWriter::create(path, s.rows(), s.cols())?;
            for i in 0..s.rows() {
                let (idx, val) = s.row(i);
                w.write_row(idx, val)?;
            }
            w.finish()?;
            Ok(())
        }
        InputFormat::Libsvm => {
            let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
            for i in 0..s.rows() {
                let (idx, val) = s.row(i);
                write_libsvm_row(&mut w, idx, val)?;
            }
            w.flush()?;
            Ok(())
        }
        InputFormat::SparseCsv => {
            let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
            for i in 0..s.rows() {
                let (idx, val) = s.row(i);
                if idx.is_empty() {
                    return Err(Error::Config(format!(
                        "sparse-csv cannot represent the all-zero row {i} \
                         (use libsvm or csr)"
                    )));
                }
                write_scsv_row(&mut w, idx, val)?;
            }
            w.flush()?;
            Ok(())
        }
        other => Err(Error::Config(format!(
            "write_sparse_matrix: {other:?} is not a sparse format"
        ))),
    }
}

/// Write one libsvm row (`0` placeholder label, 1-based indices).
pub fn write_libsvm_row<W: Write>(w: &mut W, indices: &[u32], values: &[f64]) -> Result<()> {
    w.write_all(b"0")?;
    for (&j, &v) in indices.iter().zip(values.iter()) {
        write!(w, " {}:{v}", j as u64 + 1).map_err(Error::Io)?;
    }
    w.write_all(b"\n")?;
    Ok(())
}

/// Write one sparse-CSV row (`idx:val;idx:val`, 0-based) — the single
/// definition of the scsv line format, shared by every writer.
pub fn write_scsv_row<W: Write>(w: &mut W, indices: &[u32], values: &[f64]) -> Result<()> {
    let mut first = true;
    for (&j, &v) in indices.iter().zip(values.iter()) {
        if !first {
            w.write_all(b";")?;
        }
        first = false;
        write!(w, "{j}:{v}").map_err(Error::Io)?;
    }
    w.write_all(b"\n")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("tallfat_test_sparse_io");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    fn fixture() -> SparseMatrix {
        let m = Matrix::from_rows(&[
            vec![1.5, 0.0, 0.0, -2.0],
            vec![0.0, 0.0, 0.0, 0.0],
            vec![0.0, 3.0, 0.5, 0.0],
            vec![4.0, 0.0, 0.0, 0.0],
        ])
        .unwrap();
        SparseMatrix::from_dense(&m, 0.0)
    }

    #[test]
    fn libsvm_roundtrip_including_zero_rows() {
        let s = fixture();
        let path = tmp("rt.libsvm");
        write_sparse_matrix(&s, &path, InputFormat::Libsvm).unwrap();
        let back = read_sparse_matrix(&path, InputFormat::Libsvm).unwrap();
        assert_eq!(back.rows(), 4);
        assert_eq!(back.to_dense(), s.to_dense());
        assert_eq!(count_dims_text(&path, InputFormat::Libsvm).unwrap(), (4, 4));
    }

    #[test]
    fn libsvm_parses_labels_comments_and_qid() {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        assert!(parse_libsvm_row(b"+1 qid:7 3:1.5 10:-2 # note\n", &mut idx, &mut val).unwrap());
        assert_eq!(idx, vec![2, 9]);
        assert_eq!(val, vec![1.5, -2.0]);
        // bare label = all-zero row
        assert!(parse_libsvm_row(b"0\n", &mut idx, &mut val).unwrap());
        assert!(idx.is_empty());
        // blank and comment-only lines are not rows
        assert!(!parse_libsvm_row(b"\n", &mut idx, &mut val).unwrap());
        assert!(!parse_libsvm_row(b"# header\n", &mut idx, &mut val).unwrap());
        // 1-based: index 0 rejected; descending rejected
        assert!(parse_libsvm_row(b"1 0:2.0\n", &mut idx, &mut val).is_err());
        assert!(parse_libsvm_row(b"1 5:1 3:1\n", &mut idx, &mut val).is_err());
        assert!(parse_libsvm_row(b"1 3:x\n", &mut idx, &mut val).is_err());
    }

    #[test]
    fn scsv_roundtrip_and_rejects_zero_rows() {
        let mut s = SparseMatrix::with_cols(5);
        s.push_row(&[0, 4], &[1.25, -3.0]).unwrap();
        s.push_row(&[2], &[0.5]).unwrap();
        let path = tmp("rt.scsv");
        write_sparse_matrix(&s, &path, InputFormat::SparseCsv).unwrap();
        let back = read_sparse_matrix(&path, InputFormat::SparseCsv).unwrap();
        assert_eq!(back.to_dense(), s.to_dense());
        // all-zero rows are unrepresentable
        let z = fixture();
        assert!(write_sparse_matrix(&z, &tmp("zero.scsv"), InputFormat::SparseCsv).is_err());
    }

    #[test]
    fn scsv_parse_basics() {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        assert!(parse_sparse_csv_row(b"0:1.5; 3:-2\n", &mut idx, &mut val).unwrap());
        assert_eq!(idx, vec![0, 3]);
        assert_eq!(val, vec![1.5, -2.0]);
        assert!(!parse_sparse_csv_row(b"  \n", &mut idx, &mut val).unwrap());
        assert!(parse_sparse_csv_row(b"3:1;1:2\n", &mut idx, &mut val).is_err());
        assert!(parse_sparse_csv_row(b"1.5;2\n", &mut idx, &mut val).is_err());
    }

    #[test]
    fn csr_roundtrip_and_header() {
        let s = fixture();
        let path = tmp("rt.csr");
        write_sparse_matrix(&s, &path, InputFormat::Csr).unwrap();
        let h = CsrHeader::read_from(&path).unwrap();
        assert_eq!((h.rows, h.cols, h.nnz), (4, 4, 5));
        let back = read_sparse_matrix(&path, InputFormat::Csr).unwrap();
        assert_eq!(back.to_dense(), s.to_dense());
    }

    #[test]
    fn csr_row_range_reading() {
        let s = fixture();
        let path = tmp("range.csr");
        write_sparse_matrix(&s, &path, InputFormat::Csr).unwrap();
        let mut r = CsrReader::open_rows(&path, 2, 4).unwrap();
        let mut idx = Vec::new();
        let mut val = Vec::new();
        assert!(r.next_row(&mut idx, &mut val).unwrap());
        assert_eq!(idx, vec![1, 2]);
        assert_eq!(val, vec![3.0, 0.5]);
        assert!(r.next_row(&mut idx, &mut val).unwrap());
        assert_eq!(idx, vec![0]);
        assert!(!r.next_row(&mut idx, &mut val).unwrap());
        // empty range
        let mut r = CsrReader::open_rows(&path, 4, 4).unwrap();
        assert!(!r.next_row(&mut idx, &mut val).unwrap());
    }

    #[test]
    fn csr_writer_enforces_declared_rows() {
        let path = tmp("strict.csr");
        let mut w = CsrWriter::create(&path, 2, 3).unwrap();
        w.write_row(&[1], &[1.0]).unwrap();
        // finishing early is an error
        assert!(w.finish().is_err());
        let mut w = CsrWriter::create(&path, 1, 3).unwrap();
        w.write_row(&[0], &[1.0]).unwrap();
        assert!(w.write_row(&[1], &[1.0]).is_err(), "over-declared rows");
        assert!(CsrWriter::create(&tmp("v.csr"), 1, 2)
            .unwrap()
            .write_row(&[5], &[1.0])
            .is_err());
    }

    #[test]
    fn csr_non_ascending_row_rejected() {
        // Hand-craft a corrupt v1 file whose one row stores indices
        // [3, 1] — the reader must error, not silently feed a descending
        // row to cursor-walking consumers.
        let path = tmp("desc.csr");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(CSR_MAGIC);
        bytes.extend_from_slice(&CSR_VERSION_V1.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes()); // rows
        bytes.extend_from_slice(&4u64.to_le_bytes()); // cols
        bytes.extend_from_slice(&2u64.to_le_bytes()); // nnz
        bytes.extend_from_slice(&0u64.to_le_bytes()); // indptr[0]
        bytes.extend_from_slice(&2u64.to_le_bytes()); // indptr[1]
        bytes.extend_from_slice(&3u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1.0f64.to_le_bytes());
        bytes.extend_from_slice(&1.0f64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let mut r = CsrReader::open(&path).unwrap();
        let (mut idx, mut val) = (Vec::new(), Vec::new());
        let err = r.next_row(&mut idx, &mut val).unwrap_err().to_string();
        assert!(err.contains("ascending"), "{err}");
        // The same corruption in a v2 payload (second delta 0) also errors.
        let mut buf = Vec::new();
        crate::io::codec::write_uvarint(&mut buf, 2);
        crate::io::codec::write_uvarint(&mut buf, 3);
        crate::io::codec::write_uvarint(&mut buf, 0); // delta 0 = duplicate
        let mut bits = 0u64;
        crate::io::codec::encode_f64(&mut buf, 1.0, &mut bits);
        crate::io::codec::encode_f64(&mut buf, 1.0, &mut bits);
        let err = decode_v2_row(&buf, 4, &mut idx, &mut val).unwrap_err().to_string();
        assert!(err.contains("ascending"), "{err}");
    }

    #[test]
    fn csr_v1_legacy_files_still_read() {
        // Hand-craft a well-formed v1 file (nnz-count indptr, raw
        // payloads) and check the reader decodes it — including a row
        // range, which exercises the v1 byte-offset arithmetic.
        let path = tmp("legacy.csr");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(CSR_MAGIC);
        bytes.extend_from_slice(&CSR_VERSION_V1.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes()); // rows
        bytes.extend_from_slice(&5u64.to_le_bytes()); // cols
        bytes.extend_from_slice(&3u64.to_le_bytes()); // nnz
        bytes.extend_from_slice(&0u64.to_le_bytes()); // indptr[0]
        bytes.extend_from_slice(&2u64.to_le_bytes()); // indptr[1]
        bytes.extend_from_slice(&3u64.to_le_bytes()); // indptr[2]
        // row 0: (1, 1.5), (4, -2.0)
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&4u32.to_le_bytes());
        bytes.extend_from_slice(&1.5f64.to_le_bytes());
        bytes.extend_from_slice(&(-2.0f64).to_le_bytes());
        // row 1: (0, 7.0)
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&7.0f64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let h = CsrHeader::read_from(&path).unwrap();
        assert_eq!(h.version, CSR_VERSION_V1);
        let mut r = CsrReader::open(&path).unwrap();
        let (mut idx, mut val) = (Vec::new(), Vec::new());
        assert!(r.next_row(&mut idx, &mut val).unwrap());
        assert_eq!(idx, vec![1, 4]);
        assert_eq!(val, vec![1.5, -2.0]);
        assert!(r.next_row(&mut idx, &mut val).unwrap());
        assert_eq!(idx, vec![0]);
        assert_eq!(val, vec![7.0]);
        assert!(!r.next_row(&mut idx, &mut val).unwrap());
        // row range skipping row 0 must seek by v1 (count * 12) offsets
        let mut r = CsrReader::open_rows(&path, 1, 2).unwrap();
        assert!(r.next_row(&mut idx, &mut val).unwrap());
        assert_eq!(idx, vec![0]);
        assert_eq!(val, vec![7.0]);
    }

    #[test]
    fn csr_v2_written_and_smaller_than_raw() {
        // The writer emits v2, and delta/varint coding beats the raw
        // 12 bytes/nnz payload on a clustered-index matrix.
        let mut s = SparseMatrix::with_cols(1000);
        for i in 0..200 {
            let base = (i * 3) as u32 % 900;
            let idx = [base, base + 1, base + 2, base + 7];
            let v = 0.001 * i as f64;
            s.push_row(&idx, &[v, v, v, v]).unwrap();
        }
        let path = tmp("v2size.csr");
        write_sparse_matrix(&s, &path, InputFormat::Csr).unwrap();
        let h = CsrHeader::read_from(&path).unwrap();
        assert_eq!(h.version, CSR_VERSION);
        let payload = std::fs::metadata(&path).unwrap().len() - CsrHeader::SIZE - (h.rows + 1) * 8;
        assert!(
            payload < h.nnz * NNZ_BYTES,
            "v2 payload {payload} not smaller than raw {}",
            h.nnz * NNZ_BYTES
        );
        let back = read_sparse_matrix(&path, InputFormat::Csr).unwrap();
        assert_eq!(back.to_dense(), s.to_dense());
    }

    #[test]
    fn csr_bad_magic_rejected() {
        let path = tmp("bad.csr");
        std::fs::write(&path, b"NOPExxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(CsrHeader::read_from(&path).is_err());
    }

    #[test]
    fn text_reader_respects_byte_range() {
        let path = tmp("range.libsvm");
        std::fs::write(&path, "0 1:1\n0 2:2\n0 3:3\n").unwrap();
        // First line is bytes [0, 6).
        let mut r = SparseTextReader::open_range(&path, InputFormat::Libsvm, 0, 6).unwrap();
        let mut idx = Vec::new();
        let mut val = Vec::new();
        assert!(r.next_row(&mut idx, &mut val).unwrap());
        assert_eq!(idx, vec![0]);
        assert!(!r.next_row(&mut idx, &mut val).unwrap());
    }
}
