//! tallfat binary matrix format (`.bin` / `.tfb`).
//!
//! Layout: 32-byte header, then row-major payload.
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "TFBM"
//! 4       4     version (u32 le) = 1
//! 8       8     rows (u64 le)
//! 16      8     cols (u64 le)
//! 24      1     dtype: 1 = f32, 2 = f64
//! 25      7     reserved (zero)
//! ```
//!
//! Chunking binary inputs is by row ranges (exact), not byte ranges — the
//! header makes row offsets computable, so no newline realignment is needed.

use crate::error::{Error, Result};
use crate::linalg::Matrix;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};

pub const MAGIC: &[u8; 4] = b"TFBM";
pub const VERSION: u32 = 1;

/// Element type tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32 = 1,
    F64 = 2,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
        }
    }

    fn from_u8(v: u8) -> Result<Self> {
        match v {
            1 => Ok(DType::F32),
            2 => Ok(DType::F64),
            other => Err(Error::parse(format!("binmat: bad dtype {other}"))),
        }
    }
}

/// Parsed header.
#[derive(Clone, Copy, Debug)]
pub struct BinMatHeader {
    pub rows: u64,
    pub cols: u64,
    pub dtype: DType,
}

impl BinMatHeader {
    pub const SIZE: u64 = 32;

    pub fn read_from(path: &str) -> Result<Self> {
        let mut f = File::open(path)?;
        let mut buf = [0u8; Self::SIZE as usize];
        f.read_exact(&mut buf)?;
        if &buf[0..4] != MAGIC {
            return Err(Error::parse("binmat: bad magic"));
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(Error::parse(format!("binmat: unsupported version {version}")));
        }
        Ok(BinMatHeader {
            rows: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            cols: u64::from_le_bytes(buf[16..24].try_into().unwrap()),
            dtype: DType::from_u8(buf[24])?,
        })
    }

    fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        let mut buf = [0u8; Self::SIZE as usize];
        buf[0..4].copy_from_slice(MAGIC);
        buf[4..8].copy_from_slice(&VERSION.to_le_bytes());
        buf[8..16].copy_from_slice(&self.rows.to_le_bytes());
        buf[16..24].copy_from_slice(&self.cols.to_le_bytes());
        buf[24] = self.dtype as u8;
        w.write_all(&buf)?;
        Ok(())
    }

    /// Byte offset of row `r`.
    pub fn row_offset(&self, r: u64) -> u64 {
        Self::SIZE + r * self.cols * self.dtype.size() as u64
    }
}

/// Streaming writer. Rows must be appended in order; `finish` rewrites the
/// header with the final row count.
pub struct BinMatWriter {
    w: BufWriter<File>,
    cols: u64,
    rows_written: u64,
    dtype: DType,
}

impl BinMatWriter {
    pub fn create(path: &str, cols: usize, dtype: DType) -> Result<Self> {
        let f = File::create(path)?;
        let mut w = BufWriter::with_capacity(1 << 20, f);
        // placeholder header; fixed in finish()
        BinMatHeader { rows: 0, cols: cols as u64, dtype }.write_to(&mut w)?;
        Ok(BinMatWriter { w, cols: cols as u64, rows_written: 0, dtype })
    }

    pub fn write_row(&mut self, row: &[f64]) -> Result<()> {
        if row.len() as u64 != self.cols {
            return Err(Error::shape(format!(
                "binmat write_row: {} cols, expected {}",
                row.len(),
                self.cols
            )));
        }
        match self.dtype {
            DType::F32 => {
                for &v in row {
                    self.w.write_all(&(v as f32).to_le_bytes())?;
                }
            }
            DType::F64 => {
                for &v in row {
                    self.w.write_all(&v.to_le_bytes())?;
                }
            }
        }
        self.rows_written += 1;
        Ok(())
    }

    pub fn finish(mut self) -> Result<u64> {
        self.w.flush()?;
        let mut f = self.w.into_inner().map_err(|e| Error::Other(e.to_string()))?;
        f.seek(SeekFrom::Start(0))?;
        BinMatHeader { rows: self.rows_written, cols: self.cols, dtype: self.dtype }
            .write_to(&mut f)?;
        f.sync_all()?;
        Ok(self.rows_written)
    }
}

/// Streaming reader over a row range.
pub struct BinMatReader {
    r: BufReader<File>,
    header: BinMatHeader,
    next_row: u64,
    end_row: u64,
    byte_buf: Vec<u8>,
}

impl BinMatReader {
    pub fn open(path: &str) -> Result<Self> {
        let header = BinMatHeader::read_from(path)?;
        Self::open_rows(path, 0, header.rows)
    }

    /// Open rows `[start, end)`.
    pub fn open_rows(path: &str, start: u64, end: u64) -> Result<Self> {
        let header = BinMatHeader::read_from(path)?;
        let end = end.min(header.rows);
        let mut f = File::open(path)?;
        f.seek(SeekFrom::Start(header.row_offset(start)))?;
        let row_bytes = header.cols as usize * header.dtype.size();
        Ok(BinMatReader {
            r: BufReader::with_capacity(1 << 20, f),
            header,
            next_row: start,
            end_row: end,
            byte_buf: vec![0u8; row_bytes],
        })
    }

    pub fn header(&self) -> &BinMatHeader {
        &self.header
    }

    /// Read the next row. Returns false at end of range.
    pub fn next_row(&mut self, row: &mut Vec<f64>) -> Result<bool> {
        if self.next_row >= self.end_row {
            return Ok(false);
        }
        self.r.read_exact(&mut self.byte_buf)?;
        row.clear();
        match self.header.dtype {
            DType::F32 => {
                for c in self.byte_buf.chunks_exact(4) {
                    row.push(f32::from_le_bytes(c.try_into().unwrap()) as f64);
                }
            }
            DType::F64 => {
                for c in self.byte_buf.chunks_exact(8) {
                    row.push(f64::from_le_bytes(c.try_into().unwrap()));
                }
            }
        }
        self.next_row += 1;
        Ok(true)
    }
}

/// Read a whole binary matrix into memory.
pub fn read_matrix_bin(path: &str) -> Result<Matrix> {
    let mut r = BinMatReader::open(path)?;
    let (rows, cols) = (r.header().rows as usize, r.header().cols as usize);
    let mut m = Matrix::zeros(rows, cols);
    let mut row = Vec::with_capacity(cols);
    for i in 0..rows {
        r.next_row(&mut row)?;
        m.row_mut(i).copy_from_slice(&row);
    }
    Ok(m)
}

/// Write a matrix as f64 binary.
pub fn write_matrix_bin(m: &Matrix, path: &str) -> Result<()> {
    let mut w = BinMatWriter::create(path, m.cols(), DType::F64)?;
    for i in 0..m.rows() {
        w.write_row(m.row(i))?;
    }
    w.finish()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("tallfat_test_bin");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    #[test]
    fn roundtrip_f64() {
        let m = Matrix::from_rows(&[vec![1.0, -2.5], vec![1e-300, 1e300]]).unwrap();
        let path = tmp("rt64.bin");
        write_matrix_bin(&m, &path).unwrap();
        let back = read_matrix_bin(&path).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn f32_quantizes() {
        let path = tmp("f32.bin");
        let mut w = BinMatWriter::create(&path, 2, DType::F32).unwrap();
        w.write_row(&[1.5, 0.1]).unwrap();
        assert_eq!(w.finish().unwrap(), 1);
        let back = read_matrix_bin(&path).unwrap();
        assert_eq!(back.get(0, 0), 1.5); // exact in f32
        assert!((back.get(0, 1) - 0.1).abs() < 1e-7 && back.get(0, 1) != 0.1);
    }

    #[test]
    fn header_roundtrip_and_offsets() {
        let path = tmp("hdr.bin");
        let mut w = BinMatWriter::create(&path, 3, DType::F64).unwrap();
        for i in 0..5 {
            w.write_row(&[i as f64, 0.0, 0.0]).unwrap();
        }
        w.finish().unwrap();
        let h = BinMatHeader::read_from(&path).unwrap();
        assert_eq!((h.rows, h.cols), (5, 3));
        assert_eq!(h.row_offset(2), 32 + 2 * 3 * 8);
    }

    #[test]
    fn row_range_reading() {
        let path = tmp("range.bin");
        let m = Matrix::from_fn(10, 2, |i, j| (i * 2 + j) as f64);
        write_matrix_bin(&m, &path).unwrap();
        let mut r = BinMatReader::open_rows(&path, 3, 6).unwrap();
        let mut row = Vec::new();
        let mut seen = Vec::new();
        while r.next_row(&mut row).unwrap() {
            seen.push(row[0]);
        }
        assert_eq!(seen, vec![6.0, 8.0, 10.0]);
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("bad.bin");
        std::fs::write(&path, b"NOPExxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(BinMatHeader::read_from(&path).is_err());
    }

    #[test]
    fn wrong_row_width_rejected() {
        let path = tmp("w.bin");
        let mut w = BinMatWriter::create(&path, 3, DType::F64).unwrap();
        assert!(w.write_row(&[1.0, 2.0]).is_err());
    }
}
