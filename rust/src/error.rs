//! Library-wide error type.

use thiserror::Error;

/// All errors surfaced by the tallfat library.
#[derive(Error, Debug)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla/pjrt error: {0}")]
    Xla(String),

    #[error("parse error: {0}")]
    Parse(String),

    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("numerical error: {0}")]
    Numerical(String),

    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Convenience constructor for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }

    /// Convenience constructor for parse errors.
    pub fn parse(msg: impl Into<String>) -> Self {
        Error::Parse(msg.into())
    }
}
