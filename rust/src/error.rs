//! Library-wide error type (hand-rolled — the build is dependency-free, so
//! no `thiserror`).

use std::fmt;

/// All errors surfaced by the tallfat library.
#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),
    Xla(String),
    Parse(String),
    Shape(String),
    Config(String),
    Artifact(String),
    Numerical(String),
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla/pjrt error: {m}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Convenience constructor for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }

    /// Convenience constructor for parse errors.
    pub fn parse(msg: impl Into<String>) -> Self {
        Error::Parse(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert!(Error::shape("2x2 vs 3x3").to_string().contains("shape mismatch"));
        assert!(Error::parse("bad").to_string().contains("parse error"));
        assert_eq!(Error::Other("plain".into()).to_string(), "plain");
    }

    #[test]
    fn io_source_preserved() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
