//! Leveled stderr logger (no env_logger offline).
//!
//! Level picked from `TALLFAT_LOG` (error|warn|info|debug|trace), default
//! `info`. Messages carry elapsed-since-start timestamps.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(255);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur != 255 {
        return cur;
    }
    let from_env = std::env::var("TALLFAT_LOG")
        .map(|v| Level::from_str(&v))
        .unwrap_or(Level::Info) as u8;
    LEVEL.store(from_env, Ordering::Relaxed);
    from_env
}

/// Override the log level programmatically (tests, CLI `--verbose`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Whether a message at `l` would be emitted.
pub fn log_enabled(l: Level) -> bool {
    (l as u8) <= level()
}

/// Emit a log line (prefer the [`crate::log_info!`]-style macros).
pub fn log(l: Level, module: &str, msg: &str) {
    if !log_enabled(l) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {module}] {msg}", l.tag());
}

/// Named logger handle for a module.
#[derive(Clone, Copy)]
pub struct Logger {
    module: &'static str,
}

impl Logger {
    pub const fn new(module: &'static str) -> Self {
        Logger { module }
    }

    pub fn error(&self, msg: &str) {
        log(Level::Error, self.module, msg);
    }

    pub fn warn(&self, msg: &str) {
        log(Level::Warn, self.module, msg);
    }

    pub fn info(&self, msg: &str) {
        log(Level::Info, self.module, msg);
    }

    pub fn debug(&self, msg: &str) {
        log(Level::Debug, self.module, msg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(log_enabled(Level::Error));
        assert!(log_enabled(Level::Warn));
        assert!(!log_enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn from_str_parsing() {
        assert_eq!(Level::from_str("TRACE"), Level::Trace);
        assert_eq!(Level::from_str("bogus"), Level::Info);
    }
}
