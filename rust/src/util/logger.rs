//! Leveled stderr logger (no env_logger offline).
//!
//! Level picked from `TALLFAT_LOG` (error|warn|info|debug|trace), default
//! `info`. Messages carry elapsed-since-start timestamps; call [`init`]
//! first thing in `main` so the epoch is process start, not the first log
//! call. `TALLFAT_LOG_FORMAT=json` switches to one JSON object per line
//! (`ts`, `level`, `module`, `msg`, plus `trace`/`span` ids when a span
//! is active — see [`crate::obs::trace`]).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// Output format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    Text,
    Json,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);
static FORMAT: AtomicU8 = AtomicU8::new(255);
static START: OnceLock<Instant> = OnceLock::new();

/// Pin the log epoch and load `TALLFAT_LOG` / `TALLFAT_LOG_FORMAT`.
/// Called at the top of `main`; later calls are no-ops. Without it the
/// first log call initializes lazily (epoch = first message, so relative
/// timestamps understate early work).
pub fn init() {
    START.get_or_init(Instant::now);
    level();
    format();
}

fn epoch() -> &'static Instant {
    START.get_or_init(Instant::now)
}

fn level() -> u8 {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur != 255 {
        return cur;
    }
    let from_env = std::env::var("TALLFAT_LOG")
        .map(|v| Level::from_str(&v))
        .unwrap_or(Level::Info) as u8;
    LEVEL.store(from_env, Ordering::Relaxed);
    from_env
}

fn format() -> Format {
    let cur = FORMAT.load(Ordering::Relaxed);
    if cur != 255 {
        return if cur == 1 { Format::Json } else { Format::Text };
    }
    let json = std::env::var("TALLFAT_LOG_FORMAT")
        .map(|v| v.eq_ignore_ascii_case("json"))
        .unwrap_or(false);
    FORMAT.store(if json { 1 } else { 0 }, Ordering::Relaxed);
    if json {
        Format::Json
    } else {
        Format::Text
    }
}

/// Override the log level programmatically (tests, CLI `--verbose`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Override the output format programmatically (tests).
pub fn set_format(f: Format) {
    FORMAT.store(if f == Format::Json { 1 } else { 0 }, Ordering::Relaxed);
}

/// Whether a message at `l` would be emitted.
pub fn log_enabled(l: Level) -> bool {
    (l as u8) <= level()
}

/// Render one log line in the active format (factored out for tests —
/// stderr itself is not capturable in-process).
fn render_line(f: Format, l: Level, module: &str, msg: &str, t: f64) -> String {
    match f {
        Format::Text => format!("[{t:9.3}s {} {module}] {msg}", l.tag()),
        Format::Json => {
            use crate::obs::trace::{current, json_escape};
            let mut line = format!(
                "{{\"ts\":{t:.3},\"level\":\"{}\",\"module\":\"{}\",\"msg\":\"{}\"",
                l.name(),
                json_escape(module),
                json_escape(msg),
            );
            let ctx = current();
            if !ctx.is_none() {
                line.push_str(&format!(
                    ",\"trace\":\"{:016x}\",\"span\":\"{:016x}\"",
                    ctx.trace, ctx.span
                ));
            }
            line.push('}');
            line
        }
    }
}

/// Emit a log line (prefer the [`crate::log_info!`]-style macros).
pub fn log(l: Level, module: &str, msg: &str) {
    if !log_enabled(l) {
        return;
    }
    let t = epoch().elapsed().as_secs_f64();
    eprintln!("{}", render_line(format(), l, module, msg, t));
}

/// Named logger handle for a module.
#[derive(Clone, Copy)]
pub struct Logger {
    module: &'static str,
}

impl Logger {
    pub const fn new(module: &'static str) -> Self {
        Logger { module }
    }

    pub fn error(&self, msg: &str) {
        log(Level::Error, self.module, msg);
    }

    pub fn warn(&self, msg: &str) {
        log(Level::Warn, self.module, msg);
    }

    pub fn info(&self, msg: &str) {
        log(Level::Info, self.module, msg);
    }

    pub fn debug(&self, msg: &str) {
        log(Level::Debug, self.module, msg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::json::Json;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(log_enabled(Level::Error));
        assert!(log_enabled(Level::Warn));
        assert!(!log_enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn from_str_parsing() {
        assert_eq!(Level::from_str("TRACE"), Level::Trace);
        assert_eq!(Level::from_str("bogus"), Level::Info);
    }

    #[test]
    fn init_is_idempotent_and_pins_epoch() {
        init();
        let a = *epoch();
        init();
        assert_eq!(a, *epoch());
    }

    #[test]
    fn json_lines_parse_and_escape() {
        let line = render_line(Format::Json, Level::Warn, "svd::pipeline", "bad \"row\"\n", 1.25);
        let v = Json::parse(&line).expect("log line is valid JSON");
        assert_eq!(v.get("level").unwrap().as_str(), Some("warn"));
        assert_eq!(v.get("module").unwrap().as_str(), Some("svd::pipeline"));
        assert_eq!(v.get("msg").unwrap().as_str(), Some("bad \"row\"\n"));
        assert_eq!(v.get("ts").unwrap().as_f64(), Some(1.25));
        // No active span -> no trace/span fields.
        assert!(v.get("trace").is_none());
    }

    #[test]
    fn text_line_keeps_legacy_shape() {
        let line = render_line(Format::Text, Level::Info, "m", "hello", 2.0);
        assert!(line.contains("INFO"));
        assert!(line.contains("[    2.000s"));
        assert!(line.ends_with("m] hello"));
    }
}
