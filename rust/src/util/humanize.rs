//! Human-readable formatting for the metrics reports and bench tables.

use std::time::Duration;

/// `1536` -> `"1.50 KiB"`.
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[unit])
    }
}

/// Compact duration: `"1.23s"`, `"45.6ms"`, `"789us"`, `"2m03s"`.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 60.0 {
        format!("{}m{:04.1}s", (s / 60.0) as u64, s % 60.0)
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

/// Items-per-second: `"1.25M/s"`, `"830/s"`.
pub fn fmt_rate(items: u64, elapsed: Duration) -> String {
    let secs = elapsed.as_secs_f64().max(1e-12);
    let r = items as f64 / secs;
    if r >= 1e9 {
        format!("{:.2}G/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2}M/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2}K/s", r / 1e3)
    } else {
        format!("{r:.0}/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(1536), "1.50 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn durations() {
        assert_eq!(fmt_duration(Duration::from_millis(45)), "45.0ms");
        assert_eq!(fmt_duration(Duration::from_secs_f64(1.234)), "1.23s");
        assert_eq!(fmt_duration(Duration::from_micros(500)), "500us");
        assert_eq!(fmt_duration(Duration::from_secs(123)), "2m03.0s");
    }

    #[test]
    fn rates() {
        assert_eq!(fmt_rate(1000, Duration::from_secs(1)), "1.00K/s");
        assert_eq!(fmt_rate(5, Duration::from_secs(1)), "5/s");
        assert_eq!(fmt_rate(2_500_000, Duration::from_secs(1)), "2.50M/s");
    }
}
