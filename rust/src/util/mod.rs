//! Small self-contained utilities (no external crates are available offline:
//! the CLI parser, logger, and formatting helpers are hand-rolled substrates).

pub mod cli;
pub mod humanize;
pub mod logger;

pub use cli::Args;
pub use humanize::{fmt_bytes, fmt_duration, fmt_rate};
pub use logger::{log_enabled, Level, Logger};
