//! Small self-contained utilities (no external crates are available offline:
//! the CLI parser, logger, and formatting helpers are hand-rolled substrates).

pub mod cli;
pub mod humanize;
pub mod lock;
pub mod logger;

pub use cli::Args;
pub use humanize::{fmt_bytes, fmt_duration, fmt_rate};
pub use lock::{lock_unpoisoned, read_unpoisoned, write_unpoisoned};
pub use logger::{log_enabled, Level, Logger};
