//! Poison-proof lock accessors.
//!
//! `Mutex::lock().unwrap()` turns one panicked holder into a cascade:
//! every later `lock()` sees the poison flag and panics too, so a single
//! bad request takes the whole serving process's shared state down with
//! it. For the locks in this codebase the protected data is always left
//! consistent at every await-free step (caches insert-then-touch, handles
//! swap a single `Arc`), so recovering the guard from a poisoned lock is
//! safe — the server degrades (one failed request) instead of cascading.

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Read-lock an `RwLock`, recovering the guard if a writer panicked.
pub fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Write-lock an `RwLock`, recovering the guard if a holder panicked.
pub fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::{Mutex, RwLock};

    #[test]
    fn mutex_recovers_after_poison() {
        let m = Mutex::new(41);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("holder dies");
        }));
        assert!(m.is_poisoned());
        let mut g = lock_unpoisoned(&m);
        *g += 1;
        assert_eq!(*g, 42);
    }

    #[test]
    fn rwlock_recovers_after_poison() {
        let l = RwLock::new(String::from("ok"));
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = l.write().unwrap();
            panic!("writer dies");
        }));
        assert_eq!(*read_unpoisoned(&l), "ok");
        write_unpoisoned(&l).push('!');
        assert_eq!(*read_unpoisoned(&l), "ok!");
    }
}
