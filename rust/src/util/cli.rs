//! Minimal command-line parser (clap is not available offline).
//!
//! Grammar: `tallfat <subcommand> [--flag] [--key value] [positional...]`.
//! `--key=value` is also accepted.

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self> {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    return Err(Error::parse("bare `--` not supported"));
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag or absent
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            args.options.insert(rest.to_string(), v);
                        }
                        _ => args.flags.push(rest.to_string()),
                    }
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Boolean flag (`--verbose`). Also true if passed as `--verbose=true`.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || matches!(self.options.get(name).map(String::as_str), Some("true") | Some("1"))
    }

    /// String option.
    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    /// String option with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.opt_str(name).unwrap_or(default).to_string()
    }

    /// Required string option.
    pub fn require_str(&self, name: &str) -> Result<String> {
        self.opt_str(name)
            .map(String::from)
            .ok_or_else(|| Error::Config(format!("missing required option --{name}")))
    }

    /// usize option with default.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt_str(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::parse(format!("--{name}: expected integer, got `{s}`"))),
        }
    }

    /// u64 option with default.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt_str(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::parse(format!("--{name}: expected integer, got `{s}`"))),
        }
    }

    /// f64 option with default.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt_str(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::parse(format!("--{name}: expected float, got `{s}`"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("svd input.csv extra");
        assert_eq!(a.command.as_deref(), Some("svd"));
        assert_eq!(a.positional, vec!["input.csv", "extra"]);
    }

    #[test]
    fn key_value_both_forms() {
        let a = parse("svd --k 16 --block=512");
        assert_eq!(a.usize_or("k", 0).unwrap(), 16);
        assert_eq!(a.usize_or("block", 0).unwrap(), 512);
    }

    #[test]
    fn flags() {
        let a = parse("svd --verbose --k 8");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("svd --check");
        assert!(a.flag("check"));
    }

    #[test]
    fn defaults_and_missing() {
        let a = parse("svd");
        assert_eq!(a.usize_or("workers", 4).unwrap(), 4);
        assert_eq!(a.f64_or("eps", 0.5).unwrap(), 0.5);
        assert!(a.require_str("input").is_err());
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("svd --k sixteen");
        assert!(a.usize_or("k", 0).is_err());
    }

    #[test]
    fn negative_value_consumed_as_value() {
        let a = parse("sim --offset -3.5");
        assert_eq!(a.f64_or("offset", 0.0).unwrap(), -3.5);
    }
}
