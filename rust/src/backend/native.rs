//! Pure-rust backend over `crate::linalg` (any shape, f64 throughout).

use super::Backend;
use crate::error::Result;
use crate::linalg::{self, Matrix, SparseMatrix};

/// The native block backend.
#[derive(Default, Clone, Copy)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn gram_block(&self, x: &Matrix) -> Result<Matrix> {
        Ok(linalg::gram(x))
    }

    fn project_block(&self, x: &Matrix, w: &Matrix) -> Result<Matrix> {
        linalg::matmul(x, w)
    }

    fn project_gram_block(&self, x: &Matrix, w: &Matrix) -> Result<(Matrix, Matrix)> {
        // Truly fused: YᵀY accumulates per row-stripe of the freshly
        // computed Y in the same sweep, instead of matmul followed by a
        // second full pass over Y (`linalg::matmul_gram` docs; the
        // gram(matmul(..)) oracle cross-checks it in ops.rs and below).
        linalg::matmul_gram(x, w)
    }

    fn tmul_block(&self, x: &Matrix, z: &Matrix) -> Result<Matrix> {
        linalg::matmul_tn(x, z)
    }

    fn u_recover_block(&self, y: &Matrix, m: &Matrix) -> Result<Matrix> {
        linalg::matmul(y, m)
    }

    fn eigh(&self, g: &Matrix) -> Result<(Vec<f64>, Matrix)> {
        linalg::eigen::eigh(g)
    }

    // True O(nnz) sparse kernels (the trait's defaults densify instead).

    fn gram_block_sparse(&self, x: &SparseMatrix) -> Result<Matrix> {
        Ok(linalg::sp_gram(x))
    }

    fn project_block_sparse(&self, x: &SparseMatrix, w: &Matrix) -> Result<Matrix> {
        linalg::sp_matmul(x, w)
    }

    fn project_gram_block_sparse(
        &self,
        x: &SparseMatrix,
        w: &Matrix,
    ) -> Result<(Matrix, Matrix)> {
        linalg::sp_matmul_gram(x, w)
    }

    fn tmul_block_sparse(&self, x: &SparseMatrix, z: &Matrix) -> Result<Matrix> {
        linalg::sp_tmul(x, z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Gaussian;

    fn rand(rows: usize, cols: usize, seed: u64) -> Matrix {
        let g = Gaussian::new(seed);
        Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
    }

    #[test]
    fn ops_consistent() {
        let b = NativeBackend::new();
        let x = rand(40, 8, 1);
        let w = rand(8, 4, 2);
        let (y, g) = b.project_gram_block(&x, &w).unwrap();
        assert!(y.max_abs_diff(&b.project_block(&x, &w).unwrap()) < 1e-12);
        assert!(g.max_abs_diff(&b.gram_block(&y).unwrap()) < 1e-12);
        let wm = b.tmul_block(&x, &y).unwrap();
        assert_eq!(wm.shape(), (8, 4));
        let u = b.u_recover_block(&y, &Matrix::eye(4)).unwrap();
        assert!(u.max_abs_diff(&y) < 1e-15);
    }

    #[test]
    fn eigh_descending() {
        let b = NativeBackend::new();
        let x = rand(30, 6, 3);
        let g = b.gram_block(&x).unwrap();
        let (w, _) = b.eigh(&g).unwrap();
        for i in 1..6 {
            assert!(w[i - 1] >= w[i] - 1e-12);
        }
    }
}
