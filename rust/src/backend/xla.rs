//! XLA backend: the AOT JAX/Pallas artifacts executed via the PJRT service.
//!
//! Shapes are fixed at artifact-build time; blocks with fewer rows are
//! zero-padded up to the artifact block (padded rows contribute nothing to
//! Gram/projection/tmul — the invariant both test suites pin). With
//! `fallback = true` (the `auto` backend) shapes that no artifact covers
//! fall back to the native implementation instead of erroring.
//!
//! The PJRT runtime needs the vendored `xla` bindings crate, gated behind
//! the `xla` cargo feature (see `Cargo.toml`). Without the feature this
//! module exports a stub [`XlaBackend`] with the same API whose `start`
//! always fails — callers that probe (`XlaBackend::start(..).ok()`) degrade
//! gracefully, and `backend=auto` serves natively.

#[cfg(feature = "xla")]
mod real {
    use crate::backend::{native::NativeBackend, Backend};
    use crate::error::{Error, Result};
    use crate::linalg::Matrix;
    use crate::runtime::artifact::ArtifactMeta;
    use crate::runtime::literal::matrix_to_f32_padded;
    use crate::runtime::service::{XlaHandle, XlaService};
    use crate::util::Logger;
    use std::sync::atomic::{AtomicU64, Ordering};

    static LOG: Logger = Logger::new("backend.xla");

    /// PJRT-backed block backend.
    pub struct XlaBackend {
        // Keep the service alive for the backend's lifetime.
        _service: XlaService,
        handle: XlaHandle,
        fallback: Option<NativeBackend>,
        xla_calls: AtomicU64,
        native_calls: AtomicU64,
    }

    impl XlaBackend {
        /// Boot the PJRT service over `artifacts_dir`. With `fallback`, shapes
        /// without a matching artifact run natively (the `auto` backend).
        pub fn start(artifacts_dir: &str, fallback: bool) -> Result<Self> {
            let service = XlaService::start(artifacts_dir)?;
            let handle = service.handle();
            Ok(XlaBackend {
                _service: service,
                handle,
                fallback: fallback.then(NativeBackend::new),
                xla_calls: AtomicU64::new(0),
                native_calls: AtomicU64::new(0),
            })
        }

        /// (xla, native-fallback) call counts — used by tests and benches to
        /// assert which path actually ran.
        pub fn call_counts(&self) -> (u64, u64) {
            (
                self.xla_calls.load(Ordering::Relaxed),
                self.native_calls.load(Ordering::Relaxed),
            )
        }

        fn lookup(&self, program: &str, rows: usize, n: usize, k: usize) -> Option<ArtifactMeta> {
            self.handle.manifest().lookup(program, rows, n, k).cloned()
        }

        fn missing<T>(&self, program: &str, rows: usize, n: usize, k: usize) -> Result<T> {
            Err(Error::Artifact(format!(
                "no `{program}` artifact for block>={rows} n={n} k={k} \
                 (rebuild artifacts with this variant or use backend=auto)"
            )))
        }

        fn run(
            &self,
            meta: &ArtifactMeta,
            inputs: Vec<(Vec<f32>, Vec<usize>)>,
        ) -> Result<Vec<Vec<f32>>> {
            self.xla_calls.fetch_add(1, Ordering::Relaxed);
            self.handle.execute(&meta.name, inputs)
        }

        fn out_matrix(data: &[f32], rows: usize, cols: usize, keep_rows: usize) -> Result<Matrix> {
            if data.len() != rows * cols {
                return Err(Error::shape(format!(
                    "xla output: {} elements for {rows}x{cols}",
                    data.len()
                )));
            }
            Matrix::from_f32(keep_rows, cols, &data[..keep_rows * cols])
        }
    }

    impl Backend for XlaBackend {
        fn name(&self) -> &'static str {
            "xla"
        }

        fn gram_block(&self, x: &Matrix) -> Result<Matrix> {
            let (rows, n) = x.shape();
            match self.lookup("gram", rows, n, 0) {
                Some(meta) => {
                    let xin = matrix_to_f32_padded(x, meta.block);
                    let outs = self.run(&meta, vec![(xin, vec![meta.block, n])])?;
                    Self::out_matrix(&outs[0], n, n, n)
                }
                None => match &self.fallback {
                    Some(nb) => {
                        self.native_calls.fetch_add(1, Ordering::Relaxed);
                        nb.gram_block(x)
                    }
                    None => self.missing("gram", rows, n, 0),
                },
            }
        }

        fn project_block(&self, x: &Matrix, w: &Matrix) -> Result<Matrix> {
            let (rows, n) = x.shape();
            let k = w.cols();
            match self.lookup("project", rows, n, k) {
                Some(meta) => {
                    let xin = matrix_to_f32_padded(x, meta.block);
                    let win = matrix_to_f32_padded(w, n);
                    let outs = self.run(
                        &meta,
                        vec![(xin, vec![meta.block, n]), (win, vec![n, k])],
                    )?;
                    Self::out_matrix(&outs[0], meta.block, k, rows)
                }
                None => match &self.fallback {
                    Some(nb) => {
                        self.native_calls.fetch_add(1, Ordering::Relaxed);
                        nb.project_block(x, w)
                    }
                    None => self.missing("project", rows, n, k),
                },
            }
        }

        fn project_gram_block(&self, x: &Matrix, w: &Matrix) -> Result<(Matrix, Matrix)> {
            let (rows, n) = x.shape();
            let k = w.cols();
            match self.lookup("fused", rows, n, k) {
                Some(meta) => {
                    let xin = matrix_to_f32_padded(x, meta.block);
                    let win = matrix_to_f32_padded(w, n);
                    let outs = self.run(
                        &meta,
                        vec![(xin, vec![meta.block, n]), (win, vec![n, k])],
                    )?;
                    let y = Self::out_matrix(&outs[0], meta.block, k, rows)?;
                    let g = Self::out_matrix(&outs[1], k, k, k)?;
                    Ok((y, g))
                }
                None => match &self.fallback {
                    Some(nb) => {
                        self.native_calls.fetch_add(1, Ordering::Relaxed);
                        nb.project_gram_block(x, w)
                    }
                    None => self.missing("fused", rows, n, k),
                },
            }
        }

        fn tmul_block(&self, x: &Matrix, z: &Matrix) -> Result<Matrix> {
            let (rows, n) = x.shape();
            let k = z.cols();
            if z.rows() != rows {
                return Err(Error::shape(format!(
                    "tmul: {} vs {} rows",
                    rows,
                    z.rows()
                )));
            }
            match self.lookup("tmul", rows, n, k) {
                Some(meta) => {
                    let xin = matrix_to_f32_padded(x, meta.block);
                    let zin = matrix_to_f32_padded(z, meta.block);
                    let outs = self.run(
                        &meta,
                        vec![(xin, vec![meta.block, n]), (zin, vec![meta.block, k])],
                    )?;
                    Self::out_matrix(&outs[0], n, k, n)
                }
                None => match &self.fallback {
                    Some(nb) => {
                        self.native_calls.fetch_add(1, Ordering::Relaxed);
                        nb.tmul_block(x, z)
                    }
                    None => self.missing("tmul", rows, n, k),
                },
            }
        }

        fn u_recover_block(&self, y: &Matrix, m: &Matrix) -> Result<Matrix> {
            let (rows, k) = y.shape();
            match self.lookup("urecover", rows, 0, k) {
                Some(meta) => {
                    let yin = matrix_to_f32_padded(y, meta.block);
                    let min = matrix_to_f32_padded(m, k);
                    let outs = self.run(
                        &meta,
                        vec![(yin, vec![meta.block, k]), (min, vec![k, k])],
                    )?;
                    Self::out_matrix(&outs[0], meta.block, k, rows)
                }
                None => match &self.fallback {
                    Some(nb) => {
                        self.native_calls.fetch_add(1, Ordering::Relaxed);
                        nb.u_recover_block(y, m)
                    }
                    None => self.missing("urecover", rows, 0, k),
                },
            }
        }

        fn eigh(&self, g: &Matrix) -> Result<(Vec<f64>, Matrix)> {
            let k = g.rows();
            match self.handle.manifest().lookup_eigh(k).cloned() {
                Some(meta) => {
                    let gin = matrix_to_f32_padded(g, k);
                    let outs = self.run(&meta, vec![(gin, vec![k, k])])?;
                    let w: Vec<f64> = outs[0].iter().map(|&v| v as f64).collect();
                    let v = Self::out_matrix(&outs[1], k, k, k)?;
                    Ok((w, v))
                }
                None => match &self.fallback {
                    Some(nb) => {
                        self.native_calls.fetch_add(1, Ordering::Relaxed);
                        LOG.debug(&format!("eigh k={k}: no artifact, native fallback"));
                        nb.eigh(g)
                    }
                    None => self.missing("eigh", 0, 0, k),
                },
            }
        }
    }
}

#[cfg(feature = "xla")]
pub use real::XlaBackend;

#[cfg(not(feature = "xla"))]
mod stub {
    use crate::backend::Backend;
    use crate::error::{Error, Result};
    use crate::linalg::Matrix;

    /// Stub standing in for the PJRT backend when the crate is built
    /// without the `xla` feature. `start` always fails, so probing callers
    /// (tests, benches, `backend=auto`) fall through to the native path.
    pub struct XlaBackend {
        _private: (),
    }

    fn unavailable() -> Error {
        Error::Artifact(
            "tallfat was built without the `xla` feature; vendor the PJRT \
             bindings crate first (see the note in rust/Cargo.toml), then \
             rebuild with `--features xla`"
                .into(),
        )
    }

    impl XlaBackend {
        /// Always fails in a no-`xla` build.
        pub fn start(_artifacts_dir: &str, _fallback: bool) -> Result<Self> {
            Err(unavailable())
        }

        /// Mirror of the real backend's instrumentation hook.
        pub fn call_counts(&self) -> (u64, u64) {
            (0, 0)
        }
    }

    impl Backend for XlaBackend {
        fn name(&self) -> &'static str {
            "xla-stub"
        }

        fn gram_block(&self, _x: &Matrix) -> Result<Matrix> {
            Err(unavailable())
        }

        fn project_block(&self, _x: &Matrix, _w: &Matrix) -> Result<Matrix> {
            Err(unavailable())
        }

        fn project_gram_block(&self, _x: &Matrix, _w: &Matrix) -> Result<(Matrix, Matrix)> {
            Err(unavailable())
        }

        fn tmul_block(&self, _x: &Matrix, _z: &Matrix) -> Result<Matrix> {
            Err(unavailable())
        }

        fn u_recover_block(&self, _y: &Matrix, _m: &Matrix) -> Result<Matrix> {
            Err(unavailable())
        }

        fn eigh(&self, _g: &Matrix) -> Result<(Vec<f64>, Matrix)> {
            Err(unavailable())
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaBackend;

#[cfg(all(test, not(feature = "xla")))]
mod tests {
    use super::XlaBackend;

    #[test]
    fn stub_start_fails_cleanly() {
        let err = XlaBackend::start("artifacts", true).err().expect("stub must not boot");
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
