//! Block-compute backends.
//!
//! The Split-Process jobs are backend-agnostic: every per-block operation of
//! the paper's pipeline goes through [`Backend`]. Two implementations:
//!
//! * [`native::NativeBackend`] — pure-rust `linalg`, any shape, f64.
//! * [`xla::XlaBackend`] — AOT JAX/Pallas artifacts via the PJRT service
//!   thread, fixed shapes (+ zero-row padding), f32.
//!
//! The invariant linking them (tested in `rust/tests/backend_parity.rs`):
//! identical math up to f32 roundoff, since padding rows with zeros leaves
//! Gram/projection/tmul sums unchanged.

pub mod native;
pub mod xla;

use crate::error::Result;
use crate::linalg::{Matrix, SparseMatrix};
use std::sync::Arc;

/// Per-block operations of the pipeline (shapes: x `b x n`, w `n x k`,
/// y/z `b x k`, m `k x k`, g `k x k` or `n x n`).
///
/// The `*_sparse` entry points take a CSR row block instead of a dense one.
/// Their default implementations densify and delegate — correct for any
/// backend (the XLA artifacts keep their fixed dense shapes) — while the
/// native backend overrides them with true `O(nnz)` kernels.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// `G = X^T X` (paper §2.0.2).
    fn gram_block(&self, x: &Matrix) -> Result<Matrix>;

    /// `Y = X W` (paper §2.0.3).
    fn project_block(&self, x: &Matrix, w: &Matrix) -> Result<Matrix>;

    /// Fused `(Y, Y^T Y)` — pass-1 hot path.
    fn project_gram_block(&self, x: &Matrix, w: &Matrix) -> Result<(Matrix, Matrix)>;

    /// `W = X^T Z` — pass-2 accumulation.
    fn tmul_block(&self, x: &Matrix, z: &Matrix) -> Result<Matrix>;

    /// `U = Y M` (paper §2.0.1, `U = A V Sigma^{-1}` per block).
    fn u_recover_block(&self, y: &Matrix, m: &Matrix) -> Result<Matrix>;

    /// Symmetric eigendecomposition, descending. Leader-side, small.
    fn eigh(&self, g: &Matrix) -> Result<(Vec<f64>, Matrix)>;

    // ---- sparse (CSR) block entry points ---------------------------------

    /// `G = X^T X` for a CSR block. Default: densify.
    fn gram_block_sparse(&self, x: &SparseMatrix) -> Result<Matrix> {
        self.gram_block(&x.to_dense())
    }

    /// `Y = X W` for a CSR block. Default: densify.
    fn project_block_sparse(&self, x: &SparseMatrix, w: &Matrix) -> Result<Matrix> {
        self.project_block(&x.to_dense(), w)
    }

    /// Fused `(Y, Y^T Y)` for a CSR block. Default: densify.
    fn project_gram_block_sparse(
        &self,
        x: &SparseMatrix,
        w: &Matrix,
    ) -> Result<(Matrix, Matrix)> {
        self.project_gram_block(&x.to_dense(), w)
    }

    /// `W = X^T Z` for a CSR block. Default: densify.
    fn tmul_block_sparse(&self, x: &SparseMatrix, z: &Matrix) -> Result<Matrix> {
        self.tmul_block(&x.to_dense(), z)
    }
}

/// Shared backend handle.
pub type BackendRef = Arc<dyn Backend>;

/// Build a backend per the run configuration.
///
/// `auto` degrades to the native backend when the PJRT service cannot boot
/// at all (missing artifacts, or a build without the `xla` feature); `xla`
/// is strict and surfaces the boot error.
pub fn make_backend(cfg: &crate::config::RunConfig) -> Result<BackendRef> {
    use crate::config::BackendKind;
    match cfg.backend {
        BackendKind::Native => Ok(Arc::new(native::NativeBackend::new())),
        BackendKind::Xla => Ok(Arc::new(xla::XlaBackend::start(&cfg.artifacts_dir, false)?)),
        BackendKind::Auto => match xla::XlaBackend::start(&cfg.artifacts_dir, true) {
            Ok(b) => Ok(Arc::new(b)),
            Err(e) => {
                crate::util::logger::log(
                    crate::util::logger::Level::Warn,
                    "backend",
                    &format!("auto: xla unavailable ({e}); serving natively"),
                );
                Ok(Arc::new(native::NativeBackend::new()))
            }
        },
    }
}
