//! The Split-Process engine (paper §3), scheduled dynamically.
//!
//! Each worker streams chunks of the shared input file — newline-aligned
//! byte ranges for CSV, exact row ranges for binary — through a [`RowJob`]
//! (`exec_row` per row, `post()` when the chunk drains). The leader then
//! merges the per-chunk results (a commutative reduction for every job in
//! this system).
//!
//! Unlike the paper's listing, chunks are not pinned one-per-worker: a pass
//! plans many more chunks than workers ([`plan_chunks_policy`]) and feeds
//! them through a shared work queue ([`sched::ChunkScheduler`]) with
//! bounded per-chunk retry — so a skewed chunk no longer sets the pass's
//! wall time and a poisoned chunk fails the pass with its name, not a
//! mystery hang. [`run_scheduled`] is the queue-driven engine;
//! [`run`]/[`run_chunked`] are the static one-chunk-per-worker view of it
//! kept for the standalone subcommands and benches.

pub mod block;
pub mod job;
pub mod sched;

pub use block::{BlockJob, Blocked, SparseBlockJob, SparseBlocked};
pub use job::{CenteredJob, RowJob, SparseRowJob};
pub use sched::{ChunkScheduler, Claim, SchedPolicy, SchedStats};

use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::binmat::{BinMatHeader, BinMatReader};
use crate::io::chunker::{chunk_byte_ranges, chunk_count_for_rows, chunk_row_ranges, ByteRange};
use crate::io::csv::CsvRowReader;
use crate::io::sparse::{CsrHeader, CsrReader, SparseRowReader, SparseTextReader};
use crate::io::InputSpec;
use crate::obs::trace::{self, Section, Span};

/// What a worker knows about its assignment (the paper's `workobj.ci` plus
/// the chunk geometry).
#[derive(Clone, Copy, Debug)]
pub struct ChunkMeta {
    /// Chunk index (0-based).
    pub index: usize,
    /// Total number of chunks in this run.
    pub total: usize,
    /// Byte range for CSV inputs.
    pub byte_range: Option<ByteRange>,
    /// Row range for binary inputs.
    pub row_range: Option<(u64, u64)>,
}

/// Plan `target` chunks over an input without running anything (fewer come
/// back when the file is too small for `target` boundaries).
pub fn plan_chunks(input: &InputSpec, target: usize) -> Result<Vec<ChunkMeta>> {
    if target == 0 {
        return Err(Error::Config("chunk target must be >= 1".into()));
    }
    // Chunk planning seeks and re-reads; a pipe/FIFO/stdin input must go
    // through the one-pass `tallfat stream` route instead.
    crate::io::ensure_seekable(&input.path)?;
    match input.format {
        InputFormat::Csv | InputFormat::Libsvm | InputFormat::SparseCsv => {
            let ranges = chunk_byte_ranges(&input.path, target)?;
            let total = ranges.len();
            Ok(ranges
                .into_iter()
                .enumerate()
                .map(|(index, r)| ChunkMeta {
                    index,
                    total,
                    byte_range: Some(r),
                    row_range: None,
                })
                .collect())
        }
        InputFormat::Bin | InputFormat::Csr => {
            let rows = match input.format {
                InputFormat::Bin => BinMatHeader::read_from(&input.path)?.rows,
                _ => CsrHeader::read_from(&input.path)?.rows,
            };
            let ranges = chunk_row_ranges(rows, target);
            let total = ranges.len();
            Ok(ranges
                .into_iter()
                .enumerate()
                .map(|(index, r)| ChunkMeta {
                    index,
                    total,
                    byte_range: None,
                    row_range: Some(r),
                })
                .collect())
        }
    }
}

/// Plan the fine-grained chunk schedule for `workers` under `policy`:
/// `chunk_rows` caps rows per chunk when set, otherwise
/// `workers * chunks_per_worker` chunks are targeted.
///
/// The returned plan is a *fixed point* of [`plan_chunks`]: re-planning
/// with the returned chunk count reproduces the exact same boundaries.
/// That is what lets the cluster ship only `(index, total)` over the wire —
/// every worker recomputes identical geometry from the shared file.
pub fn plan_chunks_policy(
    input: &InputSpec,
    workers: usize,
    policy: &SchedPolicy,
) -> Result<Vec<ChunkMeta>> {
    if workers == 0 {
        return Err(Error::Config("workers must be >= 1".into()));
    }
    let mut target = if policy.chunk_rows > 0 {
        chunk_count_for_rows(estimate_rows(input)?, policy.chunk_rows)
    } else {
        workers.saturating_mul(policy.chunks_per_worker.max(1))
    }
    .max(1);
    loop {
        let plan = plan_chunks(input, target)?;
        if plan.len() >= target || plan.len() <= 1 {
            return Ok(plan);
        }
        // Boundaries collapsed (short file): shrink the target until the
        // plan is reproducible from its own count.
        target = plan.len();
    }
}

/// Row count for `chunk_rows` planning: exact (header read) for binary
/// inputs, estimated from `file size / first line width` for CSV — a full
/// row-count scan of the tall file per pass would double the pass's I/O,
/// and `chunk_rows` is a granularity target, not an exactness contract.
fn estimate_rows(input: &InputSpec) -> Result<u64> {
    use std::io::BufRead;
    // `file size / line width` is garbage on a FIFO (size 0) — fail with
    // the streaming pointer instead.
    crate::io::ensure_seekable(&input.path)?;
    match input.format {
        InputFormat::Bin => Ok(BinMatHeader::read_from(&input.path)?.rows),
        InputFormat::Csr => Ok(CsrHeader::read_from(&input.path)?.rows),
        InputFormat::Csv => {
            let size = std::fs::metadata(&input.path)?.len();
            let mut reader = std::io::BufReader::new(std::fs::File::open(&input.path)?);
            let mut first = Vec::new();
            reader.read_until(b'\n', &mut first)?;
            Ok(size / (first.len() as u64).max(1))
        }
        InputFormat::Libsvm | InputFormat::SparseCsv => {
            // Sparse text rows vary wildly in width, and the first line may
            // be a comment or a bare label — one line is a terrible sample.
            // Average the first few dozen lines instead (comments and
            // blanks stay in the byte count but not the line count, which
            // only makes the estimate conservative for pathological files).
            let size = std::fs::metadata(&input.path)?.len();
            let mut reader = std::io::BufReader::new(std::fs::File::open(&input.path)?);
            let mut line = Vec::new();
            let mut sampled_bytes = 0u64;
            let mut sampled_lines = 0u64;
            for _ in 0..64 {
                line.clear();
                let n = reader.read_until(b'\n', &mut line)?;
                if n == 0 {
                    break;
                }
                sampled_bytes += n as u64;
                sampled_lines += 1;
            }
            if sampled_lines == 0 {
                return Ok(0);
            }
            let avg = (sampled_bytes / sampled_lines).max(1);
            Ok(size / avg)
        }
    }
}

/// The inner read loop of [`run_chunk`], with an untimed fast path: the
/// per-row `Instant` reads that feed the decode/compute section split only
/// run while a chunk section accumulator is open (tracing on).
fn pump_rows<J: RowJob>(
    mut next: impl FnMut(&mut Vec<f64>) -> Result<bool>,
    job: &mut J,
    row: &mut Vec<f64>,
) -> Result<u64> {
    let mut count = 0u64;
    if trace::sections_active() {
        loop {
            let t0 = std::time::Instant::now();
            let more = next(row)?;
            trace::sections_add(Section::Decode, t0.elapsed());
            if !more {
                break;
            }
            let t1 = std::time::Instant::now();
            job.exec_row(row)?;
            trace::sections_add(Section::Compute, t1.elapsed());
            count += 1;
        }
    } else {
        while next(row)? {
            job.exec_row(row)?;
            count += 1;
        }
    }
    Ok(count)
}

/// Stream one chunk's rows into a job (the paper's inner read loop).
/// Sparse inputs stream through [`run_chunk_sparse`] instead — densifying
/// them row by row here would silently undo the `O(nnz)` contract.
pub fn run_chunk<J: RowJob>(input: &InputSpec, chunk: &ChunkMeta, job: &mut J) -> Result<u64> {
    let mut row = Vec::new();
    let count;
    match input.format {
        InputFormat::Csv => {
            let r = chunk
                .byte_range
                .ok_or_else(|| Error::Config("csv chunk without byte range".into()))?;
            let mut reader = CsvRowReader::open_range(&input.path, r.start, r.end)?;
            count = pump_rows(|row| reader.next_row(row), job, &mut row)?;
        }
        InputFormat::Bin => {
            let (start, end) = chunk
                .row_range
                .ok_or_else(|| Error::Config("bin chunk without row range".into()))?;
            let mut reader = BinMatReader::open_rows(&input.path, start, end)?;
            count = pump_rows(|row| reader.next_row(row), job, &mut row)?;
        }
        InputFormat::Libsvm | InputFormat::SparseCsv | InputFormat::Csr => {
            return Err(Error::Config(format!(
                "{:?} input needs the sparse streaming path (run_chunk_sparse); \
                 this operation only supports dense csv/bin inputs",
                input.format
            )));
        }
    }
    trace::time_section(Section::Compute, || job.post())?;
    Ok(count)
}

/// Stream one chunk's rows of a *sparse* input into a [`SparseRowJob`] —
/// the sparse sibling of [`run_chunk`]. Rows never densify.
pub fn run_chunk_sparse<J: SparseRowJob>(
    input: &InputSpec,
    chunk: &ChunkMeta,
    job: &mut J,
) -> Result<u64> {
    let mut reader = match input.format {
        InputFormat::Libsvm | InputFormat::SparseCsv => {
            let r = chunk
                .byte_range
                .ok_or_else(|| Error::Config("sparse text chunk without byte range".into()))?;
            SparseRowReader::Text(SparseTextReader::open_range(
                &input.path,
                input.format,
                r.start,
                r.end,
            )?)
        }
        InputFormat::Csr => {
            let (start, end) = chunk
                .row_range
                .ok_or_else(|| Error::Config("csr chunk without row range".into()))?;
            SparseRowReader::Csr(CsrReader::open_rows(&input.path, start, end)?)
        }
        other => {
            return Err(Error::Config(format!(
                "run_chunk_sparse on dense {other:?} input"
            )));
        }
    };
    let mut indices = Vec::new();
    let mut values = Vec::new();
    let mut count = 0u64;
    if trace::sections_active() {
        loop {
            let t0 = std::time::Instant::now();
            let more = reader.next_row(&mut indices, &mut values)?;
            trace::sections_add(Section::Decode, t0.elapsed());
            if !more {
                break;
            }
            let t1 = std::time::Instant::now();
            job.exec_row(&indices, &values)?;
            trace::sections_add(Section::Compute, t1.elapsed());
            count += 1;
        }
    } else {
        while reader.next_row(&mut indices, &mut values)? {
            job.exec_row(&indices, &values)?;
            count += 1;
        }
    }
    trace::time_section(Section::Compute, || job.post())?;
    Ok(count)
}

/// Outcome of one worker.
pub struct WorkerResult<J> {
    pub chunk: ChunkMeta,
    pub rows: u64,
    pub job: J,
}

/// Run a job family over the input with `workers` parallel workers, one
/// chunk per worker (the paper's static schedule — the standalone
/// subcommands and benches keep this shape).
///
/// `factory(chunk)` builds the per-chunk job (the paper constructs a
/// `workobj` per process with `ci` = chunk index). Results come back in
/// chunk order, so concatenated worker outputs preserve global row order.
pub fn run<J, F>(input: &InputSpec, workers: usize, factory: F) -> Result<Vec<WorkerResult<J>>>
where
    J: RowJob,
    F: Fn(&ChunkMeta) -> Result<J> + Sync,
{
    run_chunked(input, workers, |chunk| {
        let mut job = factory(chunk)?;
        let rows = run_chunk(input, chunk, &mut job)?;
        Ok(WorkerResult { chunk: *chunk, rows, job })
    })
}

/// [`run_scheduled`] under the static one-chunk-per-worker policy —
/// generalizes [`run`] for callers that build their own jobs.
pub fn run_chunked<T, F>(input: &InputSpec, workers: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(&ChunkMeta) -> Result<T> + Sync,
{
    Ok(run_scheduled(input, workers, &SchedPolicy::static_one_per_worker(), f)?.0)
}

/// The queue-driven engine: plan chunks under `policy`, run `f` over each
/// through a `workers`-thread pool fed by a [`ChunkScheduler`] (bounded
/// retry on chunk failure, a panic counts as a failed attempt), and return
/// the per-chunk results **in chunk order** plus the pass's scheduling
/// stats.
pub fn run_scheduled<T, F>(
    input: &InputSpec,
    workers: usize,
    policy: &SchedPolicy,
    f: F,
) -> Result<(Vec<T>, SchedStats)>
where
    T: Send,
    F: Fn(&ChunkMeta) -> Result<T> + Sync,
{
    let chunks = plan_chunks_policy(input, workers, policy)?;
    if chunks.is_empty() {
        return Ok((Vec::new(), SchedStats::default()));
    }
    let sched = ChunkScheduler::new(chunks.len(), policy.max_retries);
    let results: Vec<std::sync::Mutex<Option<T>>> =
        chunks.iter().map(|_| std::sync::Mutex::new(None)).collect();
    let threads = workers.max(1).min(chunks.len());
    // Captured on the calling thread so every chunk span parents under the
    // pass span that is active *here*, not whatever the pool threads see.
    let recording = trace::active();
    let parent = trace::current();
    std::thread::scope(|scope| {
        let sched = &sched;
        let results = &results;
        let chunks = &chunks;
        let f = &f;
        for lane in 0..threads {
            scope.spawn(move || loop {
                match sched.claim_blocking() {
                    Claim::Finished => break,
                    Claim::Run(i) => {
                        let t0 = std::time::Instant::now();
                        let mut span = Span::with_parent(&format!("chunk {i}"), "chunk", parent);
                        span.arg_num("chunk", i as f64);
                        span.arg_str("worker", &format!("local-{lane}"));
                        if recording {
                            trace::sections_begin();
                        }
                        let outcome = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| f(&chunks[i])),
                        );
                        let sec = trace::sections_take().unwrap_or_default();
                        if recording {
                            span.arg_num("decode_ms", sec.decode_us as f64 / 1e3);
                            span.arg_num("compute_ms", sec.compute_us as f64 / 1e3);
                            span.arg_num("encode_ms", sec.encode_us as f64 / 1e3);
                        }
                        match outcome {
                            Ok(Ok(v)) => {
                                span.arg_str("outcome", "ok");
                                if sched.complete(i, t0.elapsed()) {
                                    *results[i].lock().unwrap() = Some(v);
                                }
                            }
                            Ok(Err(e)) => {
                                span.arg_str("outcome", "failed");
                                sched.fail(i, e);
                            }
                            Err(_) => {
                                span.arg_str("outcome", "panicked");
                                sched.fail(
                                    i,
                                    Error::Other(format!("chunk {i} worker panicked")),
                                );
                            }
                        }
                    }
                }
            });
        }
    });
    let stats = sched.finish()?;
    let mut out = Vec::with_capacity(results.len());
    for (i, slot) in results.into_iter().enumerate() {
        match slot.into_inner().unwrap() {
            Some(v) => out.push(v),
            None => {
                return Err(Error::Other(format!("chunk {i} completed without a result")));
            }
        }
    }
    Ok((out, stats))
}

/// Sum per-worker partial matrices — the global reduce of the paper's
/// commutative accumulations, and the *leaf* of the tree reduce: each
/// [`crate::svd::reduce::tree_reduce`] merge node is exactly this fold
/// over its pair, so star and tree topologies agree bit for bit when
/// partials are combined in chunk-index order.
pub fn reduce_partials(parts: Vec<crate::linalg::Matrix>) -> Result<crate::linalg::Matrix> {
    let mut iter = parts.into_iter();
    let mut acc = iter
        .next()
        .ok_or_else(|| Error::Other("reduce of zero partials".into()))?;
    for p in iter {
        acc.add_assign(&p)?;
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    /// Counts rows and sums all elements.
    struct SumJob {
        rows: u64,
        sum: f64,
        posted: bool,
    }

    impl RowJob for SumJob {
        fn exec_row(&mut self, row: &[f64]) -> Result<()> {
            self.rows += 1;
            self.sum += row.iter().sum::<f64>();
            Ok(())
        }

        fn post(&mut self) -> Result<()> {
            self.posted = true;
            Ok(())
        }
    }

    fn write_csv(name: &str, rows: usize) -> InputSpec {
        let dir = std::env::temp_dir().join("tallfat_test_splitproc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name).to_string_lossy().into_owned();
        let m = Matrix::from_fn(rows, 3, |i, j| (i * 3 + j) as f64);
        crate::io::csv::write_matrix_csv(&m, &path).unwrap();
        InputSpec::csv(path)
    }

    fn write_bin(name: &str, rows: usize) -> InputSpec {
        let dir = std::env::temp_dir().join("tallfat_test_splitproc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name).to_string_lossy().into_owned();
        let m = Matrix::from_fn(rows, 3, |i, j| (i * 3 + j) as f64);
        crate::io::binmat::write_matrix_bin(&m, &path).unwrap();
        InputSpec::bin(path)
    }

    fn expected_sum(rows: usize) -> f64 {
        (0..rows * 3).map(|v| v as f64).sum()
    }

    #[test]
    fn all_rows_processed_csv() {
        let input = write_csv("rows.csv", 103);
        for workers in [1, 2, 4, 9] {
            let results = run(&input, workers, |_c| {
                Ok(SumJob { rows: 0, sum: 0.0, posted: false })
            })
            .unwrap();
            let total_rows: u64 = results.iter().map(|r| r.rows).sum();
            let total_sum: f64 = results.iter().map(|r| r.job.sum).sum();
            assert_eq!(total_rows, 103, "workers={workers}");
            assert!((total_sum - expected_sum(103)).abs() < 1e-9);
            assert!(results.iter().all(|r| r.job.posted));
        }
    }

    #[test]
    fn all_rows_processed_bin() {
        let input = write_bin("rows.bin", 61);
        for workers in [1, 3, 8] {
            let results = run(&input, workers, |_c| {
                Ok(SumJob { rows: 0, sum: 0.0, posted: false })
            })
            .unwrap();
            let total_rows: u64 = results.iter().map(|r| r.rows).sum();
            assert_eq!(total_rows, 61);
            let total_sum: f64 = results.iter().map(|r| r.job.sum).sum();
            assert!((total_sum - expected_sum(61)).abs() < 1e-9);
        }
    }

    /// Counts sparse rows and sums all stored values.
    struct SparseSumJob {
        rows: u64,
        sum: f64,
        posted: bool,
    }

    impl SparseRowJob for SparseSumJob {
        fn exec_row(&mut self, _indices: &[u32], values: &[f64]) -> Result<()> {
            self.rows += 1;
            self.sum += values.iter().sum::<f64>();
            Ok(())
        }

        fn post(&mut self) -> Result<()> {
            self.posted = true;
            Ok(())
        }
    }

    fn write_sparse(name: &str, rows: usize, format: InputFormat) -> (InputSpec, f64) {
        let dir = std::env::temp_dir().join("tallfat_test_splitproc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name).to_string_lossy().into_owned();
        // every third entry nonzero, plus an all-zero row 7
        let m = Matrix::from_fn(rows, 6, |i, j| {
            if i == 7 || (i + j) % 3 != 0 {
                0.0
            } else {
                (i * 6 + j) as f64 + 1.0
            }
        });
        let total: f64 = m.data().iter().sum();
        let s = crate::linalg::SparseMatrix::from_dense(&m, 0.0);
        crate::io::sparse::write_sparse_matrix(&s, &path, format).unwrap();
        (InputSpec { path, format }, total)
    }

    #[test]
    fn sparse_chunks_see_every_row_once() {
        for (name, format) in [
            ("rows.libsvm", InputFormat::Libsvm),
            ("rows.csr", InputFormat::Csr),
        ] {
            let (input, total) = write_sparse(name, 53, format);
            for workers in [1, 2, 5] {
                let (results, _) = run_scheduled(
                    &input,
                    workers,
                    &SchedPolicy::default(),
                    |chunk| {
                        let mut job = SparseSumJob { rows: 0, sum: 0.0, posted: false };
                        let rows = run_chunk_sparse(&input, chunk, &mut job)?;
                        Ok((rows, job.sum, job.posted))
                    },
                )
                .unwrap();
                let rows: u64 = results.iter().map(|(r, _, _)| r).sum();
                let sum: f64 = results.iter().map(|(_, s, _)| s).sum();
                assert_eq!(rows, 53, "{format:?} workers={workers}");
                assert!((sum - total).abs() < 1e-9, "{format:?}");
                assert!(results.iter().all(|(_, _, p)| *p));
            }
        }
    }

    #[test]
    fn dense_run_chunk_rejects_sparse_input() {
        let (input, _) = write_sparse("reject.libsvm", 10, InputFormat::Libsvm);
        let chunks = plan_chunks(&input, 1).unwrap();
        let mut job = SumJob { rows: 0, sum: 0.0, posted: false };
        assert!(run_chunk(&input, &chunks[0], &mut job).is_err());
        // and the reverse: sparse streaming over a dense input
        let dense = write_csv("rejectd.csv", 5);
        let chunks = plan_chunks(&dense, 1).unwrap();
        let mut sjob = SparseSumJob { rows: 0, sum: 0.0, posted: false };
        assert!(run_chunk_sparse(&dense, &chunks[0], &mut sjob).is_err());
    }

    #[test]
    fn chunk_meta_indices_sequential() {
        let input = write_csv("meta.csv", 40);
        let chunks = plan_chunks(&input, 4).unwrap();
        for (i, c) in chunks.iter().enumerate() {
            assert_eq!(c.index, i);
            assert_eq!(c.total, chunks.len());
        }
    }

    #[test]
    fn factory_error_propagates() {
        let input = write_csv("err.csv", 10);
        let r = run(&input, 2, |c| -> Result<SumJob> {
            if c.index == 1 {
                Err(Error::Other("boom".into()))
            } else {
                Ok(SumJob { rows: 0, sum: 0.0, posted: false })
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn results_in_chunk_order() {
        let input = write_csv("order.csv", 50);
        let results = run(&input, 5, |_c| {
            Ok(SumJob { rows: 0, sum: 0.0, posted: false })
        })
        .unwrap();
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.chunk.index, i);
        }
    }

    #[test]
    fn reduce_partials_sums() {
        let a = Matrix::eye(2);
        let b = Matrix::eye(2).scale(3.0);
        let r = reduce_partials(vec![a, b]).unwrap();
        assert_eq!(r.get(0, 0), 4.0);
        assert!(reduce_partials(vec![]).is_err());
    }

    #[test]
    fn dynamic_policy_plans_more_chunks_than_workers() {
        let input = write_csv("dyn.csv", 120);
        let policy = SchedPolicy { chunks_per_worker: 4, ..SchedPolicy::default() };
        let (results, stats) = run_scheduled(&input, 3, &policy, |chunk| {
            let mut job = SumJob { rows: 0, sum: 0.0, posted: false };
            let rows = run_chunk(&input, chunk, &mut job)?;
            Ok((rows, job.sum))
        })
        .unwrap();
        assert!(results.len() > 3, "got {} chunks", results.len());
        assert_eq!(stats.chunks, results.len());
        let rows: u64 = results.iter().map(|(r, _)| r).sum();
        let sum: f64 = results.iter().map(|(_, s)| s).sum();
        assert_eq!(rows, 120);
        assert!((sum - expected_sum(120)).abs() < 1e-9);
    }

    #[test]
    fn chunk_rows_policy_caps_chunk_size() {
        let input = write_bin("caprows.bin", 100);
        let policy = SchedPolicy { chunk_rows: 16, ..SchedPolicy::default() };
        let chunks = plan_chunks_policy(&input, 2, &policy).unwrap();
        assert_eq!(chunks.len(), 100usize.div_ceil(16));
        for c in &chunks {
            let (s, e) = c.row_range.unwrap();
            assert!(e - s <= 16, "chunk of {} rows", e - s);
        }
    }

    #[test]
    fn chunk_rows_policy_estimates_csv_without_full_scan() {
        // CSV row counts are estimated from size / first-line width: for a
        // roughly uniform file the plan must land near rows/chunk_rows.
        let input = write_csv("caprows.csv", 120);
        let policy = SchedPolicy { chunk_rows: 20, ..SchedPolicy::default() };
        let chunks = plan_chunks_policy(&input, 2, &policy).unwrap();
        assert!(
            (4..=12).contains(&chunks.len()),
            "expected ~6 chunks, planned {}",
            chunks.len()
        );
    }

    #[test]
    fn policy_plan_is_a_fixed_point_of_its_count() {
        // Tiny file: the fine-grained target collapses; the plan must
        // still be reproducible from its own chunk count (the cluster
        // ships only (index, total) over the wire).
        let input = write_csv("fixedpoint.csv", 5);
        let policy = SchedPolicy { chunks_per_worker: 8, ..SchedPolicy::default() };
        let plan = plan_chunks_policy(&input, 4, &policy).unwrap();
        let replan = plan_chunks(&input, plan.len()).unwrap();
        assert_eq!(plan.len(), replan.len());
        for (a, b) in plan.iter().zip(replan.iter()) {
            assert_eq!(a.byte_range, b.byte_range);
            assert_eq!(a.row_range, b.row_range);
        }
    }

    #[test]
    fn poisoned_chunk_retries_then_surfaces_named_error() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let input = write_csv("poison.csv", 60);
        let attempts = AtomicUsize::new(0);
        let policy = SchedPolicy {
            chunks_per_worker: 3,
            max_retries: 2,
            ..SchedPolicy::default()
        };
        let err = run_scheduled(&input, 2, &policy, |chunk| {
            if chunk.index == 2 {
                attempts.fetch_add(1, Ordering::SeqCst);
                return Err(Error::Other("bad rows on disk".into()));
            }
            let mut job = SumJob { rows: 0, sum: 0.0, posted: false };
            run_chunk(&input, chunk, &mut job)
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("chunk 2"), "{err}");
        assert!(err.contains("3 attempts"), "{err}");
        assert!(err.contains("bad rows on disk"), "{err}");
        assert_eq!(attempts.load(Ordering::SeqCst), 3, "1 try + 2 retries");
    }

    #[test]
    fn flaky_chunk_recovers_via_retry() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let input = write_csv("flaky.csv", 80);
        let failures = AtomicUsize::new(0);
        let policy = SchedPolicy {
            chunks_per_worker: 4,
            max_retries: 2,
            ..SchedPolicy::default()
        };
        let (results, stats) = run_scheduled(&input, 2, &policy, |chunk| {
            if chunk.index == 1 && failures.fetch_add(1, Ordering::SeqCst) == 0 {
                return Err(Error::Other("transient".into()));
            }
            let mut job = SumJob { rows: 0, sum: 0.0, posted: false };
            run_chunk(&input, chunk, &mut job)
        })
        .unwrap();
        let rows: u64 = results.iter().sum();
        assert_eq!(rows, 80, "all rows seen despite the transient failure");
        assert!(stats.retried >= 1);
    }

    #[test]
    fn panicking_chunk_counts_as_failed_attempt() {
        let input = write_csv("panic.csv", 40);
        let policy = SchedPolicy {
            chunks_per_worker: 2,
            max_retries: 0,
            ..SchedPolicy::default()
        };
        let err = run_scheduled(&input, 2, &policy, |chunk| -> Result<u64> {
            if chunk.index == 0 {
                panic!("chunk job blew up");
            }
            Ok(0)
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("chunk 0"), "{err}");
        assert!(err.contains("panicked"), "{err}");
    }
}
