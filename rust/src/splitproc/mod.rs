//! The Split-Process engine (paper §3).
//!
//! Each worker is handed a chunk of the shared input file — newline-aligned
//! byte ranges for CSV, exact row ranges for binary — opens its own reader,
//! streams rows into a [`RowJob`], and calls `post()` when its chunk is
//! drained. The leader then merges the per-worker results (a commutative
//! reduction for every job in this system).
//!
//! This is the paper's `split_process` function as a library, generalized
//! over jobs exactly like its `workobj` (`exec(line)` / `post()`).

pub mod block;
pub mod job;

pub use block::{BlockJob, Blocked};
pub use job::{CenteredJob, RowJob};

use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::binmat::{BinMatHeader, BinMatReader};
use crate::io::chunker::{chunk_byte_ranges, chunk_row_ranges, ByteRange};
use crate::io::csv::CsvRowReader;
use crate::io::InputSpec;

/// What a worker knows about its assignment (the paper's `workobj.ci` plus
/// the chunk geometry).
#[derive(Clone, Copy, Debug)]
pub struct ChunkMeta {
    /// Chunk index (0-based).
    pub index: usize,
    /// Total number of chunks in this run.
    pub total: usize,
    /// Byte range for CSV inputs.
    pub byte_range: Option<ByteRange>,
    /// Row range for binary inputs.
    pub row_range: Option<(u64, u64)>,
}

/// Plan the chunk assignment for an input without running anything.
pub fn plan_chunks(input: &InputSpec, workers: usize) -> Result<Vec<ChunkMeta>> {
    if workers == 0 {
        return Err(Error::Config("workers must be >= 1".into()));
    }
    match input.format {
        InputFormat::Csv => {
            let ranges = chunk_byte_ranges(&input.path, workers)?;
            let total = ranges.len();
            Ok(ranges
                .into_iter()
                .enumerate()
                .map(|(index, r)| ChunkMeta {
                    index,
                    total,
                    byte_range: Some(r),
                    row_range: None,
                })
                .collect())
        }
        InputFormat::Bin => {
            let h = BinMatHeader::read_from(&input.path)?;
            let ranges = chunk_row_ranges(h.rows, workers);
            let total = ranges.len();
            Ok(ranges
                .into_iter()
                .enumerate()
                .map(|(index, r)| ChunkMeta {
                    index,
                    total,
                    byte_range: None,
                    row_range: Some(r),
                })
                .collect())
        }
    }
}

/// Stream one chunk's rows into a job (the paper's inner read loop).
pub fn run_chunk<J: RowJob>(input: &InputSpec, chunk: &ChunkMeta, job: &mut J) -> Result<u64> {
    let mut row = Vec::new();
    let mut count = 0u64;
    match input.format {
        InputFormat::Csv => {
            let r = chunk
                .byte_range
                .ok_or_else(|| Error::Config("csv chunk without byte range".into()))?;
            let mut reader = CsvRowReader::open_range(&input.path, r.start, r.end)?;
            while reader.next_row(&mut row)? {
                job.exec_row(&row)?;
                count += 1;
            }
        }
        InputFormat::Bin => {
            let (start, end) = chunk
                .row_range
                .ok_or_else(|| Error::Config("bin chunk without row range".into()))?;
            let mut reader = BinMatReader::open_rows(&input.path, start, end)?;
            while reader.next_row(&mut row)? {
                job.exec_row(&row)?;
                count += 1;
            }
        }
    }
    job.post()?;
    Ok(count)
}

/// Outcome of one worker.
pub struct WorkerResult<J> {
    pub chunk: ChunkMeta,
    pub rows: u64,
    pub job: J,
}

/// Run a job family over the input with `workers` parallel workers.
///
/// `factory(chunk)` builds the per-chunk job (the paper constructs a
/// `workobj` per process with `ci` = chunk index). Results come back in
/// chunk order, so concatenated worker outputs preserve global row order.
pub fn run<J, F>(input: &InputSpec, workers: usize, factory: F) -> Result<Vec<WorkerResult<J>>>
where
    J: RowJob,
    F: Fn(&ChunkMeta) -> Result<J> + Sync,
{
    run_chunked(input, workers, |chunk| {
        let mut job = factory(chunk)?;
        let rows = run_chunk(input, chunk, &mut job)?;
        Ok(WorkerResult { chunk: *chunk, rows, job })
    })
}

/// Run an arbitrary per-chunk computation with one thread per chunk and
/// collect the results in chunk order. Generalizes [`run`] for callers that
/// build their own jobs (the [`crate::svd::executor::LocalExecutor`]).
pub fn run_chunked<T, F>(input: &InputSpec, workers: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(&ChunkMeta) -> Result<T> + Sync,
{
    let chunks = plan_chunks(input, workers)?;
    let results: Vec<Result<T>> = std::thread::scope(|scope| {
        let handles: Vec<_> = chunks
            .iter()
            .map(|chunk| {
                let f = &f;
                let chunk = *chunk;
                scope.spawn(move || f(&chunk))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(Error::Other("worker panicked".into())))
            })
            .collect()
    });
    results.into_iter().collect()
}

/// Sum per-worker partial matrices — the global reduce of the paper's
/// commutative accumulations.
pub fn reduce_partials(parts: Vec<crate::linalg::Matrix>) -> Result<crate::linalg::Matrix> {
    let mut iter = parts.into_iter();
    let mut acc = iter
        .next()
        .ok_or_else(|| Error::Other("reduce of zero partials".into()))?;
    for p in iter {
        acc.add_assign(&p)?;
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    /// Counts rows and sums all elements.
    struct SumJob {
        rows: u64,
        sum: f64,
        posted: bool,
    }

    impl RowJob for SumJob {
        fn exec_row(&mut self, row: &[f64]) -> Result<()> {
            self.rows += 1;
            self.sum += row.iter().sum::<f64>();
            Ok(())
        }

        fn post(&mut self) -> Result<()> {
            self.posted = true;
            Ok(())
        }
    }

    fn write_csv(name: &str, rows: usize) -> InputSpec {
        let dir = std::env::temp_dir().join("tallfat_test_splitproc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name).to_string_lossy().into_owned();
        let m = Matrix::from_fn(rows, 3, |i, j| (i * 3 + j) as f64);
        crate::io::csv::write_matrix_csv(&m, &path).unwrap();
        InputSpec::csv(path)
    }

    fn write_bin(name: &str, rows: usize) -> InputSpec {
        let dir = std::env::temp_dir().join("tallfat_test_splitproc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name).to_string_lossy().into_owned();
        let m = Matrix::from_fn(rows, 3, |i, j| (i * 3 + j) as f64);
        crate::io::binmat::write_matrix_bin(&m, &path).unwrap();
        InputSpec::bin(path)
    }

    fn expected_sum(rows: usize) -> f64 {
        (0..rows * 3).map(|v| v as f64).sum()
    }

    #[test]
    fn all_rows_processed_csv() {
        let input = write_csv("rows.csv", 103);
        for workers in [1, 2, 4, 9] {
            let results = run(&input, workers, |_c| {
                Ok(SumJob { rows: 0, sum: 0.0, posted: false })
            })
            .unwrap();
            let total_rows: u64 = results.iter().map(|r| r.rows).sum();
            let total_sum: f64 = results.iter().map(|r| r.job.sum).sum();
            assert_eq!(total_rows, 103, "workers={workers}");
            assert!((total_sum - expected_sum(103)).abs() < 1e-9);
            assert!(results.iter().all(|r| r.job.posted));
        }
    }

    #[test]
    fn all_rows_processed_bin() {
        let input = write_bin("rows.bin", 61);
        for workers in [1, 3, 8] {
            let results = run(&input, workers, |_c| {
                Ok(SumJob { rows: 0, sum: 0.0, posted: false })
            })
            .unwrap();
            let total_rows: u64 = results.iter().map(|r| r.rows).sum();
            assert_eq!(total_rows, 61);
            let total_sum: f64 = results.iter().map(|r| r.job.sum).sum();
            assert!((total_sum - expected_sum(61)).abs() < 1e-9);
        }
    }

    #[test]
    fn chunk_meta_indices_sequential() {
        let input = write_csv("meta.csv", 40);
        let chunks = plan_chunks(&input, 4).unwrap();
        for (i, c) in chunks.iter().enumerate() {
            assert_eq!(c.index, i);
            assert_eq!(c.total, chunks.len());
        }
    }

    #[test]
    fn factory_error_propagates() {
        let input = write_csv("err.csv", 10);
        let r = run(&input, 2, |c| -> Result<SumJob> {
            if c.index == 1 {
                Err(Error::Other("boom".into()))
            } else {
                Ok(SumJob { rows: 0, sum: 0.0, posted: false })
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn results_in_chunk_order() {
        let input = write_csv("order.csv", 50);
        let results = run(&input, 5, |_c| {
            Ok(SumJob { rows: 0, sum: 0.0, posted: false })
        })
        .unwrap();
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.chunk.index, i);
        }
    }

    #[test]
    fn reduce_partials_sums() {
        let a = Matrix::eye(2);
        let b = Matrix::eye(2).scale(3.0);
        let r = reduce_partials(vec![a, b]).unwrap();
        assert_eq!(r.get(0, 0), 4.0);
        assert!(reduce_partials(vec![]).is_err());
    }
}
