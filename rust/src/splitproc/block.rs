//! Row-to-block buffering adapters.
//!
//! The XLA artifacts (and the blocked native kernels) consume fixed-size row
//! blocks, while the Split-Process engine streams single rows. [`Blocked`]
//! buffers rows into a reusable block matrix and flushes it to a
//! [`BlockJob`]; the final partial block is flushed at `post` time. Backends
//! pad partial blocks with zero rows — safe because zero rows contribute
//! nothing to Gram/projection/tmul sums (a tested invariant on both the
//! python and rust sides).
//!
//! [`SparseBlocked`] is the CSR sibling: sparse rows buffer into a reusable
//! [`SparseMatrix`] block (`O(nnz)` per block, not `O(block * n)`) and
//! flush to a [`SparseBlockJob`].

use crate::error::{Error, Result};
use crate::linalg::{Matrix, SparseMatrix};
use crate::splitproc::job::{RowJob, SparseRowJob};

/// A job consuming row *blocks* (at most `block_rows` rows per call; the
/// last block of a chunk may be smaller).
pub trait BlockJob: Send {
    /// Process one block. `block` has exactly `rows` valid rows.
    fn exec_block(&mut self, block: &Matrix) -> Result<()>;

    /// Chunk finished (called after the final partial block).
    fn post_blocks(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Adapts a [`BlockJob`] into a [`RowJob`] with an internal reusable buffer.
pub struct Blocked<J: BlockJob> {
    job: J,
    block_rows: usize,
    cols: usize,
    buf: Vec<f64>,
    filled: usize,
}

impl<J: BlockJob> Blocked<J> {
    pub fn new(job: J, block_rows: usize, cols: usize) -> Self {
        Blocked {
            job,
            block_rows,
            cols,
            buf: vec![0.0; block_rows * cols],
            filled: 0,
        }
    }

    pub fn into_inner(self) -> J {
        self.job
    }

    pub fn job(&self) -> &J {
        &self.job
    }

    fn flush(&mut self) -> Result<()> {
        if self.filled == 0 {
            return Ok(());
        }
        let block = Matrix::from_vec(
            self.filled,
            self.cols,
            self.buf[..self.filled * self.cols].to_vec(),
        )?;
        self.job.exec_block(&block)?;
        self.filled = 0;
        Ok(())
    }
}

impl<J: BlockJob> RowJob for Blocked<J> {
    fn exec_row(&mut self, row: &[f64]) -> Result<()> {
        if row.len() != self.cols {
            return Err(Error::shape(format!(
                "block buffer: row has {} cols, expected {}",
                row.len(),
                self.cols
            )));
        }
        let off = self.filled * self.cols;
        self.buf[off..off + self.cols].copy_from_slice(row);
        self.filled += 1;
        if self.filled == self.block_rows {
            self.flush()?;
        }
        Ok(())
    }

    fn post(&mut self) -> Result<()> {
        self.flush()?;
        self.job.post_blocks()
    }
}

/// A job consuming CSR row *blocks* (at most `block_rows` rows per call;
/// the last block of a chunk may be smaller).
pub trait SparseBlockJob: Send {
    /// Process one sparse block.
    fn exec_block(&mut self, block: &SparseMatrix) -> Result<()>;

    /// Chunk finished (called after the final partial block).
    fn post_blocks(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Adapts a [`SparseBlockJob`] into a [`SparseRowJob`] with a reusable CSR
/// buffer — memory stays proportional to the block's nonzeros.
pub struct SparseBlocked<J: SparseBlockJob> {
    job: J,
    block_rows: usize,
    buf: SparseMatrix,
}

impl<J: SparseBlockJob> SparseBlocked<J> {
    pub fn new(job: J, block_rows: usize, cols: usize) -> Self {
        SparseBlocked { job, block_rows, buf: SparseMatrix::with_cols(cols) }
    }

    pub fn into_inner(self) -> J {
        self.job
    }

    pub fn job(&self) -> &J {
        &self.job
    }

    fn flush(&mut self) -> Result<()> {
        if self.buf.rows() == 0 {
            return Ok(());
        }
        self.job.exec_block(&self.buf)?;
        self.buf.clear_rows();
        Ok(())
    }
}

impl<J: SparseBlockJob> SparseRowJob for SparseBlocked<J> {
    fn exec_row(&mut self, indices: &[u32], values: &[f64]) -> Result<()> {
        self.buf.push_row(indices, values)?;
        if self.buf.rows() == self.block_rows {
            self.flush()?;
        }
        Ok(())
    }

    fn post(&mut self) -> Result<()> {
        self.flush()?;
        self.job.post_blocks()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Recorder {
        blocks: Vec<(usize, usize)>,
        row_sum: f64,
        posted: bool,
    }

    impl BlockJob for Recorder {
        fn exec_block(&mut self, block: &Matrix) -> Result<()> {
            self.blocks.push(block.shape());
            self.row_sum += block.data().iter().sum::<f64>();
            Ok(())
        }

        fn post_blocks(&mut self) -> Result<()> {
            self.posted = true;
            Ok(())
        }
    }

    fn feed(rows: usize, block: usize) -> Recorder {
        let mut b = Blocked::new(
            Recorder { blocks: vec![], row_sum: 0.0, posted: false },
            block,
            2,
        );
        for i in 0..rows {
            b.exec_row(&[i as f64, 1.0]).unwrap();
        }
        b.post().unwrap();
        b.into_inner()
    }

    #[test]
    fn full_blocks_then_tail() {
        let r = feed(10, 4);
        assert_eq!(r.blocks, vec![(4, 2), (4, 2), (2, 2)]);
        assert!(r.posted);
        let want: f64 = (0..10).map(|i| i as f64 + 1.0).sum();
        assert!((r.row_sum - want).abs() < 1e-12);
    }

    #[test]
    fn exact_multiple_no_empty_tail() {
        let r = feed(8, 4);
        assert_eq!(r.blocks, vec![(4, 2), (4, 2)]);
    }

    #[test]
    fn zero_rows_posts_cleanly() {
        let r = feed(0, 4);
        assert!(r.blocks.is_empty());
        assert!(r.posted);
    }

    #[test]
    fn wrong_width_rejected() {
        let mut b = Blocked::new(
            Recorder { blocks: vec![], row_sum: 0.0, posted: false },
            4,
            3,
        );
        assert!(b.exec_row(&[1.0, 2.0]).is_err());
    }

    struct SparseRecorder {
        blocks: Vec<(usize, usize)>,
        nnz_sum: f64,
        posted: bool,
    }

    impl SparseBlockJob for SparseRecorder {
        fn exec_block(&mut self, block: &SparseMatrix) -> Result<()> {
            self.blocks.push((block.rows(), block.nnz()));
            self.nnz_sum += block.parts().2.iter().sum::<f64>();
            Ok(())
        }

        fn post_blocks(&mut self) -> Result<()> {
            self.posted = true;
            Ok(())
        }
    }

    #[test]
    fn sparse_blocked_buffers_and_flushes() {
        let mut b = SparseBlocked::new(
            SparseRecorder { blocks: vec![], nnz_sum: 0.0, posted: false },
            4,
            6,
        );
        for i in 0..10u32 {
            // one nonzero per row, plus an all-zero row in the middle
            if i == 5 {
                b.exec_row(&[], &[]).unwrap();
            } else {
                b.exec_row(&[i % 6], &[1.0]).unwrap();
            }
        }
        b.post().unwrap();
        let r = b.into_inner();
        assert_eq!(r.blocks, vec![(4, 4), (4, 3), (2, 2)]);
        assert!(r.posted);
        assert!((r.nnz_sum - 9.0).abs() < 1e-12);
        // bad row rejected
        let mut b = SparseBlocked::new(
            SparseRecorder { blocks: vec![], nnz_sum: 0.0, posted: false },
            2,
            3,
        );
        assert!(b.exec_row(&[7], &[1.0]).is_err());
    }
}
