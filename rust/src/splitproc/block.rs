//! Row-to-block buffering adapter.
//!
//! The XLA artifacts (and the blocked native kernels) consume fixed-size row
//! blocks, while the Split-Process engine streams single rows. [`Blocked`]
//! buffers rows into a reusable block matrix and flushes it to a
//! [`BlockJob`]; the final partial block is flushed at `post` time. Backends
//! pad partial blocks with zero rows — safe because zero rows contribute
//! nothing to Gram/projection/tmul sums (a tested invariant on both the
//! python and rust sides).

use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::splitproc::job::RowJob;

/// A job consuming row *blocks* (at most `block_rows` rows per call; the
/// last block of a chunk may be smaller).
pub trait BlockJob: Send {
    /// Process one block. `block` has exactly `rows` valid rows.
    fn exec_block(&mut self, block: &Matrix) -> Result<()>;

    /// Chunk finished (called after the final partial block).
    fn post_blocks(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Adapts a [`BlockJob`] into a [`RowJob`] with an internal reusable buffer.
pub struct Blocked<J: BlockJob> {
    job: J,
    block_rows: usize,
    cols: usize,
    buf: Vec<f64>,
    filled: usize,
}

impl<J: BlockJob> Blocked<J> {
    pub fn new(job: J, block_rows: usize, cols: usize) -> Self {
        Blocked {
            job,
            block_rows,
            cols,
            buf: vec![0.0; block_rows * cols],
            filled: 0,
        }
    }

    pub fn into_inner(self) -> J {
        self.job
    }

    pub fn job(&self) -> &J {
        &self.job
    }

    fn flush(&mut self) -> Result<()> {
        if self.filled == 0 {
            return Ok(());
        }
        let block = Matrix::from_vec(
            self.filled,
            self.cols,
            self.buf[..self.filled * self.cols].to_vec(),
        )?;
        self.job.exec_block(&block)?;
        self.filled = 0;
        Ok(())
    }
}

impl<J: BlockJob> RowJob for Blocked<J> {
    fn exec_row(&mut self, row: &[f64]) -> Result<()> {
        if row.len() != self.cols {
            return Err(Error::shape(format!(
                "block buffer: row has {} cols, expected {}",
                row.len(),
                self.cols
            )));
        }
        let off = self.filled * self.cols;
        self.buf[off..off + self.cols].copy_from_slice(row);
        self.filled += 1;
        if self.filled == self.block_rows {
            self.flush()?;
        }
        Ok(())
    }

    fn post(&mut self) -> Result<()> {
        self.flush()?;
        self.job.post_blocks()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Recorder {
        blocks: Vec<(usize, usize)>,
        row_sum: f64,
        posted: bool,
    }

    impl BlockJob for Recorder {
        fn exec_block(&mut self, block: &Matrix) -> Result<()> {
            self.blocks.push(block.shape());
            self.row_sum += block.data().iter().sum::<f64>();
            Ok(())
        }

        fn post_blocks(&mut self) -> Result<()> {
            self.posted = true;
            Ok(())
        }
    }

    fn feed(rows: usize, block: usize) -> Recorder {
        let mut b = Blocked::new(
            Recorder { blocks: vec![], row_sum: 0.0, posted: false },
            block,
            2,
        );
        for i in 0..rows {
            b.exec_row(&[i as f64, 1.0]).unwrap();
        }
        b.post().unwrap();
        b.into_inner()
    }

    #[test]
    fn full_blocks_then_tail() {
        let r = feed(10, 4);
        assert_eq!(r.blocks, vec![(4, 2), (4, 2), (2, 2)]);
        assert!(r.posted);
        let want: f64 = (0..10).map(|i| i as f64 + 1.0).sum();
        assert!((r.row_sum - want).abs() < 1e-12);
    }

    #[test]
    fn exact_multiple_no_empty_tail() {
        let r = feed(8, 4);
        assert_eq!(r.blocks, vec![(4, 2), (4, 2)]);
    }

    #[test]
    fn zero_rows_posts_cleanly() {
        let r = feed(0, 4);
        assert!(r.blocks.is_empty());
        assert!(r.posted);
    }

    #[test]
    fn wrong_width_rejected() {
        let mut b = Blocked::new(
            Recorder { blocks: vec![], row_sum: 0.0, posted: false },
            4,
            3,
        );
        assert!(b.exec_row(&[1.0, 2.0]).is_err());
    }
}
