//! The dynamic chunk scheduler shared by both executors.
//!
//! A pass used to be one chunk per worker: wall time = slowest worker,
//! fault tolerance = none. [`ChunkScheduler`] replaces that with a work
//! queue over many-more-chunks-than-workers and a small per-chunk state
//! machine:
//!
//! ```text
//!            +----------------------------- retry (budget left) ---+
//!            v                                                     |
//! planned -> queued -> assigned/running -+-> done (first completion wins)
//!            ^                           |
//!            +--- requeued (runner died) +-> failed (budget exhausted
//!                                             => pass fails, names chunk)
//! ```
//!
//! * **Bounded retry** — a failed execution requeues the chunk until its
//!   retry budget ([`SchedPolicy::max_retries`]) is spent; exhaustion fails
//!   the whole pass with an error naming the chunk.
//! * **Release** — when a runner vanishes (worker death) its chunk goes
//!   back to the queue without consuming retry budget.
//! * **Speculation** — at end of pass an idle worker may duplicate a
//!   still-running chunk ([`ChunkScheduler::speculate`]); the first
//!   completion is recorded, duplicates are dropped. Shard writes are
//!   staged + atomically renamed ([`crate::io::writer::ShardWriter`]), so a
//!   late duplicate publishing identical bytes is harmless.
//!
//! The in-process [`crate::splitproc::run_scheduled`] drives it with
//! blocking claims from a thread pool; the cluster leader drives the same
//! state machine event-style with [`ChunkScheduler::try_claim`].

use crate::error::{Error, Result};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Default [`SchedPolicy::chunks_per_worker`]: enough slack for the queue
/// to absorb a ~4x skew between the fastest and slowest chunk.
pub const DEFAULT_CHUNKS_PER_WORKER: usize = 4;

/// Default [`SchedPolicy::max_retries`] per chunk.
pub const DEFAULT_CHUNK_RETRIES: usize = 2;

/// Chunk-scheduling knobs (surfaced as `RunConfig::chunk_rows` /
/// `chunks_per_worker` / `chunk_retries` and the matching CLI flags).
#[derive(Clone, Copy, Debug)]
pub struct SchedPolicy {
    /// Target rows per chunk; `0` = derive the chunk count from
    /// `chunks_per_worker` instead (the default).
    pub chunk_rows: usize,
    /// Chunks planned per worker when `chunk_rows == 0`. `1` reproduces
    /// the old static one-chunk-per-worker schedule.
    pub chunks_per_worker: usize,
    /// Extra executions a chunk may consume after its first failure
    /// before the pass fails.
    pub max_retries: usize,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy {
            chunk_rows: 0,
            chunks_per_worker: DEFAULT_CHUNKS_PER_WORKER,
            max_retries: DEFAULT_CHUNK_RETRIES,
        }
    }
}

impl SchedPolicy {
    /// The pre-scheduler behavior: one chunk per worker, fail-fast.
    pub fn static_one_per_worker() -> Self {
        SchedPolicy { chunk_rows: 0, chunks_per_worker: 1, max_retries: 0 }
    }
}

/// What one pass's scheduling looked like (published as `pass_*` metrics
/// and carried on [`crate::svd::PassOutput`]).
#[derive(Clone, Debug, Default)]
pub struct SchedStats {
    /// Chunks the pass was planned into.
    pub chunks: usize,
    /// Executions that were retries after a failure.
    pub retried: usize,
    /// Speculative duplicate executions of straggling chunks.
    pub speculated: usize,
    /// Derived chunk-duration skew: p99 minus p50 chunk wall time, in
    /// milliseconds (recomputed from [`SchedStats::chunk_ms`]).
    pub skew_ms: f64,
    /// Wall time of each chunk's first completion, in chunk order, in
    /// milliseconds. Feeds the `sched_chunk_ms` histogram.
    pub chunk_ms: Vec<f64>,
}

/// Nearest-rank quantile of an ascending-sorted sample (empty -> 0).
fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// p99 minus p50 of a chunk-duration sample — the pass's straggler skew.
fn skew_of(chunk_ms: &[f64]) -> f64 {
    if chunk_ms.len() < 2 {
        return 0.0;
    }
    let mut sorted = chunk_ms.to_vec();
    sorted.sort_by(f64::total_cmp);
    quantile_sorted(&sorted, 0.99) - quantile_sorted(&sorted, 0.50)
}

impl SchedStats {
    /// Merge another pass's stats into an accumulated view. The skew is
    /// re-derived over the pooled chunk durations, not max-of-maxes.
    pub fn absorb(&mut self, other: &SchedStats) {
        self.chunks += other.chunks;
        self.retried += other.retried;
        self.speculated += other.speculated;
        self.chunk_ms.extend_from_slice(&other.chunk_ms);
        self.skew_ms = skew_of(&self.chunk_ms);
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum State {
    Queued,
    Running,
    Done,
}

struct Slot {
    state: State,
    /// Concurrent executions of this chunk (> 1 under speculation).
    running: usize,
    attempts_left: usize,
    /// Wall time of the first (recorded) completion.
    elapsed_ms: f64,
}

struct Inner {
    slots: Vec<Slot>,
    queue: VecDeque<usize>,
    done: usize,
    retried: usize,
    speculated: usize,
    fatal: Option<Error>,
}

/// Outcome of a blocking claim.
pub enum Claim {
    /// Execute this chunk.
    Run(usize),
    /// Every chunk is done (or the pass already failed) — stop.
    Finished,
}

/// The shared per-pass chunk state machine (see module docs).
pub struct ChunkScheduler {
    inner: Mutex<Inner>,
    cv: Condvar,
    max_retries: usize,
}

impl ChunkScheduler {
    pub fn new(chunks: usize, max_retries: usize) -> Self {
        let slots = (0..chunks)
            .map(|_| Slot {
                state: State::Queued,
                running: 0,
                attempts_left: max_retries,
                elapsed_ms: 0.0,
            })
            .collect();
        ChunkScheduler {
            inner: Mutex::new(Inner {
                slots,
                queue: (0..chunks).collect(),
                done: 0,
                retried: 0,
                speculated: 0,
                fatal: None,
            }),
            cv: Condvar::new(),
            max_retries,
        }
    }

    /// Blocking claim for thread-pool workers: waits while the queue is
    /// empty but other chunks are still in flight (their failure may
    /// requeue work).
    pub fn claim_blocking(&self) -> Claim {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.fatal.is_some() || g.done == g.slots.len() {
                return Claim::Finished;
            }
            if let Some(i) = g.queue.pop_front() {
                g.slots[i].state = State::Running;
                g.slots[i].running += 1;
                return Claim::Run(i);
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Non-blocking claim for the event-driven cluster leader. `eligible`
    /// filters queued chunks (worker exclusion after a death); ineligible
    /// chunks stay queued for other workers.
    pub fn try_claim(&self, eligible: impl Fn(usize) -> bool) -> Option<usize> {
        let mut g = self.inner.lock().unwrap();
        if g.fatal.is_some() {
            return None;
        }
        for _ in 0..g.queue.len() {
            let i = g.queue.pop_front().expect("queue length checked");
            if eligible(i) {
                g.slots[i].state = State::Running;
                g.slots[i].running += 1;
                return Some(i);
            }
            g.queue.push_back(i);
        }
        None
    }

    /// Chunks currently assigned/running — the speculation candidates.
    pub fn running_chunks(&self) -> Vec<usize> {
        let g = self.inner.lock().unwrap();
        (0..g.slots.len())
            .filter(|&i| g.slots[i].state == State::Running && g.slots[i].running > 0)
            .collect()
    }

    /// Record an extra, speculative execution of a running chunk.
    pub fn speculate(&self, chunk: usize) {
        let mut g = self.inner.lock().unwrap();
        g.slots[chunk].running += 1;
        g.speculated += 1;
    }

    /// Record a completed execution. Returns `true` iff this was the
    /// *first* completion of the chunk — only then should the caller keep
    /// the execution's result; duplicates are dropped.
    pub fn complete(&self, chunk: usize, elapsed: Duration) -> bool {
        let mut g = self.inner.lock().unwrap();
        let slot = &mut g.slots[chunk];
        slot.running = slot.running.saturating_sub(1);
        let first = slot.state != State::Done;
        if first {
            slot.state = State::Done;
            slot.elapsed_ms = elapsed.as_secs_f64() * 1e3;
            g.done += 1;
        }
        self.cv.notify_all();
        first
    }

    /// Record a failed execution: requeue within the retry budget, ignore
    /// if a concurrent duplicate is still running (it may yet succeed), or
    /// fail the pass naming the chunk. Returns `true` if requeued.
    pub fn fail(&self, chunk: usize, err: Error) -> bool {
        let mut g = self.inner.lock().unwrap();
        let slot = &mut g.slots[chunk];
        if slot.state != State::Running {
            // Already completed, or already back in the queue (a stale
            // report for an execution that was released): nothing to do —
            // in particular, no retry budget is consumed.
            self.cv.notify_all();
            return false;
        }
        slot.running = slot.running.saturating_sub(1);
        if slot.running > 0 {
            // A duplicate of this chunk is still trying; let it decide.
            self.cv.notify_all();
            return false;
        }
        if slot.attempts_left > 0 {
            slot.attempts_left -= 1;
            slot.state = State::Queued;
            g.retried += 1;
            g.queue.push_back(chunk);
            self.cv.notify_all();
            return true;
        }
        if g.fatal.is_none() {
            g.fatal = Some(Error::Other(format!(
                "chunk {chunk} failed after {} attempts: {err}",
                self.max_retries + 1
            )));
        }
        self.cv.notify_all();
        false
    }

    /// An execution vanished without a verdict (its worker died): requeue
    /// the chunk — without touching the retry budget — unless a duplicate
    /// is still running or it already completed.
    pub fn release(&self, chunk: usize) {
        let mut g = self.inner.lock().unwrap();
        let slot = &mut g.slots[chunk];
        slot.running = slot.running.saturating_sub(1);
        if slot.state == State::Running && slot.running == 0 {
            slot.state = State::Queued;
            g.queue.push_back(chunk);
        }
        self.cv.notify_all();
    }

    /// True once every chunk completed or the pass failed.
    pub fn is_finished(&self) -> bool {
        let g = self.inner.lock().unwrap();
        g.fatal.is_some() || g.done == g.slots.len()
    }

    /// Chunks not yet completed.
    pub fn remaining(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.slots.len() - g.done
    }

    /// Consume the scheduler: the pass's stats, or its fatal error.
    pub fn finish(self) -> Result<SchedStats> {
        let g = self.inner.into_inner().unwrap();
        if let Some(e) = g.fatal {
            return Err(e);
        }
        if g.done != g.slots.len() {
            return Err(Error::Other(format!(
                "pass ended with {} of {} chunks incomplete",
                g.slots.len() - g.done,
                g.slots.len()
            )));
        }
        let chunk_ms: Vec<f64> = g.slots.iter().map(|s| s.elapsed_ms).collect();
        Ok(SchedStats {
            chunks: g.slots.len(),
            retried: g.retried,
            speculated: g.speculated,
            skew_ms: skew_of(&chunk_ms),
            chunk_ms,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn chunks_complete_in_any_order() {
        let s = ChunkScheduler::new(3, 0);
        let mut claimed = Vec::new();
        while let Claim::Run(i) = s.claim_blocking() {
            claimed.push(i);
            s.complete(i, ms(1));
        }
        claimed.sort_unstable();
        assert_eq!(claimed, vec![0, 1, 2]);
        let st = s.finish().unwrap();
        assert_eq!(st.chunks, 3);
        assert_eq!(st.retried, 0);
    }

    #[test]
    fn failure_requeues_until_budget_exhausted() {
        let s = ChunkScheduler::new(1, 2);
        for attempt in 0..3 {
            let Claim::Run(i) = s.claim_blocking() else {
                panic!("chunk should requeue (attempt {attempt})")
            };
            assert_eq!(i, 0);
            let requeued = s.fail(0, Error::Other("boom".into()));
            assert_eq!(requeued, attempt < 2);
        }
        assert!(s.is_finished());
        let err = s.finish().unwrap_err().to_string();
        assert!(err.contains("chunk 0"), "{err}");
        assert!(err.contains("3 attempts"), "{err}");
        assert!(err.contains("boom"), "{err}");
    }

    #[test]
    fn first_completion_wins_over_duplicates() {
        let s = ChunkScheduler::new(1, 0);
        let Claim::Run(i) = s.claim_blocking() else { panic!() };
        s.speculate(i);
        assert!(s.complete(i, ms(5)), "first completion recorded");
        assert!(!s.complete(i, ms(9)), "duplicate dropped");
        let st = s.finish().unwrap();
        assert_eq!(st.speculated, 1);
    }

    #[test]
    fn duplicate_failure_does_not_consume_budget() {
        let s = ChunkScheduler::new(1, 0);
        let Claim::Run(i) = s.claim_blocking() else { panic!() };
        s.speculate(i);
        // One execution fails while the duplicate is still running: no
        // retry budget exists, but the pass must not fail yet.
        assert!(!s.fail(i, Error::Other("slow disk".into())));
        assert!(!s.is_finished());
        assert!(s.complete(i, ms(2)));
        assert!(s.finish().is_ok());
    }

    #[test]
    fn release_requeues_without_budget() {
        let s = ChunkScheduler::new(1, 0);
        let Claim::Run(_) = s.claim_blocking() else { panic!() };
        s.release(0); // worker died
        let Claim::Run(i) = s.claim_blocking() else {
            panic!("released chunk should requeue")
        };
        assert_eq!(i, 0);
        s.complete(0, ms(1));
        assert_eq!(s.finish().unwrap().retried, 0);
    }

    #[test]
    fn try_claim_respects_eligibility() {
        let s = ChunkScheduler::new(2, 0);
        assert_eq!(s.try_claim(|c| c == 1), Some(1));
        assert_eq!(s.try_claim(|c| c == 1), None); // 0 stays queued
        assert_eq!(s.try_claim(|_| true), Some(0));
        assert!(s.running_chunks().len() == 2);
    }

    #[test]
    fn incomplete_finish_is_an_error() {
        let s = ChunkScheduler::new(2, 0);
        let Claim::Run(i) = s.claim_blocking() else { panic!() };
        s.complete(i, ms(1));
        assert!(s.finish().unwrap_err().to_string().contains("incomplete"));
    }

    #[test]
    fn skew_is_p99_minus_p50() {
        let s = ChunkScheduler::new(3, 0);
        for _ in 0..3 {
            let Claim::Run(i) = s.claim_blocking() else { panic!() };
            s.complete(i, ms(10 * (i as u64 + 1)));
        }
        let st = s.finish().unwrap();
        // With 3 samples {10, 20, 30}, p99 is the max and p50 the median.
        assert!((st.skew_ms - 10.0).abs() < 1.0, "skew {}", st.skew_ms);
        assert_eq!(st.chunk_ms.len(), 3);
    }

    #[test]
    fn finish_records_chunk_durations_in_chunk_order() {
        let s = ChunkScheduler::new(2, 0);
        let Claim::Run(a) = s.claim_blocking() else { panic!() };
        let Claim::Run(b) = s.claim_blocking() else { panic!() };
        s.complete(a, ms(10 * (a as u64 + 1)));
        s.complete(b, ms(10 * (b as u64 + 1)));
        let st = s.finish().unwrap();
        assert_eq!(st.chunk_ms, vec![10.0, 20.0]);
    }

    #[test]
    fn absorb_pools_durations_and_rederives_skew() {
        let mut acc = SchedStats::default();
        let a = SchedStats {
            chunks: 2,
            retried: 1,
            speculated: 0,
            skew_ms: skew_of(&[10.0, 20.0]),
            chunk_ms: vec![10.0, 20.0],
        };
        let b = SchedStats {
            chunks: 2,
            retried: 0,
            speculated: 2,
            skew_ms: skew_of(&[30.0, 100.0]),
            chunk_ms: vec![30.0, 100.0],
        };
        acc.absorb(&a);
        acc.absorb(&b);
        assert_eq!(acc.chunks, 4);
        assert_eq!(acc.retried, 1);
        assert_eq!(acc.speculated, 2);
        assert_eq!(acc.chunk_ms.len(), 4);
        // Pooled {10,20,30,100}: p99 = 100, p50 = 20 -> skew 80, which
        // max-of-maxes (70) would have understated.
        assert!((acc.skew_ms - 80.0).abs() < 1e-9, "skew {}", acc.skew_ms);
    }

    #[test]
    fn quantile_is_nearest_rank() {
        let sorted = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile_sorted(&sorted, 0.5), 2.0);
        assert_eq!(quantile_sorted(&sorted, 0.99), 4.0);
        assert_eq!(quantile_sorted(&sorted, 0.0), 1.0);
        assert_eq!(quantile_sorted(&[], 0.5), 0.0);
    }
}
