//! The job contract — the paper's `workobj` interface.

use crate::error::Result;

/// A streaming row job: `exec_row` per input row, `post` once the chunk is
/// drained (the paper's `workobj.exec(line)` / `workobj.post()`).
pub trait RowJob: Send {
    /// Process one parsed row.
    fn exec_row(&mut self, row: &[f64]) -> Result<()>;

    /// Chunk finished: flush buffers, close writers.
    fn post(&mut self) -> Result<()> {
        Ok(())
    }
}

/// The sparse sibling of [`RowJob`]: rows arrive as `(indices, values)`
/// nonzero pairs (0-based ascending), never densified. A row may be
/// all-zero (`indices` empty) and still counts as a row.
pub trait SparseRowJob: Send {
    /// Process one sparse row.
    fn exec_row(&mut self, indices: &[u32], values: &[f64]) -> Result<()>;

    /// Chunk finished: flush buffers, close writers.
    fn post(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Adapter subtracting per-column means before delegating — the streaming
/// centering pre-step of PCA mode (`SvdOptions::center`). Means come from a
/// [`crate::jobs::ColStatsJob`] pre-pass; rows never materialize centered
/// on disk.
pub struct CenteredJob<J: RowJob> {
    inner: J,
    means: std::sync::Arc<Vec<f64>>,
    buf: Vec<f64>,
}

impl<J: RowJob> CenteredJob<J> {
    /// `means` empty = pass-through (centering disabled, zero overhead).
    pub fn new(inner: J, means: std::sync::Arc<Vec<f64>>) -> Self {
        let buf = vec![0.0; means.len()];
        CenteredJob { inner, means, buf }
    }

    pub fn into_inner(self) -> J {
        self.inner
    }
}

impl<J: RowJob> RowJob for CenteredJob<J> {
    fn exec_row(&mut self, row: &[f64]) -> Result<()> {
        if self.means.is_empty() {
            return self.inner.exec_row(row);
        }
        if row.len() != self.means.len() {
            return Err(crate::error::Error::shape(format!(
                "centered row has {} cols, means have {}",
                row.len(),
                self.means.len()
            )));
        }
        for ((b, &x), &m) in self.buf.iter_mut().zip(row).zip(self.means.iter()) {
            *b = x - m;
        }
        self.inner.exec_row(&self.buf)
    }

    fn post(&mut self) -> Result<()> {
        self.inner.post()
    }
}
