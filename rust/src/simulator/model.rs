//! The cluster cost model and its fluid-flow event loop.

use crate::error::{Error, Result};
use crate::io::InputSpec;
use crate::splitproc;
use std::time::Duration;

/// Physical parameters of the simulated cluster.
///
/// Defaults approximate the paper's 2013-era setup: commodity nodes on
/// gigabit Ethernet against one shared file server, spinning local disks.
#[derive(Clone, Debug)]
pub struct ClusterParams {
    /// Worker nodes available (workers beyond this share nodes round-robin).
    pub nodes: usize,
    /// Rows/sec one worker core sustains on the job's compute. Calibrate
    /// with [`calibrate_rows_per_sec`] — this anchors the simulation to a
    /// real measured run.
    pub cpu_rows_per_sec: f64,
    /// Shared file-server NIC bandwidth, bytes/sec (split fairly among
    /// active remote readers).
    pub fileserver_bw: f64,
    /// Local-disk streaming bandwidth, bytes/sec (used when
    /// `local_copies`, i.e. the paper's "copies of that file on each
    /// machine" deployment).
    pub disk_bw: f64,
    /// Each machine has a local copy of the input (paper §1 offers both
    /// deployments). `false` = everyone streams from the file server.
    pub local_copies: bool,
    /// Fixed per-message latency of one reduce hop, seconds.
    pub reduce_latency: f64,
    /// Bandwidth for shipping partials during the reduce, bytes/sec.
    pub reduce_bw: f64,
    /// Deterministic per-worker speed jitter amplitude (0.05 = ±5%),
    /// modeling stragglers. 0 disables.
    pub jitter: f64,
}

impl Default for ClusterParams {
    fn default() -> Self {
        ClusterParams {
            nodes: 16,
            cpu_rows_per_sec: 500_000.0,
            fileserver_bw: 117e6, // ~1 GbE payload
            disk_bw: 120e6,       // 2013 SATA streaming
            local_copies: false,
            reduce_latency: 0.5e-3,
            reduce_bw: 117e6,
            jitter: 0.0,
        }
    }
}

/// One simulated worker's outcome.
#[derive(Clone, Debug)]
pub struct WorkerTrace {
    pub worker: usize,
    pub rows: u64,
    pub bytes: u64,
    /// Time spent constrained by IO (fluid share of the link/disk).
    pub io_time: f64,
    /// Time spent constrained by CPU.
    pub cpu_time: f64,
    /// Wallclock finish time of this worker's chunk.
    pub finish: f64,
}

/// Outcome of one simulated run.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub workers: usize,
    /// Max worker finish time (the map/stream phase makespan).
    pub stream_makespan: f64,
    /// Tree-reduce time appended after the slowest worker.
    pub reduce_time: f64,
    /// Total simulated wallclock.
    pub makespan: f64,
    /// Speedup vs the same job simulated with 1 worker (filled by callers
    /// that sweep; 0.0 when not computed).
    pub speedup_vs_1: f64,
    pub traces: Vec<WorkerTrace>,
}

impl SimReport {
    /// Aggregate rows/sec over the whole simulated run.
    pub fn rows_per_sec(&self) -> f64 {
        let rows: u64 = self.traces.iter().map(|t| t.rows).sum();
        rows as f64 / self.makespan.max(1e-12)
    }
}

/// Calibrate the CPU term from a measured single-worker run: `rows`
/// processed in `elapsed` with IO known to be warm (page cache), so the
/// measurement is compute-dominated.
pub fn calibrate_rows_per_sec(rows: u64, elapsed: Duration) -> f64 {
    rows as f64 / elapsed.as_secs_f64().max(1e-12)
}

/// Deterministic straggler multiplier for worker `w` (mean 1.0).
fn jitter_mult(params: &ClusterParams, w: usize) -> f64 {
    if params.jitter == 0.0 {
        return 1.0;
    }
    // splitmix-derived uniform in [-1, 1).
    let u = crate::rng::splitmix64(0x51A6_6E55 ^ w as u64) as f64 / (u64::MAX as f64);
    1.0 + params.jitter * (2.0 * u - 1.0)
}

/// Fluid-flow simulation of `workers` readers with per-worker demands.
///
/// Each worker `w` must move `bytes[w]` through its IO path *and* spend
/// `cpu[w]` seconds of compute; the two overlap (streaming pipeline), so a
/// worker finishes at `max(io_finish, cpu_finish)`. Remote readers share
/// `fileserver_bw` max-min fairly; local readers get `disk_bw` each. The
/// event loop advances between IO completions, recomputing fair shares.
fn fluid_stream(params: &ClusterParams, bytes: &[f64], cpu: &[f64]) -> Vec<WorkerTrace> {
    let w = bytes.len();
    let mut remaining: Vec<f64> = bytes.to_vec();
    let mut io_done: Vec<f64> = vec![0.0; w];
    let mut active: Vec<bool> = bytes.iter().map(|&b| b > 0.0).collect();
    let mut now = 0.0f64;

    // Drain IO demands under fair sharing.
    while active.iter().any(|&a| a) {
        let n_active = active.iter().filter(|&&a| a).count();
        // Per-reader rate under the current active set.
        let rate = if params.local_copies {
            params.disk_bw
        } else {
            params.fileserver_bw / n_active as f64
        };
        // Next completion.
        let (next_i, dt) = active
            .iter()
            .enumerate()
            .filter(|(_, &a)| a)
            .map(|(i, _)| (i, remaining[i] / rate))
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .expect("active set non-empty");
        now += dt;
        for i in 0..w {
            if active[i] {
                remaining[i] -= rate * dt;
            }
        }
        active[next_i] = false;
        remaining[next_i] = 0.0;
        io_done[next_i] = now;
        // Clean up float dust: anything ~0 is done at the same instant.
        for i in 0..w {
            if active[i] && remaining[i] <= 1e-9 {
                active[i] = false;
                remaining[i] = 0.0;
                io_done[i] = now;
            }
        }
    }

    (0..w)
        .map(|i| {
            let finish = io_done[i].max(cpu[i]);
            WorkerTrace {
                worker: i,
                rows: 0,
                bytes: bytes[i] as u64,
                io_time: io_done[i],
                cpu_time: cpu[i],
                finish,
            }
        })
        .collect()
}

/// Tree-reduce cost: `ceil(log2(workers))` levels, each one hop of fixed
/// latency plus shipping one partial of `partial_bytes`.
fn tree_reduce_time(params: &ClusterParams, workers: usize, partial_bytes: u64) -> f64 {
    if workers <= 1 {
        return 0.0;
    }
    let levels = (workers as f64).log2().ceil();
    levels * (params.reduce_latency + partial_bytes as f64 / params.reduce_bw)
}

/// Simulate a Split-Process run over a real input file.
///
/// Chunk geometry (per-worker rows and bytes) is taken from the *actual*
/// [`splitproc::plan_chunks`] plan over `input` — the simulator only prices
/// it. `partial_bytes` is the per-worker accumulator size shipped in the
/// reduce (`n²·8` for ATA, `k²·8` for the sketch Gram, ...).
pub fn simulate_split_process(
    params: &ClusterParams,
    input: &InputSpec,
    workers: usize,
    partial_bytes: u64,
) -> Result<SimReport> {
    if workers == 0 {
        return Err(Error::Config("simulate: workers must be >= 1".into()));
    }
    let chunks = splitproc::plan_chunks(input, workers)?;
    let (m, _n) = input.dims()?;
    let file_bytes = std::fs::metadata(&input.path)?.len() as f64;

    // Per-chunk byte and row demands from the real plan.
    let mut bytes = Vec::with_capacity(chunks.len());
    let mut rows = Vec::with_capacity(chunks.len());
    for c in &chunks {
        if let Some(r) = c.byte_range {
            let b = (r.end - r.start) as f64;
            bytes.push(b);
            rows.push((m as f64 * b / file_bytes).round() as u64);
        } else if let Some((r0, r1)) = c.row_range {
            rows.push(r1 - r0);
            bytes.push(file_bytes * (r1 - r0) as f64 / m as f64);
        } else {
            return Err(Error::Other("chunk with no range".into()));
        }
    }

    let cpu: Vec<f64> = rows
        .iter()
        .enumerate()
        .map(|(w, &r)| r as f64 / (params.cpu_rows_per_sec * jitter_mult(params, w)))
        .collect();

    let mut traces = fluid_stream(params, &bytes, &cpu);
    for (t, &r) in traces.iter_mut().zip(rows.iter()) {
        t.rows = r;
    }
    let stream_makespan = traces.iter().map(|t| t.finish).fold(0.0, f64::max);
    let reduce_time = tree_reduce_time(params, traces.len(), partial_bytes);
    Ok(SimReport {
        workers: traces.len(),
        stream_makespan,
        reduce_time,
        makespan: stream_makespan + reduce_time,
        speedup_vs_1: 0.0,
        traces,
    })
}

/// Simulate the Map-Reduce execution of the same job: the map/stream phase
/// is identical, but every mapper additionally *writes* `shuffle_bytes /
/// mappers` to the file server and every reducer reads its partition back —
/// 2× the shuffle volume through the shared link, plus a sort charged at
/// CPU rate per pair.
pub fn simulate_mapreduce(
    params: &ClusterParams,
    input: &InputSpec,
    mappers: usize,
    shuffle_bytes: u64,
    pairs: u64,
) -> Result<SimReport> {
    let base = simulate_split_process(params, input, mappers, 0)?;
    // Shuffle: write + read through the shared link (even with local input
    // copies, the shuffle crosses the network — that is its defining cost).
    let shuffle_io = 2.0 * shuffle_bytes as f64 / params.fileserver_bw;
    // Sort/group: pairs * a few comparisons, priced against the row rate as
    // "pair-rows" — deliberately generous to MR (no constant inflation).
    let sort_cpu = pairs as f64 / (params.cpu_rows_per_sec * 8.0).max(1.0);
    let reduce_time = shuffle_io + sort_cpu + tree_reduce_time(params, mappers, 0);
    Ok(SimReport {
        workers: base.workers,
        stream_makespan: base.stream_makespan,
        reduce_time,
        makespan: base.stream_makespan + reduce_time,
        speedup_vs_1: 0.0,
        traces: base.traces,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn fixture(name: &str, m: usize, n: usize) -> InputSpec {
        let dir = std::env::temp_dir().join("tallfat_test_sim");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name).to_string_lossy().into_owned();
        let a = Matrix::from_fn(m, n, |i, j| (i + j) as f64);
        crate::io::csv::write_matrix_csv(&a, &path).unwrap();
        InputSpec::csv(path)
    }

    fn params() -> ClusterParams {
        ClusterParams {
            cpu_rows_per_sec: 10_000.0,
            ..ClusterParams::default()
        }
    }

    #[test]
    fn one_worker_time_is_rows_over_rate() {
        let spec = fixture("one.csv", 1000, 8);
        let r = simulate_split_process(&params(), &spec, 1, 64 * 8).unwrap();
        // CPU-bound at these sizes: ~1000 rows / 10k rows/s = 0.1 s.
        assert!((r.stream_makespan - 0.1).abs() < 0.02, "{}", r.stream_makespan);
        assert_eq!(r.reduce_time, 0.0); // single worker: no reduce hops
    }

    #[test]
    fn speedup_is_near_linear_when_cpu_bound() {
        let spec = fixture("lin.csv", 4000, 8);
        let p = params();
        let t1 = simulate_split_process(&p, &spec, 1, 0).unwrap().makespan;
        let t4 = simulate_split_process(&p, &spec, 4, 0).unwrap().makespan;
        let speedup = t1 / t4;
        assert!(speedup > 3.0, "speedup {speedup}");
    }

    #[test]
    fn fileserver_saturation_caps_speedup() {
        let spec = fixture("sat.csv", 4000, 8);
        // Very fast CPUs + slow shared link: adding workers can't help.
        let p = ClusterParams {
            cpu_rows_per_sec: 1e9,
            fileserver_bw: 1e4,
            ..ClusterParams::default()
        };
        let t1 = simulate_split_process(&p, &spec, 1, 0).unwrap().stream_makespan;
        let t8 = simulate_split_process(&p, &spec, 8, 0).unwrap().stream_makespan;
        // Link is the bottleneck: total bytes / bw either way.
        assert!((t8 / t1 - 1.0).abs() < 0.05, "t1={t1} t8={t8}");
    }

    #[test]
    fn local_copies_remove_the_shared_bottleneck() {
        let spec = fixture("local.csv", 4000, 8);
        let p = ClusterParams {
            cpu_rows_per_sec: 1e9,
            fileserver_bw: 1e4,
            disk_bw: 1e4, // same slow medium, but per-node
            local_copies: true,
            ..ClusterParams::default()
        };
        let t1 = simulate_split_process(&p, &spec, 1, 0).unwrap().stream_makespan;
        let t4 = simulate_split_process(&p, &spec, 4, 0).unwrap().stream_makespan;
        assert!(t1 / t4 > 3.0, "t1={t1} t4={t4}");
    }

    #[test]
    fn reduce_time_grows_logarithmically() {
        let spec = fixture("red.csv", 1000, 8);
        let p = params();
        let pb = 1024 * 1024; // 1 MiB partial
        let r2 = simulate_split_process(&p, &spec, 2, pb).unwrap().reduce_time;
        let r16 = simulate_split_process(&p, &spec, 16, pb).unwrap().reduce_time;
        assert!(r16 > r2);
        assert!(r16 < r2 * 8.0); // log, not linear
    }

    #[test]
    fn mapreduce_pays_for_the_shuffle() {
        let spec = fixture("mr.csv", 1000, 8);
        let p = params();
        let sp = simulate_split_process(&p, &spec, 4, 64 * 8).unwrap();
        let mr = simulate_mapreduce(&p, &spec, 4, 1000 * 64 * 16, 1000 * 64).unwrap();
        assert!(mr.makespan > sp.makespan, "mr={} sp={}", mr.makespan, sp.makespan);
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let p = ClusterParams { jitter: 0.1, ..params() };
        for w in 0..32 {
            let m = jitter_mult(&p, w);
            assert!((0.9..=1.1).contains(&m), "{m}");
            assert_eq!(m, jitter_mult(&p, w));
        }
    }

    #[test]
    fn calibration_roundtrip() {
        let rate = calibrate_rows_per_sec(50_000, Duration::from_secs_f64(2.5));
        assert!((rate - 20_000.0).abs() < 1e-6);
    }
}
