//! Discrete-event cluster cost simulator (E1's scalability substrate).
//!
//! The paper's Split-Process architecture runs on a commodity cluster with
//! a shared file server; this box has one CPU core, so wallclock cannot
//! exhibit multi-node speedup. Per DESIGN.md's substitution rule we
//! simulate the cluster: the *algorithmic* partitioning (chunk geometry,
//! per-worker row counts, reduce tree) comes from the real
//! [`crate::splitproc`] planner, and only the cluster-specific physics —
//! per-node CPU rate, local-disk vs shared-NIC bandwidth, reduce latency —
//! are modeled. CPU rate is **calibrated from a measured single-worker
//! run** ([`calibrate_rows_per_sec`]), so simulated wallclocks are anchored
//! to this machine's real throughput.
//!
//! The IO model is fluid-flow processor sharing: all workers reading from
//! the shared file server split its bandwidth equally among the currently
//! active readers; the event loop advances from completion to completion
//! recomputing shares (max-min fair). This is the standard fluid
//! approximation for TCP-fair links and captures the paper's one
//! cluster-level effect: the file server saturating as workers are added.

pub mod model;

pub use model::{
    calibrate_rows_per_sec, simulate_mapreduce, simulate_split_process, ClusterParams, SimReport,
    WorkerTrace,
};
