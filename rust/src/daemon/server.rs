//! The daemon's front door: one listener for every model and for control.
//!
//! `tallfatd` speaks the same ND-JSON-over-HTTP as `tallfat serve`, on the
//! same shared connection runtime ([`crate::net`]): event-driven accept,
//! keep-alive connections, a warm handler pool behind the admission gate
//! (`--max-inflight`/`--max-queue`; overload answers `503` +
//! `Retry-After`), and idle-connection reaping. One addition over `serve`:
//! query lines carry `"model":"name"` and are routed to that model's
//! batcher, so a single connection can interleave queries against the
//! whole fleet. Lines whose `op` is a control verb drive the daemon
//! itself:
//!
//! | op           | fields            | effect                               |
//! |--------------|-------------------|--------------------------------------|
//! | `register`   | `name`, `root`    | add a model to the fleet, persist it |
//! | `list`       |                   | names, roots, live generations       |
//! | `status`     |                   | uptime, fleet size, every job        |
//! | `submit-job` | [`JobSpec`] form  | queue a supervised update/stream job |
//! | `job-status` | `id`              | one job's state                      |
//! | `drain`      |                   | stop accepting, finish queued jobs   |
//! | `halt`       |                   | stop now; queued jobs persist        |
//!
//! Batched query lines group *per model* — each model keeps its own
//! micro-batch coalescing exactly as under standalone `serve` — and a
//! body's lines are answered in input order regardless of routing.
//! `GET /healthz` answers inline (never shed) and reports the runtime's
//! admission state alongside fleet liveness.
//!
//! A health poller reloads every model's engine on a short cadence, so
//! generations published by job workers (or by hand, out-of-process)
//! become visible to queries without a restart; job completion also
//! triggers an immediate reload from the supervisor.

use crate::backend::BackendRef;
use crate::coordinator::server::MetricsRegistry;
use crate::error::{Error, Result};
use crate::net::http::{HttpRequest, HttpResponse};
use crate::net::{NetHandler, NetOptions, NetServer, NetServerHandle};
use crate::serve::batcher::{BatchOptions, Request};
use crate::serve::http::{
    admission_json, error_json, plan_query, record_metrics, render_reply, Expect, Planned,
};
use crate::serve::json::Json;
use crate::serve::query::QueryEngine;
use crate::serve::store::ModelStore;
use crate::util::{Args, Logger};
use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

use super::client::DaemonClient;
use super::fleet::{Fleet, ModelEntry};
use super::jobs::{JobManager, JobSpec};

static LOG: Logger = Logger::new("daemon");

/// Default control/query address (distinct from `serve`'s 9925).
pub const DEFAULT_ADDR: &str = "127.0.0.1:9935";

/// Daemon construction knobs.
#[derive(Clone, Debug)]
pub struct DaemonOptions {
    /// Listen address; port 0 binds an ephemeral port (tests).
    pub addr: String,
    /// Per-model micro-batching knobs.
    pub batch: BatchOptions,
    /// Shard-cache capacity per model.
    pub cache_shards: usize,
    /// Engine-reload poll cadence (None = only job-completion reloads).
    pub health_poll: Option<Duration>,
    /// Connection-runtime knobs (pool size, queue bound, idle reaping,
    /// keep-alive policy).
    pub net: NetOptions,
}

impl Default for DaemonOptions {
    fn default() -> Self {
        DaemonOptions {
            addr: DEFAULT_ADDR.to_string(),
            batch: BatchOptions::default(),
            cache_shards: ModelStore::DEFAULT_CACHE_SHARDS,
            health_poll: Some(Duration::from_secs(2)),
            net: NetOptions::default(),
        }
    }
}

pub(crate) struct DaemonState {
    pub(crate) fleet: Arc<Fleet>,
    pub(crate) jobs: JobManager,
    started: Instant,
    draining: AtomicBool,
    /// The connection runtime's control handle: `drain`/`halt` shut the
    /// event loop down through it, `/healthz` reads admission stats.
    net: NetServerHandle,
}

/// A bound daemon (separate from [`Daemon::run`] so tests can bind port 0
/// and read the real address before serving).
pub struct Daemon {
    net: NetServer,
    state: Arc<DaemonState>,
}

impl Daemon {
    /// Open the fleet and job queue persisted under `state_dir`, bind the
    /// listener, and start the health poller.
    pub fn bind(
        state_dir: impl Into<PathBuf>,
        backend: BackendRef,
        opts: &DaemonOptions,
    ) -> Result<Daemon> {
        let state_dir = state_dir.into();
        let fleet = Arc::new(Fleet::open(&state_dir, backend, opts.cache_shards, opts.batch)?);
        let jobs = JobManager::open(fleet.clone(), &state_dir)?;
        let mut nopts = opts.net.clone();
        nopts.plane = "daemon";
        let net = NetServer::bind(&opts.addr, nopts)?;
        let state = Arc::new(DaemonState {
            fleet,
            jobs,
            started: Instant::now(),
            draining: AtomicBool::new(false),
            net: net.handle(),
        });
        if let Some(every) = opts.health_poll {
            spawn_health_poller(Arc::downgrade(&state), every);
        }
        Ok(Daemon { net, state })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.net.local_addr()
    }

    pub fn fleet(&self) -> &Arc<Fleet> {
        &self.state.fleet
    }

    /// Serve connections until a `drain` or `halt` line stops the daemon.
    /// Draining finishes every queued job before returning; halting leaves
    /// them in the manifest for the next start.
    pub fn run(self) -> Result<()> {
        let Daemon { net, state } = self;
        let handler = Arc::new(DaemonHandler { state: state.clone() });
        let result = net.run(handler);
        if state.draining.load(Ordering::SeqCst) {
            LOG.info("draining: waiting for queued jobs to finish");
            if !state.jobs.wait_idle(Duration::from_secs(600)) {
                LOG.warn("drain timed out with jobs still pending; they stay queued on disk");
            }
        }
        state.jobs.halt();
        LOG.info("daemon stopped");
        result
    }
}

/// The daemon's [`NetHandler`]: query/control bodies go through the
/// admission gate to the pool; liveness, metrics and the fleet listing
/// answer inline on the event loop (never shed).
struct DaemonHandler {
    state: Arc<DaemonState>,
}

impl NetHandler for DaemonHandler {
    fn handle(&self, req: HttpRequest) -> HttpResponse {
        match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/query") => {
                let reply = process_body(&self.state, &req.body_str());
                HttpResponse::ok("application/x-ndjson", reply)
            }
            _ => HttpResponse::json(
                404,
                error_json("unknown route (POST /query, GET /healthz /metrics /fleet)").render(),
            ),
        }
    }

    fn handle_inline(&self, req: &HttpRequest) -> Option<HttpResponse> {
        if req.method != "GET" {
            return None;
        }
        match req.path.as_str() {
            "/healthz" => Some(HttpResponse::json(200, daemon_health(&self.state).render())),
            "/metrics" => Some(HttpResponse::ok(
                "text/plain; version=0.0.4",
                MetricsRegistry::global().render(),
            )),
            "/fleet" => Some(HttpResponse::json(200, fleet_json(&self.state).render())),
            _ => None,
        }
    }
}

/// Answer one ND-JSON body: control lines inline, query lines routed by
/// model and batched per model. Every line gets a JSON object with an
/// `ok` field, in input order.
fn process_body(state: &Arc<DaemonState>, text: &str) -> String {
    struct ModelBatch {
        entry: Arc<ModelEntry>,
        engine: Arc<QueryEngine>,
        planned: Vec<(usize, Expect)>,
        reqs: Vec<Request>,
        nlines: u64,
    }
    let t0 = Instant::now();
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let mut outputs: Vec<Option<Json>> = vec![None; lines.len()];
    let mut batches: BTreeMap<String, ModelBatch> = BTreeMap::new();
    for (i, line) in lines.iter().enumerate() {
        let req = match Json::parse(line) {
            Ok(r) => r,
            Err(e) => {
                outputs[i] = Some(error_json(e));
                continue;
            }
        };
        let op = req.get("op").and_then(Json::as_str).unwrap_or("");
        if is_control_op(op) {
            outputs[i] = Some(control(state, op, &req));
            continue;
        }
        let Some(name) = req.get("model").and_then(Json::as_str) else {
            outputs[i] =
                Some(error_json("missing `model` (daemon query lines route by model name)"));
            continue;
        };
        let Some(entry) = state.fleet.get(name) else {
            outputs[i] = Some(error_json(format!("unknown model `{name}`")));
            continue;
        };
        let batch = batches.entry(name.to_string()).or_insert_with(|| {
            // One engine snapshot per model per body, mirroring `serve`:
            // inline ops answer from the generation the body started on.
            let engine = entry.state.engines.current();
            ModelBatch { entry, engine, planned: Vec::new(), reqs: Vec::new(), nlines: 0 }
        });
        batch.nlines += 1;
        match plan_query(&batch.entry.state, batch.engine.as_ref(), &req, Some(state.net.stats()))
        {
            Planned::Done(json) => outputs[i] = Some(json),
            Planned::Batch(r, expect) => {
                batch.planned.push((i, expect));
                batch.reqs.push(r);
            }
        }
    }
    for batch in batches.into_values() {
        if !batch.reqs.is_empty() {
            let replies = batch.entry.state.handle.call_many(batch.reqs);
            for ((i, expect), reply) in batch.planned.into_iter().zip(replies) {
                outputs[i] = Some(render_reply(reply, &expect));
            }
        }
        record_metrics(&batch.entry.state, batch.nlines, t0);
    }
    let mut out = String::new();
    for o in outputs {
        out.push_str(&o.unwrap_or_else(|| error_json("internal: line fell through")).render());
        out.push('\n');
    }
    out
}

fn is_control_op(op: &str) -> bool {
    matches!(
        op,
        "register" | "list" | "status" | "submit-job" | "job-status" | "drain" | "halt"
    )
}

fn control(state: &Arc<DaemonState>, op: &str, req: &Json) -> Json {
    match op {
        "register" => {
            let (Some(name), Some(root)) = (
                req.get("name").and_then(Json::as_str),
                req.get("root").and_then(Json::as_str),
            ) else {
                return error_json("register: need `name` and `root`");
            };
            match state.fleet.register(name, Path::new(root)) {
                Ok(entry) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("name", Json::str(name)),
                    ("generation", Json::num(entry.generation() as f64)),
                ]),
                Err(e) => error_json(e),
            }
        }
        "list" => fleet_json(state),
        "status" => {
            let jobs: Vec<Json> =
                state.jobs.statuses().iter().map(|s| s.to_json()).collect();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("uptime_ms", Json::num(state.started.elapsed().as_secs_f64() * 1e3)),
                ("models", Json::num(state.fleet.len() as f64)),
                ("draining", Json::Bool(state.draining.load(Ordering::SeqCst))),
                ("jobs", Json::arr(jobs)),
            ])
        }
        "submit-job" => match JobSpec::from_json(req).and_then(|s| state.jobs.submit(s)) {
            Ok(id) => {
                Json::obj(vec![("ok", Json::Bool(true)), ("id", Json::num(id as f64))])
            }
            Err(e) => error_json(e),
        },
        "job-status" => {
            let Some(id) = req.get("id").and_then(Json::as_usize) else {
                return error_json("job-status: missing integer `id`");
            };
            match state.jobs.status(id as u64) {
                Some(status) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("job", status.to_json()),
                ]),
                None => error_json(format!("unknown job id {id}")),
            }
        }
        "drain" => {
            LOG.info("drain requested: rejecting new jobs, finishing the queue");
            state.jobs.begin_drain();
            state.draining.store(true, Ordering::SeqCst);
            state.net.shutdown();
            Json::obj(vec![("ok", Json::Bool(true)), ("draining", Json::Bool(true))])
        }
        "halt" => {
            LOG.info("halt requested: stopping now, queued jobs persist");
            state.jobs.halt();
            state.net.shutdown();
            Json::obj(vec![("ok", Json::Bool(true)), ("halted", Json::Bool(true))])
        }
        other => error_json(format!("unknown control op `{other}`")),
    }
}

/// `/healthz`: fleet liveness plus the connection runtime's admission
/// state (in-flight, queue depth, sheds, open/accepted connections).
fn daemon_health(state: &DaemonState) -> Json {
    let stats = state.net.stats();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("uptime_ms", Json::num(state.started.elapsed().as_secs_f64() * 1e3)),
        ("models", Json::num(state.fleet.len() as f64)),
        ("draining", Json::Bool(state.draining.load(Ordering::SeqCst))),
        ("admission", admission_json(stats)),
        ("accepted", Json::num(stats.accepted() as f64)),
    ])
}

fn fleet_json(state: &DaemonState) -> Json {
    let models = state
        .fleet
        .entries()
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::str(e.name())),
                ("root", Json::str(e.root().display().to_string())),
                ("generation", Json::num(e.generation() as f64)),
            ])
        })
        .collect();
    Json::obj(vec![("ok", Json::Bool(true)), ("models", Json::arr(models))])
}

/// Reload every model's engine on a cadence, so out-of-band publishes
/// (and job publishes, belt-and-braces) become visible without a restart.
/// Holds only a weak reference: the poller dies with the daemon.
fn spawn_health_poller(state: Weak<DaemonState>, every: Duration) {
    let spawned = std::thread::Builder::new().name("tallfatd-health".into()).spawn(move || {
        loop {
            std::thread::sleep(every);
            let Some(state) = state.upgrade() else { return };
            if state.net.is_shutdown() {
                return;
            }
            for entry in state.fleet.entries() {
                if let Err(e) = entry.engines().reload() {
                    LOG.warn(&format!("health poll: model `{}` reload: {e}", entry.name()));
                }
                MetricsRegistry::global().set(
                    &format!("daemon_generation_{}", entry.name()),
                    entry.generation() as f64,
                );
            }
            MetricsRegistry::global().set("daemon_models", state.fleet.len() as f64);
        }
    });
    if let Err(e) = spawned {
        LOG.warn(&format!("cannot spawn health poller: {e}"));
    }
}

/// `daemon <state-dir>`: run the model-fleet daemon.
///
/// `--state DIR` (or positional), `--addr HOST:PORT` (default
/// 127.0.0.1:9935, port 0 = ephemeral), `--backend native|xla|auto`,
/// `--cache-shards N`, `--batch-window-ms MS`, `--max-batch N`,
/// `--health-poll-ms MS` (default 2000; 0 = reload only on job publish),
/// `--trace FILE` (Chrome trace-event timeline of the daemon process),
/// plus the shared connection-runtime flags `--max-inflight N`,
/// `--max-queue N`, `--idle-timeout-ms MS`, `--keep-alive`/`--no-keep-alive`
/// ([`NetOptions::with_args`]).
pub fn daemon(args: &Args) -> Result<()> {
    let state_dir = args
        .opt_str("state")
        .map(str::to_string)
        .or_else(|| args.positional.first().cloned())
        .ok_or_else(|| {
            Error::Config("daemon: state directory required (positional or --state)".into())
        })?;
    let cfg = crate::coordinator::commands::load_config(args)?;
    let backend = crate::backend::make_backend(&cfg)?;
    let opts = DaemonOptions {
        addr: args.str_or("addr", DEFAULT_ADDR),
        batch: BatchOptions {
            window: Duration::from_millis(args.u64_or("batch-window-ms", 2)?),
            max_batch: args.usize_or("max-batch", 64)?,
        },
        cache_shards: args.usize_or("cache-shards", ModelStore::DEFAULT_CACHE_SHARDS)?,
        health_poll: match args.u64_or("health-poll-ms", 2000)? {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        },
        net: NetOptions::default().with_args(args)?,
    };
    let _trace = crate::obs::trace::TraceGuard::start(args.opt_str("trace"), "daemon")?;
    let d = Daemon::bind(&state_dir, backend, &opts)?;
    LOG.info(&format!(
        "tallfatd: state {state_dir}, {} model(s), listening on http://{}/query",
        d.fleet().len(),
        d.local_addr()?
    ));
    d.run()
}

/// `daemon-client <action>`: drive a running daemon over the control
/// protocol. Actions: `register --name N --root DIR`, `list`, `status`,
/// `submit-job --model N --rows PATH [--rank K] [--seed S] [--stream]
/// [--kind update|stream] [--tol T] [--max-rank K] [--batch-rows B] [--wait]`
/// (`--stream` / `--kind stream` reads `--rows` once, forward-only — a FIFO
/// works — and folds the factors into the model),
/// `job-status --id N`, `drain`, `halt`. `--addr HOST:PORT` picks the
/// daemon (default 127.0.0.1:9935). Prints the daemon's JSON reply.
pub fn daemon_client(args: &Args) -> Result<()> {
    let action = args.positional.first().cloned().ok_or_else(|| {
        Error::Config(
            "daemon-client: action required \
             (register|list|status|submit-job|job-status|drain|halt)"
                .into(),
        )
    })?;
    let client = DaemonClient::new(args.str_or("addr", DEFAULT_ADDR));
    let reply = match action.as_str() {
        "register" => {
            client.register(&args.require_str("name")?, &args.require_str("root")?)?
        }
        "list" => client.list()?,
        "status" => client.status()?,
        "submit-job" => {
            let mut spec =
                JobSpec::new(args.require_str("model")?, args.require_str("rows")?);
            if args.flag("stream") {
                spec.kind = crate::daemon::jobs::JobKind::Stream;
            } else if let Some(kind) = args.opt_str("kind") {
                spec.kind = crate::daemon::jobs::JobKind::parse(kind)?;
            }
            spec.tol = args.f64_or("tol", spec.tol)?;
            spec.max_rank = args.usize_or("max-rank", spec.max_rank)?;
            spec.batch_rows = args.usize_or("batch-rows", spec.batch_rows)?;
            spec.rank = args.usize_or("rank", spec.rank)?;
            spec.oversample = args.usize_or("oversample", spec.oversample)?;
            spec.workers = args.usize_or("workers", spec.workers)?;
            spec.block = args.usize_or("block", spec.block)?;
            spec.seed = args.u64_or("seed", spec.seed)?;
            spec.keep_generations =
                args.usize_or("keep-generations", spec.keep_generations)?;
            spec.max_attempts = args.usize_or("max-attempts", spec.max_attempts)?;
            spec.delay_ms = args.u64_or("delay-ms", spec.delay_ms)?;
            let id = client.submit_job(&spec)?;
            if args.flag("wait") {
                let timeout = Duration::from_secs(args.u64_or("wait-secs", 600)?);
                client.wait_job(id, timeout)?
            } else {
                Json::obj(vec![("ok", Json::Bool(true)), ("id", Json::num(id as f64))])
            }
        }
        "job-status" => {
            let id = args.u64_or("id", 0)?;
            client.job_status(id)?
        }
        "drain" => client.drain()?,
        "halt" => client.halt()?,
        other => {
            return Err(Error::Config(format!("daemon-client: unknown action `{other}`")))
        }
    };
    println!("{}", reply.render());
    Ok(())
}
