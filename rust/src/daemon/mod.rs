//! `tallfatd` — the model-fleet daemon.
//!
//! Every other entry point of this crate is a foreground process over one
//! model: `svd` factorizes, `update` appends, `serve` answers queries, each
//! in its own process and its own lifetime. This subsystem is the control
//! plane that joins them into one long-running service:
//!
//! * [`fleet`] — the registry of named models. Each entry pairs a
//!   hot-swappable [`crate::serve::EngineHandle`] with its own micro-batch
//!   [`crate::serve::Batcher`]; the name→root mapping persists in a
//!   `fleet.manifest` under the daemon's state directory, so a restarted
//!   daemon reopens its whole fleet ([`fleet::Fleet`]).
//! * [`jobs`] — supervised background factorization work. Update jobs
//!   queue per model (one attempt per model at a time), run on a worker
//!   thread behind a heartbeat-wrapped executor, are reaped when zombie,
//!   requeued on failure within a retry budget, and hot-swap the model's
//!   serving engine on publish. The queue persists in `jobs.manifest`, so
//!   a queued job survives a daemon restart ([`jobs::JobManager`]).
//! * [`server`] — the one front door: ND-JSON over the shared
//!   [`crate::net`] connection runtime (event-driven accept loop,
//!   keep-alive, admission control, idle reaping). Query lines carry
//!   `"model":"name"` and route to that entry's batcher; control lines
//!   (`register`, `list`, `status`, `submit-job`, `job-status`, `drain`,
//!   `halt`) drive the daemon itself ([`server::Daemon`], the
//!   `tallfat daemon` command); `/healthz` reports admission state.
//! * [`client`] — [`client::DaemonClient`], the control protocol over the
//!   same transport, reusing one keep-alive connection across calls (the
//!   `tallfat daemon-client` command).
//! * [`scenario`] — a declarative chaos harness: a [`scenario::Scenario`]
//!   names a topology (models), a workload (query clients), a script of
//!   steps (submit, await, drain, halt, restart), and expectations (zero
//!   failed queries, generation floors); its runner boots a real in-process
//!   daemon and checks the expectations, making races like "worker dies
//!   mid-update" or "GC beats a reload" repeatable integration tests.
//!
//! ```text
//! tallfat daemon --state /var/lib/tallfat &
//! tallfat daemon-client register --name movies --root /models/movies
//! tallfat daemon-client submit-job --model movies --rows /data/new_rows.csv
//! echo '{"op":"similar","model":"movies","row":[...],"k":5}' \
//!     | curl -s --data-binary @- localhost:9935/query
//! tallfat daemon-client drain
//! ```

pub mod client;
pub mod fleet;
pub mod jobs;
pub mod scenario;
pub mod server;

pub use client::DaemonClient;
pub use fleet::{Fleet, ModelEntry};
pub use jobs::{JobKind, JobManager, JobSpec, JobState, JobStatus};
pub use scenario::{Expectation, Scenario, ScenarioReport, Step};
pub use server::{daemon, daemon_client, Daemon, DaemonOptions};
