//! Control-protocol client for a running `tallfatd`.
//!
//! The daemon has exactly one wire format — ND-JSON lines over `POST
//! /query` — so the client is a thin convenience layer: it renders one
//! line per request, reads one reply line per request, and unwraps the
//! `ok` envelope into [`crate::error::Result`]. Everything the
//! `tallfat daemon-client` CLI can do, in-process callers (including the
//! scenario harness) do through [`DaemonClient`].

use crate::error::{Error, Result};
use crate::serve::json::Json;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::jobs::JobSpec;

/// A handle on a daemon address. Stateless: every call is one connection
/// (the transport is `Connection: close`), so clones and threads are free.
#[derive(Clone, Debug)]
pub struct DaemonClient {
    addr: String,
}

impl DaemonClient {
    pub fn new(addr: impl Into<String>) -> Self {
        DaemonClient { addr: addr.into() }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Send one ND-JSON body; one parsed reply per line, in input order.
    pub fn call_many(&self, lines: &[Json]) -> Result<Vec<Json>> {
        let mut body = String::new();
        for line in lines {
            body.push_str(&line.render());
            body.push('\n');
        }
        let reply = http_post(&self.addr, "/query", &body)?;
        let mut out = Vec::new();
        for line in reply.lines().filter(|l| !l.trim().is_empty()) {
            out.push(Json::parse(line)?);
        }
        if out.len() != lines.len() {
            return Err(Error::Other(format!(
                "daemon answered {} line(s) to {} request(s)",
                out.len(),
                lines.len()
            )));
        }
        Ok(out)
    }

    /// Send one line and return its reply — `ok:false` replies included
    /// (query callers often want the error object itself).
    pub fn call(&self, line: &Json) -> Result<Json> {
        Ok(self
            .call_many(std::slice::from_ref(line))?
            .pop()
            .expect("call_many returns one reply per line"))
    }

    /// Register the model at `root` under `name`.
    pub fn register(&self, name: &str, root: &str) -> Result<Json> {
        expect_ok(self.call(&Json::obj(vec![
            ("op", Json::str("register")),
            ("name", Json::str(name)),
            ("root", Json::str(root)),
        ]))?)
    }

    /// The fleet: names, roots, live generations.
    pub fn list(&self) -> Result<Json> {
        expect_ok(self.call(&Json::obj(vec![("op", Json::str("list"))]))?)
    }

    /// Daemon status: uptime, fleet size, every job.
    pub fn status(&self) -> Result<Json> {
        expect_ok(self.call(&Json::obj(vec![("op", Json::str("status"))]))?)
    }

    /// Queue a supervised update job; returns its id.
    pub fn submit_job(&self, spec: &JobSpec) -> Result<u64> {
        let reply = expect_ok(self.call(&spec.to_json())?)?;
        reply
            .get("id")
            .and_then(Json::as_usize)
            .map(|id| id as u64)
            .ok_or_else(|| Error::parse("submit-job reply without an `id`"))
    }

    /// One job's status envelope (`{"ok":true,"job":{...}}`).
    pub fn job_status(&self, id: u64) -> Result<Json> {
        expect_ok(self.call(&Json::obj(vec![
            ("op", Json::str("job-status")),
            ("id", Json::num(id as f64)),
        ]))?)
    }

    /// Poll until the job is `done` or `failed`; errors if the timeout
    /// passes first. Returns the terminal status envelope.
    pub fn wait_job(&self, id: u64, timeout: Duration) -> Result<Json> {
        let deadline = Instant::now() + timeout;
        loop {
            let reply = self.job_status(id)?;
            let state = reply
                .get("job")
                .and_then(|j| j.get("state"))
                .and_then(Json::as_str)
                .unwrap_or("");
            if state == "done" || state == "failed" {
                return Ok(reply);
            }
            if Instant::now() >= deadline {
                return Err(Error::Other(format!(
                    "job {id} still `{state}` after {:.1}s",
                    timeout.as_secs_f64()
                )));
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    /// Stop the daemon gracefully: reject new jobs, finish the queue.
    pub fn drain(&self) -> Result<Json> {
        expect_ok(self.call(&Json::obj(vec![("op", Json::str("drain"))]))?)
    }

    /// Stop the daemon now; queued jobs persist for the next start.
    pub fn halt(&self) -> Result<Json> {
        expect_ok(self.call(&Json::obj(vec![("op", Json::str("halt"))]))?)
    }
}

fn expect_ok(reply: Json) -> Result<Json> {
    if reply.get("ok").and_then(Json::as_bool) == Some(true) {
        return Ok(reply);
    }
    let msg = reply
        .get("error")
        .and_then(Json::as_str)
        .unwrap_or("daemon refused the request")
        .to_string();
    Err(Error::Other(msg))
}

/// One blocking HTTP exchange against the daemon's dependency-free server.
fn http_post(addr: &str, path: &str, body: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| Error::Other(format!("connect {addr}: {e}")))?;
    let request = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/x-ndjson\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(request.as_bytes())?;
    let mut reply = String::new();
    stream.read_to_string(&mut reply)?;
    let (head, body) = reply
        .split_once("\r\n\r\n")
        .ok_or_else(|| Error::Other("malformed HTTP reply (no header terminator)".into()))?;
    let status = head.lines().next().unwrap_or("");
    if !status.contains(" 200 ") {
        return Err(Error::Other(format!("daemon replied `{status}`: {}", body.trim())));
    }
    Ok(body.to_string())
}
