//! Control-protocol client for a running `tallfatd`.
//!
//! The daemon has exactly one wire format — ND-JSON lines over `POST
//! /query` — so the client is a thin convenience layer: it renders one
//! line per request, reads one reply line per request, and unwraps the
//! `ok` envelope into [`crate::error::Result`]. Everything the
//! `tallfat daemon-client` CLI can do, in-process callers (including the
//! scenario harness) do through [`DaemonClient`].
//!
//! The transport is HTTP/1.1 keep-alive: the client pools one connection
//! and reuses it across calls, reconnecting transparently when the daemon
//! closes it (idle reap, drain, restart). A request that fails on a
//! pooled connection *before any reply byte arrives* is resent once on a
//! fresh connection — the daemon never saw it, so the retry cannot
//! double-execute anything.

use crate::error::{Error, Result};
use crate::serve::json::Json;
use crate::util::lock_unpoisoned;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::jobs::JobSpec;

/// A handle on a daemon address holding one pooled keep-alive connection.
/// Each clone pools its own socket (sharing one across threads would
/// interleave request/reply frames), so clones and threads stay free.
#[derive(Debug)]
pub struct DaemonClient {
    addr: String,
    conn: Mutex<Option<TcpStream>>,
}

impl Clone for DaemonClient {
    fn clone(&self) -> Self {
        DaemonClient::new(self.addr.clone())
    }
}

impl DaemonClient {
    pub fn new(addr: impl Into<String>) -> Self {
        DaemonClient { addr: addr.into(), conn: Mutex::new(None) }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Send one ND-JSON body; one parsed reply per line, in input order.
    pub fn call_many(&self, lines: &[Json]) -> Result<Vec<Json>> {
        let mut body = String::new();
        for line in lines {
            body.push_str(&line.render());
            body.push('\n');
        }
        let reply = self.http_post("/query", &body)?;
        let mut out = Vec::new();
        for line in reply.lines().filter(|l| !l.trim().is_empty()) {
            out.push(Json::parse(line)?);
        }
        if out.len() != lines.len() {
            return Err(Error::Other(format!(
                "daemon answered {} line(s) to {} request(s)",
                out.len(),
                lines.len()
            )));
        }
        Ok(out)
    }

    /// Send one line and return its reply — `ok:false` replies included
    /// (query callers often want the error object itself).
    pub fn call(&self, line: &Json) -> Result<Json> {
        Ok(self
            .call_many(std::slice::from_ref(line))?
            .pop()
            .expect("call_many returns one reply per line"))
    }

    /// Register the model at `root` under `name`.
    pub fn register(&self, name: &str, root: &str) -> Result<Json> {
        expect_ok(self.call(&Json::obj(vec![
            ("op", Json::str("register")),
            ("name", Json::str(name)),
            ("root", Json::str(root)),
        ]))?)
    }

    /// The fleet: names, roots, live generations.
    pub fn list(&self) -> Result<Json> {
        expect_ok(self.call(&Json::obj(vec![("op", Json::str("list"))]))?)
    }

    /// Daemon status: uptime, fleet size, every job.
    pub fn status(&self) -> Result<Json> {
        expect_ok(self.call(&Json::obj(vec![("op", Json::str("status"))]))?)
    }

    /// Queue a supervised update job; returns its id.
    pub fn submit_job(&self, spec: &JobSpec) -> Result<u64> {
        let reply = expect_ok(self.call(&spec.to_json())?)?;
        reply
            .get("id")
            .and_then(Json::as_usize)
            .map(|id| id as u64)
            .ok_or_else(|| Error::parse("submit-job reply without an `id`"))
    }

    /// One job's status envelope (`{"ok":true,"job":{...}}`).
    pub fn job_status(&self, id: u64) -> Result<Json> {
        expect_ok(self.call(&Json::obj(vec![
            ("op", Json::str("job-status")),
            ("id", Json::num(id as f64)),
        ]))?)
    }

    /// Poll until the job is `done` or `failed`; errors if the timeout
    /// passes first. Returns the terminal status envelope.
    pub fn wait_job(&self, id: u64, timeout: Duration) -> Result<Json> {
        let deadline = Instant::now() + timeout;
        loop {
            let reply = self.job_status(id)?;
            let state = reply
                .get("job")
                .and_then(|j| j.get("state"))
                .and_then(Json::as_str)
                .unwrap_or("");
            if state == "done" || state == "failed" {
                return Ok(reply);
            }
            if Instant::now() >= deadline {
                return Err(Error::Other(format!(
                    "job {id} still `{state}` after {:.1}s",
                    timeout.as_secs_f64()
                )));
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    /// Stop the daemon gracefully: reject new jobs, finish the queue.
    pub fn drain(&self) -> Result<Json> {
        expect_ok(self.call(&Json::obj(vec![("op", Json::str("drain"))]))?)
    }

    /// Stop the daemon now; queued jobs persist for the next start.
    pub fn halt(&self) -> Result<Json> {
        expect_ok(self.call(&Json::obj(vec![("op", Json::str("halt"))]))?)
    }

    /// One HTTP exchange on the pooled keep-alive connection, falling back
    /// to (and pooling) a fresh connection when the daemon closed ours.
    fn http_post(&self, path: &str, body: &str) -> Result<String> {
        let request = format!(
            "POST {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: application/x-ndjson\r\n\
             Content-Length: {}\r\n\r\n{body}",
            self.addr,
            body.len()
        );
        let pooled = lock_unpoisoned(&self.conn).take();
        let reply = match pooled {
            Some(stream) => match read_reply(stream, request.as_bytes()) {
                Ok(r) => r,
                // The daemon closed the pooled connection between calls,
                // before this request reached a handler; resend once.
                Err(ReplyErr::Stale(_)) => self.fresh_reply(&request)?,
                Err(ReplyErr::Fatal(e)) => return Err(e),
            },
            None => self.fresh_reply(&request)?,
        };
        *lock_unpoisoned(&self.conn) = reply.reusable;
        if !reply.status.contains(" 200 ") {
            return Err(Error::Other(format!(
                "daemon replied `{}`: {}",
                reply.status,
                reply.body.trim()
            )));
        }
        Ok(reply.body)
    }

    fn fresh_reply(&self, request: &str) -> Result<Reply> {
        let stream = TcpStream::connect(&self.addr)
            .map_err(|e| Error::Other(format!("connect {}: {e}", self.addr)))?;
        match read_reply(stream, request.as_bytes()) {
            Ok(r) => Ok(r),
            Err(ReplyErr::Stale(e)) | Err(ReplyErr::Fatal(e)) => Err(e),
        }
    }
}

fn expect_ok(reply: Json) -> Result<Json> {
    if reply.get("ok").and_then(Json::as_bool) == Some(true) {
        return Ok(reply);
    }
    let msg = reply
        .get("error")
        .and_then(Json::as_str)
        .unwrap_or("daemon refused the request")
        .to_string();
    Err(Error::Other(msg))
}

/// One parsed HTTP reply; `reusable` carries the connection back to the
/// pool when the server kept it open.
struct Reply {
    status: String,
    body: String,
    reusable: Option<TcpStream>,
}

/// Why an exchange failed: `Stale` means no reply byte ever arrived (the
/// server never saw the request — safe to resend), `Fatal` means the
/// failure happened mid-exchange and must surface.
enum ReplyErr {
    Stale(Error),
    Fatal(Error),
}

const MAX_REPLY_HEAD: usize = 64 * 1024;

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Write `request`, then read one Content-Length-framed HTTP reply.
fn read_reply(mut stream: TcpStream, request: &[u8]) -> std::result::Result<Reply, ReplyErr> {
    if let Err(e) = stream.write_all(request) {
        // A stale pooled socket surfaces as EPIPE/ECONNRESET on write.
        return Err(ReplyErr::Stale(e.into()));
    }
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_REPLY_HEAD {
            return Err(ReplyErr::Fatal(Error::Other("oversized reply head".into())));
        }
        let mut chunk = [0u8; 4096];
        match stream.read(&mut chunk) {
            Ok(0) if buf.is_empty() => {
                let msg = "daemon closed the pooled connection".to_string();
                return Err(ReplyErr::Stale(Error::Other(msg)));
            }
            Ok(0) => {
                return Err(ReplyErr::Fatal(Error::Other("daemon closed mid-reply".into())));
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if buf.is_empty() => return Err(ReplyErr::Stale(e.into())),
            Err(e) => return Err(ReplyErr::Fatal(e.into())),
        }
    };
    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(h) => h,
        Err(_) => return Err(ReplyErr::Fatal(Error::Other("non-UTF-8 reply head".into()))),
    };
    let mut lines = head.lines();
    let status = lines.next().unwrap_or("").to_string();
    let mut close = !status.starts_with("HTTP/1.1");
    let mut content_length: Option<usize> = None;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.parse().ok();
            } else if name.eq_ignore_ascii_case("connection") && value.eq_ignore_ascii_case("close")
            {
                close = true;
            }
        }
    }
    let len = match content_length {
        Some(l) => l,
        None => return Err(ReplyErr::Fatal(Error::Other("reply without Content-Length".into()))),
    };
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < len {
        let mut chunk = [0u8; 4096];
        match stream.read(&mut chunk) {
            Ok(0) => return Err(ReplyErr::Fatal(Error::Other("daemon closed mid-body".into()))),
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e) => return Err(ReplyErr::Fatal(e.into())),
        }
    }
    body.truncate(len);
    let body = match String::from_utf8(body) {
        Ok(b) => b,
        Err(_) => return Err(ReplyErr::Fatal(Error::Other("non-UTF-8 reply body".into()))),
    };
    Ok(Reply { status, body, reusable: (!close).then_some(stream) })
}
