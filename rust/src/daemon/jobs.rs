//! Supervised background jobs: queued per model, health-probed, retried.
//!
//! A job submitted over the control protocol lands in a [`JobManager`]
//! queue — either a multi-pass update over a seekable row batch
//! ([`JobKind::Update`]) or a one-pass stream over a forward-only source
//! such as a FIFO ([`JobKind::Stream`]). A supervisor thread starts at most
//! one attempt per model at a time (generations are linear — two concurrent
//! updates of one model would race the `CURRENT` pointer), watches each
//! worker through a heartbeat the executor bumps on every pass (stream
//! attempts bump it per absorbed batch), and:
//!
//! * **reaps** a worker whose heartbeat goes stale (the thread is detached
//!   — std threads cannot be killed — and the job is requeued or failed);
//! * **requeues** a failed attempt while it has retry budget, else marks
//!   the job failed with the worker's error;
//! * **hot-swaps** the model's serving engine after a successful publish,
//!   so new generations become visible to queries without a restart.
//!
//! The queue persists in `jobs.manifest` (same temp-file + rename idiom as
//! the fleet manifest). Running attempts are persisted *as queued*: after a
//! daemon restart they run again from scratch. That makes job execution
//! at-least-once — an update interrupted between publish and manifest
//! rewrite can apply twice — which is the right trade for a daemon whose
//! jobs are idempotent re-factorizations far more often than appends.
//! Stream jobs are the exception: a streamed batch is an append, so their
//! publish records the job id in the generation manifest and skips if a
//! generation already carries it — a retried (or reaped-but-alive) attempt
//! can never commit the same rows twice.
//!
//! Chaos knobs ([`JobSpec::chaos_fail_passes`], [`JobSpec::chaos_hang_ms`])
//! sabotage the *first* attempt only, turning "worker killed mid-update"
//! and "worker wedged mid-update" into deterministic scenario tests.

use crate::config::InputFormat;
use crate::coordinator::server::MetricsRegistry;
use crate::error::{Error, Result};
use crate::io::InputSpec;
use crate::serve::json::Json;
use crate::svd::executor::{Executor, LocalExecutor, Pass, PassContext, PassOutput};
use crate::update::{Update, UpdateResult};
use crate::util::{lock_unpoisoned, Logger};
use std::collections::{BTreeSet, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::fleet::{write_atomic, Fleet};

static LOG: Logger = Logger::new("daemon.jobs");

/// Queue file name under the daemon's state directory.
pub const JOBS_MANIFEST: &str = "jobs.manifest";

/// Supervisor poll cadence.
const TICK: Duration = Duration::from_millis(25);

/// Default heartbeat staleness after which a worker counts as a zombie.
/// Generous: a heartbeat lands at every pass boundary, and passes stream
/// the whole input, so slow disks beat slow heartbeats by a wide margin.
const DEFAULT_ZOMBIE_AFTER: Duration = Duration::from_secs(300);

/// Lifecycle of a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobState {
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed)
    }
}

/// What a job does with its row source.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// Multi-pass incremental update ([`crate::update::Update`]); the rows
    /// path must be seekable (re-read once per pass).
    Update,
    /// One-pass streaming append ([`crate::stream::StreamSvd`] +
    /// [`crate::update::publish_stream_result`]); the rows path may be a
    /// FIFO/pipe — it is read exactly once, forward only.
    Stream,
}

impl JobKind {
    pub fn as_str(self) -> &'static str {
        match self {
            JobKind::Update => "update",
            JobKind::Stream => "stream",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "update" => Ok(JobKind::Update),
            "stream" => Ok(JobKind::Stream),
            other => Err(Error::parse(format!("unknown job kind `{other}`"))),
        }
    }
}

/// Everything needed to run one update job against a registered model.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Assigned by [`JobManager::submit`] (0 until then).
    pub id: u64,
    /// How the rows are consumed (multi-pass update vs one-pass stream).
    pub kind: JobKind,
    /// Registered model name the update applies to.
    pub model: String,
    /// Row-batch path; format inferred from the extension.
    pub rows: String,
    /// New rank (0 = keep the model's current rank).
    pub rank: usize,
    /// Sketch oversampling for the update pass.
    pub oversample: usize,
    /// Worker threads for the update's executor.
    pub workers: usize,
    /// Rows per streamed block.
    pub block: usize,
    /// Sketch seed.
    pub seed: u64,
    /// Generations kept on disk after publish (the GC horizon).
    pub keep_generations: usize,
    /// Stream jobs: target residual for the adaptive range finder.
    pub tol: f64,
    /// Stream jobs: rank ceiling for the adaptive finder (0 = default).
    pub max_rank: usize,
    /// Stream jobs: rows absorbed per batch.
    pub batch_rows: usize,
    /// Total attempts before the job is marked failed.
    pub max_attempts: usize,
    /// Chaos: fail the first attempt after this many passes (0 = off).
    pub chaos_fail_passes: usize,
    /// Chaos: wedge the first attempt's first pass for this long (0 = off).
    pub chaos_hang_ms: u64,
    /// Hold the job in the queue this long before the first attempt
    /// (0 = run as soon as the model is free). Not persisted: a restarted
    /// daemon runs a delayed job immediately.
    pub delay_ms: u64,
}

impl JobSpec {
    pub fn new(model: impl Into<String>, rows: impl Into<String>) -> Self {
        JobSpec {
            id: 0,
            kind: JobKind::Update,
            model: model.into(),
            rows: rows.into(),
            rank: 0,
            oversample: 4,
            workers: 2,
            block: 64,
            seed: 17,
            keep_generations: 2,
            tol: crate::stream::DEFAULT_TOL,
            max_rank: 0,
            batch_rows: crate::stream::DEFAULT_BATCH_ROWS,
            max_attempts: 2,
            chaos_fail_passes: 0,
            chaos_hang_ms: 0,
            delay_ms: 0,
        }
    }

    /// Protocol form, as carried by a `submit-job` line.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("op", Json::str("submit-job")),
            ("kind", Json::str(self.kind.as_str())),
            ("model", Json::str(&self.model)),
            ("rows", Json::str(&self.rows)),
            ("rank", Json::num(self.rank as f64)),
            ("tol", Json::num(self.tol)),
            ("max_rank", Json::num(self.max_rank as f64)),
            ("batch_rows", Json::num(self.batch_rows as f64)),
            ("oversample", Json::num(self.oversample as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("block", Json::num(self.block as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("keep_generations", Json::num(self.keep_generations as f64)),
            ("max_attempts", Json::num(self.max_attempts as f64)),
            ("chaos_fail_passes", Json::num(self.chaos_fail_passes as f64)),
            ("chaos_hang_ms", Json::num(self.chaos_hang_ms as f64)),
            ("delay_ms", Json::num(self.delay_ms as f64)),
        ])
    }

    /// Parse a `submit-job` line; `model` and `rows` are required, every
    /// other knob keeps its default when absent.
    pub fn from_json(req: &Json) -> Result<JobSpec> {
        let model = req
            .get("model")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::parse("submit-job: missing `model`"))?;
        let rows = req
            .get("rows")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::parse("submit-job: missing `rows`"))?;
        let mut spec = JobSpec::new(model, rows);
        if let Some(kind) = req.get("kind").and_then(Json::as_str) {
            spec.kind = JobKind::parse(kind)?;
        }
        if let Some(tol) = req.get("tol") {
            spec.tol = tol
                .as_f64()
                .ok_or_else(|| Error::parse("submit-job: `tol` not a number"))?;
        }
        let usize_knob = |key: &str, into: &mut usize| -> Result<()> {
            if let Some(v) = req.get(key) {
                *into = v
                    .as_usize()
                    .ok_or_else(|| Error::parse(format!("submit-job: `{key}` not an integer")))?;
            }
            Ok(())
        };
        usize_knob("rank", &mut spec.rank)?;
        usize_knob("max_rank", &mut spec.max_rank)?;
        usize_knob("batch_rows", &mut spec.batch_rows)?;
        usize_knob("oversample", &mut spec.oversample)?;
        usize_knob("workers", &mut spec.workers)?;
        usize_knob("block", &mut spec.block)?;
        usize_knob("keep_generations", &mut spec.keep_generations)?;
        usize_knob("max_attempts", &mut spec.max_attempts)?;
        usize_knob("chaos_fail_passes", &mut spec.chaos_fail_passes)?;
        let mut seed = spec.seed as usize;
        usize_knob("seed", &mut seed)?;
        spec.seed = seed as u64;
        let mut hang = spec.chaos_hang_ms as usize;
        usize_knob("chaos_hang_ms", &mut hang)?;
        spec.chaos_hang_ms = hang as u64;
        let mut delay = spec.delay_ms as usize;
        usize_knob("delay_ms", &mut delay)?;
        spec.delay_ms = delay as u64;
        Ok(spec)
    }
}

/// Point-in-time view of a job, served over `job-status`.
#[derive(Clone, Debug)]
pub struct JobStatus {
    pub id: u64,
    pub model: String,
    pub state: JobState,
    /// Attempts started so far.
    pub attempts: usize,
    /// Generation published (done jobs only).
    pub generation: Option<u64>,
    /// Rows appended (done jobs only).
    pub rows_added: Option<usize>,
    /// Last error (failed jobs, or the cause of the latest requeue).
    pub error: Option<String>,
}

impl JobStatus {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            ("model", Json::str(&self.model)),
            ("state", Json::str(self.state.as_str())),
            ("attempts", Json::num(self.attempts as f64)),
        ];
        if let Some(g) = self.generation {
            fields.push(("generation", Json::num(g as f64)));
        }
        if let Some(r) = self.rows_added {
            fields.push(("rows_added", Json::num(r as f64)));
        }
        if let Some(e) = &self.error {
            fields.push(("error", Json::str(e)));
        }
        Json::obj(fields)
    }
}

/// A job waiting for its model to be free (or for its delay to pass).
struct QueuedJob {
    spec: JobSpec,
    attempts: usize,
    not_before: Option<Instant>,
    last_error: Option<String>,
    /// When the job entered the queue this time (a requeue resets it, a
    /// restart-restored job counts from restore) — the base of the
    /// `daemon_job_queue_ms{kind}` observation taken when an attempt starts.
    submitted: Instant,
}

impl QueuedJob {
    fn status(&self) -> JobStatus {
        JobStatus {
            id: self.spec.id,
            model: self.spec.model.clone(),
            state: JobState::Queued,
            attempts: self.attempts,
            generation: None,
            rows_added: None,
            error: self.last_error.clone(),
        }
    }
}

/// A live attempt: the worker thread plus the heartbeat it bumps.
struct RunningJob {
    spec: JobSpec,
    attempts: usize,
    handle: JoinHandle<Result<UpdateResult>>,
    heartbeat: Arc<Mutex<Instant>>,
    /// Carried over from the queue entry: base of `daemon_job_total_ms`.
    submitted: Instant,
    /// When this attempt's worker spawned: base of `daemon_job_run_ms`.
    started: Instant,
}

impl RunningJob {
    fn status(&self) -> JobStatus {
        JobStatus {
            id: self.spec.id,
            model: self.spec.model.clone(),
            state: JobState::Running,
            attempts: self.attempts,
            generation: None,
            rows_added: None,
            error: None,
        }
    }
}

struct Inner {
    queue: VecDeque<QueuedJob>,
    running: Vec<RunningJob>,
    finished: Vec<JobStatus>,
    next_id: u64,
    draining: bool,
}

impl Inner {
    fn find_status(&self, id: u64) -> Option<JobStatus> {
        self.running
            .iter()
            .find(|r| r.spec.id == id)
            .map(RunningJob::status)
            .or_else(|| self.queue.iter().find(|q| q.spec.id == id).map(QueuedJob::status))
            .or_else(|| self.finished.iter().find(|s| s.id == id).cloned())
    }
}

/// The per-daemon job queue and its supervisor thread (see module docs).
pub struct JobManager {
    inner: Arc<Mutex<Inner>>,
    halt: Arc<AtomicBool>,
    state_path: PathBuf,
    supervisor: Option<JoinHandle<()>>,
}

impl JobManager {
    /// Open the queue persisted under `state_dir` (restoring any jobs a
    /// previous daemon left behind) and start the supervisor.
    pub fn open(fleet: Arc<Fleet>, state_dir: &Path) -> Result<Self> {
        Self::open_with(fleet, state_dir, DEFAULT_ZOMBIE_AFTER)
    }

    /// [`JobManager::open`] with an explicit zombie horizon (tests shrink
    /// it to reap a deliberately wedged worker quickly).
    pub fn open_with(
        fleet: Arc<Fleet>,
        state_dir: &Path,
        zombie_after: Duration,
    ) -> Result<Self> {
        let state_path = state_dir.join(JOBS_MANIFEST);
        let (next_id, queue) = load_jobs(&state_path)?;
        if !queue.is_empty() {
            LOG.info(&format!("restored {} queued job(s) from a previous run", queue.len()));
        }
        let inner = Arc::new(Mutex::new(Inner {
            queue,
            running: Vec::new(),
            finished: Vec::new(),
            next_id,
            draining: false,
        }));
        let halt = Arc::new(AtomicBool::new(false));
        let supervisor = {
            let inner = inner.clone();
            let halt = halt.clone();
            let state_path = state_path.clone();
            std::thread::Builder::new()
                .name("tallfatd-supervisor".into())
                .spawn(move || supervise(fleet, inner, halt, state_path, zombie_after))
                .map_err(|e| Error::Other(format!("cannot spawn job supervisor: {e}")))?
        };
        Ok(JobManager { inner, halt, state_path, supervisor: Some(supervisor) })
    }

    /// Enqueue a job. Fails while draining, for unknown models, and for
    /// row paths that would corrupt the tab-separated manifest.
    pub fn submit(&self, mut spec: JobSpec) -> Result<u64> {
        if spec.rows.chars().any(|c| c.is_control()) {
            return Err(Error::Config("job rows path has control characters".into()));
        }
        let mut inner = lock_unpoisoned(&self.inner);
        if inner.draining {
            return Err(Error::Other("daemon is draining; not accepting jobs".into()));
        }
        spec.id = inner.next_id;
        inner.next_id += 1;
        let not_before = (spec.delay_ms > 0)
            .then(|| Instant::now() + Duration::from_millis(spec.delay_ms));
        let id = spec.id;
        let model = spec.model.clone();
        inner.queue.push_back(QueuedJob {
            spec,
            attempts: 0,
            not_before,
            last_error: None,
            submitted: Instant::now(),
        });
        persist(&self.state_path, &inner);
        drop(inner);
        MetricsRegistry::global().add("daemon_jobs_submitted", 1.0);
        LOG.info(&format!("job {id} queued for model `{model}`"));
        Ok(id)
    }

    pub fn status(&self, id: u64) -> Option<JobStatus> {
        lock_unpoisoned(&self.inner).find_status(id)
    }

    /// Every known job: running, then queued, then finished.
    pub fn statuses(&self) -> Vec<JobStatus> {
        let inner = lock_unpoisoned(&self.inner);
        let mut out: Vec<JobStatus> = inner.running.iter().map(RunningJob::status).collect();
        out.extend(inner.queue.iter().map(QueuedJob::status));
        out.extend(inner.finished.iter().cloned());
        out
    }

    /// Stop accepting jobs; already-queued work keeps running to completion.
    pub fn begin_drain(&self) {
        lock_unpoisoned(&self.inner).draining = true;
    }

    /// No queued and no running jobs.
    pub fn idle(&self) -> bool {
        let inner = lock_unpoisoned(&self.inner);
        inner.queue.is_empty() && inner.running.is_empty()
    }

    /// Block until [`JobManager::idle`] or the timeout; returns the final
    /// idleness.
    pub fn wait_idle(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while !self.idle() {
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        true
    }

    /// Stop the supervisor without waiting for the queue. Queued (and
    /// running) jobs stay in the manifest and run again after a restart.
    pub fn halt(&self) {
        self.halt.store(true, Ordering::SeqCst);
    }
}

impl Drop for JobManager {
    fn drop(&mut self) {
        self.halt();
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
    }
}

/// Scratch directory for one stream job's shards and checkpoints. Keyed by
/// the job id under the daemon state dir (ids are unique per daemon and
/// survive restarts in `jobs.manifest`), never by pid: a requeued or
/// restart-recovered attempt must find its predecessor's checkpoint to
/// resume instead of silently starting fresh.
fn stream_work_dir(state_dir: &Path, job_id: u64) -> PathBuf {
    state_dir.join("stream-scratch").join(format!("job-{job_id}"))
}

/// The supervisor loop: reap, zombie-check, start, persist — every tick.
fn supervise(
    fleet: Arc<Fleet>,
    inner: Arc<Mutex<Inner>>,
    halt: Arc<AtomicBool>,
    state_path: PathBuf,
    zombie_after: Duration,
) {
    let state_dir = state_path.parent().map(Path::to_path_buf).unwrap_or_default();
    while !halt.load(Ordering::SeqCst) {
        // Engine reloads happen outside the job lock: a reload re-opens
        // model shards from disk, and status queries must not wait on it.
        let mut reload: Vec<String> = Vec::new();
        {
            let mut inner = lock_unpoisoned(&inner);
            let mut changed = reap_finished(&mut inner, &state_dir, &mut reload);
            changed |= reap_zombies(&mut inner, &state_dir, zombie_after);
            changed |= start_eligible(&fleet, &mut inner, &state_dir);
            if changed {
                persist(&state_path, &inner);
            }
        }
        for model in reload {
            let Some(entry) = fleet.get(&model) else { continue };
            match entry.engines().reload() {
                Ok(Some(generation)) => {
                    LOG.info(&format!("model `{model}` now serving generation {generation}"));
                }
                Ok(None) => {}
                Err(e) => LOG.warn(&format!("model `{model}` reload after publish: {e}")),
            }
        }
        std::thread::sleep(TICK);
    }
}

fn reap_finished(inner: &mut Inner, state_dir: &Path, reload: &mut Vec<String>) -> bool {
    let mut changed = false;
    let mut i = 0;
    while i < inner.running.len() {
        if !inner.running[i].handle.is_finished() {
            i += 1;
            continue;
        }
        let r = inner.running.remove(i);
        changed = true;
        let outcome = r.handle.join().unwrap_or_else(|_| {
            Err(Error::Other(format!("job {} worker panicked", r.spec.id)))
        });
        match outcome {
            Ok(result) => {
                LOG.info(&format!(
                    "job {} done: model `{}` generation {} (+{} rows)",
                    r.spec.id, r.spec.model, result.generation, result.rows_added
                ));
                inner.finished.push(JobStatus {
                    id: r.spec.id,
                    model: r.spec.model.clone(),
                    state: JobState::Done,
                    attempts: r.attempts + 1,
                    generation: Some(result.generation),
                    rows_added: Some(result.rows_added),
                    error: None,
                });
                reload.push(r.spec.model);
                let reg = MetricsRegistry::global();
                reg.add("daemon_jobs_completed", 1.0);
                // Lifecycle histograms: this attempt's wall time, and the
                // whole queued→running→published arc since the job last
                // entered the queue.
                let kind = [("kind", r.spec.kind.as_str())];
                reg.observe_labeled(
                    "daemon_job_run_ms",
                    &kind,
                    r.started.elapsed().as_secs_f64() * 1e3,
                );
                reg.observe_labeled(
                    "daemon_job_total_ms",
                    &kind,
                    r.submitted.elapsed().as_secs_f64() * 1e3,
                );
            }
            Err(e) => settle_failure(inner, state_dir, r.spec, r.attempts, e.to_string()),
        }
    }
    changed
}

fn reap_zombies(inner: &mut Inner, state_dir: &Path, zombie_after: Duration) -> bool {
    let mut changed = false;
    let mut i = 0;
    while i < inner.running.len() {
        let stale = lock_unpoisoned(&inner.running[i].heartbeat).elapsed();
        if stale < zombie_after {
            i += 1;
            continue;
        }
        // std threads cannot be killed: drop the handle (detaching the
        // wedged worker) and let retry policy decide the job's fate. A
        // detached update worker errors out into nowhere; a detached stream
        // worker shares the retry's scratch dir, but commit-versioned
        // checkpoints and the idempotent per-job publish keep the overlap
        // harmless (see `run_stream_attempt`).
        let r = inner.running.remove(i);
        changed = true;
        LOG.warn(&format!(
            "job {} zombie: no heartbeat for {:.1}s, reaping worker",
            r.spec.id,
            stale.as_secs_f64()
        ));
        MetricsRegistry::global().add("daemon_zombies_reaped", 1.0);
        settle_failure(
            inner,
            state_dir,
            r.spec,
            r.attempts,
            format!("worker heartbeat stale for {:.1}s", stale.as_secs_f64()),
        );
    }
    changed
}

/// A failed attempt goes back to the front of the queue while the job has
/// retry budget, else the job is finished as failed (a failed stream job
/// also drops its scratch dir — only a retry still needs the checkpoint).
fn settle_failure(
    inner: &mut Inner,
    state_dir: &Path,
    spec: JobSpec,
    attempts: usize,
    error: String,
) {
    let spent = attempts + 1;
    if spent < spec.max_attempts {
        LOG.warn(&format!(
            "job {} attempt {spent}/{} failed ({error}); requeueing",
            spec.id, spec.max_attempts
        ));
        MetricsRegistry::global().add("daemon_jobs_requeued", 1.0);
        inner.queue.push_front(QueuedJob {
            spec,
            attempts: spent,
            not_before: None,
            last_error: Some(error),
            submitted: Instant::now(),
        });
    } else {
        LOG.warn(&format!("job {} failed after {spent} attempt(s): {error}", spec.id));
        MetricsRegistry::global().add("daemon_jobs_failed", 1.0);
        if spec.kind == JobKind::Stream {
            let _ = std::fs::remove_dir_all(stream_work_dir(state_dir, spec.id));
        }
        inner.finished.push(JobStatus {
            id: spec.id,
            model: spec.model,
            state: JobState::Failed,
            attempts: spent,
            generation: None,
            rows_added: None,
            error: Some(error),
        });
    }
}

fn start_eligible(fleet: &Fleet, inner: &mut Inner, state_dir: &Path) -> bool {
    let mut busy: BTreeSet<String> =
        inner.running.iter().map(|r| r.spec.model.clone()).collect();
    let mut changed = false;
    let mut i = 0;
    while i < inner.queue.len() {
        let ready = {
            let q = &inner.queue[i];
            let held = match q.not_before {
                Some(t) => Instant::now() < t,
                None => false,
            };
            !busy.contains(&q.spec.model) && !held
        };
        if !ready {
            i += 1;
            continue;
        }
        let Some(q) = inner.queue.remove(i) else { break };
        changed = true;
        busy.insert(q.spec.model.clone());
        match start_attempt(fleet, &q, state_dir) {
            Ok(running) => inner.running.push(running),
            Err(e) => settle_failure(inner, state_dir, q.spec, q.attempts, e.to_string()),
        }
    }
    changed
}

fn start_attempt(fleet: &Fleet, q: &QueuedJob, state_dir: &Path) -> Result<RunningJob> {
    let entry = fleet
        .get(&q.spec.model)
        .ok_or_else(|| Error::Config(format!("model `{}` is not registered", q.spec.model)))?;
    let root = entry.root().to_path_buf();
    let spec = q.spec.clone();
    let scratch = state_dir.to_path_buf();
    let heartbeat = Arc::new(Mutex::new(Instant::now()));
    let hb = heartbeat.clone();
    // Chaos sabotages the first attempt only: the retry must prove the
    // job completes once the fault clears.
    let first = q.attempts == 0;
    let handle = std::thread::Builder::new()
        .name(format!("tallfatd-job-{}", spec.id))
        .spawn(move || run_attempt(&spec, &root, &scratch, hb, first))
        .map_err(|e| Error::Other(format!("cannot spawn job worker: {e}")))?;
    LOG.info(&format!(
        "job {} attempt {} started for model `{}`",
        q.spec.id,
        q.attempts + 1,
        q.spec.model
    ));
    MetricsRegistry::global().observe_labeled(
        "daemon_job_queue_ms",
        &[("kind", q.spec.kind.as_str())],
        q.submitted.elapsed().as_secs_f64() * 1e3,
    );
    Ok(RunningJob {
        spec: q.spec.clone(),
        attempts: q.attempts,
        handle,
        heartbeat,
        submitted: q.submitted,
        started: Instant::now(),
    })
}

fn run_attempt(
    spec: &JobSpec,
    root: &Path,
    state_dir: &Path,
    heartbeat: Arc<Mutex<Instant>>,
    first_attempt: bool,
) -> Result<UpdateResult> {
    if spec.kind == JobKind::Stream {
        return run_stream_attempt(spec, root, state_dir, heartbeat, first_attempt);
    }
    let input =
        InputSpec { path: spec.rows.clone(), format: InputFormat::from_path(&spec.rows) };
    let mut exec = SupervisedExecutor {
        inner: LocalExecutor::new(spec.workers),
        heartbeat,
        fail_after: (first_attempt && spec.chaos_fail_passes > 0)
            .then_some(spec.chaos_fail_passes),
        hang_ms: if first_attempt { spec.chaos_hang_ms } else { 0 },
        passes: 0,
    };
    let mut update = Update::of(root)?
        .rows(&input)
        .oversample(spec.oversample)
        .workers(spec.workers)
        .block(spec.block)
        .seed(spec.seed)
        .keep_generations(spec.keep_generations)
        .executor(&mut exec);
    if spec.rank > 0 {
        update = update.rank(spec.rank);
    }
    update.run()
}

/// One stream-job attempt: factor the forward-only rows source in a single
/// pass, then fold the finished factors into the model as the next
/// generation. The per-batch progress callback doubles as the supervisor
/// heartbeat and keeps ticking through the finish tail (recovery, Y→U
/// rotation, publish), so only a producer that stops feeding the pipe —
/// not a long but healthy tail — trips the zombie reaper.
fn run_stream_attempt(
    spec: &JobSpec,
    root: &Path,
    state_dir: &Path,
    heartbeat: Arc<Mutex<Instant>>,
    first_attempt: bool,
) -> Result<UpdateResult> {
    // The model's geometry pins the stream: same column dictionary, same
    // centeredness — otherwise the merge would be between different spaces.
    let store = crate::serve::store::ModelStore::open(root, 1)?;
    let (n, centered) = (store.n(), store.centered());
    drop(store);
    // Stable per-job scratch (no pid!): a requeued attempt — including one
    // re-run after a daemon restart — resumes from the last checkpointed
    // batch boundary instead of silently starting fresh (the producer must
    // replay the stream; absorbed rows are skipped, their Y shards reused
    // from disk). The dir is removed on success and on terminal failure
    // (`settle_failure`). A reaped-but-still-alive predecessor shares this
    // dir; its checkpoint writes are commit-versioned (see
    // `stream::checkpoint`) and its publish is made idempotent below, so
    // the overlap cannot double-count rows.
    let work_dir = stream_work_dir(state_dir, spec.id).to_string_lossy().into_owned();
    let hb = heartbeat.clone();
    let mut builder = crate::stream::StreamSvd::open(&spec.rows)
        .format(InputFormat::from_path(&spec.rows))
        .tol(spec.tol)
        .max_rank(spec.max_rank)
        .batch_rows(spec.batch_rows)
        .oversample(spec.oversample)
        .cols(n)
        .center(centered)
        .seed(spec.seed)
        .work_dir(&work_dir)
        .checkpoint(true)
        .resume(!first_attempt)
        .progress(move |_, _| *lock_unpoisoned(&hb) = Instant::now());
    if spec.rank > 0 {
        builder = builder.rank(spec.rank);
    }
    let streamed = builder.run()?;
    let backend: crate::backend::BackendRef =
        Arc::new(crate::backend::native::NativeBackend::new());
    let out = crate::update::publish_stream_result(
        root,
        &streamed,
        &backend,
        &crate::update::StreamPublish {
            rank: (spec.rank > 0).then_some(spec.rank),
            keep_generations: spec.keep_generations,
            seed: Some(spec.seed),
            job_id: Some(spec.id),
            progress: Some(Arc::new(move || {
                *lock_unpoisoned(&heartbeat) = Instant::now()
            })),
        },
    )?;
    let _ = std::fs::remove_dir_all(&work_dir);
    Ok(out)
}

/// A [`LocalExecutor`] wrapper that (a) bumps the supervisor-visible
/// heartbeat at every pass boundary and (b) injects the spec's chaos.
struct SupervisedExecutor {
    inner: LocalExecutor,
    heartbeat: Arc<Mutex<Instant>>,
    fail_after: Option<usize>,
    hang_ms: u64,
    passes: usize,
}

impl Executor for SupervisedExecutor {
    fn name(&self) -> &str {
        "supervised-local"
    }

    fn run_pass(&mut self, ctx: &PassContext, pass: &Pass) -> Result<PassOutput> {
        *lock_unpoisoned(&self.heartbeat) = Instant::now();
        if self.passes == 0 && self.hang_ms > 0 {
            // Wedge: heartbeat goes stale while we sleep, so the zombie
            // reaper fires; then die without touching the model.
            std::thread::sleep(Duration::from_millis(self.hang_ms));
            return Err(Error::Other("chaos: worker wedged".into()));
        }
        if let Some(n) = self.fail_after {
            if self.passes >= n {
                return Err(Error::Other(format!(
                    "chaos: worker killed before pass `{}`",
                    pass.name()
                )));
            }
        }
        self.passes += 1;
        self.inner.run_pass(ctx, pass)
    }
}

fn persist(path: &Path, inner: &Inner) {
    let mut text = String::from("# tallfat jobs manifest v1\n");
    text.push_str(&format!("next_id={}\n", inner.next_id));
    // Running attempts are persisted as queued: a restart re-runs them.
    for r in &inner.running {
        text.push_str(&job_line(&r.spec, r.attempts));
    }
    for q in &inner.queue {
        text.push_str(&job_line(&q.spec, q.attempts));
    }
    if let Err(e) = write_atomic(path, &text) {
        LOG.warn(&format!("cannot persist job queue to {}: {e}", path.display()));
    }
}

fn job_line(spec: &JobSpec, attempts: usize) -> String {
    // `tol` travels as f64 bits so a restart resumes with the exact value.
    format!(
        "job\tid={}\tkind={}\tmodel={}\trows={}\trank={}\toversample={}\tworkers={}\tblock={}\t\
         seed={}\tkeep_generations={}\ttol_bits={}\tmax_rank={}\tbatch_rows={}\t\
         max_attempts={}\tchaos_fail_passes={}\tattempts={}\n",
        spec.id,
        spec.kind.as_str(),
        spec.model,
        spec.rows,
        spec.rank,
        spec.oversample,
        spec.workers,
        spec.block,
        spec.seed,
        spec.keep_generations,
        spec.tol.to_bits(),
        spec.max_rank,
        spec.batch_rows,
        spec.max_attempts,
        spec.chaos_fail_passes,
        attempts
    )
}

fn load_jobs(path: &Path) -> Result<(u64, VecDeque<QueuedJob>)> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok((1, VecDeque::new()));
        }
        Err(e) => return Err(e.into()),
    };
    let mut next_id = 1u64;
    let mut queue = VecDeque::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(v) = line.strip_prefix("next_id=") {
            next_id = v
                .parse()
                .map_err(|_| Error::parse(format!("jobs manifest: bad next_id `{v}`")))?;
            continue;
        }
        let Some(fields) = line.strip_prefix("job\t") else {
            return Err(Error::parse(format!("jobs manifest: bad line `{line}`")));
        };
        let mut spec = JobSpec::new("", "");
        let mut attempts = 0usize;
        for field in fields.split('\t') {
            let (key, value) = field.split_once('=').ok_or_else(|| {
                Error::parse(format!("jobs manifest: bad field `{field}`"))
            })?;
            let bad = || Error::parse(format!("jobs manifest: bad value `{field}`"));
            match key {
                "id" => spec.id = value.parse().map_err(|_| bad())?,
                "kind" => spec.kind = JobKind::parse(value).map_err(|_| bad())?,
                "model" => spec.model = value.to_string(),
                "tol_bits" => {
                    spec.tol = f64::from_bits(value.parse().map_err(|_| bad())?)
                }
                "max_rank" => spec.max_rank = value.parse().map_err(|_| bad())?,
                "batch_rows" => spec.batch_rows = value.parse().map_err(|_| bad())?,
                "rows" => spec.rows = value.to_string(),
                "rank" => spec.rank = value.parse().map_err(|_| bad())?,
                "oversample" => spec.oversample = value.parse().map_err(|_| bad())?,
                "workers" => spec.workers = value.parse().map_err(|_| bad())?,
                "block" => spec.block = value.parse().map_err(|_| bad())?,
                "seed" => spec.seed = value.parse().map_err(|_| bad())?,
                "keep_generations" => {
                    spec.keep_generations = value.parse().map_err(|_| bad())?
                }
                "max_attempts" => spec.max_attempts = value.parse().map_err(|_| bad())?,
                "chaos_fail_passes" => {
                    spec.chaos_fail_passes = value.parse().map_err(|_| bad())?
                }
                "attempts" => attempts = value.parse().map_err(|_| bad())?,
                // Forward compatibility: unknown knobs are ignored.
                _ => {}
            }
        }
        if spec.model.is_empty() || spec.rows.is_empty() {
            return Err(Error::parse(format!("jobs manifest: incomplete job `{line}`")));
        }
        queue.push_back(QueuedJob {
            spec,
            attempts,
            not_before: None,
            last_error: None,
            submitted: Instant::now(),
        });
    }
    Ok((next_id, queue))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::backend::BackendRef;
    use crate::io::dataset::{gen_exact, Spectrum};
    use crate::serve::batcher::BatchOptions;
    use crate::svd::Svd;

    fn dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("tallfat_test_jobs").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Base model + a row batch to update it with.
    fn fixture(d: &Path, seed: u64) -> (PathBuf, String) {
        let (a, _) = gen_exact(
            60,
            8,
            3,
            Spectrum::Geometric { scale: 5.0, decay: 0.6 },
            0.0,
            seed,
        )
        .unwrap();
        let spec = InputSpec::csv(d.join("a.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        let model = d.join("model");
        Svd::over(&spec)
            .unwrap()
            .rank(3)
            .workers(2)
            .block(32)
            .work_dir(d.join("work").to_string_lossy().into_owned())
            .save_model(model.to_string_lossy().into_owned())
            .run()
            .unwrap();
        let (b, _) = gen_exact(
            20,
            8,
            3,
            Spectrum::Geometric { scale: 4.0, decay: 0.5 },
            0.0,
            seed + 1,
        )
        .unwrap();
        let rows = InputSpec::csv(d.join("b.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&b, &rows).unwrap();
        (model, rows.path)
    }

    fn fleet_with(d: &Path, name: &str, model: &Path) -> Arc<Fleet> {
        let backend: BackendRef = Arc::new(NativeBackend::new());
        let fleet =
            Fleet::open(d.join("state"), backend, 2, BatchOptions::default()).unwrap();
        fleet.register(name, model).unwrap();
        Arc::new(fleet)
    }

    fn wait_terminal(jobs: &JobManager, id: u64, timeout: Duration) -> JobStatus {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(s) = jobs.status(id) {
                if s.state.is_terminal() {
                    return s;
                }
            }
            assert!(Instant::now() < deadline, "job {id} did not settle in time");
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    #[test]
    fn spec_round_trips_through_json() {
        let mut spec = JobSpec::new("movies", "/data/rows.csv");
        spec.kind = JobKind::Stream;
        spec.rank = 5;
        spec.seed = 99;
        spec.tol = 2.5e-4;
        spec.max_rank = 64;
        spec.batch_rows = 256;
        spec.chaos_fail_passes = 1;
        let parsed = JobSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(parsed.kind, JobKind::Stream);
        assert_eq!(parsed.model, "movies");
        assert_eq!(parsed.rows, "/data/rows.csv");
        assert_eq!(parsed.rank, 5);
        assert_eq!(parsed.seed, 99);
        assert_eq!(parsed.tol, 2.5e-4);
        assert_eq!(parsed.max_rank, 64);
        assert_eq!(parsed.batch_rows, 256);
        assert_eq!(parsed.chaos_fail_passes, 1);
        assert!(JobSpec::from_json(&Json::obj(vec![("op", Json::str("submit-job"))])).is_err());
        assert!(JobSpec::from_json(&Json::obj(vec![
            ("model", Json::str("m")),
            ("rows", Json::str("r")),
            ("kind", Json::str("teleport")),
        ]))
        .is_err());
    }

    #[test]
    fn manifest_round_trips() {
        let d = dir("manifest");
        let path = d.join(JOBS_MANIFEST);
        let mut spec = JobSpec::new("movies", "/data/rows.csv");
        spec.id = 4;
        spec.max_attempts = 3;
        spec.kind = JobKind::Stream;
        spec.tol = 7.5e-3;
        spec.max_rank = 48;
        spec.batch_rows = 333;
        let inner = Inner {
            queue: VecDeque::from([QueuedJob {
                spec,
                attempts: 1,
                not_before: None,
                last_error: None,
                submitted: Instant::now(),
            }]),
            running: Vec::new(),
            finished: Vec::new(),
            next_id: 5,
            draining: false,
        };
        persist(&path, &inner);
        let (next_id, queue) = load_jobs(&path).unwrap();
        assert_eq!(next_id, 5);
        assert_eq!(queue.len(), 1);
        assert_eq!(queue[0].spec.id, 4);
        assert_eq!(queue[0].spec.model, "movies");
        assert_eq!(queue[0].spec.max_attempts, 3);
        assert_eq!(queue[0].spec.kind, JobKind::Stream);
        assert_eq!(queue[0].spec.tol, 7.5e-3, "tol must round-trip bit-exactly");
        assert_eq!(queue[0].spec.max_rank, 48);
        assert_eq!(queue[0].spec.batch_rows, 333);
        assert_eq!(queue[0].attempts, 1);
        let (next_id, queue) = load_jobs(&d.join("missing.manifest")).unwrap();
        assert_eq!(next_id, 1);
        assert!(queue.is_empty());
    }

    #[test]
    fn job_completes_and_engine_hot_swaps() {
        let d = dir("complete");
        let (model, rows) = fixture(&d, 11);
        let fleet = fleet_with(&d, "m", &model);
        let entry = fleet.get("m").unwrap();
        assert_eq!(entry.generation(), 0);
        let jobs = JobManager::open(fleet.clone(), &d.join("state")).unwrap();
        let id = jobs.submit(JobSpec::new("m", rows)).unwrap();
        let status = wait_terminal(&jobs, id, Duration::from_secs(30));
        assert_eq!(status.state, JobState::Done);
        assert_eq!(status.generation, Some(1));
        assert_eq!(status.rows_added, Some(20));
        // The supervisor reloaded the serving engine after the publish.
        let deadline = Instant::now() + Duration::from_secs(5);
        while entry.generation() != 1 {
            assert!(Instant::now() < deadline, "engine never hot-swapped");
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(jobs.wait_idle(Duration::from_secs(1)));
    }

    #[test]
    fn stream_job_completes_and_engine_hot_swaps() {
        let d = dir("stream_complete");
        let (model, rows) = fixture(&d, 29);
        let fleet = fleet_with(&d, "m", &model);
        let entry = fleet.get("m").unwrap();
        assert_eq!(entry.generation(), 0);
        let jobs = JobManager::open(fleet.clone(), &d.join("state")).unwrap();
        let mut spec = JobSpec::new("m", rows);
        spec.kind = JobKind::Stream;
        spec.rank = 3;
        spec.batch_rows = 8;
        let id = jobs.submit(spec).unwrap();
        let status = wait_terminal(&jobs, id, Duration::from_secs(30));
        assert_eq!(status.state, JobState::Done, "error: {:?}", status.error);
        assert_eq!(status.generation, Some(1));
        assert_eq!(status.rows_added, Some(20));
        let deadline = Instant::now() + Duration::from_secs(5);
        while entry.generation() != 1 {
            assert!(Instant::now() < deadline, "engine never hot-swapped");
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    #[test]
    fn chaos_kill_requeues_then_completes() {
        let d = dir("chaos_kill");
        let (model, rows) = fixture(&d, 13);
        let fleet = fleet_with(&d, "m", &model);
        let jobs = JobManager::open(fleet, &d.join("state")).unwrap();
        let mut spec = JobSpec::new("m", rows);
        spec.chaos_fail_passes = 1;
        let id = jobs.submit(spec).unwrap();
        let status = wait_terminal(&jobs, id, Duration::from_secs(30));
        assert_eq!(status.state, JobState::Done, "error: {:?}", status.error);
        assert_eq!(status.attempts, 2, "chaos attempt should have been retried");
        assert_eq!(status.generation, Some(1));
    }

    #[test]
    fn chaos_kill_exhausts_retry_budget() {
        let d = dir("chaos_fail");
        let (model, rows) = fixture(&d, 15);
        let fleet = fleet_with(&d, "m", &model);
        let jobs = JobManager::open(fleet.clone(), &d.join("state")).unwrap();
        let mut spec = JobSpec::new("m", rows);
        spec.max_attempts = 1; // chaos hits attempt 0; no budget to retry
        spec.chaos_fail_passes = 1;
        let id = jobs.submit(spec).unwrap();
        let status = wait_terminal(&jobs, id, Duration::from_secs(30));
        assert_eq!(status.state, JobState::Failed);
        assert!(status.error.unwrap().contains("chaos"));
        assert_eq!(fleet.get("m").unwrap().generation(), 0);
    }

    #[test]
    fn wedged_worker_is_reaped_and_job_retried() {
        let d = dir("zombie");
        let (model, rows) = fixture(&d, 17);
        let fleet = fleet_with(&d, "m", &model);
        let jobs =
            JobManager::open_with(fleet, &d.join("state"), Duration::from_millis(150))
                .unwrap();
        let mut spec = JobSpec::new("m", rows);
        spec.chaos_hang_ms = 800; // well past the 150ms zombie horizon
        let id = jobs.submit(spec).unwrap();
        let status = wait_terminal(&jobs, id, Duration::from_secs(30));
        assert_eq!(status.state, JobState::Done, "error: {:?}", status.error);
        assert_eq!(status.attempts, 2);
        assert!(
            MetricsRegistry::global().get("daemon_zombies_reaped").unwrap_or(0.0) >= 1.0
        );
    }

    #[test]
    fn drain_rejects_new_jobs_and_unknown_models_fail() {
        let d = dir("drain");
        let (model, rows) = fixture(&d, 19);
        let fleet = fleet_with(&d, "m", &model);
        let jobs = JobManager::open(fleet, &d.join("state")).unwrap();
        let id = jobs.submit(JobSpec::new("ghost", rows.clone())).unwrap();
        let status = wait_terminal(&jobs, id, Duration::from_secs(10));
        assert_eq!(status.state, JobState::Failed);
        assert!(status.error.unwrap().contains("not registered"));
        jobs.begin_drain();
        assert!(jobs.submit(JobSpec::new("m", rows)).is_err());
    }

    #[test]
    fn queued_job_survives_restart() {
        let d = dir("restart");
        let (model, rows) = fixture(&d, 23);
        let state = d.join("state");
        let fleet = fleet_with(&d, "m", &model);
        let id;
        {
            let jobs = JobManager::open(fleet.clone(), &state).unwrap();
            let mut spec = JobSpec::new("m", rows);
            spec.delay_ms = 60_000; // parked in the queue well past halt
            id = jobs.submit(spec).unwrap();
            jobs.halt();
        } // drop joins the supervisor; the job is still in jobs.manifest
        let jobs = JobManager::open(fleet, &state).unwrap();
        let status = wait_terminal(&jobs, id, Duration::from_secs(30));
        assert_eq!(status.state, JobState::Done, "error: {:?}", status.error);
        assert_eq!(status.generation, Some(1));
    }
}
