//! Declarative chaos scenarios: topology + workload + script + expectations.
//!
//! A [`Scenario`] describes a daemon test the way an operator would: which
//! models exist (topology), how many query clients hammer them throughout
//! (workload), what happens to the daemon while they do (steps: submit a
//! sabotaged update, drain, halt, restart, wait), and what must hold at
//! the end (expectations). [`Scenario::run`] is the interpreter: it boots
//! a real in-process [`super::Daemon`] on an ephemeral port, drives every
//! step over the real control protocol, and checks the expectations
//! against query counters and the on-disk model state.
//!
//! ```no_run
//! # use tallfat::daemon::{JobSpec, Scenario};
//! let mut job = JobSpec::new("movies", "/data/new_rows.csv");
//! job.chaos_fail_passes = 1; // kill the first worker mid-update
//! let report = Scenario::new("worker_killed_mid_update")
//!     .model("movies", "/models/movies")
//!     .workload(2)
//!     .submit_update(job)
//!     .await_jobs(60)
//!     .expect_all_jobs_done()
//!     .expect_zero_failed_queries()
//!     .expect_generation_at_least("movies", 1)
//!     .run()
//!     .unwrap();
//! assert_eq!(report.queries_failed, 0);
//! ```
//!
//! The races this harness exists for — a worker killed mid-update, GC
//! deleting a generation under a reload, a restart with a job queued —
//! all end the same way: a consistent published generation and zero
//! failed queries, or the scenario fails.

use crate::backend::native::NativeBackend;
use crate::backend::BackendRef;
use crate::error::{Error, Result};
use crate::serve::json::Json;
use crate::serve::store::ModelStore;
use crate::util::{lock_unpoisoned, read_unpoisoned, write_unpoisoned, Logger};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::client::DaemonClient;
use super::jobs::JobSpec;
use super::server::{Daemon, DaemonOptions};

static LOG: Logger = Logger::new("daemon.scenario");

/// One scripted action against the running daemon.
#[derive(Clone, Debug)]
pub enum Step {
    /// Queue an update job over the control protocol.
    SubmitUpdate(JobSpec),
    /// Block until every submitted job is `done` or `failed`.
    AwaitJobs { timeout: Duration },
    /// Graceful stop: reject new jobs, finish the queue, then exit.
    Drain,
    /// Hard stop: queued jobs stay on disk for the next start.
    Halt,
    /// Boot a fresh daemon over the same state directory (after a halt,
    /// or implicitly halting a running one).
    Restart,
    /// Let the workload run undisturbed for a while.
    Sleep(Duration),
}

/// A property the scenario must end with.
#[derive(Clone, Debug)]
pub enum Expectation {
    /// Every query issued by the workload got an `ok:true` reply.
    ZeroFailedQueries,
    /// The model's *on-disk* published generation reached this floor.
    GenerationAtLeast { model: String, generation: u64 },
    /// Every job the script submitted ended `done` (none failed, none
    /// left behind).
    AllJobsDone,
}

/// What actually happened, for assertions beyond the expectations.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    pub queries_ok: u64,
    pub queries_failed: u64,
    /// Final *published* generation per model, read from disk.
    pub generations: BTreeMap<String, u64>,
    pub jobs_done: usize,
    pub jobs_failed: usize,
}

/// A declarative daemon test (see module docs). Build, then [`Scenario::run`].
pub struct Scenario {
    name: String,
    state_dir: PathBuf,
    models: Vec<(String, PathBuf)>,
    clients: usize,
    steps: Vec<Step>,
    expectations: Vec<Expectation>,
    health_poll: Duration,
}

impl Scenario {
    pub fn new(name: impl Into<String>) -> Self {
        let name = name.into();
        let state_dir = std::env::temp_dir().join(format!("tallfat_scenario_{name}"));
        Scenario {
            name,
            state_dir,
            models: Vec::new(),
            clients: 2,
            steps: Vec::new(),
            expectations: Vec::new(),
            health_poll: Duration::from_millis(200),
        }
    }

    /// Daemon state directory (default: a per-name temp dir, wiped at the
    /// start of the run — never wiped on restart steps).
    pub fn state_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.state_dir = dir.into();
        self
    }

    /// Topology: register the model at `root` under `name` at boot.
    pub fn model(mut self, name: impl Into<String>, root: impl Into<PathBuf>) -> Self {
        self.models.push((name.into(), root.into()));
        self
    }

    /// Workload: this many query clients run for the whole scenario,
    /// rotating health/project/info lines across every model.
    pub fn workload(mut self, clients: usize) -> Self {
        self.clients = clients;
        self
    }

    /// Engine-reload poll cadence for the daemon under test.
    pub fn health_poll_ms(mut self, ms: u64) -> Self {
        self.health_poll = Duration::from_millis(ms);
        self
    }

    pub fn step(mut self, step: Step) -> Self {
        self.steps.push(step);
        self
    }

    pub fn submit_update(self, spec: JobSpec) -> Self {
        self.step(Step::SubmitUpdate(spec))
    }

    pub fn await_jobs(self, timeout_secs: u64) -> Self {
        self.step(Step::AwaitJobs { timeout: Duration::from_secs(timeout_secs) })
    }

    pub fn drain(self) -> Self {
        self.step(Step::Drain)
    }

    pub fn halt(self) -> Self {
        self.step(Step::Halt)
    }

    pub fn restart(self) -> Self {
        self.step(Step::Restart)
    }

    pub fn sleep_ms(self, ms: u64) -> Self {
        self.step(Step::Sleep(Duration::from_millis(ms)))
    }

    pub fn expect(mut self, expectation: Expectation) -> Self {
        self.expectations.push(expectation);
        self
    }

    pub fn expect_zero_failed_queries(self) -> Self {
        self.expect(Expectation::ZeroFailedQueries)
    }

    pub fn expect_generation_at_least(self, model: impl Into<String>, generation: u64) -> Self {
        self.expect(Expectation::GenerationAtLeast { model: model.into(), generation })
    }

    pub fn expect_all_jobs_done(self) -> Self {
        self.expect(Expectation::AllJobsDone)
    }

    /// Interpret the scenario (see module docs). Returns the report on
    /// success, the first violated expectation (or infrastructure error)
    /// otherwise.
    pub fn run(self) -> Result<ScenarioReport> {
        LOG.info(&format!("scenario `{}`: starting", self.name));
        let _ = std::fs::remove_dir_all(&self.state_dir);
        std::fs::create_dir_all(&self.state_dir)?;
        let backend: BackendRef = Arc::new(NativeBackend::new());
        let opts = DaemonOptions {
            addr: "127.0.0.1:0".to_string(),
            health_poll: Some(self.health_poll),
            ..DaemonOptions::default()
        };

        let mut daemon = Some(boot(&self.state_dir, &backend, &opts)?);
        let client_for = |d: &RunningDaemon| DaemonClient::new(d.addr.clone());
        for (name, root) in &self.models {
            client_for(daemon.as_ref().unwrap())
                .register(name, &root.to_string_lossy())?;
        }

        let workload = Arc::new(Workload::new(
            daemon.as_ref().unwrap().addr.clone(),
            self.clients,
        ));
        let mut client_threads = Vec::new();
        for i in 0..self.clients {
            let w = workload.clone();
            let models: Vec<String> = self.models.iter().map(|(n, _)| n.clone()).collect();
            client_threads.push(std::thread::spawn(move || query_loop(&w, i, &models)));
        }

        let mut submitted: Vec<u64> = Vec::new();
        let mut terminal: BTreeMap<u64, String> = BTreeMap::new();
        let mut outcome = Ok(());
        for step in &self.steps {
            let result: Result<()> = match step {
                // Restart is the one step that is valid with the daemon
                // down (halt → restart is the crash-recovery scenario).
                Step::Restart => (|| {
                    if let Some(running) = daemon.take() {
                        workload.pause();
                        DaemonClient::new(running.addr.clone()).halt()?;
                        running.join()?;
                    }
                    let running = boot(&self.state_dir, &backend, &opts)?;
                    workload.point_at(&running.addr);
                    daemon = Some(running);
                    workload.unpause();
                    Ok(())
                })(),
                Step::Sleep(d) => {
                    std::thread::sleep(*d);
                    Ok(())
                }
                _ => match daemon.as_ref().map(|r| r.addr.clone()) {
                    None => Err(Error::Other(
                        "daemon already stopped (only Restart/Sleep are valid here)".into(),
                    )),
                    Some(addr) => {
                        let client = DaemonClient::new(addr);
                        match step {
                            Step::SubmitUpdate(spec) => {
                                client.submit_job(spec).map(|id| submitted.push(id))
                            }
                            Step::AwaitJobs { timeout } => {
                                await_jobs(&client, &submitted, &mut terminal, *timeout)
                            }
                            Step::Drain => {
                                workload.pause();
                                client.drain().and_then(|_| {
                                    daemon.take().expect("running daemon").join()
                                })
                            }
                            Step::Halt => {
                                workload.pause();
                                client.halt().and_then(|_| {
                                    daemon.take().expect("running daemon").join()
                                })
                            }
                            Step::Restart | Step::Sleep(_) => unreachable!("handled above"),
                        }
                    }
                },
            };
            if let Err(e) = result {
                outcome =
                    Err(Error::Other(format!("scenario `{}`: step {step:?}: {e}", self.name)));
                break;
            }
        }

        // Wind down: the workload first (no queries race the shutdown),
        // then whatever daemon is still up.
        workload.pause();
        workload.stop.store(true, Ordering::SeqCst);
        for t in client_threads {
            let _ = t.join();
        }
        if let Some(running) = daemon.take() {
            let halted = client_for(&running).halt();
            let joined = running.join();
            if outcome.is_ok() {
                halted?;
                joined?;
            }
        }
        outcome?;

        let mut generations = BTreeMap::new();
        for (name, root) in &self.models {
            generations.insert(name.clone(), published_generation(root)?);
        }
        let report = ScenarioReport {
            queries_ok: workload.ok.load(Ordering::SeqCst),
            queries_failed: workload.failed.load(Ordering::SeqCst),
            generations,
            jobs_done: terminal.values().filter(|s| *s == "done").count(),
            jobs_failed: terminal.values().filter(|s| *s == "failed").count(),
        };
        LOG.info(&format!(
            "scenario `{}`: {} ok / {} failed queries, {} done / {} failed jobs",
            self.name, report.queries_ok, report.queries_failed, report.jobs_done,
            report.jobs_failed
        ));
        check_expectations(
            &self.name,
            &self.expectations,
            &report,
            &submitted,
            &terminal,
            &workload,
        )?;
        Ok(report)
    }
}

fn check_expectations(
    name: &str,
    expectations: &[Expectation],
    report: &ScenarioReport,
    submitted: &[u64],
    terminal: &BTreeMap<u64, String>,
    workload: &Workload,
) -> Result<()> {
    for e in expectations {
        match e {
            Expectation::ZeroFailedQueries => {
                if report.queries_failed > 0 {
                    let detail = lock_unpoisoned(&workload.last_error)
                        .clone()
                        .unwrap_or_else(|| "no detail captured".into());
                    return Err(Error::Other(format!(
                        "scenario `{name}`: {} of {} queries failed (last: {detail})",
                        report.queries_failed,
                        report.queries_failed + report.queries_ok
                    )));
                }
            }
            Expectation::GenerationAtLeast { model, generation } => {
                let got = report.generations.get(model).copied().unwrap_or(0);
                if got < *generation {
                    return Err(Error::Other(format!(
                        "scenario `{name}`: model `{model}` published generation {got}, \
                         expected >= {generation}"
                    )));
                }
            }
            Expectation::AllJobsDone => {
                for id in submitted {
                    match terminal.get(id).map(String::as_str) {
                        Some("done") => {}
                        Some(state) => {
                            return Err(Error::Other(format!(
                                "scenario `{name}`: job {id} ended `{state}`"
                            )));
                        }
                        None => {
                            return Err(Error::Other(format!(
                                "scenario `{name}`: job {id} never reached a terminal \
                                 state (missing an await_jobs step?)"
                            )));
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// A booted daemon under test: its address and the thread running it.
struct RunningDaemon {
    addr: String,
    thread: JoinHandle<Result<()>>,
}

impl RunningDaemon {
    fn join(self) -> Result<()> {
        self.thread
            .join()
            .unwrap_or_else(|_| Err(Error::Other("daemon thread panicked".into())))
    }
}

fn boot(state_dir: &Path, backend: &BackendRef, opts: &DaemonOptions) -> Result<RunningDaemon> {
    let d = Daemon::bind(state_dir, backend.clone(), opts)?;
    let addr = d.local_addr()?.to_string();
    let thread = std::thread::Builder::new()
        .name("scenario-daemon".into())
        .spawn(move || d.run())
        .map_err(|e| Error::Other(format!("cannot spawn scenario daemon: {e}")))?;
    Ok(RunningDaemon { addr, thread })
}

fn await_jobs(
    client: &DaemonClient,
    submitted: &[u64],
    terminal: &mut BTreeMap<u64, String>,
    timeout: Duration,
) -> Result<()> {
    let deadline = Instant::now() + timeout;
    for id in submitted {
        if terminal.contains_key(id) {
            continue;
        }
        let left = deadline.saturating_duration_since(Instant::now());
        let reply = client.wait_job(*id, left)?;
        let state = reply
            .get("job")
            .and_then(|j| j.get("state"))
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        terminal.insert(*id, state);
    }
    Ok(())
}

/// The model root's published generation, read from disk — robust to the
/// daemon being stopped by the time expectations run.
fn published_generation(root: &Path) -> Result<u64> {
    Ok(ModelStore::open(root, 1)?.generation())
}

/// Shared state between the runner and its query clients.
struct Workload {
    addr: RwLock<String>,
    stop: AtomicBool,
    paused: AtomicBool,
    idle: Vec<AtomicBool>,
    ok: AtomicU64,
    failed: AtomicU64,
    last_error: Mutex<Option<String>>,
}

impl Workload {
    fn new(addr: String, clients: usize) -> Self {
        Workload {
            addr: RwLock::new(addr),
            stop: AtomicBool::new(false),
            paused: AtomicBool::new(false),
            idle: (0..clients).map(|_| AtomicBool::new(false)).collect(),
            ok: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            last_error: Mutex::new(None),
        }
    }

    /// Stop issuing queries and wait until every client is parked — so a
    /// daemon stop never turns half-sent queries into failures.
    fn pause(&self) {
        self.paused.store(true, Ordering::SeqCst);
        while !self.idle.iter().all(|f| f.load(Ordering::SeqCst)) {
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    fn unpause(&self) {
        self.paused.store(false, Ordering::SeqCst);
    }

    fn point_at(&self, addr: &str) {
        *write_unpoisoned(&self.addr) = addr.to_string();
    }
}

/// One workload client: rotate ops and models, count ok vs failed. A
/// failure is a transport error or any `ok:false` reply — the scenario's
/// whole point is that chaos must never surface to queries.
fn query_loop(w: &Workload, client_idx: usize, models: &[String]) {
    if models.is_empty() {
        w.idle[client_idx].store(true, Ordering::SeqCst);
        return;
    }
    let mut i = client_idx; // desynchronize the clients' rotations
    while !w.stop.load(Ordering::SeqCst) {
        if w.paused.load(Ordering::SeqCst) {
            w.idle[client_idx].store(true, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(2));
            continue;
        }
        w.idle[client_idx].store(false, Ordering::SeqCst);
        let model = &models[i % models.len()];
        let line = match i % 3 {
            0 => Json::obj(vec![
                ("op", Json::str("health")),
                ("model", Json::str(model)),
            ]),
            1 => Json::obj(vec![
                ("op", Json::str("project")),
                ("model", Json::str(model)),
                // Sparse form on purpose: exercises the sparse query row
                // path under chaos, and stays valid for any model width.
                ("indices", Json::arr(vec![Json::num(0.0)])),
                ("values", Json::arr(vec![Json::num(1.0)])),
            ]),
            _ => Json::obj(vec![("op", Json::str("info")), ("model", Json::str(model))]),
        };
        let client = DaemonClient::new(read_unpoisoned(&w.addr).clone());
        match client.call(&line) {
            Ok(reply) if reply.get("ok").and_then(Json::as_bool) == Some(true) => {
                w.ok.fetch_add(1, Ordering::SeqCst);
            }
            Ok(reply) => {
                w.failed.fetch_add(1, Ordering::SeqCst);
                *lock_unpoisoned(&w.last_error) = Some(reply.render());
            }
            Err(e) => {
                w.failed.fetch_add(1, Ordering::SeqCst);
                *lock_unpoisoned(&w.last_error) = Some(e.to_string());
            }
        }
        i += 1;
        std::thread::sleep(Duration::from_millis(2));
    }
    w.idle[client_idx].store(true, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(failed: u64, generation: u64) -> ScenarioReport {
        let mut generations = BTreeMap::new();
        generations.insert("m".to_string(), generation);
        ScenarioReport {
            queries_ok: 10,
            queries_failed: failed,
            generations,
            jobs_done: 1,
            jobs_failed: 0,
        }
    }

    #[test]
    fn expectations_catch_violations() {
        let w = Workload::new("127.0.0.1:1".into(), 0);
        let submitted = vec![7u64];
        let mut terminal = BTreeMap::new();
        terminal.insert(7u64, "done".to_string());
        let all = vec![
            Expectation::ZeroFailedQueries,
            Expectation::GenerationAtLeast { model: "m".into(), generation: 1 },
            Expectation::AllJobsDone,
        ];
        assert!(
            check_expectations("t", &all, &report(0, 1), &submitted, &terminal, &w).is_ok()
        );
        assert!(
            check_expectations("t", &all, &report(3, 1), &submitted, &terminal, &w).is_err()
        );
        assert!(
            check_expectations("t", &all, &report(0, 0), &submitted, &terminal, &w).is_err()
        );
        terminal.insert(7u64, "failed".to_string());
        assert!(
            check_expectations("t", &all, &report(0, 1), &submitted, &terminal, &w).is_err()
        );
        terminal.remove(&7u64);
        assert!(
            check_expectations("t", &all, &report(0, 1), &submitted, &terminal, &w).is_err()
        );
    }

    #[test]
    fn builder_accumulates_topology_and_script() {
        let s = Scenario::new("builder")
            .model("a", "/models/a")
            .model("b", "/models/b")
            .workload(4)
            .submit_update(JobSpec::new("a", "/rows.csv"))
            .await_jobs(30)
            .drain()
            .expect_zero_failed_queries()
            .expect_all_jobs_done();
        assert_eq!(s.models.len(), 2);
        assert_eq!(s.clients, 4);
        assert_eq!(s.steps.len(), 3);
        assert_eq!(s.expectations.len(), 2);
        assert!(matches!(s.steps[0], Step::SubmitUpdate(_)));
        assert!(matches!(s.steps[2], Step::Drain));
    }
}
