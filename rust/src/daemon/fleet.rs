//! The daemon's model registry: named models, each serving independently.
//!
//! A [`Fleet`] maps names to [`ModelEntry`]s. Every entry owns the full
//! per-model serving stack of [`crate::serve`] — a hot-swappable
//! [`EngineHandle`] over the model root's live generation plus a dedicated
//! micro-batch [`crate::serve::Batcher`] — so queries against different
//! models never contend, while queries against the *same* model coalesce
//! into shared backend matmuls exactly as under `tallfat serve`.
//!
//! Registrations persist in `fleet.manifest` (one `name=root` line per
//! model, written atomically via temp-file + rename like the `CURRENT`
//! pointer), so a restarted daemon reopens its whole fleet before it
//! accepts the first connection.

use crate::backend::BackendRef;
use crate::coordinator::server::MetricsRegistry;
use crate::error::{Error, Result};
use crate::serve::batcher::{BatchOptions, Batcher};
use crate::serve::http::ServerState;
use crate::serve::query::EngineHandle;
use crate::util::{read_unpoisoned, write_unpoisoned, Logger};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};

static LOG: Logger = Logger::new("daemon.fleet");

/// Registry file name under the daemon's state directory.
pub const FLEET_MANIFEST: &str = "fleet.manifest";

/// One registered model: its serving state and the batcher that keeps the
/// coalescing worker alive for the entry's lifetime.
pub struct ModelEntry {
    name: String,
    root: PathBuf,
    pub(crate) state: Arc<ServerState>,
    _batcher: Batcher,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The hot-swappable engine handle jobs reload after a publish.
    pub fn engines(&self) -> &Arc<EngineHandle> {
        &self.state.engines
    }

    /// Generation currently being served.
    pub fn generation(&self) -> u64 {
        self.state.engines.generation()
    }
}

/// The named-model registry (see module docs).
pub struct Fleet {
    state_dir: PathBuf,
    backend: BackendRef,
    cache_shards: usize,
    batch: BatchOptions,
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
}

impl Fleet {
    /// Open the fleet persisted under `state_dir`, reopening every model
    /// the manifest names. A model whose root fails to open is skipped
    /// with a warning (dropped from the manifest on the next register)
    /// instead of holding the rest of the fleet hostage.
    pub fn open(
        state_dir: impl Into<PathBuf>,
        backend: BackendRef,
        cache_shards: usize,
        batch: BatchOptions,
    ) -> Result<Self> {
        let state_dir = state_dir.into();
        std::fs::create_dir_all(&state_dir)?;
        let fleet = Fleet {
            state_dir,
            backend,
            cache_shards,
            batch,
            models: RwLock::new(BTreeMap::new()),
        };
        for (name, root) in load_manifest(&fleet.manifest_path())? {
            match fleet.open_entry(&name, Path::new(&root)) {
                Ok(entry) => {
                    write_unpoisoned(&fleet.models).insert(name, entry);
                }
                Err(e) => LOG.warn(&format!("skipping model `{name}` ({root}): {e}")),
            }
        }
        let n = fleet.len();
        if n > 0 {
            LOG.info(&format!("reopened {n} model(s) from {}", fleet.manifest_path().display()));
        }
        MetricsRegistry::global().set("daemon_models", n as f64);
        Ok(fleet)
    }

    fn manifest_path(&self) -> PathBuf {
        self.state_dir.join(FLEET_MANIFEST)
    }

    fn open_entry(&self, name: &str, root: &Path) -> Result<Arc<ModelEntry>> {
        let engines =
            Arc::new(EngineHandle::open(root, self.cache_shards, self.backend.clone())?);
        let batcher = Batcher::start(engines.clone(), self.batch)?;
        let state = Arc::new(ServerState::new(engines, batcher.handle()));
        Ok(Arc::new(ModelEntry {
            name: name.to_string(),
            root: root.to_path_buf(),
            state,
            _batcher: batcher,
        }))
    }

    /// Register (or idempotently re-register) the model at `root` under
    /// `name` and persist the registration.
    pub fn register(&self, name: &str, root: impl AsRef<Path>) -> Result<Arc<ModelEntry>> {
        validate_name(name)?;
        let root = root.as_ref();
        if let Some(existing) = self.get(name) {
            if existing.root() == root {
                return Ok(existing);
            }
            return Err(Error::Config(format!(
                "model `{name}` is already registered at {} (unregistering is not supported; \
                 pick another name)",
                existing.root().display()
            )));
        }
        let entry = self.open_entry(name, root)?;
        let generation = entry.generation();
        write_unpoisoned(&self.models).insert(name.to_string(), entry.clone());
        self.save_manifest()?;
        MetricsRegistry::global().set("daemon_models", self.len() as f64);
        LOG.info(&format!(
            "registered model `{name}` at {} (generation {generation})",
            root.display()
        ));
        Ok(entry)
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        read_unpoisoned(&self.models).get(name).cloned()
    }

    /// All entries, ordered by name.
    pub fn entries(&self) -> Vec<Arc<ModelEntry>> {
        read_unpoisoned(&self.models).values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        read_unpoisoned(&self.models).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn save_manifest(&self) -> Result<()> {
        let mut text = String::from("# tallfat fleet manifest v1\n");
        for entry in read_unpoisoned(&self.models).values() {
            text.push_str(&format!("{}={}\n", entry.name(), entry.root().display()));
        }
        write_atomic(&self.manifest_path(), &text)
    }
}

/// Model names key the manifest and appear in protocol lines and metric
/// names — keep them to a filesystem- and JSON-safe alphabet.
fn validate_name(name: &str) -> Result<()> {
    if name.is_empty() || name.len() > 128 {
        return Err(Error::Config("model name must be 1..=128 characters".into()));
    }
    if !name
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
    {
        return Err(Error::Config(format!(
            "model name `{name}` has characters outside [A-Za-z0-9._-]"
        )));
    }
    Ok(())
}

fn load_manifest(path: &Path) -> Result<Vec<(String, String)>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e.into()),
    };
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, root) = line.split_once('=').ok_or_else(|| {
            Error::parse(format!("fleet manifest {}: bad line `{line}`", path.display()))
        })?;
        out.push((name.to_string(), root.to_string()));
    }
    Ok(out)
}

/// Write-then-rename, the same durability idiom as the `CURRENT` pointer:
/// a crash mid-write can never leave a half-written manifest behind.
pub(crate) fn write_atomic(path: &Path, text: &str) -> Result<()> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let dir = path.parent().ok_or_else(|| {
        Error::Config(format!("manifest path {} has no parent directory", path.display()))
    })?;
    let file = path.file_name().and_then(|n| n.to_str()).unwrap_or("manifest");
    let tmp = dir.join(format!(".{file}.{}.{seq}.tmp", std::process::id()));
    std::fs::write(&tmp, text)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::io::dataset::{gen_exact, Spectrum};
    use crate::io::InputSpec;
    use crate::svd::Svd;

    fn dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("tallfat_test_fleet").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn build_model(dir: &Path, seed: u64) -> PathBuf {
        let (a, _) = gen_exact(
            60,
            8,
            3,
            Spectrum::Geometric { scale: 5.0, decay: 0.6 },
            0.0,
            seed,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("a.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        let model = dir.join("model");
        Svd::over(&spec)
            .unwrap()
            .rank(3)
            .workers(2)
            .block(32)
            .work_dir(dir.join("work").to_string_lossy().into_owned())
            .save_model(model.to_string_lossy().into_owned())
            .run()
            .unwrap();
        model
    }

    #[test]
    fn names_are_validated() {
        assert!(validate_name("movies").is_ok());
        assert!(validate_name("m-1.v_2").is_ok());
        assert!(validate_name("").is_err());
        assert!(validate_name("a b").is_err());
        assert!(validate_name("a=b").is_err());
        assert!(validate_name("a/b").is_err());
    }

    #[test]
    fn manifest_round_trips() {
        let d = dir("manifest");
        let path = d.join(FLEET_MANIFEST);
        write_atomic(&path, "# tallfat fleet manifest v1\nalpha=/models/a\nbeta=/models/b\n")
            .unwrap();
        let loaded = load_manifest(&path).unwrap();
        assert_eq!(
            loaded,
            vec![
                ("alpha".to_string(), "/models/a".to_string()),
                ("beta".to_string(), "/models/b".to_string())
            ]
        );
        assert!(load_manifest(&d.join("missing.manifest")).unwrap().is_empty());
        write_atomic(&path, "no separator here\n").unwrap();
        assert!(load_manifest(&path).is_err());
    }

    #[test]
    fn register_persists_and_reopens() {
        let d = dir("register");
        let model = build_model(&d, 7);
        let state = d.join("state");
        let backend: BackendRef = Arc::new(NativeBackend::new());
        {
            let fleet =
                Fleet::open(&state, backend.clone(), 2, BatchOptions::default()).unwrap();
            assert!(fleet.is_empty());
            let entry = fleet.register("movies", &model).unwrap();
            assert_eq!(entry.name(), "movies");
            // Idempotent for the same root, an error for a different one.
            assert!(fleet.register("movies", &model).is_ok());
            assert!(fleet.register("movies", d.join("elsewhere")).is_err());
            assert!(fleet.register("bad name", &model).is_err());
            assert!(fleet.get("nope").is_none());
        }
        // A fresh fleet over the same state dir reopens the registration.
        let fleet = Fleet::open(&state, backend, 2, BatchOptions::default()).unwrap();
        assert_eq!(fleet.len(), 1);
        let entry = fleet.get("movies").unwrap();
        assert_eq!(entry.root(), model.as_path());
        assert!(entry.engines().is_reloadable());
    }
}
