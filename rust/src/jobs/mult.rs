//! General `A @ B` streaming multiplication — the paper's `MultJob` (§3.2).
//!
//! B (`n x k`, "can be brought into memory completely") is loaded from a
//! file once per worker; each block of A rows is multiplied and the result
//! rows written to the worker's output shard.

use crate::backend::BackendRef;
use crate::error::Result;
use crate::io::writer::{ShardSet, ShardWriter};
use crate::io::InputSpec;
use crate::linalg::Matrix;
use crate::splitproc::BlockJob;

/// Block-buffered `A @ B` job.
pub struct MultJob {
    backend: BackendRef,
    b: Matrix,
    writer: Option<ShardWriter>,
    rows: u64,
}

impl MultJob {
    /// Load B from `b_file` (the paper passes `bfile` to the constructor).
    pub fn from_file(
        backend: BackendRef,
        b_file: &InputSpec,
        shards: &ShardSet,
        chunk: usize,
    ) -> Result<Self> {
        let b = crate::io::read_matrix(b_file)?;
        Self::new(backend, b, shards, chunk)
    }

    pub fn new(
        backend: BackendRef,
        b: Matrix,
        shards: &ShardSet,
        chunk: usize,
    ) -> Result<Self> {
        let k = b.cols();
        Ok(MultJob { backend, b, writer: Some(shards.open_writer(chunk, k)?), rows: 0 })
    }

    pub fn rows_processed(&self) -> u64 {
        self.rows
    }
}

impl BlockJob for MultJob {
    fn exec_block(&mut self, block: &Matrix) -> Result<()> {
        let y = self.backend.project_block(block, &self.b)?;
        if let Some(w) = self.writer.as_mut() {
            for i in 0..y.rows() {
                w.write_row(y.row(i))?;
            }
        }
        self.rows += y.rows() as u64;
        Ok(())
    }

    fn post_blocks(&mut self) -> Result<()> {
        if let Some(w) = self.writer.take() {
            w.finish()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::config::InputFormat;
    use crate::linalg::matmul;
    use crate::rng::Gaussian;
    use crate::splitproc::{Blocked, RowJob};
    use std::sync::Arc;

    #[test]
    fn mult_matches_dense_and_reads_b_from_file() {
        let dir = std::env::temp_dir().join("tallfat_test_mult");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let g = Gaussian::new(1);
        let a = Matrix::from_fn(33, 6, |i, j| g.sample(i as u64, j as u64));
        let b = Matrix::from_fn(6, 4, |i, j| g.sample(100 + i as u64, j as u64));
        let b_spec = InputSpec::csv(dir.join("B.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&b, &b_spec).unwrap();

        let shards = ShardSet::new(&dir, "C", InputFormat::Csv).unwrap();
        let job = MultJob::from_file(Arc::new(NativeBackend::new()), &b_spec, &shards, 0).unwrap();
        let mut blocked = Blocked::new(job, 8, 6);
        for i in 0..33 {
            blocked.exec_row(a.row(i)).unwrap();
        }
        blocked.post().unwrap();

        let got = shards.merge_to_matrix(1).unwrap();
        let want = matmul(&a, &b).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-9);
    }
}
