//! Random projection jobs — the paper's `RandomProjJob` (§3.3) plus the
//! fused pass-1 job of the SVD driver.
//!
//! * [`RandomProjRowJob`] — paper-literal: per row, regenerate the needed Ω
//!   rows virtually (`s += elem * omega_row`), O(k) working memory, Y row
//!   written to the worker's shard. Optionally accumulates `Y^T Y` on the
//!   fly (one outer product per produced row).
//! * [`ProjectGramJob`] — block-buffered: Ω materialized once per worker
//!   (still deterministic from the seed), blocks dispatched to the backend's
//!   fused project+gram artifact. The throughput mode.

use crate::backend::BackendRef;
use crate::error::Result;
use crate::io::writer::{ShardSet, ShardWriter};
use crate::linalg::{ops::outer_accumulate, Matrix};
use crate::rng::VirtualMatrix;
use crate::splitproc::{BlockJob, RowJob};

/// Paper-literal virtual-projection job (O(k) memory beyond the writer).
pub struct RandomProjRowJob {
    omega: VirtualMatrix,
    writer: Option<ShardWriter>,
    y_row: Vec<f64>,
    gram: Option<Matrix>,
    rows: u64,
}

impl RandomProjRowJob {
    pub fn new(omega: VirtualMatrix, shards: &ShardSet, chunk: usize) -> Result<Self> {
        let k = omega.cols();
        Ok(RandomProjRowJob {
            omega,
            writer: Some(shards.open_writer(chunk, k)?),
            y_row: vec![0.0; k],
            gram: None,
            rows: 0,
        })
    }

    /// Without any output shard (pure compute, e.g. for benches).
    pub fn sink(omega: VirtualMatrix) -> Self {
        let k = omega.cols();
        RandomProjRowJob { omega, writer: None, y_row: vec![0.0; k], gram: None, rows: 0 }
    }

    /// Also accumulate `Y^T Y` while projecting (fused pass 1).
    pub fn with_gram(mut self) -> Self {
        self.gram = Some(Matrix::zeros(self.omega.cols(), self.omega.cols()));
        self
    }

    pub fn gram_partial(&self) -> Option<&Matrix> {
        self.gram.as_ref()
    }

    pub fn into_gram_partial(self) -> Option<Matrix> {
        self.gram
    }

    pub fn rows_processed(&self) -> u64 {
        self.rows
    }
}

impl RowJob for RandomProjRowJob {
    fn exec_row(&mut self, row: &[f64]) -> Result<()> {
        self.omega.project_row(row, &mut self.y_row);
        if let Some(g) = self.gram.as_mut() {
            outer_accumulate(g, &self.y_row);
        }
        if let Some(w) = self.writer.as_mut() {
            w.write_row(&self.y_row)?;
        }
        self.rows += 1;
        Ok(())
    }

    fn post(&mut self) -> Result<()> {
        if let Some(w) = self.writer.take() {
            w.finish()?;
        }
        Ok(())
    }
}

/// Block-buffered fused project+gram job (the pass-1 hot path).
pub struct ProjectGramJob {
    backend: BackendRef,
    omega: Matrix,
    writer: Option<ShardWriter>,
    gram_acc: Matrix,
    rows: u64,
}

impl ProjectGramJob {
    /// `omega` is materialized per worker from the shared [`VirtualMatrix`]
    /// (identical bits across workers by construction).
    pub fn new(
        backend: BackendRef,
        omega: Matrix,
        shards: &ShardSet,
        chunk: usize,
    ) -> Result<Self> {
        let k = omega.cols();
        Ok(ProjectGramJob {
            backend,
            omega,
            writer: Some(shards.open_writer(chunk, k)?),
            gram_acc: Matrix::zeros(k, k),
            rows: 0,
        })
    }

    /// Compute-only variant (benches).
    pub fn sink(backend: BackendRef, omega: Matrix) -> Self {
        let k = omega.cols();
        ProjectGramJob { backend, omega, writer: None, gram_acc: Matrix::zeros(k, k), rows: 0 }
    }

    pub fn gram_partial(&self) -> &Matrix {
        &self.gram_acc
    }

    pub fn into_gram_partial(self) -> Matrix {
        self.gram_acc
    }

    pub fn rows_processed(&self) -> u64 {
        self.rows
    }
}

impl BlockJob for ProjectGramJob {
    fn exec_block(&mut self, block: &Matrix) -> Result<()> {
        let (y, g) = self.backend.project_gram_block(block, &self.omega)?;
        self.gram_acc.add_assign(&g)?;
        if let Some(w) = self.writer.as_mut() {
            for i in 0..y.rows() {
                w.write_row(y.row(i))?;
            }
        }
        self.rows += y.rows() as u64;
        Ok(())
    }

    fn post_blocks(&mut self) -> Result<()> {
        if let Some(w) = self.writer.take() {
            w.finish()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::config::InputFormat;
    use crate::linalg::{gram, matmul};
    use crate::rng::Gaussian;
    use crate::splitproc::Blocked;
    use std::sync::Arc;

    fn rand(rows: usize, cols: usize, seed: u64) -> Matrix {
        let g = Gaussian::new(seed);
        Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
    }

    fn shards(name: &str) -> ShardSet {
        let dir = std::env::temp_dir().join("tallfat_test_randproj").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        ShardSet::new(&dir, "Y", InputFormat::Csv).unwrap()
    }

    #[test]
    fn virtual_row_job_matches_dense() {
        let a = rand(40, 10, 1);
        let omega = VirtualMatrix::projection(7, 10, 4);
        let set = shards("rowjob");
        let mut job = RandomProjRowJob::new(omega, &set, 0).unwrap().with_gram();
        for i in 0..40 {
            job.exec_row(a.row(i)).unwrap();
        }
        job.post().unwrap();
        let y = set.merge_to_matrix(1).unwrap();
        let want = matmul(&a, &omega.materialize()).unwrap();
        assert!(y.max_abs_diff(&want) < 1e-9);
        assert!(job.gram_partial().unwrap().max_abs_diff(&gram(&want)) < 1e-8);
    }

    #[test]
    fn block_job_matches_row_job() {
        let a = rand(70, 8, 2);
        let vm = VirtualMatrix::projection(3, 8, 5);
        let set_b = shards("blockjob");
        let inner = ProjectGramJob::new(
            Arc::new(NativeBackend::new()),
            vm.materialize(),
            &set_b,
            0,
        )
        .unwrap();
        let mut blocked = Blocked::new(inner, 16, 8);
        for i in 0..70 {
            blocked.exec_row(a.row(i)).unwrap();
        }
        blocked.post().unwrap();
        let y_block = set_b.merge_to_matrix(1).unwrap();

        let set_r = shards("rowjob2");
        let mut rowjob = RandomProjRowJob::new(vm, &set_r, 0).unwrap().with_gram();
        for i in 0..70 {
            rowjob.exec_row(a.row(i)).unwrap();
        }
        rowjob.post().unwrap();
        let y_row = set_r.merge_to_matrix(1).unwrap();

        assert!(y_block.max_abs_diff(&y_row) < 1e-9);
        let g_block = blocked.into_inner().into_gram_partial();
        assert!(g_block.max_abs_diff(rowjob.gram_partial().unwrap()) < 1e-8);
    }

    #[test]
    fn deterministic_across_workers() {
        // Two "workers" projecting the same rows with the same seed produce
        // identical output — the §2.1 guarantee.
        let a = rand(10, 6, 5);
        let vm = VirtualMatrix::projection(11, 6, 3);
        let mut j1 = RandomProjRowJob::sink(vm);
        let mut j2 = RandomProjRowJob::sink(vm);
        for i in 0..10 {
            j1.exec_row(a.row(i)).unwrap();
        }
        for i in 0..10 {
            j2.exec_row(a.row(i)).unwrap();
        }
        assert_eq!(j1.y_row, j2.y_row);
    }
}
