//! The paper's job classes (§3.1–§3.3) plus the SVD driver's pass-2/3 jobs,
//! all expressed against the [`crate::splitproc`] engine and the
//! [`crate::backend`] abstraction.

pub mod ata;
pub mod colstats;
pub mod mult;
pub mod pass2;
pub mod randproj;
pub mod sparse;
pub mod tsqr;

pub use ata::{AtaBlockJob, AtaRowJob};
pub use colstats::ColStatsJob;
pub use mult::MultJob;
pub use pass2::Pass2Job;
pub use randproj::{ProjectGramJob, RandomProjRowJob};
pub use sparse::{
    SparseAtaJob, SparseColStatsJob, SparseMultJob, SparsePass2Job, SparseProjectGramJob,
};
pub use tsqr::{tsqr_sigma_file, TsqrJob};
