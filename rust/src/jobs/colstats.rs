//! Streaming per-column statistics (mean/variance) — a centering extension:
//! PCA-style SVD wants column-centered A, which needs one cheap pre-pass.
//! Welford accumulators per worker, merged pairwise by the leader (Chan's
//! parallel combination).

use crate::error::{Error, Result};
use crate::splitproc::RowJob;

/// Per-column Welford accumulator set.
#[derive(Clone, Debug)]
pub struct ColStatsJob {
    count: u64,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl ColStatsJob {
    pub fn new(cols: usize) -> Self {
        ColStatsJob { count: 0, mean: vec![0.0; cols], m2: vec![0.0; cols] }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn means(&self) -> &[f64] {
        &self.mean
    }

    /// Population variance per column.
    pub fn variances(&self) -> Vec<f64> {
        if self.count == 0 {
            return vec![0.0; self.mean.len()];
        }
        self.m2.iter().map(|&m2| m2 / self.count as f64).collect()
    }

    /// Merge another partial into this one (Chan et al. combination).
    pub fn merge(&mut self, other: &ColStatsJob) -> Result<()> {
        if self.mean.len() != other.mean.len() {
            return Err(Error::shape("colstats merge: width mismatch"));
        }
        if other.count == 0 {
            return Ok(());
        }
        if self.count == 0 {
            *self = other.clone();
            return Ok(());
        }
        let (na, nb) = (self.count as f64, other.count as f64);
        let n = na + nb;
        for j in 0..self.mean.len() {
            let delta = other.mean[j] - self.mean[j];
            self.mean[j] += delta * nb / n;
            self.m2[j] += other.m2[j] + delta * delta * na * nb / n;
        }
        self.count += other.count;
        Ok(())
    }
}

impl RowJob for ColStatsJob {
    fn exec_row(&mut self, row: &[f64]) -> Result<()> {
        if row.len() != self.mean.len() {
            return Err(Error::shape(format!(
                "colstats: row width {} != {}",
                row.len(),
                self.mean.len()
            )));
        }
        self.count += 1;
        let n = self.count as f64;
        for (j, &x) in row.iter().enumerate() {
            let delta = x - self.mean[j];
            self.mean[j] += delta / n;
            self.m2[j] += delta * (x - self.mean[j]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(job: &mut ColStatsJob, rows: &[[f64; 2]]) {
        for r in rows {
            job.exec_row(r).unwrap();
        }
    }

    #[test]
    fn mean_and_variance() {
        let mut j = ColStatsJob::new(2);
        feed(&mut j, &[[1.0, 10.0], [2.0, 10.0], [3.0, 10.0]]);
        assert!((j.means()[0] - 2.0).abs() < 1e-12);
        assert!((j.means()[1] - 10.0).abs() < 1e-12);
        let v = j.variances();
        assert!((v[0] - 2.0 / 3.0).abs() < 1e-12);
        assert!(v[1].abs() < 1e-12);
    }

    #[test]
    fn merge_equals_single_pass() {
        let rows: Vec<[f64; 2]> = (0..50)
            .map(|i| [(i as f64) * 0.3 - 2.0, ((i * i) % 7) as f64])
            .collect();
        let mut whole = ColStatsJob::new(2);
        feed(&mut whole, &rows);
        let mut a = ColStatsJob::new(2);
        let mut b = ColStatsJob::new(2);
        feed(&mut a, &rows[..20]);
        feed(&mut b, &rows[20..]);
        a.merge(&b).unwrap();
        assert_eq!(a.count(), whole.count());
        for j in 0..2 {
            assert!((a.means()[j] - whole.means()[j]).abs() < 1e-10);
            assert!((a.variances()[j] - whole.variances()[j]).abs() < 1e-10);
        }
    }

    #[test]
    fn merge_with_empty() {
        let mut a = ColStatsJob::new(2);
        let mut b = ColStatsJob::new(2);
        b.exec_row(&[1.0, 2.0]).unwrap();
        a.merge(&b).unwrap();
        assert_eq!(a.count(), 1);
        let mut c = ColStatsJob::new(2);
        a.merge(&c).unwrap();
        assert_eq!(a.count(), 1);
        c.merge(&a).unwrap();
        assert_eq!(c.count(), 1);
    }

    #[test]
    fn width_mismatch_rejected() {
        let mut j = ColStatsJob::new(2);
        assert!(j.exec_row(&[1.0]).is_err());
        let other = ColStatsJob::new(3);
        assert!(j.merge(&other).is_err());
    }
}
