//! Streaming TSQR job — the stable alternative route (see
//! [`crate::linalg::tsqr`]). Each worker folds its row blocks into an
//! `n x n` R factor; the leader reduces R factors by stacking + one more QR
//! ([`crate::linalg::tsqr::svd_from_partials`] — the same fold the
//! distributed W reduction uses for its banded completion).

use crate::error::Result;
use crate::linalg::tsqr::TsqrAccumulator;
use crate::linalg::Matrix;
use crate::splitproc::BlockJob;

/// Block job folding rows into a running R factor.
pub struct TsqrJob {
    acc: TsqrAccumulator,
}

impl TsqrJob {
    pub fn new(n: usize) -> Self {
        TsqrJob { acc: TsqrAccumulator::new(n) }
    }

    /// The worker's final R partial.
    pub fn into_r(self) -> Result<Matrix> {
        self.acc.finish()
    }
}

impl BlockJob for TsqrJob {
    fn exec_block(&mut self, block: &Matrix) -> Result<()> {
        self.acc.push_block(block)
    }
}

/// Streaming σ(A) over a file via TSQR (Split-Process workers).
pub fn tsqr_sigma_file(
    input: &crate::io::InputSpec,
    workers: usize,
    block: usize,
) -> Result<Vec<f64>> {
    use crate::splitproc::{self, Blocked};
    let (_, n) = input.dims()?;
    let results = splitproc::run(input, workers, |_| {
        Ok(Blocked::new(TsqrJob::new(n), block, n))
    })?;
    let partials: Vec<Matrix> = results
        .into_iter()
        .map(|r| r.job.into_inner().into_r())
        .collect::<Result<_>>()?;
    crate::linalg::tsqr::sigma_from_partials(n, partials)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::dataset::{gen_exact, Spectrum};
    use crate::io::InputSpec;
    use crate::linalg::exact_svd;

    #[test]
    fn file_sigma_matches_exact() {
        let dir = std::env::temp_dir().join("tallfat_test_tsqr_job");
        std::fs::create_dir_all(&dir).unwrap();
        let (a, _) = gen_exact(
            250,
            10,
            10,
            Spectrum::Geometric { scale: 5.0, decay: 0.7 },
            0.01,
            3,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("a.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        let got = tsqr_sigma_file(&spec, 3, 32).unwrap();
        let want = exact_svd(&a).unwrap().sigma;
        for (g, w) in got.iter().zip(&want) {
            // CSV roundtrips ~12 significant digits.
            assert!((g - w).abs() < 1e-6 * w.max(1.0), "{g} vs {w}");
        }
    }
}
