//! `A^T A` accumulation — the paper's `ATAJob` (§3.1).
//!
//! Two modes:
//! * [`AtaRowJob`] — the paper-literal row-at-a-time outer-product sum
//!   (`self.C += outer(vec, vec)`), kept for E5 and as an oracle.
//! * [`AtaBlockJob`] — block-buffered, dispatching `X^T X` per block to a
//!   [`crate::backend::Backend`] (native blocked-syrk or the XLA gram artifact).
//!
//! Both optionally spill their partial to a shard file at `post` time, like
//! the paper's `/tmp/C-%d.csv` (the leader can also reduce in memory).

use crate::backend::BackendRef;
use crate::error::Result;
use crate::io::writer::ShardSet;
use crate::linalg::{ops::outer_accumulate, Matrix};
use crate::splitproc::{BlockJob, RowJob};

/// Paper-literal streaming job: one outer product per row.
pub struct AtaRowJob {
    acc: Matrix,
    spill: Option<(ShardSet, usize)>,
}

impl AtaRowJob {
    pub fn new(n: usize) -> Self {
        AtaRowJob { acc: Matrix::zeros(n, n), spill: None }
    }

    /// Also write the partial to `shards[chunk]` at post time (paper §3.1).
    pub fn with_spill(mut self, shards: ShardSet, chunk: usize) -> Self {
        self.spill = Some((shards, chunk));
        self
    }

    pub fn partial(&self) -> &Matrix {
        &self.acc
    }

    pub fn into_partial(self) -> Matrix {
        self.acc
    }
}

impl RowJob for AtaRowJob {
    fn exec_row(&mut self, row: &[f64]) -> Result<()> {
        outer_accumulate(&mut self.acc, row);
        Ok(())
    }

    fn post(&mut self) -> Result<()> {
        if let Some((shards, chunk)) = &self.spill {
            let mut w = shards.open_writer(*chunk, self.acc.cols())?;
            for i in 0..self.acc.rows() {
                w.write_row(self.acc.row(i))?;
            }
            w.finish()?;
        }
        Ok(())
    }
}

/// Block-buffered Gram job dispatching to a backend.
pub struct AtaBlockJob {
    backend: BackendRef,
    acc: Matrix,
    blocks: u64,
}

impl AtaBlockJob {
    pub fn new(backend: BackendRef, n: usize) -> Self {
        AtaBlockJob { backend, acc: Matrix::zeros(n, n), blocks: 0 }
    }

    pub fn partial(&self) -> &Matrix {
        &self.acc
    }

    pub fn into_partial(self) -> Matrix {
        self.acc
    }

    pub fn blocks_processed(&self) -> u64 {
        self.blocks
    }
}

impl BlockJob for AtaBlockJob {
    fn exec_block(&mut self, block: &Matrix) -> Result<()> {
        let g = self.backend.gram_block(block)?;
        self.acc.add_assign(&g)?;
        self.blocks += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::linalg::gram;
    use crate::rng::Gaussian;
    use crate::splitproc::Blocked;
    use std::sync::Arc;

    fn rand(rows: usize, cols: usize, seed: u64) -> Matrix {
        let g = Gaussian::new(seed);
        Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
    }

    #[test]
    fn row_job_matches_dense_gram() {
        let x = rand(57, 6, 1);
        let mut job = AtaRowJob::new(6);
        for i in 0..57 {
            job.exec_row(x.row(i)).unwrap();
        }
        job.post().unwrap();
        assert!(job.partial().max_abs_diff(&gram(&x)) < 1e-10);
    }

    #[test]
    fn block_job_matches_dense_gram() {
        let x = rand(100, 5, 2);
        let inner = AtaBlockJob::new(Arc::new(NativeBackend::new()), 5);
        let mut job = Blocked::new(inner, 16, 5);
        for i in 0..100 {
            job.exec_row(x.row(i)).unwrap();
        }
        job.post().unwrap();
        let inner = job.into_inner();
        assert_eq!(inner.blocks_processed(), 7); // 6 full + 1 tail
        assert!(inner.partial().max_abs_diff(&gram(&x)) < 1e-10);
    }

    #[test]
    fn spill_roundtrip() {
        let dir = std::env::temp_dir().join("tallfat_test_ata");
        let _ = std::fs::remove_dir_all(&dir);
        let shards = ShardSet::new(&dir, "C", crate::config::InputFormat::Csv).unwrap();
        let x = rand(20, 4, 3);
        let mut job = AtaRowJob::new(4).with_spill(shards.clone(), 0);
        for i in 0..20 {
            job.exec_row(x.row(i)).unwrap();
        }
        job.post().unwrap();
        let back = shards.merge_to_matrix(1).unwrap();
        assert!(back.max_abs_diff(&gram(&x)) < 1e-9);
    }
}
