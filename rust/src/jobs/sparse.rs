//! Sparse (CSR) versions of the streaming pass jobs.
//!
//! Each job mirrors its dense sibling (`colstats` / `ata` / `randproj` /
//! `pass2` / `mult`) but consumes CSR row blocks through the backend's
//! `*_sparse` entry points, so work and memory scale with `nnz`, not
//! `m·n`.
//!
//! **Centering never densifies.** The dense path subtracts means row by
//! row ([`crate::splitproc::CenteredJob`]) — doing that to a sparse row
//! would fill it in. Instead these jobs compute on the raw sparse rows and
//! apply the algebraic rank-1 corrections:
//!
//! ```text
//! (A - 1μᵀ)ᵀ(A - 1μᵀ) = AᵀA - sμᵀ - μsᵀ + c·μμᵀ     (s = col sums, c = rows)
//! (A - 1μᵀ) Ω          = AΩ - 1·(μᵀΩ)
//! (A - 1μᵀ)ᵀ U₀        = AᵀU₀ - μ·(1ᵀU₀)
//! ```
//!
//! so the chunk partials equal what the dense centered path produces, up
//! to float associativity.

use crate::backend::BackendRef;
use crate::error::{Error, Result};
use crate::io::writer::{ShardReader, ShardSet, ShardWriter};
use crate::linalg::{Matrix, SparseMatrix};
use crate::splitproc::{SparseBlockJob, SparseRowJob};
use std::sync::Arc;

/// `μᵀ B` for a mean vector and a dense `n x k` operand (the constant row
/// every centered projection subtracts).
fn mu_times(means: &[f64], b: &Matrix) -> Result<Vec<f64>> {
    if means.len() != b.rows() {
        return Err(Error::shape(format!(
            "centered sparse job: {} means for operand with {} rows",
            means.len(),
            b.rows()
        )));
    }
    let k = b.cols();
    let mut out = vec![0.0; k];
    for (j, &m) in means.iter().enumerate() {
        if m == 0.0 {
            continue;
        }
        let row = b.row(j);
        for (o, &v) in out.iter_mut().zip(row.iter()) {
            *o += m * v;
        }
    }
    Ok(out)
}

/// Per-column sums over sparse rows — pass 0 of PCA mode. The additive
/// partial is the sums themselves (the driver divides by the row count).
pub struct SparseColStatsJob {
    sums: Vec<f64>,
    count: u64,
}

impl SparseColStatsJob {
    pub fn new(cols: usize) -> Self {
        SparseColStatsJob { sums: vec![0.0; cols], count: 0 }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// The additive partial: per-column sums as a `1 x n` matrix.
    pub fn into_sums(self) -> Matrix {
        let n = self.sums.len();
        Matrix::from_vec(1, n, self.sums).expect("sums length is n")
    }
}

impl SparseRowJob for SparseColStatsJob {
    fn exec_row(&mut self, indices: &[u32], values: &[f64]) -> Result<()> {
        for (&j, &v) in indices.iter().zip(values.iter()) {
            let slot = self
                .sums
                .get_mut(j as usize)
                .ok_or_else(|| Error::shape(format!("colstats: column {j} out of range")))?;
            *slot += v;
        }
        self.count += 1;
        Ok(())
    }
}

/// Sparse `AᵀA` accumulation (exact-Gram pass 1), centered via the rank-1
/// correction at post time.
pub struct SparseAtaJob {
    backend: BackendRef,
    acc: Matrix,
    means: Arc<Vec<f64>>,
    /// Chunk-local per-column sums (centered mode only).
    col_sums: Vec<f64>,
    row_count: u64,
}

impl SparseAtaJob {
    pub fn new(backend: BackendRef, n: usize, means: Arc<Vec<f64>>) -> Self {
        let col_sums = if means.is_empty() { Vec::new() } else { vec![0.0; n] };
        SparseAtaJob { backend, acc: Matrix::zeros(n, n), means, col_sums, row_count: 0 }
    }

    pub fn into_partial(self) -> Matrix {
        self.acc
    }
}

impl SparseBlockJob for SparseAtaJob {
    fn exec_block(&mut self, block: &SparseMatrix) -> Result<()> {
        let g = self.backend.gram_block_sparse(block)?;
        self.acc.add_assign(&g)?;
        if !self.means.is_empty() {
            for (s, v) in self.col_sums.iter_mut().zip(block.col_sums()) {
                *s += v;
            }
            self.row_count += block.rows() as u64;
        }
        Ok(())
    }

    fn post_blocks(&mut self) -> Result<()> {
        if self.means.is_empty() {
            return Ok(());
        }
        // G_centered = G - sμᵀ - μsᵀ + c·μμᵀ
        let n = self.acc.cols();
        if self.means.len() != n {
            return Err(Error::shape(format!(
                "sparse ata: {} means for {n} columns",
                self.means.len()
            )));
        }
        let c = self.row_count as f64;
        let mu = self.means.as_slice();
        let s = &self.col_sums;
        for a in 0..n {
            let row = self.acc.row_mut(a);
            for (b, slot) in row.iter_mut().enumerate() {
                *slot += -s[a] * mu[b] - mu[a] * s[b] + c * mu[a] * mu[b];
            }
        }
        Ok(())
    }
}

/// Sparse fused project+gram (randomized pass 1): `Y = (A - 1μᵀ) Ω` rows
/// to the chunk's shard, plus the additive `YᵀY` partial.
pub struct SparseProjectGramJob {
    backend: BackendRef,
    omega: Matrix,
    writer: Option<ShardWriter>,
    gram_acc: Matrix,
    /// `μᵀΩ` (centered mode only): the constant row subtracted from AΩ.
    mu_w: Option<Vec<f64>>,
    rows: u64,
}

impl SparseProjectGramJob {
    pub fn new(
        backend: BackendRef,
        omega: Matrix,
        shards: &ShardSet,
        chunk: usize,
        means: &[f64],
    ) -> Result<Self> {
        let k = omega.cols();
        let mu_w = if means.is_empty() { None } else { Some(mu_times(means, &omega)?) };
        Ok(SparseProjectGramJob {
            backend,
            omega,
            writer: Some(shards.open_writer(chunk, k)?),
            gram_acc: Matrix::zeros(k, k),
            mu_w,
            rows: 0,
        })
    }

    pub fn into_gram_partial(self) -> Matrix {
        self.gram_acc
    }

    pub fn rows_processed(&self) -> u64 {
        self.rows
    }
}

impl SparseBlockJob for SparseProjectGramJob {
    fn exec_block(&mut self, block: &SparseMatrix) -> Result<()> {
        let y = match &self.mu_w {
            None => {
                // Uncentered: one fused sparse kernel call.
                let (y, g) = self.backend.project_gram_block_sparse(block, &self.omega)?;
                self.gram_acc.add_assign(&g)?;
                y
            }
            Some(mu_w) => {
                // Centered: Y = AΩ - 1·(μᵀΩ), and the gram must be of the
                // *centered* Y, so it runs after the subtraction.
                let mut y = self.backend.project_block_sparse(block, &self.omega)?;
                for r in 0..y.rows() {
                    for (v, m) in y.row_mut(r).iter_mut().zip(mu_w.iter()) {
                        *v -= m;
                    }
                }
                let g = self.backend.gram_block(&y)?;
                self.gram_acc.add_assign(&g)?;
                y
            }
        };
        if let Some(w) = self.writer.as_mut() {
            for i in 0..y.rows() {
                w.write_row(y.row(i))?;
            }
        }
        self.rows += y.rows() as u64;
        Ok(())
    }

    fn post_blocks(&mut self) -> Result<()> {
        if let Some(w) = self.writer.take() {
            w.finish()?;
        }
        Ok(())
    }
}

/// Sparse pass 2: re-stream the chunk's A rows against its Y shard,
/// `U0 = Y M` to the U0 shard, `W += (A - 1μᵀ)ᵀ U0` as the partial.
pub struct SparsePass2Job {
    backend: BackendRef,
    m: Matrix,
    y_reader: ShardReader,
    u0_writer: Option<ShardWriter>,
    w_acc: Matrix,
    y_buf: Vec<f64>,
    means: Arc<Vec<f64>>,
    /// `1ᵀU0` accumulated over blocks (centered mode only).
    u0_col_sums: Vec<f64>,
    rows: u64,
}

impl SparsePass2Job {
    pub fn new(
        backend: BackendRef,
        m: Matrix,
        y_shards: &ShardSet,
        u0_shards: &ShardSet,
        chunk: usize,
        n: usize,
        means: Arc<Vec<f64>>,
    ) -> Result<Self> {
        let k = m.rows();
        let u0_col_sums = if means.is_empty() { Vec::new() } else { vec![0.0; m.cols()] };
        Ok(SparsePass2Job {
            backend,
            m,
            y_reader: y_shards.open_reader(chunk)?,
            u0_writer: Some(u0_shards.open_writer(chunk, k)?),
            w_acc: Matrix::zeros(n, k),
            y_buf: Vec::with_capacity(k),
            means,
            u0_col_sums,
            rows: 0,
        })
    }

    pub fn into_w_partial(self) -> Matrix {
        self.w_acc
    }

    pub fn rows_processed(&self) -> u64 {
        self.rows
    }

    fn read_y_block(&mut self, rows: usize) -> Result<Matrix> {
        let k = self.m.rows();
        let mut y = Matrix::zeros(rows, k);
        for i in 0..rows {
            if !self.y_reader.next_row(&mut self.y_buf)? {
                return Err(Error::Other(format!(
                    "Y shard exhausted at block row {i} (A/Y misaligned)"
                )));
            }
            if self.y_buf.len() != k {
                return Err(Error::shape(format!(
                    "Y shard row has {} cols, expected {k}",
                    self.y_buf.len()
                )));
            }
            y.row_mut(i).copy_from_slice(&self.y_buf);
        }
        Ok(y)
    }
}

impl SparseBlockJob for SparsePass2Job {
    fn exec_block(&mut self, block: &SparseMatrix) -> Result<()> {
        let y_block = self.read_y_block(block.rows())?;
        let u0 = self.backend.u_recover_block(&y_block, &self.m)?;
        let w = self.backend.tmul_block_sparse(block, &u0)?;
        self.w_acc.add_assign(&w)?;
        if !self.means.is_empty() {
            for r in 0..u0.rows() {
                for (s, &v) in self.u0_col_sums.iter_mut().zip(u0.row(r).iter()) {
                    *s += v;
                }
            }
        }
        if let Some(wr) = self.u0_writer.as_mut() {
            for i in 0..u0.rows() {
                wr.write_row(u0.row(i))?;
            }
        }
        self.rows += block.rows() as u64;
        Ok(())
    }

    fn post_blocks(&mut self) -> Result<()> {
        if !self.means.is_empty() {
            // W_centered = W - μ·(1ᵀU0)
            let k = self.w_acc.cols();
            if self.u0_col_sums.len() != k {
                return Err(Error::shape("sparse pass2: U0 column-sum width mismatch"));
            }
            for (j, &mu) in self.means.iter().enumerate() {
                if mu == 0.0 {
                    continue;
                }
                let row = self.w_acc.row_mut(j);
                for (w, &s) in row.iter_mut().zip(self.u0_col_sums.iter()) {
                    *w -= mu * s;
                }
            }
        }
        if let Some(w) = self.u0_writer.take() {
            w.finish()?;
        }
        Ok(())
    }
}

/// Sparse exact-Gram pass 2: `U = (A - 1μᵀ) M` rows straight to U shards.
pub struct SparseMultJob {
    backend: BackendRef,
    m: Matrix,
    writer: Option<ShardWriter>,
    /// `μᵀM` (centered mode only).
    mu_m: Option<Vec<f64>>,
    rows: u64,
}

impl SparseMultJob {
    pub fn new(
        backend: BackendRef,
        m: Matrix,
        shards: &ShardSet,
        chunk: usize,
        means: &[f64],
    ) -> Result<Self> {
        let k = m.cols();
        let mu_m = if means.is_empty() { None } else { Some(mu_times(means, &m)?) };
        Ok(SparseMultJob {
            backend,
            m,
            writer: Some(shards.open_writer(chunk, k)?),
            mu_m,
            rows: 0,
        })
    }

    pub fn rows_processed(&self) -> u64 {
        self.rows
    }
}

impl SparseBlockJob for SparseMultJob {
    fn exec_block(&mut self, block: &SparseMatrix) -> Result<()> {
        let mut u = self.backend.project_block_sparse(block, &self.m)?;
        if let Some(mu_m) = &self.mu_m {
            for r in 0..u.rows() {
                for (v, m) in u.row_mut(r).iter_mut().zip(mu_m.iter()) {
                    *v -= m;
                }
            }
        }
        if let Some(w) = self.writer.as_mut() {
            for i in 0..u.rows() {
                w.write_row(u.row(i))?;
            }
        }
        self.rows += u.rows() as u64;
        Ok(())
    }

    fn post_blocks(&mut self) -> Result<()> {
        if let Some(w) = self.writer.take() {
            w.finish()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::config::InputFormat;
    use crate::linalg::{gram, matmul, matmul_tn};
    use crate::rng::Gaussian;
    use crate::splitproc::SparseBlocked;

    fn sparse_fixture(rows: usize, cols: usize, seed: u64) -> (SparseMatrix, Matrix) {
        let g = Gaussian::new(seed);
        let mut dense = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                let u = crate::rng::splitmix::to_unit_open(crate::rng::splitmix::mix3(
                    seed ^ 0xF00D,
                    i as u64,
                    j as u64,
                ));
                if u < 0.2 {
                    dense.set(i, j, g.sample(i as u64, j as u64));
                }
            }
        }
        (SparseMatrix::from_dense(&dense, 0.0), dense)
    }

    fn feed_blocks<J: SparseBlockJob>(s: &SparseMatrix, block: usize, job: J) -> J {
        let mut b = SparseBlocked::new(job, block, s.cols());
        for i in 0..s.rows() {
            let (idx, val) = s.row(i);
            b.exec_row(idx, val).unwrap();
        }
        b.post().unwrap();
        b.into_inner()
    }

    fn centered(dense: &Matrix, means: &[f64]) -> Matrix {
        Matrix::from_fn(dense.rows(), dense.cols(), |i, j| dense.get(i, j) - means[j])
    }

    fn col_means(dense: &Matrix) -> Vec<f64> {
        (0..dense.cols())
            .map(|j| (0..dense.rows()).map(|i| dense.get(i, j)).sum::<f64>() / dense.rows() as f64)
            .collect()
    }

    fn shards(name: &str, stem: &str) -> ShardSet {
        let dir = std::env::temp_dir().join("tallfat_test_sparse_jobs").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        ShardSet::new(&dir, stem, InputFormat::Bin).unwrap()
    }

    #[test]
    fn colstats_sums_match_dense() {
        let (s, dense) = sparse_fixture(40, 7, 1);
        let mut job = SparseColStatsJob::new(7);
        for i in 0..s.rows() {
            let (idx, val) = s.row(i);
            job.exec_row(idx, val).unwrap();
        }
        assert_eq!(job.count(), 40);
        let sums = job.into_sums();
        for j in 0..7 {
            let want: f64 = (0..40).map(|i| dense.get(i, j)).sum();
            assert!((sums.get(0, j) - want).abs() < 1e-10, "col {j}");
        }
    }

    #[test]
    fn ata_matches_dense_gram_centered_and_not() {
        let (s, dense) = sparse_fixture(60, 8, 2);
        let backend: BackendRef = Arc::new(NativeBackend::new());
        // uncentered
        let job = SparseAtaJob::new(backend.clone(), 8, Arc::new(Vec::new()));
        let got = feed_blocks(&s, 16, job).into_partial();
        assert!(got.max_abs_diff(&gram(&dense)) < 1e-9);
        // centered: rank-1 corrections equal the densified centered gram
        let means = col_means(&dense);
        let job = SparseAtaJob::new(backend, 8, Arc::new(means.clone()));
        let got = feed_blocks(&s, 16, job).into_partial();
        let want = gram(&centered(&dense, &means));
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn project_gram_matches_dense_centered_and_not() {
        let (s, dense) = sparse_fixture(50, 9, 3);
        let g = Gaussian::new(4);
        let omega = Matrix::from_fn(9, 4, |i, j| g.sample(500 + i as u64, j as u64));
        let backend: BackendRef = Arc::new(NativeBackend::new());
        for center in [false, true] {
            let means = if center { col_means(&dense) } else { Vec::new() };
            let set = shards(if center { "pg_c" } else { "pg" }, "Y");
            let job = SparseProjectGramJob::new(
                backend.clone(),
                omega.clone(),
                &set,
                0,
                &means,
            )
            .unwrap();
            let got = feed_blocks(&s, 16, job).into_gram_partial();
            let x = if center { centered(&dense, &means) } else { dense.clone() };
            let y_want = matmul(&x, &omega).unwrap();
            assert!(got.max_abs_diff(&gram(&y_want)) < 1e-9, "center={center}");
            let y_got = set.merge_to_matrix(1).unwrap();
            assert!(y_got.max_abs_diff(&y_want) < 1e-9, "center={center}");
        }
    }

    #[test]
    fn pass2_matches_dense_centered_and_not() {
        let (s, dense) = sparse_fixture(45, 6, 5);
        let g = Gaussian::new(6);
        let y = Matrix::from_fn(45, 3, |i, j| g.sample(700 + i as u64, j as u64));
        let m = Matrix::from_fn(3, 3, |i, j| g.sample(800 + i as u64, j as u64));
        let backend: BackendRef = Arc::new(NativeBackend::new());
        for center in [false, true] {
            let means = if center { col_means(&dense) } else { Vec::new() };
            let name = if center { "p2_c" } else { "p2" };
            let y_shards = shards(name, "Y");
            let mut w = y_shards.open_writer(0, 3).unwrap();
            for i in 0..45 {
                w.write_row(y.row(i)).unwrap();
            }
            w.finish().unwrap();
            let u0_shards = shards(&format!("{name}_u0"), "U0");
            let job = SparsePass2Job::new(
                backend.clone(),
                m.clone(),
                &y_shards,
                &u0_shards,
                0,
                6,
                Arc::new(means.clone()),
            )
            .unwrap();
            let got = feed_blocks(&s, 16, job).into_w_partial();
            let u0_want = matmul(&y, &m).unwrap();
            let x = if center { centered(&dense, &means) } else { dense.clone() };
            let w_want = matmul_tn(&x, &u0_want).unwrap();
            assert!(got.max_abs_diff(&w_want) < 1e-9, "center={center}");
            let u0_got = u0_shards.merge_to_matrix(1).unwrap();
            assert!(u0_got.max_abs_diff(&u0_want) < 1e-9);
        }
    }

    #[test]
    fn mult_matches_dense_centered_and_not() {
        let (s, dense) = sparse_fixture(30, 5, 7);
        let g = Gaussian::new(8);
        let m = Matrix::from_fn(5, 2, |i, j| g.sample(900 + i as u64, j as u64));
        let backend: BackendRef = Arc::new(NativeBackend::new());
        for center in [false, true] {
            let means = if center { col_means(&dense) } else { Vec::new() };
            let set = shards(if center { "mult_c" } else { "mult" }, "U");
            let job =
                SparseMultJob::new(backend.clone(), m.clone(), &set, 0, &means).unwrap();
            feed_blocks(&s, 8, job);
            let x = if center { centered(&dense, &means) } else { dense.clone() };
            let want = matmul(&x, &m).unwrap();
            let got = set.merge_to_matrix(1).unwrap();
            assert!(got.max_abs_diff(&want) < 1e-9, "center={center}");
        }
    }

    #[test]
    fn mu_times_validates_shape() {
        let b = Matrix::zeros(3, 2);
        assert!(mu_times(&[1.0, 2.0], &b).is_err());
        let r = mu_times(&[1.0, 0.0, 2.0], &Matrix::eye(3)).unwrap();
        assert_eq!(r, vec![1.0, 0.0, 2.0]);
    }
}
