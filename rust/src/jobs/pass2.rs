//! Pass-2 job of the randomized SVD driver.
//!
//! Worker `i` re-reads its chunk of A while streaming its own Y shard (row
//! alignment is free: the shard was produced from the same chunk in pass 1).
//! Per block:
//!
//! ```text
//! U0_blk = Y_blk M            (M = V_y Sigma_y^{-1}, the k x k leader result)
//! W     += A_blk^T U0_blk     (the commutative A^T U0 partial)
//! ```
//!
//! `U0_blk` rows go to the worker's U0 shard; the `W` partial reduces across
//! workers. On the XLA backend both steps run as one fused artifact
//! (`urecover_tmul`).

use crate::backend::BackendRef;
use crate::error::{Error, Result};
use crate::io::writer::{ShardReader, ShardSet, ShardWriter};
use crate::linalg::Matrix;
use crate::splitproc::BlockJob;

/// Pass-2 block job (see module docs).
pub struct Pass2Job {
    backend: BackendRef,
    m: Matrix,
    y_reader: ShardReader,
    u0_writer: Option<ShardWriter>,
    w_acc: Matrix,
    y_buf: Vec<f64>,
    rows: u64,
}

impl Pass2Job {
    pub fn new(
        backend: BackendRef,
        m: Matrix,
        y_shards: &ShardSet,
        u0_shards: &ShardSet,
        chunk: usize,
        n: usize,
    ) -> Result<Self> {
        let k = m.rows();
        Ok(Pass2Job {
            backend,
            m,
            y_reader: y_shards.open_reader(chunk)?,
            u0_writer: Some(u0_shards.open_writer(chunk, k)?),
            w_acc: Matrix::zeros(n, k),
            y_buf: Vec::with_capacity(k),
            rows: 0,
        })
    }

    pub fn into_w_partial(self) -> Matrix {
        self.w_acc
    }

    pub fn w_partial(&self) -> &Matrix {
        &self.w_acc
    }

    /// Read the next `rows` rows of this worker's Y shard as a block.
    fn read_y_block(&mut self, rows: usize) -> Result<Matrix> {
        let k = self.m.rows();
        let mut y = Matrix::zeros(rows, k);
        for i in 0..rows {
            if !self.y_reader.next_row(&mut self.y_buf)? {
                return Err(Error::Other(format!(
                    "Y shard exhausted at block row {i} (A/Y misaligned)"
                )));
            }
            if self.y_buf.len() != k {
                return Err(Error::shape(format!(
                    "Y shard row has {} cols, expected {k}",
                    self.y_buf.len()
                )));
            }
            y.row_mut(i).copy_from_slice(&self.y_buf);
        }
        Ok(y)
    }
}

impl BlockJob for Pass2Job {
    fn exec_block(&mut self, a_block: &Matrix) -> Result<()> {
        let y_block = self.read_y_block(a_block.rows())?;
        let u0 = self.backend.u_recover_block(&y_block, &self.m)?;
        let w = self.backend.tmul_block(a_block, &u0)?;
        self.w_acc.add_assign(&w)?;
        if let Some(wr) = self.u0_writer.as_mut() {
            for i in 0..u0.rows() {
                wr.write_row(u0.row(i))?;
            }
        }
        self.rows += a_block.rows() as u64;
        Ok(())
    }

    fn post_blocks(&mut self) -> Result<()> {
        if let Some(w) = self.u0_writer.take() {
            w.finish()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::config::InputFormat;
    use crate::linalg::{matmul, matmul_tn};
    use crate::rng::Gaussian;
    use crate::splitproc::Blocked;
    use std::sync::Arc;

    fn rand(rows: usize, cols: usize, seed: u64) -> Matrix {
        let g = Gaussian::new(seed);
        Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
    }

    #[test]
    fn pass2_matches_dense() {
        let dir = std::env::temp_dir().join("tallfat_test_pass2");
        let _ = std::fs::remove_dir_all(&dir);
        let a = rand(50, 7, 1);
        let y = rand(50, 3, 2);
        let m = rand(3, 3, 3);

        let y_shards = ShardSet::new(&dir, "Y", InputFormat::Csv).unwrap();
        let mut w = y_shards.open_writer(0, 3).unwrap();
        for i in 0..50 {
            w.write_row(y.row(i)).unwrap();
        }
        w.finish().unwrap();
        let u0_shards = ShardSet::new(&dir, "U0", InputFormat::Csv).unwrap();

        let job = Pass2Job::new(
            Arc::new(NativeBackend::new()),
            m.clone(),
            &y_shards,
            &u0_shards,
            0,
            7,
        )
        .unwrap();
        let mut blocked = Blocked::new(job, 16, 7);
        for i in 0..50 {
            use crate::splitproc::RowJob;
            blocked.exec_row(a.row(i)).unwrap();
        }
        use crate::splitproc::RowJob;
        blocked.post().unwrap();

        let u0_want = matmul(&y, &m).unwrap();
        let w_want = matmul_tn(&a, &u0_want).unwrap();
        let u0_got = u0_shards.merge_to_matrix(1).unwrap();
        assert!(u0_got.max_abs_diff(&u0_want) < 1e-9);
        assert!(blocked.into_inner().into_w_partial().max_abs_diff(&w_want) < 1e-8);
    }

    #[test]
    fn misaligned_shard_errors() {
        let dir = std::env::temp_dir().join("tallfat_test_pass2_mis");
        let _ = std::fs::remove_dir_all(&dir);
        let y_shards = ShardSet::new(&dir, "Y", InputFormat::Csv).unwrap();
        let mut w = y_shards.open_writer(0, 2).unwrap();
        w.write_row(&[1.0, 2.0]).unwrap(); // only ONE y row
        w.finish().unwrap();
        let u0_shards = ShardSet::new(&dir, "U0", InputFormat::Csv).unwrap();
        let mut job = Pass2Job::new(
            Arc::new(NativeBackend::new()),
            Matrix::eye(2),
            &y_shards,
            &u0_shards,
            0,
            3,
        )
        .unwrap();
        let a_block = Matrix::zeros(2, 3); // asks for TWO y rows
        assert!(job.exec_block(&a_block).is_err());
    }
}
