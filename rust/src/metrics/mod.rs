//! Phase timing, throughput counters, and report tables.
//!
//! Every coordinator run and every bench harness reports through these so
//! EXPERIMENTS.md rows can be regenerated verbatim.

use std::time::{Duration, Instant};

/// A single timed phase with an item count (rows, blocks, requests...).
#[derive(Clone, Debug)]
pub struct Phase {
    pub name: String,
    pub elapsed: Duration,
    pub items: u64,
    pub bytes: u64,
}

/// Collects phases and prints an aligned report table.
#[derive(Default, Debug)]
pub struct PhaseReport {
    phases: Vec<Phase>,
}

impl PhaseReport {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure as a named phase.
    pub fn time<T>(&mut self, name: &str, items: u64, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.push(name, t0.elapsed(), items, 0);
        out
    }

    pub fn push(&mut self, name: &str, elapsed: Duration, items: u64, bytes: u64) {
        self.phases.push(Phase { name: name.to_string(), elapsed, items, bytes });
    }

    pub fn phases(&self) -> &[Phase] {
        &self.phases
    }

    pub fn total(&self) -> Duration {
        self.phases.iter().map(|p| p.elapsed).sum()
    }

    pub fn get(&self, name: &str) -> Option<&Phase> {
        self.phases.iter().find(|p| p.name == name)
    }

    /// Render the aligned table used in logs and EXPERIMENTS.md.
    pub fn render(&self) -> String {
        use crate::util::humanize::{fmt_duration, fmt_rate};
        let mut out = String::new();
        let total = self.total().as_secs_f64().max(1e-12);
        out.push_str(&format!(
            "{:<28} {:>10} {:>7} {:>12} {:>12}\n",
            "phase", "time", "%", "items", "rate"
        ));
        for p in &self.phases {
            let pct = 100.0 * p.elapsed.as_secs_f64() / total;
            out.push_str(&format!(
                "{:<28} {:>10} {:>6.1}% {:>12} {:>12}\n",
                p.name,
                fmt_duration(p.elapsed),
                pct,
                p.items,
                if p.items > 0 { fmt_rate(p.items, p.elapsed) } else { "-".into() },
            ));
        }
        out.push_str(&format!("{:<28} {:>10}\n", "TOTAL", fmt_duration(self.total())));
        out
    }
}

/// Simple monotonic stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn lap(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Online mean/min/max aggregator for repeated measurements.
#[derive(Clone, Debug)]
pub struct Stats {
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Stats {
    fn default() -> Self {
        Self::new()
    }
}

impl Stats {
    pub fn new() -> Self {
        Stats { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Measure a closure `reps` times and return per-rep stats (seconds).
pub fn bench_timings(reps: usize, mut f: impl FnMut()) -> Stats {
    let mut st = Stats::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        st.add(t0.elapsed().as_secs_f64());
    }
    st
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_report_accumulates() {
        let mut r = PhaseReport::new();
        r.push("a", Duration::from_millis(10), 100, 0);
        r.push("b", Duration::from_millis(30), 0, 0);
        assert_eq!(r.total(), Duration::from_millis(40));
        assert_eq!(r.get("a").unwrap().items, 100);
        let table = r.render();
        assert!(table.contains("a"));
        assert!(table.contains("TOTAL"));
    }

    #[test]
    fn time_measures_and_returns() {
        let mut r = PhaseReport::new();
        let v = r.time("work", 1, || 42);
        assert_eq!(v, 42);
        assert_eq!(r.phases().len(), 1);
    }

    #[test]
    fn stats_default_is_empty_with_infinite_min() {
        // Regression: `Stats` once carried both `#[derive(Default)]` and a
        // manual `impl Default` (E0119). The manual impl must win so an
        // empty aggregator starts at +inf/-inf, not 0/0.
        let s = Stats::default();
        assert_eq!(s.count(), 0);
        assert_eq!(s.min(), f64::INFINITY);
        assert_eq!(s.max(), f64::NEG_INFINITY);
    }

    #[test]
    fn stats_aggregation() {
        let mut s = Stats::new();
        for v in [2.0, 4.0, 6.0] {
            s.add(v);
        }
        assert_eq!(s.count(), 3);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 6.0);
    }

    #[test]
    fn bench_timings_runs_reps() {
        let mut calls = 0;
        let st = bench_timings(5, || calls += 1);
        assert_eq!(calls, 5);
        assert_eq!(st.count(), 5);
    }
}
